"""Fig. 8: convergence of DGL / Sylvie-S / Sylvie-A / Sylvie-A with Bounded
Staleness Adaptor (eps_s in {2, 5})."""
from __future__ import annotations

from . import common

EPOCHS = 40


def run() -> dict:
    variants = {
        "DGL": dict(cfg=dict(mode="vanilla", bits=32), eps=None),
        "Sylvie-S": dict(cfg=dict(mode="sync", bits=1), eps=None),
        "Sylvie-A": dict(cfg=dict(mode="async", bits=1), eps=None),
        "Sylvie-A2": dict(cfg=dict(mode="async", bits=1), eps=2),
        "Sylvie-A5": dict(cfg=dict(mode="async", bits=1), eps=5),
    }
    curves = {}
    for name, v in variants.items():
        tr = common.make_trainer(common.REF_DS, "gcn", parts=8,
                                 eps_s=v["eps"], **v["cfg"])
        accs = []
        for e in range(EPOCHS):
            tr.train_epoch()
            if (e + 1) % 5 == 0:
                accs.append(round(tr.evaluate("val"), 4))
        curves[name] = accs
    print(f"\n== Fig 8: val accuracy every 5 epochs (GCN, {common.REF_DS}) ==")
    rows = [[n] + [f"{a:.3f}" for a in accs] for n, accs in curves.items()]
    print(common.fmt_table(
        ["method"] + [f"e{5*(i+1)}" for i in range(EPOCHS // 5)], rows))
    common.save("fig8_convergence", curves)
    # Sylvie-S tracks DGL; the adaptor keeps Sylvie-A near it at the end
    assert curves["Sylvie-S"][-1] > curves["DGL"][-1] - 0.05
    assert curves["Sylvie-A2"][-1] > curves["DGL"][-1] - 0.05
    return curves


if __name__ == "__main__":
    run()
