"""Fig. 2: epoch time breakdown of vanilla distributed GNN training.

Shows communication dominating the epoch (the paper profiles up to 89% on
8 GPUs). Columns: exact bytes moved, modeled TPU comm time (bytes / ICI_BW),
modeled compute time (analytic FLOPs / peak), comm fraction.
"""
from __future__ import annotations

from repro.launch.cells import _gnn_model_flops
from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16

from . import common


def run() -> dict:
    rows = []
    rec = {}
    for ds in common.DATASETS:
        for model_name in ("graphsage", "gcn"):
            tr = common.make_trainer(ds, model_name, parts=8,
                                     mode="vanilla", bits=32)
            pb, eb = tr.comm_bytes_per_epoch()   # totals across partitions
            comm_s = (pb + eb) / tr.pg.plan.n_parts / ICI_BW
            g, _ = common.build_dataset(ds)
            flops = _gnn_model_flops(model_name, tr.model, g.n_nodes,
                                     g.n_edges, g.x.shape[1], True) / 8
            comp_s = flops / PEAK_FLOPS_BF16
            frac = comm_s / (comm_s + comp_s)
            cpu_s = common.timed_epochs(tr, epochs=5)
            rows.append([ds, model_name, f"{pb/1e6:.1f}",
                         f"{comm_s*1e6:.1f}", f"{comp_s*1e6:.1f}",
                         f"{100*frac:.1f}%", f"{cpu_s*1e3:.1f}"])
            rec[f"{ds}/{model_name}"] = dict(payload_mb=pb / 1e6,
                                             comm_frac=frac)
    print("\n== Fig 2: vanilla epoch breakdown (8 partitions) ==")
    print(common.fmt_table(
        ["dataset", "model", "comm MB", "comm us (TPU)", "compute us (TPU)",
         "comm frac", "CPU ms/epoch"], rows))
    common.save("fig2_breakdown", rec)
    # the paper's observation: comm dominates
    assert all(v["comm_frac"] > 0.5 for v in rec.values())
    return rec


if __name__ == "__main__":
    run()
