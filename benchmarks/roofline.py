"""Aggregate artifacts/dryrun/*.json into the §Roofline table (markdown)."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records(mesh: str = "pod", tag: str = ""):
    recs = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") == mesh and r.get("tag", "") == tag:
            recs.append(r)
    return recs


def fmt(x, nd=4):
    if x is None:
        return "-"
    return f"{x:.{nd}g}"


def markdown_table(recs) -> str:
    hdr = ("| arch | shape | step | compute s | memory s | collective s | "
           "bottleneck | useful ratio | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{fmt(ro['compute_s'])} | {fmt(ro['memory_s'])} | "
            f"{fmt(ro['collective_s'])} | {ro['bottleneck']} | "
            f"{fmt(ro['useful_flop_ratio'], 3)} | "
            f"{fmt(ro['roofline_fraction'], 3)} |")
    return "\n".join(lines)


def run() -> dict:
    out = {}
    for mesh in ("pod", "multipod"):
        recs = load_records(mesh)
        if not recs:
            continue
        print(f"\n== Roofline table ({mesh}, {len(recs)} cells) ==")
        print(markdown_table(recs))
        out[mesh] = len(recs)
    if not out:
        print("no dry-run artifacts yet — run: "
              "python -m repro.launch.dryrun --all --mesh both")
    return out


if __name__ == "__main__":
    run()
