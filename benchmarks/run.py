"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig9]

Each module prints its table, persists artifacts/bench/<name>.json and
asserts the paper's qualitative claim holds (32x comm cut, throughput
ordering, accuracy retention, ...). ``roofline`` additionally aggregates the
dry-run artifacts when present.

Datasets are named workloads from the ``repro.datasets`` registry
(``benchmarks/common.DATASETS``); partition plans are cached under
``artifacts/plans/``, so re-runs skip the Graph Engine. For ad-hoc sweeps
beyond the paper's figures use the scenario runner:
``python -m repro.launch.train --scenario ...``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (fig2_breakdown, fig8_convergence, fig9_bitwidth,
               fig10_overhead, roofline, table1_sampling, table2_throughput,
               table3_commvolume, table4_quantall)

ALL = {
    "table1": table1_sampling,
    "fig2": fig2_breakdown,
    "table2": table2_throughput,
    "table3": table3_commvolume,
    "fig8": fig8_convergence,
    "fig9": fig9_bitwidth,
    "table4": table4_quantall,
    "fig10": fig10_overhead,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table2,fig9")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    failed = []
    for name in names:
        t0 = time.time()
        print(f"\n{'='*72}\nbenchmark: {name}\n{'='*72}")
        try:
            ALL[name].run()
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print(f"\n{len(names)-len(failed)}/{len(names)} benchmarks passed")
    if failed:
        print("failed:", failed)
        sys.exit(1)


if __name__ == "__main__":
    main()
