"""Overlap-schedule benchmark: blocking vs fenced issue/land halo exchange.

Tracks the DESIGN §14 overlap claim from this PR onward by writing
``BENCH_overlap.json`` at the repo root. On the skewed 8-part power-law
reference (same workload as ``bench_halo.py``) it records, per schedule:

* measured XLA-CPU wall ms/epoch of full sync training (informational —
  on CPU both schedules run the same collectives back-to-back);
* the modeled-TPU comm split: total comm seconds, the share the overlap
  schedule hides under each site's local aggregation window
  (``overlapped_i = min(comm_i, compute_i)``), and the exposed remainder;
* modeled step seconds = compute + exposed.

Gate (the PR's acceptance metric): the modeled overlap step time must be
strictly below blocking's compute + comm sum — i.e. the schedule must hide a
non-zero share of comm behind compute on the reference workload.

A bit-exactness spot check rides along: the two schedules must produce
identical loss trajectories and bit-identical parameters under sync mode
(the overlap fence reorders, it must never perturb a value).

``--smoke`` shrinks everything so CI can run it in seconds
(``BENCH_overlap.smoke.json``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.sylvie import SCHEDULES, SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.launch.cells import _gnn_model_flops
from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16
from repro.models.gnn.models import PAPER_ARCHS
from repro.train.trainer import GNNTrainer

ROOT = Path(__file__).resolve().parents[1]
ARCH = "gcn"


def _build_pg(n, d_feat, parts):
    g = synthetic.powerlaw(n_nodes=n, d_feat=d_feat, avg_degree=16, seed=0)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)
    return partition.partition_graph(g, parts, method="skewed",
                                     edge_weight=ew, layout="compact")


def _train(pg, schedule, epochs):
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=True,
                      schedule=schedule)
    model = PAPER_ARCHS[ARCH](pg.x.shape[-1], pg.n_classes)
    tr = GNNTrainer(model, pg, cfg, seed=0)
    tr.train_epoch()                            # compile + warm
    t0 = time.perf_counter()
    losses = [float(tr.train_epoch().loss) for _ in range(epochs)]
    wall_ms = (time.perf_counter() - t0) / epochs * 1e3
    return tr, losses, wall_ms


def _modeled(tr, pg, schedule):
    n_nodes = int(pg.part_of.shape[0])
    n_edges = int(pg.edge_mask.sum())
    flops_per_part = _gnn_model_flops(
        ARCH, tr.model, n_nodes, n_edges, pg.x.shape[-1],
        True) / pg.plan.n_parts
    exposed, overlapped = tr.modeled_comm_split(flops_per_part,
                                                PEAK_FLOPS_BF16, ICI_BW)
    return dict(
        modeled_compute_s=flops_per_part / PEAK_FLOPS_BF16,
        modeled_comm_s=exposed + overlapped,
        modeled_comm_exposed_s=exposed,
        modeled_comm_overlapped_s=overlapped,
        modeled_step_s=flops_per_part / PEAK_FLOPS_BF16 + exposed,
    )


def run(smoke: bool = False) -> dict:
    n, d_feat, parts, epochs = (2000, 32, 8, 2) if smoke else (8000, 64, 8, 4)
    pg = _build_pg(n, d_feat, parts)

    per_sched = {}
    trainers = {}
    for sched in SCHEDULES:
        tr, losses, wall_ms = _train(pg, sched, epochs)
        trainers[sched] = tr
        per_sched[sched] = dict(losses=losses, wall_ms_per_epoch=wall_ms,
                                **_modeled(tr, pg, sched))

    # bit-exactness spot check: the fence must be value-transparent
    bl, ov = per_sched["blocking"], per_sched["overlap"]
    assert bl["losses"] == ov["losses"], \
        f"overlap loss trajectory diverged: {bl['losses']} vs {ov['losses']}"
    leaves_b = jax.tree.leaves(trainers["blocking"].state.params)
    leaves_o = jax.tree.leaves(trainers["overlap"].state.params)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves_b, leaves_o)), \
        "overlap params are not bit-identical to blocking under sync"

    rec = dict(
        config=dict(n_nodes=n, d_feat=d_feat, parts=parts, arch=ARCH, bits=1,
                    method="skewed", layout="compact", epochs=epochs,
                    smoke=smoke, backend=jax.default_backend(),
                    ici_bw=ICI_BW, peak_flops=PEAK_FLOPS_BF16),
        blocking=bl, overlap=ov,
        bit_exact=True,
        overlap_speedup=bl["modeled_step_s"] / ov["modeled_step_s"],
        hidden_comm_fraction=ov["modeled_comm_overlapped_s"]
        / max(ov["modeled_comm_s"], 1e-30),
    )

    print(f"== bench_overlap (P={parts}, n={n}, d={d_feat}, 1-bit, skewed) ==")
    for sched in SCHEDULES:
        r = per_sched[sched]
        print(f"{sched:9s} wall={r['wall_ms_per_epoch']:7.1f} ms/epoch  "
              f"modeled step={r['modeled_step_s'] * 1e6:8.2f} us "
              f"(compute={r['modeled_compute_s'] * 1e6:.2f} us, "
              f"exposed={r['modeled_comm_exposed_s'] * 1e6:.2f} us, "
              f"hidden={r['modeled_comm_overlapped_s'] * 1e6:.2f} us)")
    print(f"bit-exact under sync: True   "
          f"modeled speedup: {rec['overlap_speedup']:.3f}x   "
          f"comm hidden: {rec['hidden_comm_fraction']:.1%}")

    # --smoke is a CI freshness/regression check; only full runs update the
    # tracked perf-trajectory record
    out = ROOT / ("BENCH_overlap.smoke.json" if smoke else "BENCH_overlap.json")
    out.write_text(json.dumps(rec, indent=1, default=float))

    # the acceptance gate: overlap must model strictly faster than
    # compute + comm (blocking), i.e. hide a non-zero comm share
    blocking_sum = bl["modeled_compute_s"] + bl["modeled_comm_s"]
    assert ov["modeled_step_s"] < blocking_sum, \
        (f"overlap schedule hides nothing: modeled step "
         f"{ov['modeled_step_s']:.3e}s >= compute+comm {blocking_sum:.3e}s")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + fewer epochs (CI freshness check)")
    run(**vars(ap.parse_args()))
