"""Chaos benchmark: what does the fault-injection path cost when it fires?

Tracks ``BENCH_chaos.json`` at the repo root. On the ``yelp_like`` reference
workload, trains a fault-free trainer and an armed twin (seeded drop+corrupt
schedule, escalation disabled so every timed epoch runs the degraded path,
not a full-precision recovery) and compares **median per-epoch wall time**.
The armed executable carries the whole fault machinery — per-row checksums,
checksum exchange, cache blending — and the armed host loop draws, expands
and ships the epoch's masks; both are inside the measurement.

Acceptance gate: armed overhead **<= 5%** over fault-free (ISSUE: chaos must
be cheap enough to leave on). The record also keeps the accounting totals of
the armed run (``faults_injected == halos_reused + forced_syncs`` is asserted
— a benchmark that silently stopped injecting would be measuring nothing).

``--smoke`` shrinks the workload so CI can run it in seconds (writes the
untracked ``BENCH_chaos.smoke.json``; only full runs update the tracked
record).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro import datasets
from repro.core.sylvie import SylvieConfig
from repro.faults import FaultPlan
from repro.models.gnn.models import PAPER_ARCHS
from repro.policy import Uniform
from repro.train.trainer import GNNTrainer

ROOT = Path(__file__).resolve().parents[1]
OVERHEAD_GATE = 0.05       # armed vs fault-free epoch time, full workload
# the 500-node smoke graph runs a ~8 ms epoch where fixed per-op overhead
# (mask transfer, checksum dispatch) can't amortize — the smoke lane only
# checks the benchmark still runs and injects; the <= 5% claim is the
# tracked full record's.
SMOKE_OVERHEAD_GATE = 0.30
WARMUP_EPOCHS = 2          # tracing + first-touch, excluded from the stats


def _timed_epoch(tr: GNNTrainer) -> float:
    t0 = time.perf_counter()
    tr.train_epoch()
    return time.perf_counter() - t0


def run(smoke: bool = False) -> dict:
    ref, parts, epochs = ("yelp_like@smoke", 4, 12) if smoke \
        else ("yelp_like@small", 4, 24)
    seed = 0
    # escalation off: a forced full-precision recovery epoch would be timed
    # as "faulty" while running a different (32-bit sync) program entirely.
    plan = FaultPlan(seed=7, drop_rate=0.1, corrupt_rate=0.05,
                     escalate_after=10**9)
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    model_of = PAPER_ARCHS["gcn"]

    trainers = {}
    for name, fault_plan in (("fault_free", None), ("armed", plan)):
        trainers[name] = GNNTrainer(
            model_of(pg.x.shape[-1], pg.n_classes), pg,
            SylvieConfig(mode="async"), policy=Uniform(bits=1),
            seed=seed, fault_plan=fault_plan)
    for tr in trainers.values():
        for _ in range(WARMUP_EPOCHS):
            tr.train_epoch()
    # interleave the timed epochs pairwise so machine drift (frequency
    # scaling, background load) hits both columns equally instead of
    # masquerading as fault-path overhead.
    times: dict[str, list[float]] = {name: [] for name in trainers}
    for _ in range(epochs):
        for name, tr in trainers.items():
            times[name].append(_timed_epoch(tr))

    rows = {}
    for name, tr in trainers.items():
        injected = sum(m.faults_injected for m in tr.history)
        reused = sum(m.halos_reused for m in tr.history)
        forced = sum(m.forced_syncs for m in tr.history)
        assert injected == reused + forced, "chaos accounting broken"
        if name == "armed":
            assert injected > 0, "armed benchmark injected nothing"
        rows[name] = dict(
            min_epoch_s=float(np.min(times[name])),
            median_epoch_s=float(np.median(times[name])),
            p90_epoch_s=float(np.percentile(times[name], 90)),
            epochs=epochs, faults_injected=injected,
            halos_reused=reused, forced_syncs=forced,
            stall_s=float(sum(m.stall_s for m in tr.history)))

    # gate on the min-vs-min ratio: the minimum is the classic noise-robust
    # estimate of intrinsic cost (everything above it is scheduler/GC noise,
    # which the median still partly carries on a shared CI box).
    overhead = rows["armed"]["min_epoch_s"] \
        / max(rows["fault_free"]["min_epoch_s"], 1e-12) - 1.0
    rec = dict(
        config=dict(graph=ref, parts=parts, arch="gcn", mode="async",
                    bits=1, epochs=epochs, smoke=smoke,
                    drop_rate=plan.drop_rate, corrupt_rate=plan.corrupt_rate,
                    seed=plan.seed),
        runs=rows,
        armed_overhead=float(overhead),
    )

    print(f"== bench_chaos ({ref}, P={parts}, drop={plan.drop_rate}, "
          f"corrupt={plan.corrupt_rate}) ==")
    for name, r in rows.items():
        print(f"{name:10s} min {r['min_epoch_s']*1e3:8.2f} ms/epoch  "
              f"median {r['median_epoch_s']*1e3:8.2f} ms  "
              f"injected {r['faults_injected']}")
    gate = SMOKE_OVERHEAD_GATE if smoke else OVERHEAD_GATE
    print(f"armed overhead: {overhead*100:+.2f}% (gate <= {gate*100:.0f}%)")

    out = ROOT / ("BENCH_chaos.smoke.json" if smoke else "BENCH_chaos.json")
    out.write_text(json.dumps(rec, indent=1, default=float))
    assert overhead <= gate, \
        f"fault path regressed: {overhead*100:.2f}% epoch overhead " \
        f"> {gate*100:.0f}%"
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI freshness check)")
    run(**vars(ap.parse_args()))
