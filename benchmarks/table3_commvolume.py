"""Table 3: per-epoch communication volume (main payload + error-compensated
info) and epoch time, vanilla vs Sylvie-S. Bytes are exact *true wire* counts
(independent of hardware): diagonal self-blocks and padding rows are excluded
by ``exchange_bytes``, so the table reports what actually crosses the
interconnect. The ~32x payload reduction is the paper's headline number and is
padding-invariant (both methods ship the same rows; only bits/value change).
"""
from __future__ import annotations

from . import common


def run() -> dict:
    rows = []
    rec = {}
    for ds in common.DATASETS:
        tr32 = common.make_trainer(ds, "graphsage", parts=8, mode="vanilla",
                                   bits=32)
        tr1 = common.make_trainer(ds, "graphsage", parts=8, mode="sync",
                                  bits=1)
        p32, e32 = tr32.comm_bytes_per_epoch()
        p1, e1 = tr1.comm_bytes_per_epoch()
        t32 = common.timed_epochs(tr32, epochs=5)
        t1 = common.timed_epochs(tr1, epochs=5)
        rows.append([ds, "vanilla", f"{p32/1e6:.1f}", f"{e32/1e6:.1f}",
                     f"{t32*1e3:.1f}"])
        rows.append([ds, "Sylvie-S", f"{p1/1e6:.1f}", f"{e1/1e6:.1f}",
                     f"{t1*1e3:.1f}"])
        rec[ds] = dict(reduction=p32 / p1, ec_frac=e1 / p32)
    print("\n== Table 3: comm volume per epoch (GraphSAGE, 8 partitions) ==")
    print(common.fmt_table(
        ["dataset", "method", "main MB", "error-comp MB", "CPU ms/epoch"],
        rows))
    common.save("table3_commvolume", rec)
    for v in rec.values():
        assert v["reduction"] == 32.0           # exact 32x payload cut
        assert v["ec_frac"] < 0.02              # EC info negligible
    return rec


if __name__ == "__main__":
    run()
