"""Fig. 9: bit-width sweep — comm volume, modeled epoch time, accuracy.

Extended beyond the paper's static sweep with two adaptive CommPolicy rows:

* ``warmup`` — full precision for 5 epochs, 1-bit afterwards;
* ``adaqp``  — AdaQP-style variance-budgeted per-site bits with a uniform
  4-bit byte budget. Its mean per-epoch payload must not exceed the static
  4-bit row's (the budget is a hard cap by construction) at no worse than
  1% accuracy loss.

Adaptive rows report the *mean per-epoch* payload summed from each epoch's
actual ``EpochDecision`` (heterogeneous bits change the bytes epoch to epoch).
"""
from __future__ import annotations

from repro.launch.mesh import ICI_BW
from repro.policy import AdaQPVariance, Warmup

from . import common

EPOCHS = 40
BITS = (32, 16, 8, 4, 2, 1)
POLICIES = {
    "warmup": Warmup(epochs=5, bits=1),
    "adaqp": AdaQPVariance(budget_bits=4),
}


def _row(rows, rec, key, label, tr, acc):
    pb = sum(m.comm_payload_mb for m in tr.history) / len(tr.history) * 1e6
    eb = sum(m.comm_ec_mb for m in tr.history) / len(tr.history) * 1e6
    comm_s = (pb + eb) / ICI_BW
    rows.append([label, f"{pb/1e6:.2f}", f"{eb/1e6:.3f}",
                 f"{comm_s*1e6:.1f}", f"{100*acc:.2f}"])
    rec[key] = dict(payload_mb=pb / 1e6, acc=acc)


def run() -> dict:
    rows = []
    rec = {}
    for bits in BITS:
        mode = "vanilla" if bits == 32 else "sync"
        tr = common.make_trainer(common.REF_DS, "graphsage", parts=8,
                                 mode=mode, bits=bits)
        tr.fit(EPOCHS)
        _row(rows, rec, bits, str(bits), tr, tr.evaluate("test"))
    for name, policy in POLICIES.items():
        tr = common.make_trainer(common.REF_DS, "graphsage", parts=8,
                                 mode="sync", policy=policy)
        tr.fit(EPOCHS)
        _row(rows, rec, name, name, tr, tr.evaluate("test"))
    print("\n== Fig 9: bit-width sweep + adaptive policies "
          "(GraphSAGE, 8 partitions) ==")
    print(common.fmt_table(
        ["bits", "main MB", "EC MB", "comm us (TPU)", "test acc %"], rows))
    common.save("fig9_bitwidth", rec)
    assert rec[32]["payload_mb"] / rec[1]["payload_mb"] == 32
    assert rec[1]["acc"] > rec[32]["acc"] - 0.03    # 1-bit holds accuracy
    # the adaptive schedule stays inside the uniform-4-bit byte budget and
    # costs at most 1% accuracy against it
    assert rec["adaqp"]["payload_mb"] <= rec[4]["payload_mb"] * 1.001
    assert rec["adaqp"]["acc"] >= rec[4]["acc"] - 0.01
    return rec


if __name__ == "__main__":
    run()
