"""Fig. 9: bit-width sweep — comm volume, modeled epoch time, accuracy."""
from __future__ import annotations

from repro.launch.mesh import ICI_BW

from . import common

EPOCHS = 40
BITS = (32, 16, 8, 4, 2, 1)


def run() -> dict:
    rows = []
    rec = {}
    for bits in BITS:
        mode = "vanilla" if bits == 32 else "sync"
        tr = common.make_trainer("planted-sm", "graphsage", parts=8,
                                 mode=mode, bits=bits)
        tr.fit(EPOCHS)
        acc = tr.evaluate("test")
        pb, eb = tr.comm_bytes_per_epoch()
        comm_s = (pb + eb) / ICI_BW
        rows.append([bits, f"{pb/1e6:.2f}", f"{eb/1e6:.3f}",
                     f"{comm_s*1e6:.1f}", f"{100*acc:.2f}"])
        rec[bits] = dict(payload_mb=pb / 1e6, acc=acc)
    print("\n== Fig 9: bit-width sweep (GraphSAGE, 8 partitions) ==")
    print(common.fmt_table(
        ["bits", "main MB", "EC MB", "comm us (TPU)", "test acc %"], rows))
    common.save("fig9_bitwidth", rec)
    assert rec[32]["payload_mb"] / rec[1]["payload_mb"] == 32
    assert rec[1]["acc"] > rec[32]["acc"] - 0.03    # 1-bit holds accuracy
    return rec


if __name__ == "__main__":
    run()
