"""Serving benchmark: quantized inference engine + load-tested request path.

Tracks the serving trajectory of the system by writing ``BENCH_serve.json`` at
the repo root. On the ``yelp_like@small`` workload (the benchmark reference
graph), measures:

* **refresh wire bytes** — what one cache refresh ships, for a 32-bit full
  sweep, a 1-bit full sweep, and a 1-bit k-hop delta refresh of a small
  changed-feature batch (exact accounting, ``repro.serve.delta``). The
  acceptance gate asserts the quantized delta path ships **<= 10%** of the
  full-sweep 32-bit bytes — the reason a serving tier built on this stack can
  absorb continuous feature updates;
* **request path** — closed-loop load (seeded clients x batches of node-id
  queries through the microbatching admission-queue server): QPS, p50/p99 ms;
* **sweep latency** — wall time of the full cache sweep per bit-width
  (XLA-CPU numbers; see DESIGN.md §8 for the measurement caveat).

``--smoke`` shrinks the workload/tier so CI can run it in seconds (writes the
untracked ``BENCH_serve.smoke.json``; only full runs update the tracked
record).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import numpy as np

from repro import datasets
from repro.models.gnn.models import PAPER_ARCHS
from repro.serve import EmbeddingServer, InferenceEngine, ServeConfig
from repro.serve.loadgen import closed_loop
from repro.train.trainer import GNNTrainer
from repro.core.sylvie import SylvieConfig

ROOT = Path(__file__).resolve().parents[1]
DELTA_BYTE_GATE = 0.10     # delta refresh vs full 32-bit sweep


def run(smoke: bool = False) -> dict:
    ref, parts, epochs, requests = ("yelp_like@smoke", 4, 3, 60) if smoke \
        else ("yelp_like@small", 4, 5, 300)
    seed = 0
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    n_nodes = int(pg.part_of.shape[0])
    model = PAPER_ARCHS["gcn"](pg.x.shape[-1], pg.n_classes)
    changed = max(1, n_nodes // 100)       # ~1% of nodes change per refresh

    with tempfile.TemporaryDirectory() as td:
        tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1),
                        seed=seed, ckpt_dir=td)
        tr.fit(epochs)
        tr.save()
        test_acc = tr.evaluate("test")

        rng = np.random.default_rng(seed + 1)
        ids = rng.choice(n_nodes, size=changed, replace=False)
        rows = rng.normal(0, 1, (changed, pg.x.shape[-1])).astype(np.float32)

        rows_out = {}
        for name, bits, delta in (("full_32bit", 32, False),
                                  ("full_1bit", 1, False),
                                  ("delta_1bit", 1, True)):
            engine, _ = InferenceEngine.from_checkpoint(
                td, model, pg, config=ServeConfig(bits=bits), seed=seed)
            t0 = time.perf_counter()
            engine.full_sweep()
            sweep_s = time.perf_counter() - t0
            rep = engine.refresh(ids, rows, full=not delta)
            rows_out[name] = dict(
                bits=bits, refresh=("delta" if delta else "full"),
                changed_nodes=int(rep.changed),
                affected_rows=list(rep.affected_rows),
                refresh_payload_bytes=rep.payload_bytes,
                refresh_ec_bytes=rep.ec_bytes,
                refresh_meta_bytes=rep.meta_bytes,
                refresh_wire_bytes=rep.wire_bytes,
                sweep_seconds=sweep_s)
            if name == "full_1bit":
                load_engine = engine       # serve the quantized engine

        load = closed_loop(EmbeddingServer(load_engine, microbatch=128),
                           n_nodes, clients=8, batch=16, requests=requests,
                           seed=seed)

    ratio = rows_out["delta_1bit"]["refresh_wire_bytes"] \
        / max(rows_out["full_32bit"]["refresh_wire_bytes"], 1)
    rec = dict(
        config=dict(graph=ref, parts=parts, arch="gcn",
                    train_epochs=epochs, changed_nodes=changed,
                    smoke=smoke, test_acc=float(test_acc)),
        refresh=rows_out,
        load=load,
        delta_vs_full32_bytes=ratio,
    )

    print(f"== bench_serve ({ref}, P={parts}, {changed} changed nodes) ==")
    for name, r in rows_out.items():
        print(f"{name:11s} refresh {r['refresh_wire_bytes']/1e3:9.2f} kB "
              f"(rows {r['affected_rows']}) sweep {r['sweep_seconds']*1e3:7.1f} ms")
    print(f"load: {load['qps']:.0f} qps  p50 {load['p50_ms']:.3f} ms  "
          f"p99 {load['p99_ms']:.3f} ms")
    print(f"delta/full32 bytes: {ratio:.4f} (gate <= {DELTA_BYTE_GATE})")

    out = ROOT / ("BENCH_serve.smoke.json" if smoke else "BENCH_serve.json")
    out.write_text(json.dumps(rec, indent=1, default=float))
    assert ratio <= DELTA_BYTE_GATE, \
        f"delta refresh regressed: {ratio:.4f} of full 32-bit bytes " \
        f"> {DELTA_BYTE_GATE}"
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI freshness check)")
    run(**vars(ap.parse_args()))
