"""Embedding-store benchmark: sharded store + hot-node cache + streaming
refresh under open-loop load.

Tracks the scale-out serving trajectory by writing ``BENCH_store.json`` at
the repo root (DESIGN.md §13 documents the schema). Three gated sections:

* **bit-exactness** — the store-backed read path on ``yelp_like@small`` must
  answer every query bit-identically to the materialized-table engine, after
  a full sweep *and* after a k-hop delta refresh (the cache-coherence
  invariant ``verify_store`` asserts row by row);
* **hot-node cache** — on the seeded Zipf-skewed query workload the hot tier
  (pinned head + LRU tail, capacity a fraction of the table) must serve
  **>= 90%** of row reads from cache; misses are byte-accounted as the
  modeled remote-tier traffic;
* **open-loop SLO** — a ``ReplicaSet`` over one store sustains fixed-QPS
  Poisson arrivals while the calibrated ``gdelt_like`` mutation stream
  drives delta refreshes through the staleness bound: p99 must hold the
  declared SLO, nothing may be lost, and no partition may end beyond
  ``max_staleness`` sweeps stale (escalations to forced full sweeps are
  counted, not forbidden — they are the bound working).

``--smoke`` shrinks the workload/tier so CI can run it in seconds (writes
the untracked ``BENCH_store.smoke.json``; only full runs update the tracked
record).
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

import numpy as np

from repro import datasets
from repro.core.sylvie import SylvieConfig
from repro.models.gnn.models import PAPER_ARCHS
from repro.serve import InferenceEngine, ReplicaSet, ServeConfig
from repro.serve.loadgen import open_loop
from repro.store import MutationStream, ShardedEmbeddingStore, zipf_popularity
from repro.train.trainer import GNNTrainer

ROOT = Path(__file__).resolve().parents[1]

HIT_RATE_GATE = 0.90        # cache hit rate on the skewed workload
CACHE_FRACTION = 0.50       # hot-tier capacity as a fraction of the table
PIN_FRACTION = 0.15         # head of the popularity order pinned outright


def _train(pg, td, *, epochs, seed):
    model = PAPER_ARCHS["gcn"](pg.x.shape[-1], pg.n_classes)
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1),
                    seed=seed, ckpt_dir=td)
    tr.fit(epochs)
    tr.save()
    return model


def bench_bitexact(ref: str, parts: int, epochs: int, seed: int) -> dict:
    """Store-backed engine vs materialized engine: every logit row equal,
    after the full sweep and again after a delta refresh."""
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    n_nodes = int(pg.part_of.shape[0])
    with tempfile.TemporaryDirectory() as td:
        model = _train(pg, td, epochs=epochs, seed=seed)
        store = ShardedEmbeddingStore(cache_bytes=1 << 24)   # holds everything
        eng_s, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1), seed=seed, store=store)
        eng_m, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1), seed=seed)
        eng_s.full_sweep()
        eng_m.full_sweep()
        ids = np.arange(n_nodes)
        full_equal = bool(np.array_equal(eng_s.query(ids).logits,
                                         eng_m.query(ids).logits))
        rng = np.random.default_rng(seed + 1)
        ch = rng.choice(n_nodes, size=max(1, n_nodes // 100), replace=False)
        rows = rng.normal(0, 1, (ch.size, pg.x.shape[-1])).astype(np.float32)
        eng_s.refresh(ch, rows)
        eng_m.refresh(ch, rows)
        delta_equal = bool(np.array_equal(eng_s.query(ids).logits,
                                          eng_m.query(ids).logits))
        verified = eng_s.verify_store()
    return dict(graph=ref, nodes=n_nodes, full_equal=full_equal,
                delta_equal=delta_equal, rows_verified=int(verified))


def bench_cache(ref: str, parts: int, epochs: int, seed: int, *,
                skew: float, queries: int) -> dict:
    """Windowed hit rate of the hot tier on the seeded skewed workload:
    pin the popularity head, LRU the rest, warm up, then measure."""
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    n_nodes = int(pg.part_of.shape[0])
    with tempfile.TemporaryDirectory() as td:
        model = _train(pg, td, epochs=epochs, seed=seed)
        # capacity: a fraction of the logits table (the only table queried;
        # pin_hot below pins logits only, so "emb" rows never take space —
        # put_rows doesn't admit, only misses do)
        row_bytes = pg.n_classes * 4
        cache_bytes = int(CACHE_FRACTION * n_nodes) * row_bytes
        store = ShardedEmbeddingStore(cache_bytes=cache_bytes)
        eng, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1), seed=seed, store=store)
        eng.full_sweep()
        pop = zipf_popularity(n_nodes, skew, seed)
        hot = np.argsort(pop)[::-1][:int(PIN_FRACTION * n_nodes)]
        eng.pin_hot(hot, tables=("logits",))
        rng = np.random.default_rng(seed + 2)
        qids = rng.choice(n_nodes, size=(queries, 16), p=pop)
        warm = queries // 5
        for q in qids[:warm]:                      # warm the LRU tail
            eng.query(q)
        s0 = store.stats()
        for q in qids[warm:]:
            eng.query(q)
        s1 = store.stats()
    window = (s1.hits + s1.misses) - (s0.hits + s0.misses)
    hit_rate = ((s1.hits - s0.hits) / window) if window else 0.0
    return dict(graph=ref, nodes=n_nodes, skew=float(skew),
                queries=int(queries), warmup_queries=int(warm),
                cache_bytes=int(cache_bytes), pinned_rows=int(hot.size),
                hit_rate=float(hit_rate),
                miss_bytes=int(s1.miss_bytes - s0.miss_bytes),
                evictions=int(s1.evictions - s0.evictions),
                table_bytes=int(n_nodes * row_bytes))


def bench_open_loop(ref: str, parts: int, epochs: int, seed: int, *,
                    qps: float, requests: int, slo_ms: float,
                    stream_events: int, window_s: float,
                    max_staleness: int, replicas: int) -> dict:
    """ReplicaSet over one store under fixed-QPS Poisson arrivals while the
    calibrated mutation stream refreshes through the staleness bound."""
    g, stream = MutationStream.from_workload(ref, seed=seed)
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    n_nodes = int(pg.part_of.shape[0])
    with tempfile.TemporaryDirectory() as td:
        model = _train(pg, td, epochs=epochs, seed=seed)
        store = ShardedEmbeddingStore(cache_bytes=1 << 24)
        eng, _ = InferenceEngine.from_checkpoint(
            td, model, pg,
            config=ServeConfig(bits=1, max_staleness=max_staleness),
            seed=seed, store=store)
        eng.full_sweep()
        feed = stream.batches(stream_events, window_s,
                              rows_of=eng.feature_rows)
        # one delta up front so the traced refresh executable is compiled
        # before the clock starts — compile time is not a serving cost
        t0, ids0, rows0 = feed[0]
        eng.refresh(ids0, rows0)
        rs = ReplicaSet(eng, n_replicas=replicas, microbatch=128)
        load = open_loop(rs, n_nodes, qps=qps, requests=requests, batch=16,
                         seed=seed, skew=stream.skew, slo_ms=slo_ms,
                         feed=feed[1:])
        staleness = [int(s) for s in eng.part_staleness]
    within_bound = max(staleness, default=0) <= max_staleness
    return dict(graph=ref, nodes=n_nodes, replicas=int(replicas),
                max_staleness=int(max_staleness),
                stream=dict(events=int(stream_events),
                            window_s=float(window_s), rate=stream.rate,
                            feat_frac=stream.feat_frac, skew=stream.skew),
                part_staleness=staleness,
                staleness_within_bound=bool(within_bound),
                per_replica=rs.per_replica(), load=load)


def run(smoke: bool = False) -> dict:
    if smoke:
        exact_ref, stream_ref = "yelp_like@smoke", "gdelt_like@smoke"
        parts, epochs = 4, 2
        # stream_events is sized so refresh work (~200 ms per delta on 4
        # forced host devices) doesn't saturate the arrival window: the SLO
        # prices in arrivals queued behind one refresh stall plus drain
        queries, requests, stream_events = 400, 150, 30
        qps, slo_ms, window_s = 300.0, 600.0, 0.25
    else:
        exact_ref, stream_ref = "yelp_like@small", "gdelt_like@small"
        parts, epochs = 4, 3
        queries, requests, stream_events = 1500, 400, 150
        qps, slo_ms, window_s = 400.0, 750.0, 0.5
    seed = 0
    skew = 1.1      # gdelt_like's calibrated query/update skew

    exact = bench_bitexact(exact_ref, parts, epochs, seed)
    cache = bench_cache(exact_ref, parts, epochs, seed,
                        skew=skew, queries=queries)
    ol = bench_open_loop(stream_ref, parts, epochs, seed, qps=qps,
                         requests=requests, slo_ms=slo_ms,
                         stream_events=stream_events, window_s=window_s,
                         max_staleness=8, replicas=2)

    gates = dict(
        bitexact=exact["full_equal"] and exact["delta_equal"],
        hit_rate=cache["hit_rate"] >= HIT_RATE_GATE,
        slo=bool(ol["load"]["slo_pass"]),
        staleness=ol["staleness_within_bound"])
    rec = dict(config=dict(exact_graph=exact_ref, stream_graph=stream_ref,
                           parts=parts, arch="gcn", train_epochs=epochs,
                           smoke=smoke, seed=seed),
               bitexact=exact, cache=cache, open_loop=ol, gates=gates)

    print(f"== bench_store ({exact_ref} / {stream_ref}, P={parts}) ==")
    print(f"bit-exact: full={exact['full_equal']} "
          f"delta={exact['delta_equal']} "
          f"({exact['rows_verified']} rows verified)")
    print(f"cache: hit rate {cache['hit_rate']:.3f} "
          f"(gate >= {HIT_RATE_GATE}), miss {cache['miss_bytes']/1e3:.1f} kB,"
          f" {cache['evictions']} evictions, capacity "
          f"{cache['cache_bytes']/1e3:.1f}/{cache['table_bytes']/1e3:.1f} kB")
    lo = ol["load"]
    print(f"open loop: {lo['qps_offered']:.0f} qps offered, p99 "
          f"{lo['p99_ms']:.1f} ms vs SLO {lo['slo_ms']:.0f} ms "
          f"({'PASS' if lo['slo_pass'] else 'FAIL'}), {lo['completed']} "
          f"completed, {lo['lost']} lost, {lo['refreshes']} refreshes "
          f"({lo['refresh_escalations']} escalated), lag max "
          f"{lo['refresh_lag_max_s']*1e3:.0f} ms")
    print(f"staleness: {ol['part_staleness']} "
          f"(bound {ol['max_staleness']}) -> "
          f"{'OK' if ol['staleness_within_bound'] else 'VIOLATED'}")

    out = ROOT / ("BENCH_store.smoke.json" if smoke else "BENCH_store.json")
    out.write_text(json.dumps(rec, indent=1, default=float))
    failed = sorted(k for k, ok in gates.items() if not ok)
    assert not failed, f"bench_store gates failed: {failed}"
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI freshness check)")
    run(**vars(ap.parse_args()))
