"""Halo-exchange microbenchmark: dense vs compacted plan, jnp vs fused Pallas
quantize. Tracks the perf trajectory of the system's hottest path from this PR
onward by writing ``BENCH_halo.json`` at the repo root.

Measures, on a skewed power-law partition (8 parts, geometric sizes):

* rows/bytes: buffer rows, wire rows/bytes the layout ships, true halo rows —
  the compact plan's reduction factor vs the dense ``(P, P*h_pad)`` layout;
* ms: jit wall time of the full quantized halo round trip (gather -> quantize
  -> exchange -> dequantize), forward and forward+backward, per layout;
* quantize impls: jnp vs the fused Pallas kernel on the compacted send-buffer
  shape (off-TPU the kernel runs *interpret mode* — correctness-path timing
  only; the one-HBM-pass claim is a TPU number).

``--smoke`` shrinks everything so CI can run it in seconds.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import quantization as qlib
from repro.core.exchange import PlanArrays, exchange_bytes, wire_bytes
from repro.core.sylvie import quantized_halo
from repro.graph import formats, partition, synthetic

ROOT = Path(__file__).resolve().parents[1]
def _key():
    # built lazily: no device work at import time (lint RA104)
    return jax.random.PRNGKey(0)


def _timed(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))             # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e3


def _bench_layout(pg, d_feat, bits, reps):
    plan = PlanArrays.from_plan(pg.plan)
    p = plan.n_parts
    h = jax.random.normal(_key(), (p, plan.n_local, d_feat), jnp.float32)
    k1, k2 = jax.random.split(_key())

    @jax.jit
    def fwd(x):
        return quantized_halo(x, plan, k1, k2, bits, bits, True, jnp.bfloat16,
                              None, "jnp")

    @jax.jit
    def fwdbwd(x):
        # quadratic loss: the backward cotangent depends on x, so XLA cannot
        # constant-fold the quantized backward communication away
        return jax.grad(lambda v: (quantized_halo(
            v, plan, k1, k2, bits, bits, True, jnp.bfloat16, None,
            "jnp") ** 2).sum() / 2)(x)

    pb, eb = wire_bytes(plan, d_feat, bits)
    tb, _ = exchange_bytes(plan, d_feat, bits)
    return dict(
        layout=pg.plan.layout,
        halo_rows_per_part=plan.halo_rows,
        buffer_rows_total=p * plan.halo_rows,
        wire_rows=plan.wire_rows,
        real_rows=plan.real_rows,
        wire_payload_bytes=pb,
        wire_ec_bytes=eb,
        true_payload_bytes=tb,
        pad_efficiency=pg.plan.pad_efficiency(),
        fwd_ms=_timed(fwd, h, reps=reps),
        fwd_bwd_ms=_timed(fwdbwd, h, reps=reps),
    )


def _bench_quantize(rows, d_feat, bits, reps):
    h = jax.random.normal(_key(), (rows, d_feat), jnp.float32)
    out = {}
    for impl in ("jnp", "pallas"):
        qfn = jax.jit(lambda x, impl=impl: qlib.dequantize(
            qlib.quantize(x, bits, _key(), True, impl=impl), impl=impl))
        out[impl] = _timed(qfn, h, reps=reps)
    out["pallas_mode"] = ("compiled" if jax.default_backend() == "tpu"
                          else "interpret")
    return out


def run(smoke: bool = False) -> dict:
    # full config sized for XLA-CPU wall clocks (DESIGN.md §8); the byte/row
    # columns — the acceptance metric — are exact at any size
    n, d_feat, parts, reps = (2000, 32, 8, 2) if smoke else (8000, 64, 8, 3)
    bits = 1
    g = synthetic.powerlaw(n_nodes=n, d_feat=d_feat, avg_degree=16, seed=0)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)

    layouts = {}
    for layout in ("dense", "compact"):
        pg = partition.partition_graph(g, parts, method="skewed",
                                       edge_weight=ew, layout=layout)
        layouts[layout] = _bench_layout(pg, d_feat, bits, reps)

    # cap the impl-comparison rows: off-TPU the Pallas kernel runs interpret
    # mode, whose wall clock is meaningless beyond a correctness-path signal
    q_rows = min(layouts["compact"]["buffer_rows_total"], 16384)
    rec = dict(
        config=dict(n_nodes=n, d_feat=d_feat, parts=parts, bits=bits,
                    method="skewed", smoke=smoke,
                    backend=jax.default_backend()),
        dense=layouts["dense"],
        compact=layouts["compact"],
        wire_reduction=layouts["compact"]["wire_payload_bytes"]
        / max(layouts["dense"]["wire_payload_bytes"], 1),
        quantize=_bench_quantize(max(q_rows, 8), d_feat, bits,
                                 reps=1 if smoke else reps),
    )

    print(f"== bench_halo (P={parts}, n={n}, d={d_feat}, {bits}-bit, skewed) ==")
    for lay in ("dense", "compact"):
        r = layouts[lay]
        print(f"{lay:8s} rows/part={r['halo_rows_per_part']:6d} "
              f"wire={r['wire_payload_bytes'] / 1e3:9.1f} kB "
              f"pad_eff={r['pad_efficiency']:.3f} "
              f"fwd={r['fwd_ms']:7.2f} ms fwd+bwd={r['fwd_bwd_ms']:7.2f} ms")
    q = rec["quantize"]
    print(f"wire reduction (compact/dense): {rec['wire_reduction']:.3f}")
    print(f"quantize {q_rows}x{d_feat}: jnp={q['jnp']:.2f} ms  "
          f"pallas[{q['pallas_mode']}]={q['pallas']:.2f} ms")

    # --smoke is a CI freshness/regression check; only full runs update the
    # tracked perf-trajectory record
    out = ROOT / ("BENCH_halo.smoke.json" if smoke else "BENCH_halo.json")
    out.write_text(json.dumps(rec, indent=1, default=float))
    assert rec["wire_reduction"] <= 0.6, \
        f"compact layout regressed: wire ratio {rec['wire_reduction']:.3f} > 0.6"
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 1 rep (CI freshness check)")
    run(**vars(ap.parse_args()))
