"""Fig. 10: Low-bit Module overhead — time spent in quantize / dequantize
vs exchange vs compute within one Sylvie-S epoch (measured on CPU by timing
the jitted pieces in isolation; the paper's point is that the module is a
small fraction of the epoch)."""
from __future__ import annotations

import time

import jax

from repro.core import quantization as qlib
from repro.core.exchange import exchange, gather_boundary

from . import common


def _time(f, *args, n=20):
    jax.block_until_ready(f(*args))              # compile + warmup
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n


def run() -> dict:
    tr = common.make_trainer(common.REF_DS, "graphsage", parts=8,
                             mode="sync", bits=1)
    block, x = tr.block, tr.x
    key = jax.random.PRNGKey(0)
    buf = gather_boundary(x, block.plan)

    quant = jax.jit(lambda b: qlib.quantize(b, 1, key).data)
    qt = qlib.quantize(buf, 1, key)
    deq = jax.jit(qlib.dequantize)
    exch = jax.jit(lambda b: exchange(b, None))
    full = jax.jit(lambda s: tr._ts(s, block, x, tr.y, tr.train_mask, key)[1])

    t_q = _time(quant, buf)
    t_d = _time(deq, qt)
    t_x = _time(exch, buf)
    tr.train_epoch()
    t_epoch = common.timed_epochs(tr, epochs=10)
    n_sites = 2 * len(tr.model.comm_dims())       # fwd + bwd per layer
    lowbit_frac = n_sites * (t_q + t_d) / t_epoch

    rows = [["quantize (per site)", f"{t_q*1e6:.1f}"],
            ["dequantize (per site)", f"{t_d*1e6:.1f}"],
            ["exchange (per site)", f"{t_x*1e6:.1f}"],
            ["full epoch", f"{t_epoch*1e6:.1f}"],
            ["Low-bit Module fraction", f"{100*lowbit_frac:.1f}%"]]
    print("\n== Fig 10: Low-bit Module overhead (CPU measured, us) ==")
    print(common.fmt_table(["component", "time"], rows))
    rec = dict(quant_us=t_q * 1e6, dequant_us=t_d * 1e6,
               exchange_us=t_x * 1e6, epoch_us=t_epoch * 1e6,
               lowbit_frac=lowbit_frac)
    common.save("fig10_overhead", rec)
    # NB: CPU wall fractions are not the paper's GPU/TPU regime (no fused
    # quant kernel on CPU and tiny graphs) — this table is report-only; the
    # TPU-side overhead argument is the Pallas kernel's single-HBM-pass
    # design (kernels/quant) + the byte accounting in table3.
    return rec


if __name__ == "__main__":
    run()
