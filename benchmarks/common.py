"""Shared benchmark harness: datasets, trainers, timing, modeled-TPU columns.

CPU-only caveat (DESIGN.md §8): wall-clock here measures XLA-CPU, so every
table reports (i) measured CPU wall time, (ii) exact communication bytes
(independent of hardware), and (iii) modeled TPU comm time = bytes / ICI_BW.
The paper's claims are validated against (ii)/(iii) and the accuracy columns.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro import datasets
from repro.core.sylvie import SylvieConfig
from repro.graph import formats
from repro.launch.mesh import ICI_BW
from repro.models.gnn.models import PAPER_ARCHS
from repro.policy import BoundedStaleness
from repro.train.trainer import GNNTrainer

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"

# Named-workload refs from the repro.datasets registry (the paper's dataset
# stand-ins at benchmark size). REF_DS is the accuracy-meaningful reference
# every single-dataset table trains on; repeated runs hit the partition-plan
# cache under artifacts/plans/.
REF_DS = "yelp_like@small"
DATASETS = (REF_DS, "products_like@small")

MODELS = PAPER_ARCHS

# The six methods of Table 2, expressed as runtime configs of THIS framework.
METHODS = {
    "vanilla(DGL)": dict(mode="vanilla", bits=32),
    "PipeGCN~": dict(mode="async", bits=32),
    "BNS-GCN~": dict(mode="vanilla", bits=32, boundary_sample_p=0.9),
    "Sylvie-S": dict(mode="sync", bits=1),
    "Sylvie-A": dict(mode="async", bits=1),
}


def build_dataset(ds: str):
    """GCN-normalized registry graph + edge weights (``ds`` = "name@tier")."""
    return formats.gcn_normalize(datasets.load(ds))


def make_trainer(ds: str, model_name: str, parts: int = 8, eps_s=None,
                 policy=None, seed: int = 0, **cfg_kw) -> GNNTrainer:
    pg, _ = datasets.load_partitioned(ds, parts)
    model = MODELS[model_name](pg.x.shape[-1], pg.n_classes)
    cfg = SylvieConfig(**cfg_kw)
    if eps_s is not None:           # benchmark shorthand for the adaptor
        assert policy is None
        policy = BoundedStaleness(eps_s=eps_s, bits=cfg.effective_bits,
                                  stochastic=cfg.stochastic,
                                  boundary_sample_p=cfg.boundary_sample_p)
    return GNNTrainer(model, pg, cfg, policy=policy, seed=seed)


def timed_epochs(tr: GNNTrainer, epochs: int, warmup: int = 3):
    for _ in range(warmup):
        tr.train_epoch()
    t0 = time.time()
    for _ in range(epochs):
        tr.train_epoch()
    return (time.time() - t0) / epochs


def modeled_comm_s(tr: GNNTrainer) -> float:
    """Modeled per-device TPU comm time: comm_bytes_per_epoch totals across
    partitions, exchanges run concurrently, ICI_BW is per-device."""
    pb, eb = tr.comm_bytes_per_epoch()
    return (pb + eb) / tr.pg.plan.n_parts / ICI_BW


def save(name: str, record: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(record, indent=1,
                                                 default=float))


def fmt_table(headers, rows) -> str:
    w = [max(len(str(h)), *(len(str(r[i])) for r in rows))
         for i, h in enumerate(headers)]
    out = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(headers))]
    out.append("  ".join("-" * w[i] for i in range(len(headers))))
    for r in rows:
        out.append("  ".join(str(c).ljust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)
