"""Table 4: Sylvie's boundary-only quantization vs quantizing ALL activations.

Quantizing everything to 1 bit destroys accuracy (paper: 97.2% -> 70.6% on
Reddit); the subset (boundary) quantization is what makes 1-bit viable.
The quantize-all variant reuses the same Low-bit Module via the
straight-through wrapper applied to every layer activation.
"""
from __future__ import annotations

import dataclasses

import jax

from repro import datasets
from repro.core.quantization import straight_through_quantize
from repro.core.sylvie import SylvieConfig
from repro.models.gnn.models import GCN, GraphSAGE
from repro.train.trainer import GNNTrainer

from . import common

EPOCHS = 50


@dataclasses.dataclass(frozen=True)
class QuantAllWrapper:
    """Model decorator: 1-bit fake-quantize every post-layer activation."""
    inner: object
    bits: int = 1

    def comm_dims(self):
        return self.inner.comm_dims()

    def init(self, key):
        return self.inner.init(key)

    def apply(self, params, block, x, comm):
        # quantize the input features and intercept comm.halo to quantize
        # the *local* activations too (halo is already quantized by Sylvie)
        orig_halo = comm.halo
        key = comm.key

        def halo_and_quant(h):
            h = straight_through_quantize(h, self.bits,
                                          jax.random.fold_in(key, h.shape[-1]))
            return orig_halo(h)

        comm.halo = halo_and_quant
        out = self.inner.apply(params, block, x, comm)
        comm.halo = orig_halo
        return out


def run() -> dict:
    rows = []
    rec = {}
    for name, ctor in (("graphsage", GraphSAGE), ("gcn", GCN)):
        pg, _ = datasets.load_partitioned(common.REF_DS, 8)
        accs = {}
        for variant in ("Sylvie-S", "QuantAll"):
            model = ctor(pg.x.shape[-1], 64, pg.n_classes, n_layers=2)
            if variant == "QuantAll":
                model = QuantAllWrapper(model)
            tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1))
            tr.fit(EPOCHS)
            accs[variant] = tr.evaluate("test")
        rows.append([name, f"{100*accs['Sylvie-S']:.2f}",
                     f"{100*accs['QuantAll']:.2f}"])
        rec[name] = accs
    print("\n== Table 4: boundary-only vs quantize-all (1-bit) ==")
    print(common.fmt_table(["model", "Sylvie-S %", "Quant-All %"], rows))
    common.save("table4_quantall", rec)
    assert all(v["Sylvie-S"] >= v["QuantAll"] - 1e-6 for v in rec.values())
    return rec


if __name__ == "__main__":
    run()
