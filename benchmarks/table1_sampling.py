"""Table 1: sampling-based vs full-graph training accuracy (GraphSAGE).

Full-graph training beats neighbor-sampled training, and the gap widens as
the sample size shrinks — the paper's motivation for distributed full-graph.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, sampling
from repro.models.gnn import blocks as B
from repro.models.gnn.models import GraphSAGE
from repro.train import optimizer as opt
from repro.train.gnn_step import GNNTrainState, make_gnn_steps

from . import common

EPOCHS = 60


def _sampled_accuracy(g, fanout, epochs=EPOCHS, seed=0):
    """Mini-batch neighbor-sampled training (the Table-1 baseline)."""
    key = jax.random.PRNGKey(seed)
    model = GraphSAGE(g.x.shape[1], 64, g.n_classes, n_layers=2)
    o = opt.adam(1e-2)
    sampler = sampling.NeighborSampler(g, fanouts=(fanout, fanout), seed=seed)
    state = None
    cfg = SylvieConfig(mode="vanilla")
    for e in range(epochs):
        sub = sampler.sample(batch_nodes=256)
        ei = formats.add_self_loops(sub.edge_index, sub.n_nodes)
        sub2 = formats.Graph(sub.n_nodes, ei, sub.x, sub.y, sub.train_mask,
                             sub.val_mask, sub.test_mask,
                             n_classes=g.n_classes)
        pg = partition.partition_graph(sub2, 1)
        block = B.build_block(pg)
        ts, _, _ = make_gnn_steps(model, cfg, o)
        if state is None:
            state = GNNTrainState.create(model, o, key, block.plan,
                                         stacked_parts=1)
        else:
            state = GNNTrainState(state.params, state.opt_state,
                                  __import__("repro.core.staleness",
                                             fromlist=["HaloState"])
                                  .HaloState.zeros(block.plan,
                                                   model.comm_dims(),
                                                   stacked_parts=1),
                                  state.step, state.ef, state.site_stats)
        state, _ = jax.jit(ts)(state, block, jnp.asarray(pg.x),
                               jnp.asarray(pg.y), jnp.asarray(pg.train_mask),
                               jax.random.fold_in(key, e))
    # evaluate full-graph
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    gf = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                       g.test_mask, n_classes=g.n_classes)
    pgf = partition.partition_graph(gf, 1)
    blockf = B.build_block(pgf)
    _, _, ev = make_gnn_steps(model, cfg, o)
    c, n = jax.jit(ev)(state.params, blockf, jnp.asarray(pgf.x),
                       jnp.asarray(pgf.y), jnp.asarray(pgf.test_mask), key)
    return float(c) / max(float(n), 1.0)


def run() -> dict:
    g, _ = common.build_dataset(common.REF_DS)
    rows = []
    for fanout in (5, 10, 15):
        acc = _sampled_accuracy(g, fanout)
        rows.append([f"sampled fanout={fanout}", f"{100*acc:.2f}"])
    tr = common.make_trainer(common.REF_DS, "graphsage", parts=1,
                             mode="vanilla", bits=32)
    tr.fit(EPOCHS)
    full = tr.evaluate("test")
    rows.append(["full-graph", f"{100*full:.2f}"])
    print(f"\n== Table 1: sampling vs full-graph (GraphSAGE, {common.REF_DS}) ==")
    print(common.fmt_table(["training", "test acc %"], rows))
    rec = dict(rows=rows, full_graph_acc=full)
    common.save("table1_sampling", rec)
    assert full >= max(float(r[1]) for r in rows[:-1]) / 100 - 0.02
    return rec


if __name__ == "__main__":
    run()
