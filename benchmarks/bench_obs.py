"""Observability overhead benchmark: tracing must be free when off.

Tracks the PR-10 acceptance gate by writing ``BENCH_obs.json`` at the repo
root. Two measurements on the smoke-scale training workload:

* **disabled cost** — per-call ns of the three hot instrumentation seams with
  no tracer armed (``obs.span`` returning the shared null singleton,
  ``obs.event`` no-op, ``obs.count`` registry bump), times the seam density
  one traced epoch actually emits (counted by draining a real traced epoch),
  divided by the measured untraced epoch wall time. **Gate: <= 1%.** In
  practice the fraction is orders of magnitude below the gate — the gate
  exists to catch an accidental allocation or clock read sneaking into the
  null path.
* **enabled cost** — the same ratio with the tracer armed (informational,
  not gated: tracing is opt-in per run).

``--smoke`` shrinks the workload so CI can run it in seconds
(``BENCH_obs.smoke.json``, untracked; only full runs update the tracked
record).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

from repro import obs
from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import PAPER_ARCHS
from repro.train.trainer import GNNTrainer

ROOT = Path(__file__).resolve().parents[1]
ARCH = "gcn"
OVERHEAD_GATE = 0.01


def _per_call_ns(fn, calls: int) -> float:
    t0 = time.perf_counter()
    for _ in range(calls):
        fn()
    return (time.perf_counter() - t0) / calls * 1e9


def _null_span():
    with obs.span("step"):
        pass


def _live_span():
    with obs.span("step", {"mode": "sync"}):
        pass


def _build_trainer(n, d_feat, parts):
    g = synthetic.powerlaw(n_nodes=n, d_feat=d_feat, avg_degree=16, seed=0)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)
    pg = partition.partition_graph(g, parts, method="skewed",
                                   edge_weight=ew, layout="compact")
    model = PAPER_ARCHS[ARCH](pg.x.shape[-1], pg.n_classes)
    return GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1,
                                              schedule="overlap"), seed=0)


def run(smoke: bool = False) -> dict:
    n, d_feat, parts, epochs, calls = \
        (1500, 16, 4, 3, 50_000) if smoke else (6000, 32, 4, 5, 500_000)
    obs.disable()

    # the three disabled seams, per call
    null_span_ns = _per_call_ns(_null_span, calls)
    null_event_ns = _per_call_ns(lambda: obs.event("halo.issue"), calls)
    count_ns = _per_call_ns(lambda: obs.count("bench.calls"), calls)

    # seam density: drain one *traced* epoch and count what it emitted
    # (spans + instant events; counters ride the same host seams)
    tr = _build_trainer(n, d_feat, parts)
    tr.train_epoch()                            # compile + warm
    obs.enable()
    t0 = time.perf_counter()
    tr.train_epoch()
    traced_epoch_s = time.perf_counter() - t0
    seams_per_epoch = len(obs.drain())
    obs.disable()

    t0 = time.perf_counter()
    for _ in range(epochs):
        tr.train_epoch()
    epoch_s = (time.perf_counter() - t0) / epochs

    # the gate: disabled instrumentation cost per epoch vs epoch wall time.
    # charge every seam at the priciest disabled rate — still tiny.
    worst_ns = max(null_span_ns, null_event_ns, count_ns)
    disabled_frac = seams_per_epoch * worst_ns / 1e9 / epoch_s
    live_span_ns = None
    enabled_frac = (traced_epoch_s - epoch_s) / epoch_s
    obs.enable()
    live_span_ns = _per_call_ns(_live_span, calls)
    obs.drain()
    obs.disable()

    rec = dict(
        config=dict(n_nodes=n, d_feat=d_feat, parts=parts, arch=ARCH,
                    epochs=epochs, calls=calls, smoke=smoke,
                    backend=jax.default_backend()),
        null_span_ns=null_span_ns,
        null_event_ns=null_event_ns,
        count_ns=count_ns,
        live_span_ns=live_span_ns,
        seams_per_epoch=seams_per_epoch,
        epoch_wall_s=epoch_s,
        disabled_overhead_fraction=disabled_frac,
        enabled_overhead_fraction=enabled_frac,
        gate=OVERHEAD_GATE,
    )

    print(f"== bench_obs (P={parts}, n={n}, d={d_feat}) ==")
    print(f"disabled: span={null_span_ns:7.1f} ns  event={null_event_ns:6.1f}"
          f" ns  count={count_ns:6.1f} ns   enabled span={live_span_ns:7.1f}"
          " ns")
    print(f"{seams_per_epoch} seams/epoch over {epoch_s*1e3:.1f} ms/epoch -> "
          f"disabled overhead {disabled_frac:.3e} "
          f"(gate {OVERHEAD_GATE:.0%}), enabled {enabled_frac:+.2%}")

    out = ROOT / ("BENCH_obs.smoke.json" if smoke else "BENCH_obs.json")
    out.write_text(json.dumps(rec, indent=1, default=float))

    assert disabled_frac <= OVERHEAD_GATE, \
        (f"disabled-tracer overhead {disabled_frac:.3e} exceeds the "
         f"{OVERHEAD_GATE:.0%} gate — the null path stopped being free "
         f"({worst_ns:.0f} ns/seam x {seams_per_epoch} seams/epoch)")
    print(f"wrote {out}")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run -> BENCH_obs.smoke.json (untracked)")
    run(**vars(ap.parse_args()))
