"""Table 2 / Fig. 5: throughput + accuracy of the six methods on 3 models.

Methods are runtime configs of this framework (benchmarks/common.METHODS):
vanilla (DGL stand-in), PipeGCN~ (pipelined fp32), BNS-GCN~ (boundary
sampling p=0.9), Sylvie-S, Sylvie-A. SAR is noted in DESIGN.md (its
contribution is sequential rematerialization for memory, orthogonal here).

Throughput columns: modeled-TPU epoch/s normalized to vanilla (comm-bound
regime: epoch time ~ max(comm, compute) with Sylvie-A overlapping comm), and
measured CPU wall time for reference. Accuracy after EPOCHS epochs.
"""
from __future__ import annotations

from repro.launch.cells import _gnn_model_flops
from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16

from . import common

EPOCHS = 40


def _modeled_epoch_s(tr, model_name, overlap: bool) -> float:
    pb, eb = tr.comm_bytes_per_epoch()   # totals across partitions
    comm = (pb + eb) / tr.pg.plan.n_parts / ICI_BW
    g, _ = common.build_dataset(common.REF_DS)
    flops = _gnn_model_flops(model_name, tr.model, g.n_nodes, g.n_edges,
                             g.x.shape[1], True) / tr.pg.plan.n_parts
    comp = flops / PEAK_FLOPS_BF16
    if tr.cfg.boundary_sample_p > 0:
        comm = comm * (1 - tr.cfg.boundary_sample_p)
    return max(comm, comp) if overlap else comm + comp


def run() -> dict:
    rows = []
    rec = {}
    for model_name in ("graphsage", "gcn", "gat"):
        base = None
        for method, cfg_kw in common.METHODS.items():
            tr = common.make_trainer(common.REF_DS, model_name, parts=8,
                                     **cfg_kw)
            tr.fit(EPOCHS)
            acc = tr.evaluate("test")
            ep_s = _modeled_epoch_s(tr, model_name,
                                    overlap=(cfg_kw["mode"] == "async"))
            cpu_s = common.timed_epochs(tr, epochs=5)
            if base is None:
                base = ep_s
            thr = base / ep_s
            rows.append([model_name, method, f"{thr:.2f}x",
                         f"{100*acc:.2f}", f"{cpu_s*1e3:.1f}"])
            rec[f"{model_name}/{method}"] = dict(thr=thr, acc=acc)
    print("\n== Table 2: throughput (modeled-TPU, normalized) + accuracy ==")
    print(common.fmt_table(
        ["model", "method", "thr", "test acc %", "CPU ms/epoch"], rows))
    common.save("table2_throughput", rec)
    for m in ("graphsage", "gcn", "gat"):
        assert rec[f"{m}/Sylvie-S"]["thr"] > rec[f"{m}/vanilla(DGL)"]["thr"]
        assert rec[f"{m}/Sylvie-A"]["thr"] >= rec[f"{m}/Sylvie-S"]["thr"]
        assert rec[f"{m}/Sylvie-S"]["acc"] > rec[f"{m}/vanilla(DGL)"]["acc"] - 0.03
    return rec


if __name__ == "__main__":
    run()
