"""Scenario-matrix runner: expansion, reports, schema, plan-cache reuse."""
import json

import pytest

from repro.launch import scenarios as S

# The v1 report keys, pinned independently of the source: v2 must stay a
# strict superset (schema versioning means old consumers keep working).
V1_REPORT_KEYS = {
    "scenario", "cell", "arch", "dataset", "policy", "policy_spec", "mode",
    "runtime", "n_parts", "epochs", "seed", "plan_cache_hit", "final_loss",
    "val_acc", "test_acc", "comm_payload_bytes_per_epoch",
    "comm_ec_bytes_per_epoch", "wire_payload_bytes_per_epoch",
    "wire_ec_bytes_per_epoch", "modeled_tpu_comm_s", "schedule",
    "modeled_tpu_comm_exposed_s", "modeled_tpu_comm_overlapped_s",
    "bits_per_site", "seconds", "fault", "faults_injected", "halos_reused",
    "forced_syncs", "stall_s",
}


def test_report_schema_is_versioned_superset():
    assert S.REPORT_SCHEMA_VERSION == 2
    assert V1_REPORT_KEYS < S.REPORT_KEYS
    assert S.REPORT_KEYS - V1_REPORT_KEYS == \
        {"schema_version", "obs", "trace_path"}


def test_smoke_scenario_matrix_shape():
    """The acceptance matrix: >= 2 archs x 2 datasets x 2 policies."""
    scn = S.resolve("smoke")
    assert len(scn.archs) >= 2 and len(scn.datasets) >= 2
    assert len(scn.policies) >= 2
    cells = scn.cells()
    assert len(cells) == (len(scn.archs) * len(scn.datasets)
                          * len(scn.policies))
    assert len({c.cell_id for c in cells}) == len(cells)     # ids unique


def test_parse_policy_specs():
    from repro import policy as P
    assert isinstance(S.parse_policy("uniform:32"), P.Uniform)
    assert S.parse_policy("uniform:32").bits == 32
    w = S.parse_policy("warmup:3:2")
    assert (w.epochs, w.bits) == (3, 2)
    b = S.parse_policy("bounded_staleness:4:1")
    assert (b.eps_s, b.bits) == (4, 1)
    assert S.parse_policy("adaqp:4").budget_bits == 4
    with pytest.raises(KeyError, match="unknown policy"):
        S.parse_policy("nope:1")


def test_unknown_scenario_and_empty_filter():
    with pytest.raises(KeyError, match="unknown scenario"):
        S.resolve("nope")
    with pytest.raises(ValueError, match="matched no cell"):
        S.run_scenario("smoke", only="no_such_cell")


@pytest.mark.slow
def test_run_scenario_writes_reports_and_reuses_plan_cache(tmp_path):
    """End-to-end on a 2x2x2-shaped tiny matrix; the second invocation must
    hit the partition-plan cache in every cell (the acceptance criterion).
    Trains 16 cells end-to-end (~30s) — slow suite."""
    scn = S.Scenario(
        name="tiny",
        archs=("gcn", "graphsage"),
        datasets=("yelp_like@smoke", "mesh_like@smoke"),
        policies=("uniform:1", "uniform:32"),
        parts=2, epochs=1)
    out, cache = tmp_path / "scenarios", tmp_path / "plans"
    reports = S.run_scenario(scn, out_dir=out, cache_dir=cache)
    assert len(reports) == 8
    # one JSON per cell + summary, all parseable, full schema
    files = sorted((out / "tiny").glob("*.json"))
    assert len(files) == 9
    summary = json.loads((out / "tiny" / "summary.json").read_text())
    assert summary["n_cells"] == 8
    for rep in reports:
        on_disk = json.loads((out / "tiny" / f"{rep['cell']}.json")
                             .read_text())
        # the exact pinned key set: keys cannot silently drop OR appear
        assert set(on_disk) == S.REPORT_KEYS
        assert on_disk["schema_version"] == S.REPORT_SCHEMA_VERSION
        assert on_disk["obs"]["enabled"] is False
        assert on_disk["obs"]["n_epochs"] == 1
        assert on_disk["trace_path"] is None
        assert on_disk["epochs"] == 1 and on_disk["n_parts"] == 2
        assert on_disk["comm_payload_bytes_per_epoch"] > 0
        assert on_disk["modeled_tpu_comm_s"] > 0
    # first run: each dataset is partitioned from scratch exactly once and
    # memoized across its cells, so every cell reports that disk miss...
    assert not any(r["plan_cache_hit"] for r in reports)
    assert len(list(cache.glob("*.npz"))) == 2        # one entry per dataset
    # ...and a second full invocation is served by the on-disk cache
    reports2 = S.run_scenario(scn, out_dir=out, cache_dir=cache)
    assert all(r["plan_cache_hit"] for r in reports2)
    # 32-bit cells ship 32x the payload of 1-bit cells, same everything else
    by_cell = {r["cell"]: r for r in reports2}
    for cell, r in by_cell.items():
        if "uniform-1__" in cell:
            r32 = by_cell[cell.replace("uniform-1__", "uniform-32__")]
            ratio = (r32["comm_payload_bytes_per_epoch"]
                     / r["comm_payload_bytes_per_epoch"])
            assert ratio == 32.0


def test_only_filter_selects_a_slice_and_summary_merges(tmp_path):
    scn = S.Scenario(name="slice", archs=("gcn", "graphsage"),
                     datasets=("mesh_like@smoke",),
                     policies=("uniform:1",), parts=2, epochs=1)
    reports = S.run_scenario(scn, out_dir=tmp_path / "s",
                             cache_dir=tmp_path / "p", only="graphsage")
    assert len(reports) == 1 and reports[0]["arch"] == "graphsage"
    # running the complementary slice must extend — not clobber — the summary
    S.run_scenario(scn, out_dir=tmp_path / "s", cache_dir=tmp_path / "p",
                   only="gcn")
    summary = json.loads((tmp_path / "s" / "slice" / "summary.json")
                         .read_text())
    assert summary["n_cells"] == 2
    assert {c["arch"] for c in summary["cells"]} == {"gcn", "graphsage"}
    # a full (unfiltered) run of a shrunk matrix prunes orphaned cell files
    shrunk = S.Scenario(name="slice", archs=("gcn",),
                        datasets=("mesh_like@smoke",),
                        policies=("uniform:1",), parts=2, epochs=1)
    S.run_scenario(shrunk, out_dir=tmp_path / "s", cache_dir=tmp_path / "p")
    summary = json.loads((tmp_path / "s" / "slice" / "summary.json")
                         .read_text())
    assert summary["n_cells"] == 1
    assert {c["arch"] for c in summary["cells"]} == {"gcn"}


def test_traced_cell_writes_obs_artifacts_with_full_schema(tmp_path):
    """One traced cell end-to-end: the report carries the exact v2 key set
    with a populated obs block, and the obs artifacts are a valid Perfetto
    trace + a summarizable metrics file (the --obs acceptance path)."""
    from repro.obs import export as ox

    scn = S.Scenario(name="one", archs=("gcn",),
                     datasets=("mesh_like@smoke",),
                     policies=("uniform:1",), parts=2, epochs=2)
    [cell] = scn.cells()
    obs_dir = tmp_path / "obs" / "one"
    rep = S.run_cell(scn, cell, cache_dir=tmp_path / "p", obs_dir=obs_dir)
    assert set(rep) == S.REPORT_KEYS
    assert rep["schema_version"] == S.REPORT_SCHEMA_VERSION
    assert rep["obs"]["enabled"] is True
    assert rep["obs"]["n_epochs"] == 2 and rep["obs"]["mean_wall_s"] > 0.0
    # drift = measured wall - modeled exposed comm; CPU wall time dwarfs the
    # modeled TPU wire time, so the drift is large and positive by design
    assert rep["obs"]["drift_s"] > 0.0
    trace = obs_dir / f"{cell.cell_id}.trace.json"
    metrics = obs_dir / f"{cell.cell_id}.metrics.json"
    assert rep["trace_path"] == str(trace)
    names = {e["name"] for e in ox.load_trace(trace)}
    assert {"epoch", "decide", "step"} <= names
    body = ox.load_metrics(metrics)
    assert body["run"] == f"one/{cell.cell_id}"
    assert body["modeled_vs_measured"]["n_epochs"] == 2
    assert body["metrics"]["counters"]["retrace.train"] >= 1
    summary = ox.render_summary(obs_dir)
    assert f"one/{cell.cell_id}" in summary
    # and the tracer is torn down again: later cells run untraced
    from repro import obs
    assert not obs.enabled()
