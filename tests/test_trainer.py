"""Trainer + checkpoint/restart + elastic repartition + EF21 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN
from repro.train import checkpoint as ckpt
from repro.train import compression, optimizer as opt
from repro.train.trainer import GNNTrainer

KEY = jax.random.PRNGKey(0)


def _graph(n=300, d=16, seed=0):
    g = synthetic.planted_partition(n_nodes=n, d_feat=d, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _trainer(parts=4, mode="async", eps_s=None, ckpt_dir=None, seed=0):
    g, ew = _graph(seed=seed)
    pg = partition.partition_graph(g, parts, edge_weight=ew)
    model = GCN(d_in=16, d_hidden=32, d_out=g.n_classes, n_layers=2)
    return GNNTrainer(model, pg, SylvieConfig(mode=mode, bits=1),
                      eps_s=eps_s, ckpt_dir=ckpt_dir, seed=seed)


def test_staleness_adaptor_schedule_in_trainer():
    tr = _trainer(mode="async", eps_s=3)
    modes = [tr.train_epoch().mode for _ in range(7)]
    assert modes == ["sync", "async", "async", "sync", "async", "async",
                     "sync"]


def test_trainer_convergence_and_metrics():
    tr = _trainer(mode="sync")
    hist = tr.fit(30)
    assert hist[-1].loss < hist[0].loss
    assert tr.evaluate("test") > 0.85
    assert hist[0].comm_payload_mb > 0
    # 1-bit comm is ~32x below vanilla
    tr32 = _trainer(mode="vanilla")
    assert tr32.comm_bytes_per_epoch()[0] / tr.comm_bytes_per_epoch()[0] == 32


def test_checkpoint_bitexact_resume(tmp_path):
    tr = _trainer(mode="async", ckpt_dir=str(tmp_path))
    for _ in range(5):
        tr.train_epoch()
    tr.save()
    losses_ref = [tr.train_epoch().loss for _ in range(3)]

    tr2 = _trainer(mode="async", ckpt_dir=str(tmp_path))
    assert tr2.resume()
    assert tr2.epoch == 5
    losses_resumed = [tr2.train_epoch().loss for _ in range(3)]
    np.testing.assert_allclose(losses_ref, losses_resumed, rtol=1e-6)


def test_checkpoint_atomic_and_keep_k(tmp_path):
    tr = _trainer(ckpt_dir=str(tmp_path))
    for e in range(6):
        tr.train_epoch()
        tr.save()
    dirs = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert len(dirs) == 3                      # keep-k
    assert not any(p.name.startswith(".tmp") for p in tmp_path.iterdir())
    assert ckpt.latest_step(tmp_path) == 6


def test_elastic_repartition_resume(tmp_path):
    """Save at 4 partitions, resume at 2: weights carry over; halo caches are
    rebuilt by a forced synchronous epoch."""
    tr4 = _trainer(parts=4, mode="async", ckpt_dir=str(tmp_path))
    for _ in range(6):
        tr4.train_epoch()
    acc4 = tr4.evaluate("val")
    tr4.save()

    tr2 = _trainer(parts=2, mode="async", ckpt_dir=str(tmp_path))
    assert tr2.resume()
    assert tr2._needs_sync                      # halo shapes mismatched
    m = tr2.train_epoch()
    assert m.mode == "sync"                     # forced refresh epoch
    acc2 = tr2.evaluate("val")
    assert acc2 > acc4 - 0.1                    # knowledge survived the move
    m2 = tr2.train_epoch()
    assert m2.mode == "async"                   # pipeline resumes


def test_corrupt_checkpoint_falls_back(tmp_path):
    tr = _trainer(ckpt_dir=str(tmp_path))
    tr.train_epoch()
    tr.save()
    tr.train_epoch()
    tr.save()
    # corrupt the newest checkpoint's arrays, keep manifest
    import shutil
    newest = sorted(p for p in tmp_path.iterdir() if p.is_dir())[-1]
    shutil.rmtree(newest)
    tr2 = _trainer(ckpt_dir=str(tmp_path))
    assert tr2.resume()                         # falls back to the older one
    assert tr2.epoch == 1


# ---------------------------------------------------------------------------
def test_ef21_allreduce_converges_to_true_gradient():
    """Repeated EF21 rounds on a FIXED gradient drive the estimate to it."""
    g = {"w": jax.random.normal(KEY, (32, 16)),
         "b": jax.random.normal(jax.random.fold_in(KEY, 1), (16,))}
    state = compression.EFState.zeros_like(g)
    est = None
    for _ in range(60):
        est, state = compression.ef_allreduce(g, state, bits=1)
    for k in g:
        err = np.abs(np.asarray(est[k]) - np.asarray(g[k])).mean()
        scale = np.abs(np.asarray(g[k])).mean()
        assert err < 0.15 * scale, (k, err, scale)


def test_ef21_wire_bytes_32x():
    g = {"w": jnp.zeros((64, 64))}
    p1, _ = compression.ef_wire_bytes(g, 1)
    p32, _ = compression.ef_wire_bytes(g, 32)
    assert p32 / p1 == 32


def test_ef21_training_matches_uncompressed_quality():
    """GCN trained with EF21-compressed gradients reaches comparable loss."""
    from repro.models.gnn import blocks as B
    from repro.train.gnn_step import GNNTrainState, make_gnn_steps
    g, ew = _graph(seed=2)
    pg = partition.partition_graph(g, 2, edge_weight=ew)
    block = B.build_block(pg)
    model = GCN(d_in=16, d_hidden=32, d_out=g.n_classes, n_layers=2)
    o = opt.adam(1e-2)
    cfg = SylvieConfig(mode="sync", bits=1)
    ts, _, ev = make_gnn_steps(model, cfg, o)

    # manual loop with EF compression on top of the step's gradients
    st = GNNTrainState.create(model, o, KEY, block.plan, stacked_parts=2)
    x, y, m = jnp.asarray(pg.x), jnp.asarray(pg.y), jnp.asarray(pg.train_mask)
    ts = jax.jit(ts)
    for i in range(30):
        st, loss = ts(st, block, x, y, m, jax.random.fold_in(KEY, i))
    c, n = jax.jit(ev)(st.params, block, x, y, jnp.asarray(pg.test_mask), KEY)
    assert float(c) / float(n) > 0.8
