"""Dry-run machinery: cell building, HLO collective parser, roofline math.

Full production-mesh lowering is exercised by launch/dryrun.py (artifacts
under artifacts/dryrun/); here we validate the machinery at subprocess scale
so the suite stays minutes-fast.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.launch import hlo as hlolib

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_collective_parser_ring_factors():
    text = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[2,512]{1,0} %p), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = f32[64,32]{1,0} all-to-all(f32[64,32]{1,0} %z), replica_groups={{0,1}}, dimensions={0}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %w), source_target_pairs={{0,1}}
"""
    st = hlolib.collective_bytes(text, 8)
    ops = st.by_op
    # all-gather: result 16*512*2 = 16384 B over g=8 -> operand 2048; wire x7
    assert ops["all-gather"]["payload"] == 2048
    assert ops["all-gather"]["wire"] == 2048 * 7
    assert ops["all-reduce"]["payload"] == 4096
    assert ops["all-reduce"]["wire"] == pytest.approx(4096 * 2 * 3 / 4)
    assert ops["reduce-scatter"]["wire"] == pytest.approx(256 * 4 * 3 / 4)
    assert ops["all-to-all"]["wire"] == pytest.approx(64 * 32 * 4 * 0.5)
    assert ops["collective-permute"]["wire"] == 100
    assert st.count == 5


def test_collective_parser_async_pairs_counted_once():
    text = """
  %ars = f32[128]{0} all-reduce-start(f32[128]{0} %x), replica_groups={{0,1}}
  %ard = f32[128]{0} all-reduce-done(f32[128]{0} %ars)
"""
    st = hlolib.collective_bytes(text, 2)
    assert st.count == 1
    assert st.payload_bytes == 512


def test_roofline_terms_and_bottleneck():
    r = hlolib.Roofline(flops_per_device=197e12, hbm_bytes_per_device=819e9,
                        wire_bytes_per_device=25e9, n_devices=4,
                        model_flops_total=4 * 197e12 * 0.5)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.useful_flop_ratio == pytest.approx(0.5)


CELL_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys; sys.path.insert(0, {src!r})
import jax
from repro.launch import cells as C, hlo as hlolib
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((4, 4), ("data", "model"))
for arch, shape in {cells!r}:
    cell = C.build_cell(arch, shape, mesh)
    compiled = cell.lower().compile()
    roof, coll, mem = hlolib.analyze(compiled, cell.n_devices,
                                     cell.model_flops)
    assert roof.flops_per_device > 0, (arch, shape)
    print("OK", arch, shape, roof.bottleneck)
"""


@pytest.mark.slow
@pytest.mark.parametrize("cells", [
    [("granite-3-2b", "decode_32k"), ("gemma2-27b", "long_500k")],
    [("pna", "full_graph_sm"), ("schnet", "molecule")],
    [("dlrm-mlperf", "serve_p99"), ("dlrm-mlperf", "retrieval_cand")],
])
def test_cells_lower_and_compile_at_16dev(cells):
    prog = textwrap.dedent(CELL_PROG.format(src=SRC, cells=cells))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert r.stdout.count("OK") == len(cells)


def test_all_cells_enumerated():
    from repro.launch.cells import all_cells
    cells = all_cells()
    assert len(cells) == 40
    archs = {a for a, _ in cells}
    assert len(archs) == 10
