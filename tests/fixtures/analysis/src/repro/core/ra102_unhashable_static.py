# ruff: noqa
"""Planted RA102: jit static arg with an unhashable (list) default."""
from functools import partial

import jax


@partial(jax.jit, static_argnums=(1,))
def apply(x, widths=[64, 32]):    # RA102: static arg defaults to a list
    return x * len(widths)
