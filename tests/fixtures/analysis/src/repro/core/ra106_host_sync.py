# ruff: noqa
"""Planted RA106: host synchronization inside a traced module."""


def loss_scalar(loss):
    return loss.item()            # RA106: device sync in a hot path
