# ruff: noqa
"""Planted RA101: python control flow branching on a traced expression."""
import jax.numpy as jnp


def scale(h):
    if jnp.max(h) > 1.0:          # RA101: traced value in python `if`
        h = h / jnp.max(h)
    return h
