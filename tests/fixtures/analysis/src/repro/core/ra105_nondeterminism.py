# ruff: noqa
"""Planted RA105: wall-clock nondeterminism inside a traced module."""
import time


def noisy_scale(h):
    return h * (time.time() % 1.0)   # RA105: frozen at trace time
