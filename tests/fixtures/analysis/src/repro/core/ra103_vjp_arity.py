# ruff: noqa
"""Planted RA103: custom_vjp bwd returns the wrong cotangent arity.

``halo(x, y, plan, bits)`` with nondiff (2, 3) has two differentiable
primals, so bwd must return a 2-tuple; it returns 3.
"""
from functools import partial

import jax


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def halo(x, y, plan, bits):
    return x + y


def halo_fwd(x, y, plan, bits):
    return x + y, (x, y)


def halo_bwd(plan, bits, res, g):
    x, y = res
    return (g, g, None)           # RA103: 3-tuple, needs 2 cotangents


halo.defvjp(halo_fwd, halo_bwd)
