# ruff: noqa
"""Planted RA107: unused import."""
import os


def double(x):
    return 2 * x
