# ruff: noqa
"""Planted RA104: JAX device work at import time."""
import jax.numpy as jnp

IDENTITY = jnp.eye(4)             # RA104: allocates on import


def apply(x):
    return IDENTITY @ x
