"""Planted RA108: a raw wall-clock read inside an obs-instrumented module.

serve/ threads every timestamp through ``repro.obs.clock()`` (or an injected
clock) so FakeClock tests and span traces share one time source; a direct
``time.perf_counter()`` forks the timeline. Exactly one offending call —
``time.sleep`` below stays legal (it waits, it doesn't measure).
"""
import time


def measure_step(server):
    time.sleep(0.0)
    t0 = time.perf_counter()          # RA108: bypasses the injected clock
    server.step()
    return t0
