"""Runtime facade + pluggable communicator backends.

Fast tests cover the backend protocol on the single real CPU device (imports,
constructors, simulated exchange semantics); the `slow` parity test forks a
subprocess with 4 forced host devices (jax locks device count at first init)
and checks that sync and async training produce identical losses and params
under SimulatedBackend vs ShardMapBackend — end-to-end through `repro.api`.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_trainer_and_cells_import_cleanly():
    """The production shard_map path must exist: no ModuleNotFoundError on
    `repro.dist` from any layer that consumes it."""
    import repro.api  # noqa: F401
    import repro.dist.api  # noqa: F401
    import repro.launch.cells  # noqa: F401
    import repro.train.trainer  # noqa: F401


def test_runtime_constructors_and_introspection():
    from repro.dist import Runtime, ShardMapBackend, SimulatedBackend

    rt = Runtime.simulated(4)
    assert isinstance(rt.backend, SimulatedBackend)
    assert not rt.is_sharded and rt.mesh is None and rt.n_parts == 4

    rt_any = Runtime.simulated()
    assert rt_any.n_parts is None

    rt_sm = Runtime.sharded()          # 1-D mesh over the host's devices
    assert rt_sm.is_sharded and isinstance(rt_sm.backend, ShardMapBackend)
    assert rt_sm.n_parts == len(jax.devices())


def test_backends_are_hashable_jit_keys():
    """Backends ride through custom_vjp nondiff argnums: hash + eq required."""
    from repro.dist import Runtime, ShardMapBackend, SimulatedBackend

    assert SimulatedBackend() == SimulatedBackend()
    assert hash(SimulatedBackend(4)) == hash(SimulatedBackend(4))
    b = ShardMapBackend(axes=("parts",))
    assert b == ShardMapBackend(axes=("parts",)) and hash(b) == hash(b)
    assert b != ShardMapBackend(axes=("data", "model"))
    assert hash(Runtime.simulated(2)) == hash(Runtime.simulated(2))


def test_simulated_backend_reference_semantics():
    from repro.dist import SimulatedBackend

    be = SimulatedBackend()
    p, h, d = 4, 3, 5
    x = jax.random.normal(jax.random.PRNGKey(0), (p, p * h, d))
    y = be.exchange(x)
    for pi in range(p):
        for qi in range(p):
            np.testing.assert_allclose(
                np.asarray(y[pi, qi * h:(qi + 1) * h]),
                np.asarray(x[qi, pi * h:(pi + 1) * h]))
    np.testing.assert_allclose(np.asarray(be.exchange(y)), np.asarray(x))
    assert be.axis_index() is None
    np.testing.assert_allclose(np.asarray(be.psum(x)), np.asarray(x))
    assert be.device_put({"a": x})["a"] is x


def test_as_backend_normalizes_legacy_designators():
    from repro.core.exchange import exchange
    from repro.dist import ShardMapBackend, SimulatedBackend, as_backend

    assert isinstance(as_backend(None), SimulatedBackend)
    assert as_backend("parts") == ShardMapBackend(axes=("parts",))
    assert as_backend(("a", "b")) == ShardMapBackend(axes=("a", "b"))
    be = SimulatedBackend()
    assert as_backend(be) is be
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 3))
    np.testing.assert_allclose(np.asarray(exchange(x, None)),
                               np.asarray(exchange(x, be)))


def test_meshless_backend_rejects_host_side_ops():
    from repro.dist import ShardMapBackend

    with pytest.raises(ValueError):
        ShardMapBackend()
    be = ShardMapBackend(axes=("parts",))
    with pytest.raises(ValueError):
        be.shard(lambda s: s)
    with pytest.raises(ValueError):
        be.device_put({"a": jnp.zeros(3)})


def test_trainer_rejects_partition_count_mismatch():
    import repro.api as repro
    from repro.graph import synthetic
    from repro.models.gnn.models import GCN

    g = synthetic.planted_partition(n_nodes=120, d_feat=8)
    pg = repro.partition(g, n_parts=2)
    model = GCN(d_in=8, d_hidden=16, d_out=g.n_classes, n_layers=2)
    with pytest.raises(ValueError, match="partition"):
        repro.train(model, pg, mode="sync", bits=1,
                    runtime=repro.Runtime.simulated(4))


PARITY = """
import repro.api as repro
from repro.graph import synthetic
from repro.models.gnn.models import GCN
from repro.train import optimizer as opt

g = synthetic.planted_partition(n_nodes=400, d_feat=16)
model = GCN(d_in=16, d_hidden=32, d_out=g.n_classes, n_layers=2)
rt_sim = repro.Runtime.simulated(4)
rt_sm = repro.Runtime.from_mesh(repro.make_gnn_mesh(4))
pg = repro.partition(g, runtime=rt_sim)


def run(runtime, mode, epochs):
    cfg = repro.SylvieConfig(mode=mode, bits=1, stochastic=False)
    return repro.train(model, pg, cfg, runtime=runtime, opt=opt.sgd(1e-1),
                       epochs=epochs)


for mode, epochs in (("sync", 3), ("async", 4)):
    a = run(rt_sim, mode, epochs)
    b = run(rt_sm, mode, epochs)
    np.testing.assert_allclose([m.loss for m in a.history],
                               [m.loss for m in b.history], rtol=1e-5)
    for pa, pb in zip(jax.tree.leaves(a.state.params),
                      jax.tree.leaves(jax.device_get(b.state.params))):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=1e-4, atol=1e-6)
    assert abs(a.evaluate("val") - b.evaluate("val")) < 1e-6, mode
print("OK")
"""


@pytest.mark.slow
def test_backend_parity_sync_and_async_on_host_devices():
    """Simulated vs shard_map: identical losses/params, both train modes."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, numpy as np
    """) + textwrap.dedent(PARITY)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
