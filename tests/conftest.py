import os
import sys

# Tests run on the single real CPU device; multi-device tests fork
# subprocesses that set --xla_force_host_platform_device_count themselves
# (see test_distributed.py). Do NOT set it here (per launch/dryrun.py docs).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
