"""Graph substrate: partitioner invariants (property-based), sampler, formats."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based partitioner tests need the 'hypothesis' dev extra")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.graph import formats, partition, sampling, synthetic


def _random_graph(n, e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    x = rng.normal(0, 1, (n, 8)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    m = np.ones(n, bool)
    return formats.Graph(n, np.stack([src, dst]).astype(np.int32), x, y,
                         m, m, m, n_classes=4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 120), e=st.integers(1, 500), p=st.integers(1, 8),
       seed=st.integers(0, 10),
       method=st.sampled_from(["block", "random", "skewed"]),
       layout=st.sampled_from(["dense", "compact"]))
def test_partition_invariants(n, e, p, seed, method, layout):
    """Every node appears exactly once; every edge lands in its dst
    partition with the correct (possibly halo) source slot — in both the
    dense pairwise and the compact ring-bucket plan layout."""
    g = _random_graph(n, e, seed)
    pg = partition.partition_graph(g, p, method=method, seed=seed,
                                   layout=layout)
    plan = pg.plan

    ids = pg.global_ids[pg.node_mask]
    assert sorted(ids.tolist()) == list(range(n))            # exact cover
    assert pg.edge_mask.sum() == e                           # all edges kept

    # halo slots: send_idx refers to real local nodes of the sender
    flat_send_mask = plan.send_mask.reshape(p, -1)
    flat_send_idx = plan.send_idx.reshape(p, -1)
    for q in range(p):
        idxs = flat_send_idx[q][flat_send_mask[q]]
        assert (idxs < pg.node_mask[q].sum()).all()

    if layout == "compact":
        assert plan.bucket_sizes[0] == 0        # diagonal never on the wire
        assert plan.halo_rows == plan.bucket_sizes.sum()
        assert plan.real_rows() == plan.send_mask.sum()
        bstart = np.zeros(p + 1, dtype=np.int64)
        np.cumsum(plan.bucket_sizes, out=bstart[1:])

    # reconstruct each edge's endpoints via the extended table and compare
    # with the original edge set (as multisets)
    n_local, h_pad = plan.n_local, plan.h_pad
    recon = []
    for pi in range(p):
        for k in range(pg.edge_mask.shape[1]):
            if not pg.edge_mask[pi, k]:
                continue
            s_ext, d_loc = pg.edges[pi, k]
            dst_gid = pg.global_ids[pi, d_loc]
            if s_ext < n_local:
                src_gid = pg.global_ids[pi, s_ext]
            elif layout == "dense":
                slot = s_ext - n_local
                q, s = slot // h_pad, slot % h_pad
                src_gid = pg.global_ids[q, plan.send_idx.reshape(p, p, -1)[q, pi, s]]
            else:
                pos = s_ext - n_local
                kk = int(np.searchsorted(bstart, pos, side="right")) - 1
                q = (pi - kk) % p                # ring: bucket kk came from pi-kk
                assert flat_send_mask[q, pos]
                src_gid = pg.global_ids[q, flat_send_idx[q, pos]]
            recon.append((int(src_gid), int(dst_gid)))
    orig = sorted(map(tuple, g.edge_index.T.tolist()))
    assert sorted(recon) == orig


def test_unpartition_roundtrip():
    g = synthetic.planted_partition(n_nodes=200, d_feat=12)
    pg = partition.partition_graph(g, 4)
    back = pg.unpartition(pg.x)
    np.testing.assert_allclose(back, g.x)


def test_pad_efficiency_reported():
    g = synthetic.powerlaw(n_nodes=500, avg_degree=8)
    pg = partition.partition_graph(g, 4)
    eff = pg.plan.pad_efficiency()
    assert 0.0 < eff <= 1.0


def test_gcn_edge_weights_symmetric_norm():
    g = synthetic.planted_partition(n_nodes=50, d_feat=4, seed=1)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    w = formats.gcn_edge_weights(ei, g.n_nodes)
    deg = np.bincount(ei[1], minlength=g.n_nodes).astype(np.float64)
    i = 5
    loops = (ei[0] == i) & (ei[1] == i)
    np.testing.assert_allclose(w[loops], 1.0 / deg[i], rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 20), batch=st.integers(4, 32))
def test_neighbor_sampler_subgraph_valid(seed, batch):
    g = synthetic.powerlaw(n_nodes=300, avg_degree=10, seed=seed)
    s = sampling.NeighborSampler(g, fanouts=(5, 3), seed=seed)
    sub = s.sample(batch_nodes=batch)
    assert sub.n_nodes <= sampling.SamplerShapes(batch, (5, 3)).max_nodes
    assert sub.edge_index.min() >= 0
    assert sub.edge_index.max() < sub.n_nodes
    assert sub.train_mask.sum() <= batch
    # every sampled edge must exist in the original graph
    orig = set(map(tuple, g.edge_index.T.tolist()))
    nodes = np.where(sub.train_mask)[0]
    assert len(nodes) > 0


def test_sampler_shapes_static():
    ss = sampling.SamplerShapes(1024, (15, 10))
    assert ss.max_nodes == 1024 + 1024 * 15 + 1024 * 150
    assert ss.max_edges == 1024 * 15 + 1024 * 150
