"""repro.serve: inference engine, k-hop delta refresh, request path.

Contracts under test:
  * ``khop_frontier`` == brute-force BFS on the global edge list (both plan
    layouts — the frontier is reconstructed from the plan's boundary
    structure, so this also validates ``halo_source_globals``);
  * serving parity: engine logits at 32 bits == a direct jit'd forward of the
    trained model, **bit-for-bit**, simulated and shard_map; quantized
    serving stays within the accuracy band the training-side parity tests
    use;
  * incremental refresh: a k-hop delta refresh == a full recompute
    **exactly** under deterministic rounding (same executable — structural
    guarantee), while shipping a fraction of the bytes; the staleness bound
    escalates to a forced full sweep;
  * train -> save -> serve: ``restore_for_inference`` round-trips params
    (manifest carries ``format_version``), refuses zero-fill;
  * server/loadgen: microbatching answers == direct engine lookups, the
    admission queue rejects past its depth, the seeded closed loop reports a
    full latency distribution.

The shard_map checks run inline when the session already has >= 4 devices
(the CI ``--serve`` lane) and in a `slow` subprocess otherwise.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sylvie import SylvieComm, SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn import blocks as B
from repro.models.gnn.models import GCN, GraphSAGE
from repro.serve import (EmbeddingServer, InferenceEngine, Rejection,
                         ServeConfig, closed_loop)
from repro.serve import delta as deltalib
from repro.train import checkpoint as ckpt
from repro.train.trainer import GNNTrainer

SRC = str(Path(__file__).resolve().parents[1] / "src")
KEY = jax.random.PRNGKey(0)


def _graph(n=300, d=16, seed=0):
    g = synthetic.planted_partition(n_nodes=n, d_feat=d, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _pg(parts=4, layout="compact", **kw):
    g, ew = _graph(**kw)
    return g, partition.partition_graph(g, parts, edge_weight=ew,
                                        layout=layout)


def _trained(pg, g, tmp_path, epochs=6, model=None):
    model = model or GCN(g.x.shape[1], 32, g.n_classes, n_layers=2)
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1),
                    ckpt_dir=str(tmp_path))
    tr.fit(epochs)
    tr.save()
    return model, tr


# ---------------------------------------------------------------------------
# khop_frontier vs brute-force BFS (satellite: graph/partition.py helper)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_khop_frontier_matches_bruteforce_bfs(layout):
    g, pg = _pg(layout=layout, n=250)
    seeds = np.array([3, 57, 101])
    k = 3
    fr = partition.khop_frontier(pg, seeds, k)
    assert fr.shape == (k + 1, g.n_nodes)
    src, dst = g.edge_index
    cur = np.zeros(g.n_nodes, bool)
    cur[seeds] = True
    for h in range(k + 1):
        np.testing.assert_array_equal(fr[h], cur, err_msg=f"hop {h}")
        nxt = cur.copy()
        for s, t in zip(src, dst):       # brute force, edge at a time
            if cur[s]:
                nxt[t] = True
        cur = nxt
    # monotone and eventually saturating on a connected-ish graph
    assert (fr.sum(axis=1) == np.maximum.accumulate(fr.sum(axis=1))).all()


def test_khop_frontier_validates_seeds():
    _, pg = _pg(n=100)
    with pytest.raises(ValueError):
        partition.khop_frontier(pg, [100], 1)
    fr = partition.khop_frontier(pg, [], 2)     # empty seed set is legal
    assert fr.sum() == 0


@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_global_edges_reconstruct_edge_set(layout):
    g, pg = _pg(layout=layout, n=200)
    src_g, dst_g = partition.global_edges(pg)
    got = set(zip(src_g.tolist(), dst_g.tolist()))
    want = set(zip(g.edge_index[0].tolist(), g.edge_index[1].tolist()))
    assert got == want


# ---------------------------------------------------------------------------
# serving parity (satellite: engine == direct forward, bit-for-bit)
# ---------------------------------------------------------------------------
def test_engine_fp32_bitexact_vs_direct_forward(tmp_path):
    g, pg = _pg()
    model, tr = _trained(pg, g, tmp_path)
    eng, meta = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                                config=ServeConfig(bits=32))
    assert meta["format_version"] == ckpt.FORMAT_VERSION
    eng.full_sweep()

    block, x = B.build_block(pg), jnp.asarray(pg.x)

    @jax.jit
    def direct(params, block, x, key):
        comm = SylvieComm(SylvieConfig(mode="vanilla", stochastic=False),
                          block.plan, key)
        return model.apply(params, block, x, comm)

    ref = np.asarray(direct(tr.state.params, block, x, KEY))
    np.testing.assert_array_equal(eng._logits_host, ref)
    # and the query path agrees with the unpartitioned table
    ids = np.array([0, 7, 123, g.n_nodes - 1])
    np.testing.assert_array_equal(eng.query(ids).logits, eng.logits[ids])


def test_engine_quantized_within_training_parity_band(tmp_path):
    """1-bit serving must hold the accuracy band the trainer's own quantized
    runs are held to (test_trainer: 1-bit training reaches > 0.85)."""
    g, pg = _pg()
    model, tr = _trained(pg, g, tmp_path, epochs=12)
    f32, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                             config=ServeConfig(bits=32))
    q1, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                            config=ServeConfig(bits=1))
    f32.full_sweep()
    q1.full_sweep()
    y = np.asarray(g.y)
    mask = np.asarray(g.test_mask)
    acc32 = (f32.logits.argmax(-1) == y)[mask].mean()
    acc1 = (q1.logits.argmax(-1) == y)[mask].mean()
    assert acc32 > 0.85
    assert acc1 >= acc32 - 0.02, (acc1, acc32)
    # 1-bit payload is 32x smaller; scale/zero error-compensation (2 bf16 per
    # row) caps the *total* wire ratio near 14x at this feature width
    assert f32.full_sweep_wire_bytes() > 10 * q1.full_sweep_wire_bytes()


def test_engine_per_site_bits_via_decision(tmp_path):
    """Per-site widths ride the same EpochDecision lattice training uses."""
    from repro.policy.base import EpochDecision, SiteDecision
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=2)
    dec = EpochDecision(sites=(SiteDecision(fwd_bits=1, bwd_bits=1,
                                            stochastic=False),
                               SiteDecision(fwd_bits=8, bwd_bits=8,
                                            stochastic=False)))
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                             decision=dec)
    rep = eng.full_sweep()
    d0, d1 = eng.site_dims
    rows = rep.affected_rows
    from repro.core.quantization import comm_bytes
    want = comm_bytes(rows[0], d0, 1)[0] + comm_bytes(rows[1], d1, 8)[0]
    assert rep.payload_bytes == want


# ---------------------------------------------------------------------------
# incremental refresh (satellite: delta == full recompute, staleness bound)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model_cls", [GCN, GraphSAGE])
@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_delta_refresh_equals_full_recompute(tmp_path, layout, model_cls):
    g, pg = _pg(layout=layout)
    model = model_cls(g.x.shape[1], 32, g.n_classes, n_layers=2)
    model, _ = _trained(pg, g, tmp_path, epochs=4, model=model)

    rng = np.random.default_rng(7)
    ids = rng.choice(g.n_nodes, size=6, replace=False)
    rows = rng.normal(0, 1, (6, g.x.shape[1])).astype(np.float32)

    a, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                           config=ServeConfig(bits=1))
    b, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                           config=ServeConfig(bits=1))
    a.full_sweep()
    b.full_sweep()
    da = a.refresh(ids, rows)                  # k-hop delta
    db = b.refresh(ids, rows, full=True)       # ground truth: full recompute
    assert da.kind == "delta" and db.kind == "full"
    np.testing.assert_array_equal(a._logits_host, b._logits_host)
    for la, lb in zip(a._layers, b._layers):   # every cached layer, exactly
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for ha, hb in zip(a._halos, b._halos):
        np.testing.assert_array_equal(np.asarray(ha), np.asarray(hb))
    # the delta shipped a strict subset of the rows + the bitmap metadata
    assert all(r1 < r2 for r1, r2 in zip(da.affected_rows, db.affected_rows))
    assert da.meta_bytes > 0 and db.meta_bytes == 0
    assert da.wire_bytes < db.wire_bytes


def test_delta_affected_rows_grow_with_hops(tmp_path):
    """Site i re-ships the i-hop frontier: monotone nondecreasing row counts
    across sites, and exact against a host-side recount."""
    g, pg = _pg()
    ids = np.array([11, 42])
    plan = deltalib.plan_refresh(pg, ids, n_sites=2)
    assert plan.affected_rows[0] <= plan.affected_rows[1]
    fr = partition.khop_frontier(pg, ids, 1)
    sg = deltalib._send_globals(pg)
    base = pg.plan.send_mask.reshape(pg.plan.n_parts, -1)
    for i in range(2):
        want = int((base & fr[i][np.clip(sg, 0, None)]).sum())
        assert plan.affected_rows[i] == want


def test_staleness_bound_forces_full_sweep(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=2)
    eng, _ = InferenceEngine.from_checkpoint(
        tmp_path, model, pg, config=ServeConfig(bits=1, max_staleness=2))
    eng.full_sweep()
    rng = np.random.default_rng(0)
    kinds = []
    for i in range(5):
        ids = rng.choice(g.n_nodes, 3, replace=False)
        rows = rng.normal(0, 1, (3, g.x.shape[1])).astype(np.float32)
        r = eng.refresh(ids, rows)
        kinds.append((r.kind, r.forced))
    # two deltas, then the bound escalates, then the clock restarts
    assert kinds == [("delta", False), ("delta", False), ("full", True),
                     ("delta", False), ("delta", False)]


def test_refresh_validates_ids_and_rows_before_mutating(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=1)
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg)
    eng.full_sweep()
    with pytest.raises(ValueError):
        eng.refresh([1, 2], np.zeros((2, 3), np.float32))
    # out-of-range (incl. negative — numpy would silently wrap) ids must be
    # rejected *before* any feature row is touched
    before = eng._x_host.copy()
    for bad in ([-2], [g.n_nodes]):
        with pytest.raises(ValueError):
            eng.refresh(np.array(bad),
                        np.zeros((1, g.x.shape[1]), np.float32))
    np.testing.assert_array_equal(eng._x_host, before)
    with pytest.raises(ValueError):
        eng.query([-1])
    # embeddings gather stays row-sized and correct
    emb = eng.embeddings([3, 5], site=0)
    assert emb.shape == (2, g.x.shape[1])


# ---------------------------------------------------------------------------
# train -> save -> serve handoff (satellite: checkpoint round trip)
# ---------------------------------------------------------------------------
def test_restore_for_inference_roundtrip_and_guards(tmp_path):
    g, pg = _pg()
    model, tr = _trained(pg, g, tmp_path, epochs=3)
    example = model.init(jax.random.PRNGKey(9))   # any key: structure only
    params, meta = ckpt.restore_for_inference(tmp_path, example)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, tr.state.params)
    assert meta["format_version"] == ckpt.FORMAT_VERSION
    assert meta["step"] == tr.epoch

    # wrong model structure -> loud failure, never zero-fill
    other = GCN(g.x.shape[1], 64, g.n_classes, n_layers=2)
    with pytest.raises(ValueError):
        ckpt.restore_for_inference(tmp_path, other.init(KEY))
    with pytest.raises(KeyError):
        ckpt.restore_for_inference(
            tmp_path, {"not_a_layer": np.zeros((2, 2), np.float32)})


def test_checkpoint_refuses_newer_format(tmp_path):
    import json
    g, pg = _pg()
    _trained(pg, g, tmp_path, epochs=1)
    man_path = next(Path(tmp_path).glob("step_*/manifest.json"))
    man = json.loads(man_path.read_text())
    assert man["format_version"] == ckpt.FORMAT_VERSION
    man["format_version"] = ckpt.FORMAT_VERSION + 1
    man_path.write_text(json.dumps(man))
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"x": np.zeros(1)})


def test_save_restore_serve_equivalence(tmp_path):
    """Serving restored params == serving the in-memory trained params."""
    g, pg = _pg()
    model, tr = _trained(pg, g, tmp_path, epochs=4)
    from_ckpt, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg)
    in_mem = InferenceEngine(model, pg,
                             jax.tree.map(np.asarray, tr.state.params))
    from_ckpt.full_sweep()
    in_mem.full_sweep()
    np.testing.assert_array_equal(from_ckpt._logits_host, in_mem._logits_host)


# ---------------------------------------------------------------------------
# request path: microbatching server + closed-loop load generator
# ---------------------------------------------------------------------------
def test_server_microbatching_matches_engine(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=2)
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg)
    eng.full_sweep()
    srv = EmbeddingServer(eng, microbatch=8, max_queue=16)
    reqs = [np.array([1, 2, 3]), np.array([4]), np.array([5, 6, 7, 8]),
            np.array([9, 10])]
    rids = [srv.submit(r) for r in reqs]
    assert rids == [0, 1, 2, 3]
    # first step packs requests 0+1+2 (3+1+4=8 ids); request 3 waits
    out = srv.step()
    assert [r.req_id for r in out] == [0, 1, 2]
    out += srv.step()
    assert [r.req_id for r in out] == [0, 1, 2, 3] and srv.depth == 0
    for r, ids in zip(out, reqs):
        np.testing.assert_array_equal(r.logits, eng.query(ids).logits)
        assert r.latency_s >= 0

    with pytest.raises(ValueError):
        srv.submit(np.arange(9))          # oversize request
    with pytest.raises(ValueError):
        srv.submit([])


def test_server_admission_queue_rejects(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=1)
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg)
    eng.full_sweep()
    srv = EmbeddingServer(eng, microbatch=4, max_queue=2)
    assert srv.submit([1]) == 0
    assert srv.submit([2]) == 1
    r = srv.submit([3])                   # admission control: typed rejection
    assert isinstance(r, Rejection)
    assert r.reason == "queue_full" and r.depth == 2
    assert r.retry_after_hint >= 0.0
    assert srv.rejected == 1
    assert len(srv.drain()) == 2
    assert srv.submit([3]) == 2           # capacity freed


def test_closed_loop_report_and_determinism(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=2)
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                             config=ServeConfig(bits=1))
    eng.full_sweep()
    rep = closed_loop(EmbeddingServer(eng), g.n_nodes, clients=4, batch=8,
                      requests=40, seed=3, refresh_every=15, refresh_nodes=4)
    assert rep["requests"] == 40
    assert rep["qps"] > 0 and rep["p99_ms"] >= rep["p50_ms"] >= 0
    assert rep["refreshes"] == 2 and rep["refresh_wire_bytes"] > 0
    # the workload (not the wall clock) is seeded: byte-identical id streams
    assert np.random.default_rng(3).integers(0, g.n_nodes, 8).tolist() \
        == np.random.default_rng(3).integers(0, g.n_nodes, 8).tolist()


def test_query_before_sweep_raises(tmp_path):
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=1)
    eng, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg)
    with pytest.raises(RuntimeError):
        eng.query([0])


def test_refresh_before_sweep_escalates_to_full(tmp_path):
    """A delta against zero-initialized caches would serve garbage; the first
    refresh must run the full sweep instead."""
    g, pg = _pg()
    model, _ = _trained(pg, g, tmp_path, epochs=2)
    a, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                           config=ServeConfig(bits=1))
    b, _ = InferenceEngine.from_checkpoint(tmp_path, model, pg,
                                           config=ServeConfig(bits=1))
    rng = np.random.default_rng(2)
    ids = rng.choice(g.n_nodes, 4, replace=False)
    rows = rng.normal(0, 1, (4, g.x.shape[1])).astype(np.float32)
    rep = a.refresh(ids, rows)               # no sweep ran yet
    assert rep.kind == "full" and rep.forced
    b.full_sweep()
    b.refresh(ids, rows)
    np.testing.assert_array_equal(a._logits_host, b._logits_host)


# ---------------------------------------------------------------------------
# shard_map parity (inline on >= 4 devices — the CI --serve lane — plus a
# slow subprocess fallback)
# ---------------------------------------------------------------------------
SHARDMAP_SERVE = """
import numpy as np, tempfile
import repro.api as repro
from repro.graph import synthetic
from repro.models.gnn.models import GCN
from repro.core.sylvie import SylvieConfig
from repro.train.trainer import GNNTrainer
from repro.serve import InferenceEngine, ServeConfig

g = synthetic.planted_partition(n_nodes=300, d_feat=16, seed=0)
pg = repro.partition(g, n_parts=4)
model = GCN(16, 32, g.n_classes, n_layers=2)
rt = repro.Runtime.from_mesh(repro.make_gnn_mesh(4))
with tempfile.TemporaryDirectory() as td:
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1), ckpt_dir=td)
    tr.fit(4); tr.save()
    rng = np.random.default_rng(0)
    ids = rng.choice(g.n_nodes, 5, replace=False)
    rows = rng.normal(0, 1, (5, 16)).astype(np.float32)
    for bits in (32, 1):
        sim, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=bits))
        shd, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=bits), runtime=rt)
        sim.full_sweep(); shd.full_sweep()
        assert np.array_equal(sim._logits_host, shd._logits_host), bits
        ra, rb = sim.refresh(ids, rows), shd.refresh(ids, rows)
        assert ra.kind == rb.kind == "delta"
        assert ra.wire_bytes == rb.wire_bytes
        assert np.array_equal(sim._logits_host, shd._logits_host), bits
print("OK")
"""


def test_serve_shardmap_parity_inline():
    """Runs when the session already has >= 4 devices (CI --serve lane)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    exec(textwrap.dedent(SHARDMAP_SERVE), {})


@pytest.mark.slow
def test_serve_shardmap_parity_subprocess():
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
    """) + textwrap.dedent(SHARDMAP_SERVE)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
