"""repro.obs: span tracer, metrics registry, exporters, CLI, instrumentation.

Four layers of evidence:

* the tracer itself — disabled calls return the shared null span (no
  allocation, no clock read), enabled spans nest/thread/sort, FakeClock makes
  every timestamp deterministic;
* the metrics registry — typed instruments, in-place reset, and the TraceLog
  shim keeping full list semantics while counting ``retrace.<scope>``;
* the exporters — Perfetto trace JSON and metrics JSON round-trip, the
  modeled-vs-measured join produces the drift number, the CLI renders all
  three subcommands and exit-codes its failures;
* the instrumented layers — the trainer emits ``epoch > decide > step``
  spans and per-epoch ``wall_s``, the server emits request-path spans and
  rejection counters, the store counts hits/miss-bytes, and ``open_loop``
  under a FakeClock is fully deterministic (identical reports, no wall
  waits).
"""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import export as ox

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
CLI_ENV = {**os.environ,
           "PYTHONPATH": os.path.join(ROOT, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends untraced with zeroed metrics."""
    obs.disable()
    obs.reset_metrics()
    yield
    obs.disable()
    obs.reset_metrics()


# ---------------------------------------------------------------------------
# spans: null path, nesting, FakeClock, threads
# ---------------------------------------------------------------------------
def test_disabled_tracer_is_allocation_free():
    assert not obs.enabled() and obs.current() is None
    # the hot-path contract: one shared singleton, never a fresh object
    assert obs.span("epoch") is obs.NULL_SPAN
    assert obs.span("epoch", {"k": 1}) is obs.NULL_SPAN
    obs.event("halo.issue", {"bits": 1})        # no-op, no error
    assert obs.drain() == []


def test_fake_clock_semantics():
    c = obs.FakeClock(start=10.0, tick=0.5)
    assert c() == 10.0 and c() == 10.5          # tick auto-advances per read
    c.sleep(2.0)
    assert c() == 13.0
    c.sleep(-1.0)                               # negative sleep never rewinds
    assert c() == 13.5
    c.advance(0.25)
    assert c() == 14.25


def test_spans_nest_and_events_interleave():
    obs.enable(obs.FakeClock(tick=1.0))
    with obs.span("epoch", {"epoch": 0}):       # enter @0
        with obs.span("step"):                  # enter @1, exit @2
            pass
        obs.event("retrace", {"scope": "train"})  # @3
    ev = obs.drain()                            # epoch exit @4
    assert [(e["name"], e["ph"]) for e in ev] == \
        [("epoch", "X"), ("step", "X"), ("retrace", "i")]
    epoch, step, mark = ev
    assert epoch["ts"] == 0.0 and epoch["dur"] == 4.0
    assert step["ts"] == 1.0 and step["dur"] == 1.0
    assert mark["ts"] == 3.0
    assert epoch["args"] == {"epoch": 0} and "args" not in step
    assert obs.drain() == []                    # drain clears the buffers


def test_span_records_even_when_body_raises():
    obs.enable(obs.FakeClock(tick=1.0))
    with pytest.raises(RuntimeError):
        with obs.span("step"):
            raise RuntimeError("boom")
    ev = obs.drain()
    assert [e["name"] for e in ev] == ["step"]  # recorded, not swallowed


def test_thread_buffers_merge_time_sorted():
    clock = obs.FakeClock(tick=0.125)
    obs.enable(clock)

    barrier = threading.Barrier(3)              # all alive at once, so thread
                                                # idents cannot be reused
    def emit(tag):
        barrier.wait()
        for i in range(5):
            obs.event(tag, {"i": i})
        barrier.wait()

    threads = [threading.Thread(target=emit, args=(f"t{k}",))
               for k in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    obs.event("main")
    ev = obs.drain()
    assert len(ev) == 16
    assert [e["ts"] for e in ev] == sorted(e["ts"] for e in ev)
    assert len({e["tid"] for e in ev}) == 4     # one buffer per thread


# ---------------------------------------------------------------------------
# metrics registry + TraceLog shim
# ---------------------------------------------------------------------------
def test_registry_instruments_and_reset_in_place():
    obs.count("faults.injected", 3)
    obs.count("faults.injected")
    obs.gauge("queue.depth").set(7)
    obs.observe("step.seconds", 2.0)
    obs.observe("step.seconds", 4.0)
    snap = obs.snapshot()
    assert snap["counters"]["faults.injected"] == 4
    assert snap["gauges"]["queue.depth"] == 7
    h = snap["histograms"]["step.seconds"]
    assert h == {"count": 2, "sum": 6.0, "min": 2.0, "max": 4.0, "mean": 3.0}
    obs.reset_metrics()
    snap = obs.snapshot()
    # names survive a reset with zeroed values: a zero is evidence the seam
    # ran and saw nothing, absence is not
    assert snap["counters"]["faults.injected"] == 0
    assert snap["histograms"]["step.seconds"]["count"] == 0


def test_tracelog_keeps_list_semantics_and_counts_retraces():
    log = obs.TraceLog("train")
    assert log == [] and len(log) == 0
    log.append("sync")
    log.append("async")
    assert list(log) == ["sync", "async"] and log[-1] == "async"
    assert obs.snapshot()["counters"]["retrace.train"] == 2
    log.clear()
    assert len(log) == 0                        # clear() is plain list.clear
    assert obs.snapshot()["counters"]["retrace.train"] == 2
    obs.enable(obs.FakeClock())
    log.append("sync")
    ev = obs.drain()
    assert [e["name"] for e in ev] == ["retrace"]
    assert ev[0]["args"] == {"scope": "train", "tag": "sync"}


def test_production_trace_logs_are_shims():
    from repro.serve import engine as englib
    from repro.train import gnn_step
    assert isinstance(gnn_step.TRACE_LOG, obs.TraceLog)
    assert isinstance(englib.TRACE_LOG, obs.TraceLog)
    assert isinstance(gnn_step.TRACE_LOG, list)   # contracts count via len()


# ---------------------------------------------------------------------------
# exporters: trace JSON, metrics JSON, renderers
# ---------------------------------------------------------------------------
def _sample_events():
    obs.enable(obs.FakeClock(tick=0.001))
    with obs.span("epoch", {"epoch": 0}):
        with obs.span("step"):
            pass
        obs.event("halo.issue", {"bits": 1})
    return obs.drain()


def test_trace_roundtrip_is_perfetto_shaped(tmp_path):
    path = ox.write_trace(tmp_path / "deep" / "run.trace.json",
                          _sample_events())
    body = json.loads(path.read_text())
    assert body["displayTimeUnit"] == "ms"
    events = body["traceEvents"]
    assert {e["ph"] for e in events} == {"X", "i"}
    for e in events:                # trace_event wants integer microseconds
        assert isinstance(e["ts"], int) and e["ts"] >= 0
        assert "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert isinstance(e["dur"], int) and e["dur"] >= 0
    assert ox.load_trace(path) == events
    art = ox.render_timeline(path, width=32)
    assert "epoch" in art and "halo.issue" in art
    art = ox.render_timeline(path, width=32, limit=1)
    assert "more (raise --limit)" in art


def test_modeled_vs_measured_join():
    mm = ox.modeled_vs_measured([2.0, 4.0], exposed_s=0.5, overlapped_s=0.25)
    assert mm["n_epochs"] == 2 and mm["mean_wall_s"] == 3.0
    assert mm["drift_s"] == 2.5                 # mean wall - modeled exposed
    assert [r["drift_s"] for r in mm["epochs"]] == [1.5, 3.5]
    empty = ox.modeled_vs_measured([], 0.5, 0.0)
    assert empty["n_epochs"] == 0 and empty["drift_s"] == -0.5


def test_metrics_roundtrip_summary_and_diff(tmp_path):
    obs.count("retrace.train", 3)
    obs.count("store.hits", 10)
    mm = ox.modeled_vs_measured([1.0], 0.25, 0.0)
    a = ox.write_metrics(tmp_path / "a.metrics.json", metrics=obs.snapshot(),
                         run="smoke/cell_a", merge=mm)
    obs.count("retrace.train", 2)
    b = ox.write_metrics(tmp_path / "b.metrics.json", metrics=obs.snapshot(),
                         run="smoke/cell_b", merge=mm)
    assert ox.load_metrics(a)["run"] == "smoke/cell_a"
    assert ox.metrics_files(tmp_path) == [a, b]
    summary = ox.render_summary(tmp_path)
    assert "smoke/cell_a" in summary and "smoke/cell_b" in summary
    assert "drift" in summary
    diff = ox.render_diff(a, b)
    assert "retrace.train" in diff and "+2" in diff
    # schema and emptiness are hard errors, not silent garbage
    (tmp_path / "junk.metrics.json").write_text('{"schema": "nope"}')
    with pytest.raises(ValueError):
        ox.load_metrics(tmp_path / "junk.metrics.json")
    with pytest.raises(FileNotFoundError):
        ox.render_summary(tmp_path / "empty")


# ---------------------------------------------------------------------------
# CLI: subcommands + exit codes
# ---------------------------------------------------------------------------
def _cli(*args):
    return subprocess.run([sys.executable, "-m", "repro.obs", *args],
                          capture_output=True, text=True, env=CLI_ENV,
                          cwd=ROOT, timeout=120)


def test_cli_summarize_timeline_diff(tmp_path):
    trace = ox.write_trace(tmp_path / "cell.trace.json", _sample_events())
    ox.write_metrics(tmp_path / "cell.metrics.json", metrics=obs.snapshot(),
                     run="smoke/cell",
                     merge=ox.modeled_vs_measured([1.0], 0.25, 0.0),
                     trace_path=str(trace))
    r = _cli("summarize", str(tmp_path))
    assert r.returncode == 0 and "smoke/cell" in r.stdout
    r = _cli("timeline", str(trace), "--width", "24")
    assert r.returncode == 0 and "epoch" in r.stdout
    r = _cli("diff", str(tmp_path / "cell.metrics.json"),
             str(tmp_path / "cell.metrics.json"))
    assert r.returncode == 0 and "retrace" in r.stdout


def test_cli_exit_codes_on_bad_input(tmp_path):
    r = _cli("summarize", str(tmp_path / "nowhere"))
    assert r.returncode == 2 and "error:" in r.stderr
    bad = tmp_path / "bad.trace.json"
    bad.write_text("{}")
    r = _cli("timeline", str(bad))
    assert r.returncode == 2 and "error:" in r.stderr


# ---------------------------------------------------------------------------
# instrumented layers: trainer, server, store, loadgen
# ---------------------------------------------------------------------------
def _tiny_trainer(epochs=2):
    from repro.core.sylvie import SylvieConfig
    from repro.graph import formats, partition, synthetic
    from repro.models.gnn.models import GCN
    from repro.train.trainer import GNNTrainer

    g0 = synthetic.planted_partition(n_nodes=120, d_feat=8, seed=0)
    ei = formats.add_self_loops(g0.edge_index, g0.n_nodes)
    ew = formats.gcn_edge_weights(ei, g0.n_nodes)
    g = formats.Graph(g0.n_nodes, ei, g0.x, g0.y, g0.train_mask, g0.val_mask,
                      g0.test_mask, n_classes=g0.n_classes)
    pg = partition.partition_graph(g, 4, edge_weight=ew, layout="compact")
    model = GCN(g.x.shape[1], 16, g.n_classes, n_layers=2)
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1))
    tr.fit(epochs)
    return g, tr


def test_trainer_emits_epoch_spans_and_wall_s():
    obs.enable(obs.FakeClock(tick=0.01))
    _, tr = _tiny_trainer(epochs=2)
    ev = obs.drain()
    spans = [e["name"] for e in ev if e["ph"] == "X"]
    assert spans.count("epoch") == 2
    assert spans.count("decide") == 2 and spans.count("step") == 2
    steps = [e for e in ev if e["name"] == "step"]
    assert steps[0]["args"]["mode"] in ("sync", "async")
    # wall_s is the whole-epoch clock (decide + step + host bookkeeping),
    # measured on the same deterministic clock as the spans
    for m in tr.history:
        assert m.wall_s > 0.0
    # wall_s brackets the epoch span (it opens one clock read earlier and
    # closes one later — 2 ticks of skew on the FakeClock)
    epochs = [e for e in ev if e["name"] == "epoch"]
    assert epochs[0]["dur"] <= tr.history[0].wall_s \
        <= epochs[0]["dur"] + 0.03


def test_trainer_wall_s_populated_untraced():
    _, tr = _tiny_trainer(epochs=1)
    assert tr.history[0].wall_s > 0.0           # obs.clock works untraced
    assert tr.history[0].wall_s >= tr.history[0].seconds


def _tiny_server(microbatch=8, max_queue=2, clock=None):
    from repro.serve import EmbeddingServer, InferenceEngine, ServeConfig

    g, tr = _tiny_trainer(epochs=1)
    eng = InferenceEngine(tr.model, tr.pg, tr.state.params,
                          config=ServeConfig(bits=1))
    eng.full_sweep()
    return g, EmbeddingServer(eng, microbatch=microbatch, max_queue=max_queue,
                              clock=clock)


def test_server_spans_and_rejection_counters():
    from repro.serve import Rejection

    g, srv = _tiny_server(max_queue=1)
    obs.enable(obs.FakeClock(tick=0.001))
    assert isinstance(srv.submit([1, 2]), int)
    rej = srv.submit([3])
    assert isinstance(rej, Rejection) and rej.reason == "queue_full"
    srv.step()
    ev = obs.drain()
    names = [e["name"] for e in ev if e["ph"] == "X"]
    assert names.count("admit") == 2            # accepted AND rejected submits
    assert "request" in names and "lookup" in names
    req = next(e for e in ev if e["name"] == "request")
    assert req["args"] == {"requests": 1, "nodes": 2}
    assert obs.snapshot()["counters"]["serve.rejected.queue_full"] == 1
    srv.start_draining()
    srv.submit([4])
    assert obs.snapshot()["counters"]["serve.rejected.draining"] == 1


def test_store_counts_hits_and_miss_bytes():
    from repro.store.backend import ShardedEmbeddingStore

    store = ShardedEmbeddingStore(cache_bytes=1 << 16)
    store.create_table("t", part_rows=(8,), d=4)
    rows = np.arange(32, dtype=np.float32).reshape(8, 4)
    store.put_rows("t", 0, np.arange(8), rows)
    store.get_rows("t", 0, np.array([0, 1]))    # cold: 2 misses
    store.get_rows("t", 0, np.array([0, 1]))    # warm: 2 hits
    c = obs.snapshot()["counters"]
    assert c["store.hits"] == 2
    assert c["store.miss_bytes"] == 2 * 4 * 4   # 2 rows x 4 feats x fp32


def test_open_loop_fake_clock_is_deterministic():
    """Satellite (a): open_loop on an injected FakeClock — the idle waits
    advance fake time (no wall sleeps), and two runs over the same seed
    produce *identical* reports, latencies included."""
    from repro.serve.loadgen import open_loop

    g, srv1 = _tiny_server(microbatch=8, max_queue=64)
    srv2 = type(srv1)(srv1.engine, microbatch=8, max_queue=64)

    def run(srv):
        return open_loop(srv, g.n_nodes, qps=500.0, requests=24, batch=2,
                         seed=7, clock=obs.FakeClock(tick=1e-5))

    rep1, rep2 = run(srv1), run(srv2)
    assert rep1 == rep2                         # bit-identical, floats and all
    assert rep1["completed"] == 24 and rep1["lost"] == 0
    assert rep1["seconds"] > 0.0
    # the run's duration is fake-clock time: it covers the Poisson schedule's
    # horizon even though no wall-clock waiting happened
    arrivals = np.cumsum(np.random.default_rng(7).exponential(1 / 500.0,
                                                              size=24))
    assert rep1["seconds"] >= arrivals[-1] - 1e-3


def test_server_inherits_fake_clock_from_obs(tmp_path):
    """server.clock defaults to obs.clock: arming a FakeClock tracer makes
    the whole request path deterministic with no constructor plumbing."""
    g, srv = _tiny_server()
    obs.enable(obs.FakeClock(start=100.0, tick=0.5))
    srv.submit([1])
    [resp] = srv.step()
    obs.disable()
    assert resp.latency_s > 0.0
    assert resp.latency_s == pytest.approx(round(resp.latency_s / 0.5) * 0.5)
