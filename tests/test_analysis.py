"""repro.analysis: planted-violation fixtures, clean-repo runs, CLI gating.

Three layers of evidence that the analysis pass *can* catch what it claims:

* every AST lint rule fires on its planted fixture
  (``tests/fixtures/analysis/``) and the real repo is clean;
* every jaxpr contract check fires on a fabricated or monkeypatched
  violation (fp32 leak on a quantized exchange, a second psum, un-inverted
  backward rings, an all_gather, a host callback, a busted quantize payload,
  a retracing serve sweep) and the contract suite is clean on the repo;
* the ``python -m repro.analysis`` CLI exits non-zero on a fixture and zero
  once the finding is baselined.

shard_map contracts need 4 devices and are exercised by ``tools/ci.sh
--analysis`` (which forces 4 host devices); here they report as skipped.
"""
import collections
import os
import subprocess
import sys

import jax
import pytest

from repro.analysis import contracts
from repro.analysis.jaxpr_checks import (CollectiveOp, ExchangeExpectation,
                                         JaxprSummary, check_exchange_census,
                                         check_no_callbacks,
                                         check_no_collectives,
                                         check_wire_dtypes, cyclic_shift,
                                         expected_shift_census, summarize)
from repro.analysis.lint import run_lint
from repro.analysis.report import (Finding, load_baseline,
                                   split_by_baseline, stale_baseline_entries,
                                   write_report)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "analysis")
CLI_ENV = {**os.environ,
           "PYTHONPATH": os.path.join(ROOT, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}


def _fixture(code: str) -> str:
    # RA101-107 fire in traced modules (core/); RA108 fires in
    # obs-instrumented modules (serve/) — each fixture lives where its rule
    # is scoped so it trips exactly one rule.
    rel = {"RA101": "core/ra101_traced_branch",
           "RA102": "core/ra102_unhashable_static",
           "RA103": "core/ra103_vjp_arity",
           "RA104": "core/ra104_import_time",
           "RA105": "core/ra105_nondeterminism",
           "RA106": "core/ra106_host_sync",
           "RA107": "core/ra107_unused_import",
           "RA108": "serve/ra108_wallclock"}[code]
    return os.path.join(FIXTURES, "src", "repro", *rel.split("/")) + ".py"


# ---------------------------------------------------------------------------
# AST lint: planted fixtures + clean repo
# ---------------------------------------------------------------------------
ALL_LINT_CODES = ["RA101", "RA102", "RA103", "RA104", "RA105", "RA106",
                  "RA107", "RA108"]


@pytest.mark.parametrize("code", ALL_LINT_CODES)
def test_planted_lint_fixture_fires(code):
    findings = run_lint([_fixture(code)], root=FIXTURES)
    assert any(f.code == code for f in findings), \
        f"{code} did not fire on its planted fixture"


@pytest.mark.parametrize("code", ALL_LINT_CODES)
def test_planted_lint_fixture_fires_exactly_one_rule(code):
    findings = run_lint([_fixture(code)], root=FIXTURES)
    assert {f.code for f in findings} == {code}, \
        f"fixture for {code} trips other rules too: {findings}"


def test_ra108_fires_exactly_once():
    # the ISSUE-level guarantee: one offending read, one finding — sleep and
    # the module-level import don't count
    findings = run_lint([_fixture("RA108")], root=FIXTURES)
    assert [f.code for f in findings] == ["RA108"]


def test_ra108_scoped_to_instrumented_paths(tmp_path):
    # the same wall-clock read outside INSTRUMENTED_MODULES must stay silent
    src = open(_fixture("RA108")).read()
    elsewhere = tmp_path / "src" / "repro" / "launch"
    elsewhere.mkdir(parents=True)
    (elsewhere / "wallclock.py").write_text(src)
    findings = run_lint([str(elsewhere / "wallclock.py")],
                        root=str(tmp_path), only=["RA108"])
    assert findings == []


def test_ra108_catches_aliased_import(tmp_path):
    # `from time import perf_counter as pc` must not smuggle the read past
    # the attribute-chain check
    mod = tmp_path / "src" / "repro" / "store" / "timing.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("from time import perf_counter as pc\n\n\n"
                   "def read():\n    return pc()\n")
    findings = run_lint([str(mod)], root=str(tmp_path), only=["RA108"])
    assert [f.code for f in findings] == ["RA108"]


def test_traced_module_rules_scoped_to_traced_paths(tmp_path):
    # the same offending source outside TRACED_MODULES must NOT fire RA105
    src = open(_fixture("RA105")).read()
    host_side = tmp_path / "src" / "repro" / "serve"
    host_side.mkdir(parents=True)
    (host_side / "host_timing.py").write_text(src)
    findings = run_lint([str(host_side / "host_timing.py")],
                        root=str(tmp_path), only=["RA105"])
    assert findings == []


def test_clean_repo_lint():
    findings = run_lint([os.path.join(ROOT, "src", "repro"),
                         os.path.join(ROOT, "benchmarks")], root=ROOT)
    assert findings == [], "repo lint must be clean (fix or baseline):\n" + \
        "\n".join(f.render() for f in findings)


def test_noqa_suppresses(tmp_path):
    mod = tmp_path / "src" / "repro" / "core" / "m.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("import time  # noqa: RA105 - trace-time timestamp ok\n")
    assert run_lint([str(mod)], root=str(tmp_path), only=["RA105"]) == []


def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    findings = run_lint([str(bad)], root=str(tmp_path))
    assert [f.code for f in findings] == ["RA100"]


# ---------------------------------------------------------------------------
# jaxpr checks: fabricated-summary planted violations (no devices needed)
# ---------------------------------------------------------------------------
BUCKETS = (0, 44, 28, 24)   # ragged, as produced by the skewed partitioner


def _exp(**kw):
    base = dict(fwd_ops=2, bwd_ops=1, bits=1, buckets=BUCKETS, psums=7)
    base.update(kw)
    return ExchangeExpectation(**base)


def _pp(shift, rows, dtype="uint8", n=1):
    return [CollectiveOp(prim="ppermute", dtype=dtype, shape=(1, rows, 4),
                         shift=shift)] * n


def _summary(collectives=(), counts=None, callbacks=()):
    counter = collections.Counter(counts or {})
    for op in collectives:
        counter[op.prim] += 1
    return JaxprSummary(prim_counts=counter, collectives=list(collectives),
                        callbacks=list(callbacks))


def _clean_compact_ops(exp):
    ops = []
    for (shift, rows), n in expected_shift_census(exp).items():
        ops += _pp(shift, rows, n=n)
    return ops


def test_clean_census_passes():
    exp = _exp()
    s = _summary(_clean_compact_ops(exp), counts={"psum": 7})
    assert check_exchange_census(s, exp, "t") == []


def test_second_psum_fires():
    exp = _exp()
    s = _summary(_clean_compact_ops(exp), counts={"psum": 8})
    codes = [f.code for f in check_exchange_census(s, exp, "t")]
    assert codes == ["RC201"]


def test_missing_bucket_fires():
    exp = _exp()
    ops = _clean_compact_ops(exp)[:-1]          # drop one bucket's ppermute
    s = _summary(ops, counts={"psum": 7})
    assert any(f.code == "RC201"
               for f in check_exchange_census(s, exp, "t"))


def test_uninverted_backward_rings_fire_rc203():
    # backward ran the FORWARD rings: same totals per rows-class, wrong shifts
    exp = _exp()
    ops = []
    p = len(BUCKETS)
    for k, b in enumerate(BUCKETS):
        if k == 0 or not b:
            continue
        ops += _pp(k, b, n=exp.fwd_ops * exp.comps)     # fwd: correct
        ops += _pp(k, b, n=exp.bwd_ops * exp.comps)     # bwd: NOT p-k
    s = _summary(ops, counts={"psum": 7})
    codes = {f.code for f in check_exchange_census(s, exp, "t")}
    assert codes == {"RC203"}


def test_fp32_leak_on_quantized_exchange_fires_rc202():
    exp = _exp()
    ops = _clean_compact_ops(exp)[:-1] + _pp(3, 24, dtype="float32")
    s = _summary(ops, counts={"psum": 7})
    codes = {f.code for f in check_wire_dtypes(s, exp, "t")}
    assert codes == {"RC202"}


def test_psum_exempt_from_wire_audit():
    s = _summary([CollectiveOp(prim="psum", dtype="float32",
                               shape=(4, 4), shift=None)])
    assert check_wire_dtypes(s, _exp(), "t") == []


def test_all_gather_fires():
    exp = _exp()
    s = _summary(_clean_compact_ops(exp),
                 counts={"psum": 7, "all_gather": 1})
    assert any("all_gather" in f.message
               for f in check_exchange_census(s, exp, "t"))


def test_callback_fires_rc205():
    s = _summary(callbacks=["pure_callback"])
    assert [f.code for f in check_no_callbacks(s, "t")] == ["RC205"]
    assert check_no_callbacks(_summary(), "t") == []


def test_simulated_collective_leak_fires():
    s = _summary(counts={"ppermute": 1})
    assert [f.code for f in check_no_collectives(s, "t")] == ["RC201"]


def test_cyclic_shift_extraction():
    assert cyclic_shift([(0, 1), (1, 2), (2, 3), (3, 0)]) == 1
    assert cyclic_shift([(0, 3), (1, 0), (2, 1), (3, 2)]) == 3
    assert cyclic_shift([(0, 1), (1, 0)]) == 1
    assert cyclic_shift([(0, 2), (1, 2)]) is None     # not a permutation
    assert cyclic_shift([]) is None


def test_summarize_recurses_into_jit():
    def f(x):
        return jax.jit(lambda y: y * 2)(x) + 1

    s = summarize(jax.make_jaxpr(f)(1.0))
    assert s.count("mul") == 1        # found inside the pjit sub-jaxpr


# ---------------------------------------------------------------------------
# contracts: monkeypatched planted violations + clean run
# ---------------------------------------------------------------------------
def test_quantize_payload_contract_clean():
    findings, skipped = contracts.contract_quantize_payload()
    assert findings == [] and skipped == []


def test_quantize_payload_contract_fires_on_fp32_payload(monkeypatch):
    from repro.core import quantization as qlib

    real = qlib.quantize

    def leaky(h, bits, *a, **kw):
        qt = real(h, bits, *a, **kw)
        if bits <= 8:       # ship dequantized fp32 instead of the payload
            return qlib.QuantizedTensor(qt.data.astype("float32"), qt.scale,
                                        qt.zero, qt.bits, qt.feat_dim)
        return qt

    monkeypatch.setattr(qlib, "quantize", leaky)
    findings, _ = contracts.contract_quantize_payload()
    assert findings and all(f.code == "RC206" for f in findings)


def test_recompile_budget_contract_clean():
    findings, skipped = contracts.contract_recompile_budget()
    assert findings == [] and skipped == []


def test_serve_one_executable_contract_clean():
    findings, skipped = contracts.contract_serve_one_executable()
    assert findings == [] and skipped == []


def test_serve_one_executable_fires_on_retracing_sweep(monkeypatch):
    from repro.dist.runtime import Runtime

    def leaky_shard_serve_fn(self, sweep_fn):
        # a sweep that builds a FRESH executable per invocation — the exact
        # failure mode the one-executable contract exists to catch (the fresh
        # lambda defeats jax's function-identity trace cache)
        def call(*args):
            return jax.jit(lambda *a: sweep_fn(*a))(*args)
        return call

    monkeypatch.setattr(Runtime, "shard_serve_fn", leaky_shard_serve_fn)
    findings, _ = contracts.contract_serve_one_executable()
    assert any(f.code == "RC204" for f in findings)


def test_overlap_budget_contract_clean():
    findings, skipped = contracts.contract_overlap_budget()
    assert findings == [] and skipped == []


def test_overlap_budget_fires_on_uncached_steps(monkeypatch):
    from repro.dist.runtime import Runtime

    # a runtime that hands back the raw eager steps: every invocation re-runs
    # the python body, so the TRACE_LOG grows per call instead of per decision
    monkeypatch.setattr(Runtime, "shard_gnn_steps",
                        lambda self, ts, ta, ev, *a: (ts, ta, ev))
    findings, _ = contracts.contract_overlap_budget()
    assert any(f.code == "RC209" for f in findings)


def test_overlap_census_fires_without_fence(monkeypatch):
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    from repro.dist import overlap as olap

    # strip the fence: values are unchanged (identity), but the land can fold
    # back into the issue — exactly what RC209's barrier census must catch
    monkeypatch.setattr(olap, "fence", lambda backend, tree: tree)
    findings, _ = contracts.contract_overlap_census()
    assert any(f.code == "RC209" for f in findings)


def test_obs_transparency_contract_clean():
    findings, skipped = contracts.contract_obs_transparency()
    assert findings == [] and skipped == []


def test_obs_transparency_fires_on_leaky_instrumentation(monkeypatch):
    from repro import obs
    from repro.train import gnn_step

    class LeakyLog(obs.TraceLog):
        # the planted violation: instrumentation that emits a *traced op*
        # (a debug callback) when the tracer is armed — exactly what RC210
        # exists to catch at the TRACE_LOG seam
        def append(self, tag):
            super().append(tag)
            if obs.enabled():
                jax.debug.print("retraced {}", 0)

    monkeypatch.setattr(gnn_step, "TRACE_LOG", LeakyLog("train"))
    findings, _ = contracts.contract_obs_transparency()
    assert any(f.code == "RC210" for f in findings)
    assert all("train" in f.where for f in findings if f.code == "RC210")


def test_contract_error_reported_not_swallowed(monkeypatch):
    monkeypatch.setitem(contracts.CONTRACTS, "boom",
                        lambda: (_ for _ in ()).throw(RuntimeError("nope")))
    findings, _ = contracts.run_contracts(only=["boom"])
    assert [f.code for f in findings] == ["RC200"]
    assert "nope" in findings[0].message


def test_full_contract_suite_clean():
    """The acceptance gate: zero findings on the repo. On a 1-device pytest
    run the shard_map entry points report as skipped (never as passes); under
    tools/ci.sh --analysis all of them run."""
    findings, skipped = contracts.run_contracts()
    assert findings == [], "\n".join(f.render() for f in findings)
    if len(jax.devices()) < 4:
        assert any("shard_map" in s for s in skipped)


def test_shard_map_contracts_run_with_devices():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (tools/ci.sh --analysis lane)")
    for name in ("train_sync/gcn/compact/shard_map",
                 "serve_sweep/gcn/compact/shard_map",
                 "overlap_census/gcn/compact/shard_map"):
        findings, skipped = contracts.run_contracts(only=[name])
        assert findings == [] and skipped == []


# ---------------------------------------------------------------------------
# baseline + report plumbing
# ---------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    f1 = Finding(code="RA107", where="src/x.py", message="unused import 'os'",
                 line=3)
    f2 = Finding(code="RC202", where="contract:t", message="fp32 leak")
    base = tmp_path / "baseline.txt"
    base.write_text(f"# accepted: legacy debt\n{f1.fingerprint}\n")
    baseline = load_baseline(str(base))
    fresh, known = split_by_baseline([f1, f2], baseline)
    assert fresh == [f2] and known == [f1]
    # line numbers are not part of the fingerprint
    moved = Finding(code="RA107", where="src/x.py",
                    message="unused import 'os'", line=99)
    assert moved.fingerprint in baseline
    # paid-off debt is reported as stale
    assert stale_baseline_entries([f2], baseline) == [f1.fingerprint]


def test_report_schema(tmp_path):
    import json
    f1 = Finding(code="RA101", where="src/a.py", message="m", line=1)
    path = write_report(str(tmp_path / "report.json"), [f1], set(),
                        skipped=["contract:x (needs 4 devices)"],
                        meta={"lanes": ["lint"]})
    body = json.load(open(path))
    assert body["counts"] == {"fresh": 1, "baselined": 0}
    assert body["findings"][0]["code"] == "RA101"
    assert body["findings"][0]["baselined"] is False
    assert body["skipped"] == ["contract:x (needs 4 devices)"]
    assert body["stale_baseline"] == []


# ---------------------------------------------------------------------------
# CLI: exit codes gate
# ---------------------------------------------------------------------------
def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=CLI_ENV, cwd=ROOT, timeout=120)


def test_cli_exits_nonzero_on_planted_fixture():
    r = _cli("--lint-only", "--root", FIXTURES,
             os.path.join(FIXTURES, "src", "repro"))
    assert r.returncode == 1, r.stdout + r.stderr
    for code in ALL_LINT_CODES:
        assert code in r.stdout


def test_cli_exits_zero_with_baseline(tmp_path):
    fixture_dir = os.path.join(FIXTURES, "src", "repro", "core")
    findings = run_lint([fixture_dir], root=FIXTURES)
    base = tmp_path / "baseline.txt"
    base.write_text("# test baseline: every planted fixture accepted\n" +
                    "".join(f.fingerprint + "\n" for f in findings))
    r = _cli("--lint-only", "--root", FIXTURES, "--baseline", str(base),
             fixture_dir)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_lint_only_clean_repo():
    r = _cli("--lint-only")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stdout


def test_repo_baseline_is_empty_or_justified():
    baseline = load_baseline(os.path.join(ROOT, "tools",
                                          "analysis_baseline.txt"))
    assert baseline == set(), \
        "repo baseline must stay empty unless debt is justified in-file"
