"""repro.store: LRU/pinned cache semantics, sharded store accounting,
mutation stream determinism, store-backed serving equivalence.

Contracts under test:
  * ``LRUCache``: strict LRU eviction order (recency updated on hit),
    pinned rows never evicted, byte accounting exact;
  * ``ShardedEmbeddingStore``: hit/miss/put byte accounting, read-your-writes
    coherence through interleaved ``put_rows`` (pinned rows write-through
    refreshed, LRU rows invalidated), ``check_coherence`` catches divergence;
  * ``MutationStream``: events/batches are pure functions of the seed,
    last-write-wins within a window, edge events touch both endpoints,
    registry calibration (``gdelt_like``) round-trips;
  * store-backed engine: queries bit-exact vs the materialized table after
    full sweeps and interleaved delta refreshes, even through a cache too
    small to hold the table; ``StoreReader`` replicas and a ``ReplicaSet``
    answer consistently under a seeded mixed read/refresh workload;
  * ``open_loop``: offered schedule is seeded-deterministic, losses and the
    SLO verdict are reported.
"""
import numpy as np
import pytest

from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN
from repro.serve import (EmbeddingServer, InferenceEngine, ReplicaSet,
                         ServeConfig, StoreReader)
from repro.serve.loadgen import open_loop
from repro.store import (LRUCache, MutationStream, ShardedEmbeddingStore,
                         StoreBackend, zipf_popularity)
from repro.train.trainer import GNNTrainer


def _row(d=4, fill=1.0):
    return np.full(d, fill, dtype=np.float32)


# ---------------------------------------------------------------------------
# LRUCache
# ---------------------------------------------------------------------------
def test_lru_evicts_in_recency_order():
    d = 4
    cache = LRUCache(capacity_bytes=3 * _row(d).nbytes)
    for k in "abc":
        cache.insert(k, _row(d))
    assert cache.lru_keys() == ("a", "b", "c")
    # a hit moves "a" to most-recent; "b" becomes the eviction candidate
    assert cache.lookup("a") is not None
    cache.insert("d", _row(d))
    assert "b" not in cache
    assert cache.lru_keys() == ("c", "a", "d")
    assert cache.evictions == 1
    assert cache.evicted_bytes == _row(d).nbytes


def test_lru_byte_accounting_and_capacity():
    d = 8
    rb = _row(d).nbytes
    cache = LRUCache(capacity_bytes=2 * rb)
    cache.insert("a", _row(d))
    cache.insert("b", _row(d))
    assert cache.lru_bytes == 2 * rb and cache.bytes_cached == 2 * rb
    cache.insert("c", _row(d))                 # evicts "a"
    assert cache.lru_bytes == 2 * rb
    # a row larger than the whole capacity is never admitted
    cache.insert("huge", np.zeros(1000, np.float32))
    assert "huge" not in cache
    # hits/misses/hit_bytes count through lookup only
    assert cache.lookup("b") is not None and cache.lookup("zz") is None
    assert (cache.hits, cache.misses, cache.hit_bytes) == (1, 1, rb)


def test_pinned_rows_survive_eviction_pressure():
    d = 4
    rb = _row(d).nbytes
    cache = LRUCache(capacity_bytes=3 * rb)
    cache.pin("hot", _row(d, 7.0))
    for i in range(10):                        # churn far past capacity
        cache.insert(f"cold{i}", _row(d, float(i)))
    assert cache.is_pinned("hot")
    np.testing.assert_array_equal(cache.lookup("hot"), _row(d, 7.0))
    assert cache.pinned_bytes == rb
    # capacity bounds the LRU tier only; pinned rows don't compete for it
    assert cache.lru_bytes <= 3 * rb
    # repin refreshes in place; unpin demotes to absent
    assert cache.repin("hot", _row(d, 9.0))
    np.testing.assert_array_equal(cache.lookup("hot"), _row(d, 9.0))
    cache.unpin("hot")
    assert "hot" not in cache
    assert not cache.repin("hot", _row(d))     # no longer pinned


# ---------------------------------------------------------------------------
# ShardedEmbeddingStore
# ---------------------------------------------------------------------------
def _store(cache_rows=4, parts=2, rows=6, d=4, seed=0):
    st = ShardedEmbeddingStore(cache_bytes=cache_rows * d * 4)
    st.create_table("t", part_rows=(rows,) * parts, d=d)
    rng = np.random.default_rng(seed)
    for p in range(parts):
        st.put_rows("t", p, np.arange(rows),
                    rng.normal(0, 1, (rows, d)).astype(np.float32))
    return st


def test_store_is_a_store_backend():
    assert isinstance(_store(), StoreBackend)


def test_store_hit_miss_byte_accounting():
    st = _store(cache_rows=8)
    d4 = 4 * 4                                  # row bytes
    s0 = st.stats()
    assert (s0.hits, s0.misses, s0.miss_bytes) == (0, 0, 0)
    st.get_rows("t", 0, [0, 1])                 # two cold misses
    s1 = st.stats()
    assert (s1.misses, s1.miss_bytes) == (2, 2 * d4)
    st.get_rows("t", 0, [1, 2])                 # one hit, one miss
    s2 = st.stats()
    assert (s2.hits, s2.hit_bytes) == (1, d4)
    assert (s2.misses, s2.miss_bytes) == (3, 3 * d4)
    assert s2.gets == 2 and s2.hit_rate == pytest.approx(1 / 4)
    # puts are counted too, and unknown tables raise
    assert s2.put_rows == 12 and s2.put_bytes == 12 * d4
    with pytest.raises(KeyError):
        st.get_rows("nope", 0, [0])
    with pytest.raises(ValueError):
        st.create_table("t", part_rows=(6, 6), d=4)


def test_store_reads_coherent_through_interleaved_writes():
    """Cache-vs-shard equivalence after interleaved refreshes: pinned rows
    write-through, LRU rows invalidate — reads always match ``peek_rows``."""
    st = _store(cache_rows=4, rows=8)
    st.pin("t", 0, [0, 1])
    rng = np.random.default_rng(3)
    for it in range(6):
        slots = rng.choice(8, size=3, replace=False)
        st.put_rows("t", 0, slots,
                    rng.normal(0, 1, (3, 4)).astype(np.float32))
        got = st.get_rows("t", 0, np.arange(8))
        np.testing.assert_array_equal(got, st.peek_rows("t", 0, np.arange(8)),
                                      err_msg=f"iteration {it}")
        assert st.check_coherence() > 0
    assert st.stats().evictions > 0             # the LRU tail actually churned


def test_store_put_rejects_shape_mismatch():
    st = _store()
    with pytest.raises(ValueError):
        st.put_rows("t", 0, [0, 1], np.zeros((2, 5), np.float32))


# ---------------------------------------------------------------------------
# MutationStream
# ---------------------------------------------------------------------------
def test_stream_events_deterministic_and_calibrated():
    s = MutationStream(100, 8, rate=50.0, feat_frac=0.7, skew=1.0, seed=4)
    a, b = s.events(40), s.events(40)
    assert len(a) == 40
    for ea, eb in zip(a, b):
        assert (ea.t, ea.kind, ea.node, ea.dst) == (eb.t, eb.kind, eb.node,
                                                    eb.dst)
        if ea.kind == "feat":
            np.testing.assert_array_equal(ea.row, eb.row)
    ts = np.array([e.t for e in a])
    assert (np.diff(ts) > 0).all()              # strictly increasing clock
    kinds = {e.kind for e in a}
    assert kinds <= {"feat", "edge"}


def test_stream_batches_last_write_wins_and_edge_touch():
    s = MutationStream(50, 4, rate=200.0, feat_frac=0.6, seed=1)
    current = np.zeros((50, 4), np.float32)
    batches = s.batches(80, window_s=0.1, rows_of=lambda ids: current[ids])
    assert batches, "80 events at 200/s must fill at least one window"
    evs = s.events(80)
    for t_due, ids, rows in batches:
        assert rows.shape == (ids.size, 4)
        assert ids.size == np.unique(ids).size
        window = [e for e in evs if t_due - 0.1 < e.t <= t_due]
        for j, i in enumerate(ids.tolist()):
            feat = [e for e in window if e.kind == "feat" and e.node == i]
            if feat:                            # last write in the window wins
                np.testing.assert_array_equal(rows[j], feat[-1].row)
            else:                               # edge-touched at current rows
                assert any(e.kind == "edge" and i in (e.node, e.dst)
                           for e in window)
                np.testing.assert_array_equal(rows[j], current[i])


def test_stream_from_workload_calibration():
    g, s = MutationStream.from_workload("gdelt_like@smoke", seed=2)
    assert (s.n_nodes, s.d_feat) == (g.n_nodes, g.x.shape[1])
    assert s.rate == 40.0 and s.skew == pytest.approx(1.1)
    with pytest.raises(KeyError):
        MutationStream.from_workload("yelp_like@smoke")   # no stream tiers


def test_zipf_popularity_shapes():
    p = zipf_popularity(100, 1.2, seed=0)
    assert p.shape == (100,) and p.sum() == pytest.approx(1.0)
    u = zipf_popularity(100, 0.0, seed=0)
    np.testing.assert_allclose(u, 1 / 100)


# ---------------------------------------------------------------------------
# store-backed serving (engine + replicas)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    """One trained checkpoint served three ways: materialized engine,
    store-backed engine (roomy cache), store-backed engine (tiny cache)."""
    g0 = synthetic.planted_partition(n_nodes=240, d_feat=12, seed=0)
    ei = formats.add_self_loops(g0.edge_index, g0.n_nodes)
    ew = formats.gcn_edge_weights(ei, g0.n_nodes)
    g = formats.Graph(g0.n_nodes, ei, g0.x, g0.y, g0.train_mask, g0.val_mask,
                      g0.test_mask, n_classes=g0.n_classes)
    pg = partition.partition_graph(g, 4, edge_weight=ew, layout="compact")
    model = GCN(g.x.shape[1], 16, g.n_classes, n_layers=2)
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1),
                        ckpt_dir=td)
        tr.fit(3)
        tr.save()
        eng_m, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1))
        eng_big, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1),
            store=ShardedEmbeddingStore(cache_bytes=1 << 22))
        eng_tiny, _ = InferenceEngine.from_checkpoint(
            td, model, pg, config=ServeConfig(bits=1),
            store=ShardedEmbeddingStore(cache_bytes=40 * g.n_classes * 4))
        for e in (eng_m, eng_big, eng_tiny):
            e.full_sweep()
        yield g, pg, eng_m, eng_big, eng_tiny


def test_store_engine_bitexact_vs_materialized(served):
    g, pg, eng_m, eng_big, eng_tiny = served
    ids = np.arange(g.n_nodes)
    ref = eng_m.query(ids).logits
    np.testing.assert_array_equal(eng_big.query(ids).logits, ref)
    # a cache far too small for the table must change *nothing* but traffic
    np.testing.assert_array_equal(eng_tiny.query(ids).logits, ref)
    assert eng_tiny.store.stats().miss_bytes > 0
    assert eng_big.verify_store() > 0
    assert eng_tiny.verify_store() > 0


def test_store_engine_bitexact_through_interleaved_refreshes(served):
    g, pg, eng_m, eng_big, eng_tiny = served
    rng = np.random.default_rng(7)
    all_ids = np.arange(g.n_nodes)
    for it in range(3):
        ch = rng.choice(g.n_nodes, size=6, replace=False)
        rows = rng.normal(0, 1, (6, g.x.shape[1])).astype(np.float32)
        for e in (eng_m, eng_big, eng_tiny):
            e.refresh(ch, rows)
        qids = rng.choice(g.n_nodes, size=40)
        ref = eng_m.query(qids).logits
        np.testing.assert_array_equal(eng_big.query(qids).logits, ref,
                                      err_msg=f"iteration {it}")
        np.testing.assert_array_equal(eng_tiny.query(qids).logits, ref,
                                      err_msg=f"iteration {it}")
        np.testing.assert_array_equal(eng_big.query(all_ids).logits,
                                      eng_m.query(all_ids).logits)
    assert eng_big.verify_store() > 0


def test_store_reader_is_query_only_replica(served):
    g, pg, eng_m, eng_big, _ = served
    rd = eng_big.reader()
    assert isinstance(rd, StoreReader)
    ids = np.array([0, 5, 100, g.n_nodes - 1])
    np.testing.assert_array_equal(rd.query(ids).logits,
                                  eng_big.query(ids).logits)
    np.testing.assert_array_equal(rd.embeddings(ids), eng_big.embeddings(ids))
    assert not hasattr(rd, "refresh")          # readers cannot write
    # a storeless engine serves itself as its own "reader"
    assert eng_m.reader() is eng_m
    with pytest.raises(ValueError):
        StoreReader(eng_m)


def test_replicaset_consistent_under_mixed_workload(served):
    """Multi-replica answer consistency: N replicas over one store answer a
    seeded mixed read/refresh workload identically to the materialized
    engine, with all replicas sharing the load."""
    g, pg, eng_m, eng_big, _ = served
    rs = ReplicaSet(eng_big, n_replicas=3, microbatch=32)
    assert all(isinstance(s.engine, StoreReader) for s in rs.replicas)
    rng = np.random.default_rng(11)
    want: dict[int, np.ndarray] = {}
    got: dict[int, np.ndarray] = {}
    for round_ in range(8):
        for _ in range(6):
            ids = rng.integers(0, g.n_nodes, size=4)
            rid = rs.submit(ids)
            assert isinstance(rid, int)
            want[rid] = ids
        if round_ % 3 == 2:                     # interleaved refresh (writer)
            ch = rng.choice(g.n_nodes, size=5, replace=False)
            rows = rng.normal(0, 1, (5, g.x.shape[1])).astype(np.float32)
            assert rs.refresh(ch, rows) is not None
            eng_m.refresh(ch, rows)
        for resp in rs.drain():
            got[resp.req_id] = resp.logits
            # answered from the same (possibly pre-refresh) table state the
            # materialized engine now holds: refreshes only happen when the
            # queues are drained, so logits must match the current reference
            np.testing.assert_array_equal(resp.logits,
                                          eng_m.query(want[resp.req_id]).logits)
    assert set(got) == set(want)                # every request answered once
    per = rs.per_replica()
    assert sum(r["served"] for r in per) == len(want)
    assert all(r["accepted"] > 0 for r in per)  # admission actually balanced


def test_replicaset_drains_and_routes_around_draining_replica(served):
    g, pg, eng_m, eng_big, _ = served
    rs = ReplicaSet(eng_big, n_replicas=2, microbatch=16)
    rs.replicas[0].start_draining()
    rids = [rs.submit([i]) for i in range(5)]
    assert all(isinstance(r, int) for r in rids)
    assert rs.replicas[1].accepted == 5         # all routed to the live one
    assert rs.health == "healthy"               # one live replica -> still up
    rs.replicas[1].start_draining()
    from repro.serve import Rejection
    assert isinstance(rs.submit([0]), Rejection)
    assert rs.health == "draining"
    assert len(rs.drain()) == 5


# ---------------------------------------------------------------------------
# open-loop load generation
# ---------------------------------------------------------------------------
def test_open_loop_reports_slo_and_determinism(served):
    g, pg, eng_m, eng_big, _ = served
    srv = EmbeddingServer(eng_big, microbatch=64)
    rep = open_loop(srv, g.n_nodes, qps=2000.0, requests=60, batch=4,
                    seed=3, skew=1.1, slo_ms=1000.0)
    assert rep["completed"] == 60 and rep["lost"] == 0
    assert rep["slo_pass"] is True and rep["slo_ms"] == 1000.0
    assert rep["p99_ms"] >= rep["p50_ms"] >= 0.0
    # the offered schedule is a pure function of the seed
    r1 = np.random.default_rng(3).exponential(1 / 2000.0, size=60)
    r2 = np.random.default_rng(3).exponential(1 / 2000.0, size=60)
    np.testing.assert_array_equal(np.cumsum(r1), np.cumsum(r2))
    with pytest.raises(ValueError):
        open_loop(srv, g.n_nodes, qps=0.0)


def test_open_loop_feed_drives_refreshes(served):
    g, pg, eng_m, eng_big, _ = served
    stream = MutationStream(g.n_nodes, g.x.shape[1], rate=300.0, seed=5)
    feed = stream.batches(30, 0.05, rows_of=eng_big.feature_rows)
    srv = EmbeddingServer(eng_big, microbatch=64)
    rep = open_loop(srv, g.n_nodes, qps=1000.0, requests=40, batch=4,
                    seed=6, feed=feed)
    assert rep["refreshes"] == len(feed)
    assert rep["refresh_failures"] == 0
    assert rep["refresh_wire_bytes"] > 0
    assert rep["refresh_lag_max_s"] >= 0.0
    assert eng_big.verify_store() > 0           # still coherent afterwards
