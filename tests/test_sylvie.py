"""Sylvie core: halo exchange semantics, quantized custom_vjp, staleness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as q
from repro.core.exchange import (PlanArrays, exchange, gather_boundary,
                                 scatter_boundary_grad)
from repro.core.staleness import HaloState, use_sync_step
from repro.core.sylvie import SylvieComm, SylvieConfig, quantized_halo
from repro.graph import formats, partition, synthetic
from repro.models.gnn import blocks as B
from repro.models.gnn.models import GCN
from repro.train import optimizer as opt
from repro.train.gnn_step import GNNTrainState, make_gnn_steps

KEY = jax.random.PRNGKey(0)


def _setup(n=300, p=4, d=16, seed=0):
    g = synthetic.planted_partition(n_nodes=n, d_feat=d, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)
    pg = partition.partition_graph(g, p, edge_weight=ew)
    return g, pg, B.build_block(pg)


def test_exchange_is_transpose_involution():
    p, h, d = 4, 3, 5
    x = jax.random.normal(KEY, (p, p * h, d))
    y = exchange(x, None)
    # transpose: out[p, q*h+s] = in[q, p*h+s]
    for pi in range(p):
        for qi in range(p):
            np.testing.assert_allclose(
                np.asarray(y[pi, qi * h:(qi + 1) * h]),
                np.asarray(x[qi, pi * h:(pi + 1) * h]))
    np.testing.assert_allclose(np.asarray(exchange(y, None)), np.asarray(x))


def test_vanilla_halo_matches_global_gather():
    """bits=32 halo exchange delivers exactly the neighbors' features."""
    g, pg, block = _setup()
    x = jnp.asarray(pg.x)
    comm = SylvieComm(SylvieConfig(mode="vanilla"), block.plan, KEY)
    halo = comm.halo(x)
    table = B.halo_table(x, halo)
    src_feats = B.gather_src(block, table)
    # compare against a global gather
    glob_x = g.x
    for pi in range(pg.n_parts):
        for k in range(0, int(pg.edge_mask[pi].sum()), 7):
            s_gid_feat = np.asarray(src_feats[pi, k])
            # find edge endpoints in global terms
            d_loc = pg.edges[pi, k, 1]
            # recompute src gid from reconstruction logic
    # spot-check sums: aggregated features equal the global aggregation
    agg = B.agg_sum(block, src_feats * block.edge_weight[..., None])
    glob_agg = np.zeros_like(glob_x)
    ew = formats.gcn_edge_weights(g.edge_index, g.n_nodes)
    np.add.at(glob_agg, g.edge_index[1], glob_x[g.edge_index[0]] * ew[:, None])
    back = pg.unpartition(np.asarray(agg))
    np.testing.assert_allclose(back, glob_agg, rtol=1e-4, atol=1e-5)


def test_quantized_halo_unbiased():
    _, pg, block = _setup(n=120, p=3, d=8)
    x = jnp.asarray(pg.x)
    cfgv = SylvieConfig(mode="vanilla")
    ref = SylvieComm(cfgv, block.plan, KEY).halo(x)
    acc = jnp.zeros_like(ref)
    n = 300
    for i in range(n):
        comm = SylvieComm(SylvieConfig(mode="sync", bits=1), block.plan,
                          jax.random.fold_in(KEY, i))
        acc = acc + comm.halo(x)
    err = np.abs(np.asarray(acc / n) - np.asarray(ref))
    mask = np.asarray(block.plan.recv_mask)
    # 1-bit stochastic rounding: per-element SE of the mean <= range/(2 sqrt n)
    rng_rows = (np.asarray(x).max(-1) - np.asarray(x).min(-1)).max()
    se = rng_rows / (2 * np.sqrt(n))
    mean_err = err[mask].mean()
    assert mean_err < 3 * se * np.sqrt(2 / np.pi), (mean_err, se)


def test_backward_scatter_adds_duplicate_sends():
    """A node sent to multiple partitions accumulates all their gradients."""
    _, pg, block = _setup(n=80, p=4, d=4)
    plan = block.plan
    x = jnp.asarray(pg.x)

    def f(h):
        halo = quantized_halo(h, plan, KEY, KEY, 32, 32, False, jnp.bfloat16,
                              None, "jnp")
        return (halo ** 2).sum() / 2

    g = jax.grad(f)(x)
    # expected: each sent node's grad = sum over receivers of its value
    sends = np.asarray(plan.send_mask).reshape(plan.n_parts, -1)
    idx = np.asarray(plan.send_idx)
    expected = np.zeros_like(np.asarray(x))
    for p in range(plan.n_parts):
        for slot in range(idx.shape[1]):
            if sends[p, slot]:
                expected[p, idx[p, slot]] += np.asarray(x)[p, idx[p, slot]]
    np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-4, atol=1e-5)


def test_async_one_step_staleness_dataflow():
    """Async step consumes exactly the previous step's halo features."""
    _, pg, block = _setup(n=100, p=4, d=8)
    model = GCN(d_in=8, d_hidden=16, d_out=4, n_layers=2)
    o = opt.sgd(0.0)   # freeze params: isolates the cache dataflow
    cfg = SylvieConfig(mode="async", bits=32, stochastic=False)
    ts, ta, _ = make_gnn_steps(model, cfg, o)
    st = GNNTrainState.create(model, o, KEY, block.plan, stacked_parts=4)
    x = jnp.asarray(pg.x); y = jnp.asarray(pg.y); m = jnp.asarray(pg.train_mask)
    st1, _ = jax.jit(ts)(st, block, x, y, m, KEY)     # warmup: fills caches
    # with frozen params, the async step's fresh caches equal the sync ones
    st2, _ = jax.jit(ta)(st1, block, x, y, m, KEY)
    for a, b in zip(st1.halo.feats, st2.halo.feats):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_async_converges_on_planted_graph():
    _, pg, block = _setup(n=400, p=4, d=24, seed=3)
    model = GCN(d_in=24, d_hidden=32, d_out=7, n_layers=2)
    o = opt.adam(1e-2)
    cfg = SylvieConfig(mode="async", bits=1)
    ts, ta, ev = make_gnn_steps(model, cfg, o)
    st = GNNTrainState.create(model, o, KEY, block.plan, stacked_parts=4)
    x = jnp.asarray(pg.x); y = jnp.asarray(pg.y); m = jnp.asarray(pg.train_mask)
    ts = jax.jit(ts); ta = jax.jit(ta)
    st, _ = ts(st, block, x, y, m, KEY)
    for i in range(40):
        st, loss = ta(st, block, x, y, m, jax.random.fold_in(KEY, i))
    c, n = jax.jit(ev)(st.params, block, x, y, jnp.asarray(pg.test_mask), KEY)
    assert float(c) / float(n) > 0.8


def test_bounded_staleness_schedule():
    assert use_sync_step(0, None) is True           # warmup
    assert use_sync_step(3, None) is False          # pure async
    assert [use_sync_step(e, 3) for e in range(7)] == \
        [True, False, False, True, False, False, True]
    assert all(use_sync_step(e, 1) for e in range(5))


def test_halo_state_pytree():
    _, pg, block = _setup(n=60, p=2, d=4)
    hs = HaloState.zeros(block.plan, [4, 8], stacked_parts=2)
    leaves = jax.tree.leaves(hs)
    assert len(leaves) == 4
    assert all(l.shape[0] == 2 for l in leaves)
