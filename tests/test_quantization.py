"""Low-bit Module: unbiasedness, variance bound, pack/unpack, comm accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as q

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("shape", [(7, 5), (64, 33), (128, 288)])
def test_pack_unpack_roundtrip_exact(bits, shape):
    vals = jax.random.randint(KEY, shape, 0, 2**bits).astype(jnp.uint8)
    packed = q.pack_bits(vals, bits)
    out = q.unpack_bits(packed, bits, shape[-1])
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(out))
    if bits in q.PACKABLE_BITS:
        assert packed.shape[-1] == q.packed_width(shape[-1], bits)


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_quantize_error_within_one_bin(bits):
    h = jax.random.normal(KEY, (50, 40))
    qt = q.quantize(h, bits, KEY)
    back = q.dequantize(qt)
    scale = np.asarray(qt.scale, np.float32)[:, None]
    assert (np.abs(np.asarray(back) - np.asarray(h)) <= scale + 1e-5).all()


def test_stochastic_rounding_unbiased():
    h = jax.random.normal(KEY, (16, 24))
    n = 600
    acc = 0.0
    for i in range(n):
        acc = acc + q.fake_quantize(h, 1, jax.random.fold_in(KEY, i))
    mean = np.asarray(acc) / n
    # SE of the mean ~ scale/sqrt(6n); allow 5 sigma
    scale = (np.asarray(h).max(-1) - np.asarray(h).min(-1))[:, None]
    tol = 5 * scale / np.sqrt(6 * n)
    assert (np.abs(mean - np.asarray(h)) < tol + 1e-4).all()


def test_variance_matches_theorem1():
    """Empirical Var(dequant) ~ D (max-min)^2 / (6 B^2) summed over D."""
    h = jax.random.normal(KEY, (4, 64))
    n = 800
    samples = np.stack([np.asarray(q.fake_quantize(h, 1, jax.random.fold_in(KEY, i)))
                        for i in range(n)])
    emp_var = samples.var(axis=0).sum(-1)            # per-row total variance
    theo = np.asarray(q.theoretical_variance(h, 1))
    # stochastic-rounding variance p(1-p) <= 1/4 per lane; Theorem 1 uses the
    # uniform-fraction bound 1/6 -- empirical should be within ~2x
    assert (emp_var < 2.0 * theo).all()
    assert (emp_var > 0.05 * theo).all()


def test_deterministic_round_nearest():
    h = jnp.asarray([[0.0, 0.24, 0.26, 0.5, 0.76, 1.0]])
    qt = q.quantize(h, 2, stochastic=False)
    back = q.dequantize(qt)
    # half-bin bound + bf16 scale-rounding slack
    assert np.abs(np.asarray(back) - np.asarray(h)).max() <= (1.0 / 3.0) / 2 + 5e-3


def test_passthrough_bits():
    h = jax.random.normal(KEY, (8, 16))
    for bits, rtol in ((32, 0), (16, 1e-2)):
        back = q.dequantize(q.quantize(h, bits))
        np.testing.assert_allclose(np.asarray(back), np.asarray(h), rtol=rtol,
                                   atol=1e-2 if bits == 16 else 0)


def test_comm_bytes_32x_reduction():
    """Table 3: 1-bit payload is ~32x smaller than fp32; error-compensation
    info is a small fraction of the original payload."""
    payload32, ec32 = q.comm_bytes(10000, 256, 32)
    payload1, ec1 = q.comm_bytes(10000, 256, 1)
    assert payload32 / payload1 == 32.0
    assert ec32 == 0
    assert ec1 < 0.02 * payload32


def test_straight_through_gradient():
    h = jax.random.normal(KEY, (4, 8))
    g = jax.grad(lambda x: q.straight_through_quantize(x, 1, KEY).sum())(h)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_constant_rows():
    h = jnp.ones((3, 7)) * 2.5
    back = q.dequantize(q.quantize(h, 1, KEY))
    np.testing.assert_allclose(np.asarray(back), 2.5, rtol=1e-6)
