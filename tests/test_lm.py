"""LM stack: attention equivalences, cache semantics, MoE dispatch, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import model as LM
from repro.models.lm.config import (AttnConfig, LayerConfig, LMConfig,
                                    MoEConfig, Segment)

KEY = jax.random.PRNGKey(3)


def _dense_reference_attention(q, k, v, causal, window, softcap, scale):
    """O(S^2) reference."""
    b, sq, h, d = q.shape
    _, skv, hkv, dv = v.shape
    g = h // hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= qp - kp < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("softcap", [None, 30.0])
@pytest.mark.parametrize("block", [4, 16, 64])
def test_blockwise_attention_matches_dense(window, softcap, block):
    b, s, h, hkv, d = 2, 33, 4, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d))
    out = LM.blockwise_attention(q, k, v, causal=True, window=window,
                                 softcap=softcap, q_offset=0, kv_len=s,
                                 block=block, scale=d**-0.5)
    ref = _dense_reference_attention(q, k, v, True, window, softcap, d**-0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_decode_matches_blockwise_last_row():
    b, s, h, hkv, d = 2, 16, 4, 2, 8
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d))
    full = LM.blockwise_attention(q, k, v, causal=True, window=None,
                                  softcap=None, q_offset=0, kv_len=s,
                                  scale=1.0)
    dec = LM.decode_attention(q[:, -1:], k, v, softcap=None, kv_len=s,
                              scale=1.0)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-5)


def test_rope_rotation_property():
    """RoPE: dot(q_i, k_j) depends only on i-j."""
    d = 16
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, d))
    def dot_at(i, j):
        qi = LM.rope(q, jnp.asarray([i]), 10000.0)
        kj = LM.rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(10, 8), rtol=1e-5)
    np.testing.assert_allclose(dot_at(5, 5), dot_at(0, 0), rtol=1e-5)
    assert abs(dot_at(5, 1) - dot_at(5, 2)) > 1e-6


def _tiny(moe_cf=None, window=None):
    gqa = AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16,
                     window=window)
    moe = None if moe_cf is None else MoEConfig(
        n_experts=8, top_k=2, d_ff=32, n_shared=1, d_ff_shared=32,
        capacity_factor=moe_cf)
    layer = LayerConfig(gqa, d_ff=64) if moe is None else \
        LayerConfig(gqa, moe=moe)
    return LMConfig(name="t", d_model=32, vocab=101,
                    segments=(Segment(2, (layer,)),))


def test_moe_no_drop_matches_decode():
    """With capacity >= T, decode == full-forward last token (no drops)."""
    cfg = _tiny(moe_cf=16.0)
    params = LM.init_params(KEY, cfg, dtype=jnp.float32)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    logits, _, _ = LM.forward(params, tokens, cfg)
    caches = LM.init_cache(cfg, 2, 16, dtype=jnp.float32)
    _, _, caches = LM.forward(params, tokens[:, :-1], cfg, caches=caches,
                              cache_pos=0, kv_len=11)
    dec = jax.jit(LM.make_decode_step(cfg))
    lg, _ = dec(params, caches, tokens[:, -1:], jnp.asarray(11, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = _tiny(moe_cf=0.1)      # aggressive drops
    params = LM.init_params(KEY, cfg, dtype=jnp.float32)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    logits, aux, _ = LM.forward(params, tokens, cfg)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


def test_moe_flops_scale_with_topk_not_experts():
    """Compiled FLOPs must track active experts (capacity dispatch), not a
    dense all-experts compute."""
    x = jax.random.normal(KEY, (64, 32))
    m8 = MoEConfig(n_experts=8, top_k=2, d_ff=16)
    m32 = MoEConfig(n_experts=32, top_k=2, d_ff=16)
    def flops(m):
        p = LM.ffn_params(jax.random.fold_in(KEY, m.n_experts),
                          _tiny(), LayerConfig(AttnConfig(), moe=m), jnp.float32)
        from repro.dist import compat
        c = jax.jit(lambda xx: LM.moe_ffn(p, xx, m)[0]).lower(x).compile()
        return compat.cost_analysis(c).get("flops", 0.0)
    f8, f32 = flops(m8), flops(m32)
    # 4x experts at fixed top-k: expert GEMM flops stay ~constant (capacity
    # shrinks as 1/E); total must grow far less than 4x
    assert f32 < 2.0 * f8, (f8, f32)


def test_window_ring_cache_decode_long():
    """Decode far past the window: ring cache must equal full-cache result."""
    cfg_ring = _tiny(window=8)
    params = LM.init_params(KEY, cfg_ring, dtype=jnp.float32)
    s = 24
    tokens = jax.random.randint(KEY, (1, s), 0, cfg_ring.vocab)
    # reference: full forward over s+1 tokens
    nxt = jax.random.randint(jax.random.fold_in(KEY, 9), (1, 1),
                             0, cfg_ring.vocab)
    full, _, _ = LM.forward(params, jnp.concatenate([tokens, nxt], 1),
                            cfg_ring)
    caches = LM.init_cache(cfg_ring, 1, s + 8, dtype=jnp.float32)
    assert jax.tree.leaves(caches)[0].shape[2] == 8     # ring-buffered
    _, _, caches = LM.forward(params, tokens, cfg_ring, caches=caches,
                              cache_pos=0, kv_len=s)
    dec = jax.jit(LM.make_decode_step(cfg_ring))
    lg, _ = dec(params, caches, nxt, jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_loss_ignores_vocab_padding():
    cfg = _tiny()
    assert cfg.vocab_padded == 256
    params = LM.init_params(KEY, cfg, dtype=jnp.float32)
    # corrupt padded unembed rows: loss must not change
    tokens = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 8), 0,
                                cfg.vocab)
    l1 = LM.lm_loss(params, tokens, labels, cfg)
    params2 = dict(params)
    emb = np.asarray(params["embed"]).copy()
    emb[cfg.vocab:] = 1e3
    params2["embed"] = jnp.asarray(emb)
    l2 = LM.lm_loss(params2, tokens, labels, cfg)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_param_count_matches_init():
    for arch in ("granite-3-2b", "olmoe-1b-7b", "deepseek-v2-236b"):
        from repro import configs as configlib
        cfg = configlib.get(arch).reduced()
        params = LM.init_params(KEY, cfg, dtype=jnp.float32)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expected = cfg.param_count()
        # padding of the vocab is the only allowed delta
        pad = (cfg.vocab_padded - cfg.vocab) * cfg.d_model
        if not cfg.tie_embeddings:
            pad *= 2
        assert abs(actual - expected) <= pad + 4 * cfg.d_model * cfg.n_layers
