"""Property-based invariants of the quantized halo exchange + overlap model.

Needs the ``hypothesis`` dev extra (CI installs it; skipped otherwise, like
``test_graph.py``). Three families, each over randomly drawn skewed
partitions — so the ring buckets are ragged and every example exercises a
different static bucket-size tuple:

* quantize -> exchange -> dequantize commutes with exchange -> dequantize
  across the whole low-bit lattice {1, 2, 4, 8} (the exchange permutes whole
  rows together with their per-row scale/zero, so dequantized values are
  *bit-identical* either way — the property the overlap issue/land split
  relies on to be value-transparent);
* the compact ring exchange is an involution: ``reverse=True`` undoes
  ``reverse=False`` bit-exactly, for raw buffers and quantized payloads (the
  backward-gradient path of ``dist/overlap.py`` depends on this inversion);
* the DESIGN §14 comm-split model: exposed + overlapped always equals the
  blocking total, the hidden share never exceeds either operand, and the
  modeled overlap step is never slower than blocking.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based overlap tests need the 'hypothesis' dev extra")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

import jax  # noqa: E402

from repro.core import quantization as qlib  # noqa: E402
from repro.core.exchange import (PlanArrays, exchange_halo,  # noqa: E402
                                 exchange_quantized_halo)
from repro.dist import overlap as olap  # noqa: E402
from repro.dist.backend import SimulatedBackend  # noqa: E402
from repro.graph import formats, partition, synthetic  # noqa: E402

pytestmark = pytest.mark.overlap

BE = SimulatedBackend()


def _plan(n, parts, seed):
    g = synthetic.powerlaw(n_nodes=n, d_feat=8, avg_degree=8, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)
    pg = partition.partition_graph(g, parts, method="skewed",
                                   edge_weight=ew, layout="compact")
    return PlanArrays.from_plan(pg.plan)


def _buf(plan, d_feat, seed):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (plan.n_parts, plan.halo_rows, d_feat))


@settings(max_examples=8, deadline=None)
@given(bits=st.sampled_from([1, 2, 4, 8]),
       n=st.integers(min_value=120, max_value=320),
       parts=st.sampled_from([2, 4]),
       seed=st.integers(min_value=0, max_value=31))
def test_quantized_exchange_dequantize_roundtrip(bits, n, parts, seed):
    """dequantize(exchange(quantize(x))) == exchange(dequantize(quantize(x)))
    bit-exactly: the exchange moves payload + scale + zero as one row."""
    plan = _plan(n, parts, seed)
    x = _buf(plan, 8, seed)
    qt = qlib.quantize(x, bits, jax.random.PRNGKey(seed), stochastic=False)
    via_wire = qlib.dequantize(exchange_quantized_halo(qt, plan, BE))
    local = exchange_halo(qlib.dequantize(qt), plan, BE)
    np.testing.assert_array_equal(np.asarray(via_wire), np.asarray(local))


@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=120, max_value=320),
       parts=st.sampled_from([2, 4]),
       seed=st.integers(min_value=0, max_value=31),
       bits=st.sampled_from([1, 4]))
def test_exchange_involution(n, parts, seed, bits):
    """reverse=True inverts reverse=False over random ragged buckets, for raw
    buffers and for quantized payload/scale/zero triples."""
    plan = _plan(n, parts, seed)
    x = _buf(plan, 8, seed)
    back = exchange_halo(exchange_halo(x, plan, BE), plan, BE, reverse=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    qt = qlib.quantize(x, bits, jax.random.PRNGKey(seed), stochastic=False)
    qback = exchange_quantized_halo(
        exchange_quantized_halo(qt, plan, BE), plan, BE, reverse=True)
    for a, b in zip(jax.tree.leaves(qt), jax.tree.leaves(qback)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_comm_split_model_invariants(data):
    """Pure-model properties of split_comm_time / modeled_step_seconds."""
    n_sites = data.draw(st.integers(min_value=1, max_value=6))
    secs = st.floats(min_value=0.0, max_value=10.0,
                     allow_nan=False, allow_infinity=False)
    comm = tuple(data.draw(secs) for _ in range(n_sites))
    compute = tuple(data.draw(secs) for _ in range(n_sites))
    exp_b, hid_b = olap.split_comm_time(comm, compute, "blocking")
    exp_o, hid_o = olap.split_comm_time(comm, compute, "overlap")
    assert hid_b == 0.0 and exp_b == pytest.approx(sum(comm))
    assert exp_o + hid_o == pytest.approx(sum(comm))
    assert hid_o <= min(sum(comm), sum(compute)) + 1e-12
    assert (olap.modeled_step_seconds(comm, compute, "overlap")
            <= olap.modeled_step_seconds(comm, compute, "blocking") + 1e-12)
    with pytest.raises(ValueError):
        olap.split_comm_time(comm, compute, "eager")
