"""Overlap-schedule parity: the fenced issue/land pipeline vs blocking.

Contract (DESIGN §14): ``schedule="overlap"`` reorders work around the halo
collective — it must never perturb a value. Under sync (fresh) exchange the
overlap step is **bit-exact** to blocking: identical loss trajectories and
bit-identical parameters, in the simulated stack and under shard_map. Under
async/BoundedStaleness the stale-halo micro-step variant holds the same
staleness contract, checked to a 2% accuracy band. The `slow` test forks a
subprocess with 4 forced host devices; ``test_shardmap_overlap_parity_inline``
runs the same check in-process when the session already has >= 4 devices (the
CI ``--overlap`` lane).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.api as repro
from repro.core.sylvie import SCHEDULES, SylvieConfig
from repro.dist import overlap as olap
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN
from repro.policy import BoundedStaleness
from repro.train import optimizer as opt

pytestmark = pytest.mark.overlap

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _skewed_graph(n=600, d=16, seed=0):
    g = synthetic.powerlaw(n_nodes=n, d_feat=d, avg_degree=10, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _pg(layout="compact", n=600, parts=4):
    g, ew = _skewed_graph(n=n)
    return partition.partition_graph(g, parts, method="skewed",
                                     edge_weight=ew, layout=layout)


def _train(pg, schedule, mode="sync", epochs=3, policy=None,
           stochastic=False):
    model = GCN(d_in=pg.x.shape[-1], d_hidden=24, d_out=pg.n_classes,
                n_layers=2)
    cfg = SylvieConfig(mode=mode, bits=1, stochastic=stochastic,
                       schedule=schedule)
    return repro.train(model, pg, cfg, opt=opt.sgd(1e-1), epochs=epochs,
                       policy=policy, seed=0)


def _assert_bit_exact(tr_a, tr_b, what=""):
    la = [m.loss for m in tr_a.history]
    lb = [m.loss for m in tr_b.history]
    assert la == lb, f"{what}: loss trajectories diverged: {la} vs {lb}"
    for a, b in zip(jax.tree.leaves(jax.device_get(tr_a.state.params)),
                    jax.tree.leaves(jax.device_get(tr_b.state.params))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{what}: params are not bit-identical"


# ---------------------------------------------------------------------------
# simulated stack: bit-exactness under sync, both layouts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_sync_bitexact_simulated(layout):
    """Fresh (sync) overlap is value-transparent: same losses, same bits."""
    pg = _pg(layout)
    blocking = _train(pg, "blocking", mode="sync")
    overlap = _train(pg, "overlap", mode="sync")
    _assert_bit_exact(blocking, overlap, f"sync/{layout}")
    assert all(m.schedule == "overlap" for m in overlap.history)
    assert all(m.schedule == "blocking" for m in blocking.history)


def test_sync_bitexact_stochastic_rounding():
    """Bit-exactness holds under stochastic rounding too — the overlap path
    consumes the identical per-site PRNG keys as blocking."""
    pg = _pg("compact")
    _assert_bit_exact(_train(pg, "blocking", stochastic=True),
                      _train(pg, "overlap", stochastic=True),
                      "sync/stochastic")


def test_async_uniform_bitexact_simulated():
    """The stale-halo micro-step variant: cached features consumed, fresh
    exchange fenced into the next step's cache — values match blocking
    Sylvie-A exactly under the Uniform policy."""
    pg = _pg("compact")
    _assert_bit_exact(_train(pg, "blocking", mode="async", epochs=4),
                      _train(pg, "overlap", mode="async", epochs=4),
                      "async/uniform")


def test_async_bounded_staleness_accuracy_band():
    """Under BoundedStaleness (periodic sync refresh epochs interleaved with
    stale micro-steps) the overlap schedule must track blocking to within a
    2% accuracy band (DESIGN §14 acceptance)."""
    pg = _pg("compact")
    pol = lambda: BoundedStaleness(eps_s=2, bits=1, stochastic=False)  # noqa: E731
    blocking = _train(pg, "blocking", mode="async", epochs=8, policy=pol())
    overlap = _train(pg, "overlap", mode="async", epochs=8, policy=pol())
    acc_b, acc_o = blocking.evaluate("val"), overlap.evaluate("val")
    assert abs(acc_b - acc_o) <= 0.02, (acc_b, acc_o)
    lb, lo = blocking.history[-1].loss, overlap.history[-1].loss
    assert abs(lb - lo) <= 0.02 * max(abs(lb), 1e-8), (lb, lo)


def test_loss_trajectory_parity_dense_vs_compact_under_overlap():
    """The overlap schedule preserves the dense<->compact layout-parity
    contract of test_halo_compact: same trajectories to fp32 tolerance."""
    for mode, epochs in (("sync", 3), ("async", 4)):
        runs = {lay: _train(_pg(lay), "overlap", mode=mode, epochs=epochs)
                for lay in ("dense", "compact")}
        np.testing.assert_allclose(
            [m.loss for m in runs["dense"].history],
            [m.loss for m in runs["compact"].history], rtol=1e-5)
        for a, b in zip(jax.tree.leaves(runs["dense"].state.params),
                        jax.tree.leaves(runs["compact"].state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# schedule knob plumbing + comm-split model
# ---------------------------------------------------------------------------
def test_unknown_schedule_rejected():
    pg = _pg("compact", n=200)
    with pytest.raises(ValueError, match="unknown schedule"):
        _train(pg, "eager")
    with pytest.raises(ValueError, match="unknown schedule"):
        olap.split_comm_time((1.0,), (1.0,), "eager")
    assert "blocking" in SCHEDULES and "overlap" in SCHEDULES


def test_modeled_comm_split():
    """Blocking exposes every comm second; overlap hides up to each site's
    compute window; the split always sums to the blocking total."""
    pg = _pg("compact", n=400)
    tr = _train(pg, "overlap", epochs=1)
    flops = 1e9
    exp_b, hid_b = _train(pg, "blocking", epochs=1).modeled_comm_split(
        flops, 197e12, 50e9)
    exp_o, hid_o = tr.modeled_comm_split(flops, 197e12, 50e9)
    assert hid_b == 0.0 and exp_b > 0
    assert hid_o > 0.0
    np.testing.assert_allclose(exp_o + hid_o, exp_b, rtol=1e-12)
    # pure-model invariants
    comm, compute = (3.0, 1.0, 0.5), (1.0, 2.0, 0.1)
    exp, hid = olap.split_comm_time(comm, compute, "overlap")
    assert hid == sum(min(c, w) for c, w in zip(comm, compute))
    assert exp + hid == sum(comm)
    assert (olap.modeled_step_seconds(comm, compute, "overlap")
            <= olap.modeled_step_seconds(comm, compute, "blocking"))


def test_fence_is_value_transparent():
    """The backend fence is optimization_barrier: identity on values, for
    arbitrary pytrees including empty passthrough scale/zero leaves."""
    from repro.core import quantization as qlib
    from repro.dist.backend import SimulatedBackend
    be = SimulatedBackend()
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 8))
    qt = qlib.quantize(x, 1, jax.random.PRNGKey(1), stochastic=False)
    out = be.fence(qt)
    for a, b in zip(jax.tree.leaves(qt), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    jaxpr = str(jax.make_jaxpr(be.fence)((x, x)))
    assert "optimization_barrier" in jaxpr


def test_serve_sweep_overlap_bitexact():
    """The serving sweep under schedule="overlap" (payload + affected-mask
    exchanges landed through one fence) is bit-exact to blocking."""
    from repro.dist.runtime import Runtime
    from repro.serve.engine import InferenceEngine, ServeConfig
    pg = _pg("compact", n=300)
    model = GCN(d_in=pg.x.shape[-1], d_hidden=24, d_out=pg.n_classes,
                n_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    ids = np.arange(0, 300, 7)
    out = {}
    for sched in SCHEDULES:
        eng = InferenceEngine(
            model, pg, params,
            config=ServeConfig(bits=1, stochastic=False, schedule=sched),
            runtime=Runtime.simulated(4))
        eng.full_sweep()
        out[sched] = (eng.query(ids).logits, eng.embeddings(ids))
    for a, b in zip(out["blocking"], out["overlap"]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# shard_map parity
# ---------------------------------------------------------------------------
OVERLAP_PARITY = """
import repro.api as repro
from repro.graph import synthetic
from repro.models.gnn.models import GCN
from repro.train import optimizer as opt

g = synthetic.powerlaw(n_nodes=500, d_feat=16, avg_degree=10, seed=0)
model = GCN(d_in=16, d_hidden=24, d_out=g.n_classes, n_layers=2)
rt = repro.Runtime.from_mesh(repro.make_gnn_mesh(4))
pg = repro.partition(g, n_parts=4, method="skewed", layout="compact")


def run(schedule, mode, epochs):
    cfg = repro.SylvieConfig(mode=mode, bits=1, stochastic=False,
                             schedule=schedule)
    return repro.train(model, pg, cfg, runtime=rt, opt=opt.sgd(1e-1),
                       epochs=epochs)


for mode, epochs in (("sync", 3), ("async", 4)):
    ref = run("blocking", mode, epochs)
    got = run("overlap", mode, epochs)
    assert ([m.loss for m in ref.history] == [m.loss for m in got.history]), (
        mode, [m.loss for m in ref.history], [m.loss for m in got.history])
    for pa, pb in zip(jax.tree.leaves(jax.device_get(ref.state.params)),
                      jax.tree.leaves(jax.device_get(got.state.params))):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), mode
print("OK")
"""


def test_shardmap_overlap_parity_inline():
    """Runs when the session already has >= 4 devices (the CI --overlap
    lane): overlap under shard_map is bit-exact to blocking, sync and
    async."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    env = {"repro": repro, "jax": jax, "np": np}
    exec(textwrap.dedent(OVERLAP_PARITY), env)


@pytest.mark.slow
def test_shardmap_overlap_parity_subprocess():
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, numpy as np
    """) + textwrap.dedent(OVERLAP_PARITY)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
