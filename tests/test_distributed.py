"""Multi-device equivalence tests. Each test forks a subprocess that sets
--xla_force_host_platform_device_count (jax locks device count at first init,
and the rest of the suite must see the real single device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(body: str, devices: int = 8):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


GNN_EQUIV = """
from repro.graph import synthetic, partition, formats
from repro.models.gnn import models as M, blocks as B
from repro.core.sylvie import SylvieConfig
from repro.train.gnn_step import GNNTrainState, make_gnn_steps
from repro.train import optimizer as opt
from repro.dist import api as dist

P_ = 8
g = synthetic.planted_partition(n_nodes=800, d_feat=32)
ei = formats.add_self_loops(g.edge_index, g.n_nodes)
ew = formats.gcn_edge_weights(ei, g.n_nodes)
g2 = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                   g.test_mask, n_classes=g.n_classes)
pg = partition.partition_graph(g2, P_, edge_weight=ew)
block = B.build_block(pg)
model = M.GCN(d_in=32, d_hidden=64, d_out=g.n_classes, n_layers=2)
o = opt.sgd(1e-1)   # scale-sensitive: catches any grad-scaling bug
key = jax.random.PRNGKey(0)
x = jnp.asarray(pg.x); y = jnp.asarray(pg.y); m = jnp.asarray(pg.train_mask)

cfg = SylvieConfig(mode="sync", bits=1, stochastic=False)
ts_sim, ta_sim, _ = make_gnn_steps(model, cfg, o)
st_sim = GNNTrainState.create(model, o, key, block.plan, stacked_parts=P_)
st_sim, _ = jax.jit(ts_sim)(st_sim, block, x, y, m, key)
st_sim, loss_sim = jax.jit(ta_sim)(st_sim, block, x, y, m, key)

from repro.dist import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
ts_sm, ta_sm, ev_sm = make_gnn_steps(model, cfg, o,
                                     backend=dist.ShardMapBackend(mesh))
st = GNNTrainState.create(model, o, key, block.plan, stacked_parts=P_)
ts_w, ta_w, ev_w = dist.shard_gnn_steps(ts_sm, ta_sm, ev_sm, mesh, st, block)
st_d, block_d, arrs = dist.device_put_gnn(mesh, st, block, (x, y, m))
st_d, _ = ts_w(st_d, block_d, *arrs, key)
st_d, loss_sm = ta_w(st_d, block_d, *arrs, key)
np.testing.assert_allclose(float(loss_sim), float(loss_sm), rtol=1e-5)
for a, b in zip(jax.tree.leaves(st_sim.params),
                jax.tree.leaves(jax.device_get(st_d.params))):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-6)
c, n = ev_w(st_d.params, block_d, *arrs[:2], arrs[2], key)
print("OK", float(loss_sm))
"""


DLRM_EQUIV = """
from repro.models.recsys import dlrm as D
from repro.train import optimizer as opt

cfg = D.DLRMConfig(n_dense=13, embed_dim=16, table_sizes=(50, 30, 20, 40),
                   bot_mlp=(32, 16), top_mlp=(64, 32, 1), hot=(2, 1, 1, 3))
key = jax.random.PRNGKey(0)
dp = D.init_dense_params(key, cfg)
B = 32
offs = cfg.row_offsets
rng = np.random.default_rng(0)
ids = np.concatenate([rng.integers(offs[f], offs[f+1], (B, h))
                      for f, h in enumerate(cfg.hots)],
                     axis=1).reshape(-1).astype(np.int32)
dx = jnp.asarray(rng.normal(0, 1, (B, 13)), jnp.float32)
labels = jnp.asarray(rng.integers(0, 2, B), jnp.float32)
tb1 = D.init_table(jax.random.fold_in(key, 1), cfg, n_dev=1)
o = opt.sgd(0.5)
step1 = jax.jit(D.make_train_step(cfg, o, None))
st = (dp, tb1, o.init(dp), o.init(tb1), jnp.zeros((), jnp.int32))
for i in range(8):
    st, loss1 = step1(st, dx, jnp.asarray(ids), labels, key)
from repro.dist import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
ax = ("data", "model")
rpd = D.rows_per_device(cfg, 8)
tb8 = jnp.pad(tb1, ((0, rpd*8 - tb1.shape[0]), (0, 0)))
shard = P(ax); rep = P()
sm = jax.jit(compat.shard_map(D.make_train_step(cfg, o, ax), mesh,
    in_specs=((rep, shard, rep, (), rep), shard, shard, shard, rep),
    out_specs=((rep, shard, rep, (), rep), rep)))
st8 = (dp, tb8, o.init(dp), o.init(tb8), jnp.zeros((), jnp.int32))
for i in range(8):
    st8, loss8 = sm(st8, dx, jnp.asarray(ids), labels, key)
np.testing.assert_allclose(float(loss1), float(loss8), rtol=1e-4)
np.testing.assert_allclose(np.asarray(st[1])[:cfg.total_rows],
    np.asarray(jax.device_get(st8[1]))[:cfg.total_rows], rtol=1e-3, atol=1e-5)
# quantized embedding exchange (beyond-paper) trains too
cfgq = D.DLRMConfig(n_dense=13, embed_dim=16, table_sizes=(50, 30, 20, 40),
                    bot_mlp=(32, 16), top_mlp=(64, 32, 1), hot=(2, 1, 1, 3),
                    quantize_collective_bits=8)
smq = jax.jit(compat.shard_map(D.make_train_step(cfgq, o, ax), mesh,
    in_specs=((rep, shard, rep, (), rep), shard, shard, shard, rep),
    out_specs=((rep, shard, rep, (), rep), rep)))
stq = (dp, tb8, o.init(dp), o.init(tb8), jnp.zeros((), jnp.int32))
for i in range(8):
    stq, lossq = smq(stq, dx, jnp.asarray(ids), labels,
                     jax.random.fold_in(key, i))
assert abs(float(lossq) - float(loss8)) < 0.1
print("OK", float(loss8), float(lossq))
"""


LM_GSPMD = """
import sys; sys.path.insert(0, {src!r})
from repro import configs as configlib
from repro.models.lm import model as LM
from repro.models.lm import sharding as lm_sharding
from repro.train import optimizer as optlib
from jax.sharding import NamedSharding

cfg = configlib.get("olmoe-1b-7b").reduced()
key = jax.random.PRNGKey(0)
params = LM.init_params(key, cfg, dtype=jnp.float32)
tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab)
labels = jax.random.randint(jax.random.fold_in(key, 1), (8, 16), 0, cfg.vocab)
o = optlib.adam(1e-3)
state = (params, o.init(params), jnp.zeros((), jnp.int32))
ts = jax.jit(LM.make_train_step(cfg, o))
state1, loss1 = ts(state, tokens, labels)

from repro.dist import compat
mesh = compat.make_mesh((2, 2), ("data", "model"))
p_specs = lm_sharding.param_specs(params, cfg, mesh)
pp = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                         p_specs))
state_d = (pp, o.init(pp), jnp.zeros((), jnp.int32))
LM.set_shard_ctx(LM.shard_ctx_from_mesh(mesh))
with compat.use_mesh(mesh):
    ts_d = jax.jit(LM.make_train_step(cfg, o))
    state2, loss2 = ts_d(state_d, tokens, labels)
LM.set_shard_ctx(None)
np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-4)
for a, b in zip(jax.tree.leaves(state1[0]),
                jax.tree.leaves(jax.device_get(state2[0]))):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-4)
print("OK", float(loss2))
"""


@pytest.mark.slow
def test_gnn_shard_map_equals_simulated():
    assert "OK" in _run(GNN_EQUIV)


@pytest.mark.slow
def test_dlrm_shard_map_equals_single_device():
    assert "OK" in _run(DLRM_EQUIV)


@pytest.mark.slow
def test_lm_gspmd_sharded_equals_single_device():
    assert "OK" in _run(LM_GSPMD.format(src=SRC), devices=4)
