"""repro.faults: seeded chaos schedules, one-bit wire checksums, recovery.

Acceptance contracts under test (ISSUE: fault-tolerant runtime):
  * schedules are deterministic in (seed, epoch) alone, epoch 0 is clean
    under ``warmup_clean``, corruption/delay are disjoint from drops, and a
    preempted partition folds every one of its messages into drops;
  * event -> wire-row mask expansion respects both layouts' geometry and the
    forward/backward buffer flip;
  * a corrupted 1-bit payload is *detected* by the per-row checksum and
    handled exactly like a drop — never silently dequantized;
  * a rate-0 plan is bit-identical to no plan at all (sync + async), and a
    seeded schedule dropping >= 10% of exchanges on ``yelp_like@smoke``
    trains to within 2% test accuracy of the fault-free twin, with
    ``faults_injected == halos_reused + forced_syncs`` exact on every epoch;
  * staleness-as-recovery escalates: a site faulted ``escalate_after``
    consecutive epochs forces one clean full-precision synchronous retry, and
    ``BoundedStaleness`` treats fault staleness like scheduled staleness;
  * arming faults costs exactly one extra traced executable (masks are data);
  * checkpointing GCs ``.tmp_step_*`` crash orphans, and the kill-and-resume
    harness proves bit-exact resume under Uniform/sync (`slow`: subprocess);
  * serving keeps answering 100% of in-deadline requests while a partition
    is down, with correct per-partition staleness stamps, typed admission
    rejections, deadline expiry, and refresh-failure degradation.
"""
import dataclasses
import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import datasets
from repro.core.exchange import PlanArrays, exchange_quantized_halo, gather_boundary
from repro.core.quantization import quantize
from repro.core.sylvie import SylvieConfig
from repro.dist.backend import SimulatedBackend
from repro.faults import (BWD, FWD, FaultCtl, FaultPlan, RowGeometry,
                          checked_exchange, flip_rows, row_checksum)
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN, PAPER_ARCHS
from repro.policy import BoundedStaleness, Telemetry, Uniform
from repro.serve import EmbeddingServer, InferenceEngine, Rejection, ServeConfig
from repro.serve.loadgen import closed_loop
from repro.train import gnn_step
from repro.train.checkpoint import latest_step
from repro.train.trainer import GNNTrainer

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _graph(n=240, d=16, seed=0):
    g = synthetic.planted_partition(n_nodes=n, d_feat=d, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _trainer(mode="sync", fault_plan=None, parts=4, seed=0, policy=None,
             layout="compact", **kw):
    g, ew = _graph(seed=seed)
    pg = partition.partition_graph(g, parts, edge_weight=ew, layout=layout)
    model = GCN(d_in=16, d_hidden=24, d_out=g.n_classes, n_layers=2)
    return GNNTrainer(model, pg, SylvieConfig(mode=mode),
                      policy=policy or Uniform(bits=1), seed=seed,
                      fault_plan=fault_plan, **kw)


# ---------------------------------------------------------------------------
# FaultPlan: seeded schedules
# ---------------------------------------------------------------------------
def test_plan_events_deterministic_and_warmup_clean():
    plan = FaultPlan(seed=5, drop_rate=0.3, corrupt_rate=0.1, delay_rate=0.1)
    a, b = plan.events(3, 2, 4), plan.events(3, 2, 4)
    assert (a.drop == b.drop).all() and (a.corrupt == b.corrupt).all()
    assert (a.delay == b.delay).all() and (a.preempted == b.preempted).all()
    assert (plan.events(4, 2, 4).drop != a.drop).any()          # epoch-keyed
    other = dataclasses.replace(plan, seed=6).events(3, 2, 4)
    assert (other.drop != a.drop).any()                         # seed-keyed
    e0 = plan.events(0, 2, 4)
    assert e0.n_injected == 0 and not e0.delay.any()            # warmup
    hot = dataclasses.replace(plan, warmup_clean=False).events(0, 2, 4)
    assert hot.n_injected > 0


def test_plan_faults_offdiagonal_and_disjoint():
    ev = FaultPlan(seed=1, drop_rate=0.5, corrupt_rate=0.5,
                   delay_rate=0.5).events(2, 2, 4)
    eye = np.eye(4, dtype=bool)
    for field in (ev.drop, ev.corrupt, ev.delay):
        assert not field[:, :, eye].any()       # no self-messages
    assert not (ev.corrupt & ev.drop).any()     # lost != corrupted
    assert not (ev.delay & ev.drop).any()       # lost != late
    assert ev.n_injected == int(ev.drop.sum() + ev.corrupt.sum())


def test_plan_preemption_folds_into_drop():
    ev = FaultPlan(seed=0, preempt_rate=1.0).events(1, 2, 4)
    assert ev.preempted.all()
    off = ~np.eye(4, dtype=bool)
    assert ev.drop[:, :, off].all()             # every real message lost
    assert not ev.corrupt.any()                 # folded, not double-counted
    assert ev.n_injected == 2 * 2 * 4 * 3 == FaultPlan.n_units(2, 4)


def test_plan_stall_is_critical_path_not_total():
    plan = FaultPlan(delay_s=0.25)
    shape = (1, 2, 4, 4)
    delay = np.zeros(shape, bool)
    delay[0, FWD, 0, 2] = delay[0, FWD, 1, 2] = True    # 2 pile up on dst 2
    delay[0, BWD, 0, 3] = True
    ev = dataclasses.replace(plan.events(0, 1, 4), delay=delay)
    assert ev.stall_s(plan.delay_s) == pytest.approx(0.5)   # 2 * 0.25, not 3
    assert plan.events(0, 1, 4).stall_s(plan.delay_s) == 0.0


# ---------------------------------------------------------------------------
# event -> wire-row mask geometry
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_fault_ctl_expand_geometry(layout):
    g, ew = _graph()
    pg = partition.partition_graph(g, 4, edge_weight=ew, layout=layout)
    geom = RowGeometry.from_plan(PlanArrays.from_plan(pg.plan))
    peer_recv, peer_send = geom.peers()
    src, dst = 1, 2
    ev = FaultPlan().events(0, 2, 4)    # all-false template
    drop = np.zeros_like(ev.drop)
    drop[0, FWD, src, dst] = True       # forward message src -> dst lost
    drop[1, BWD, src, dst] = True       # backward gradient src -> dst lost
    ctl = FaultCtl.expand(dataclasses.replace(ev, drop=drop), geom, 2)
    # forward drop masks the *recv* buffer of dst, exactly the rows fed by src
    df = np.asarray(ctl.sites[0].drop_fwd)
    assert df[dst].sum() == (peer_recv[dst] == src).sum() > 0
    assert (df[np.arange(4) != dst] == False).all()  # noqa: E712
    # backward drop masks the returned-grad (send-geometry) buffer of dst
    db = np.asarray(ctl.sites[1].drop_bwd)
    assert db[dst].sum() == (peer_send[dst] == src).sum() > 0
    assert (db[np.arange(4) != dst] == False).all()  # noqa: E712
    # untouched site/masks stay all-false
    assert not np.asarray(ctl.sites[1].drop_fwd).any()
    assert not np.asarray(ctl.sites[0].corrupt_fwd).any()
    # clean() shares the pytree structure (one executable for recovery epochs)
    clean = FaultCtl.clean(geom, 2)
    assert (jax.tree_util.tree_structure(clean)
            == jax.tree_util.tree_structure(ctl))
    assert not any(bool(leaf.any()) for leaf in jax.tree_util.tree_leaves(clean))


# ---------------------------------------------------------------------------
# wire: checksum detection of corrupted payloads
# ---------------------------------------------------------------------------
def test_flip_rows_checksum_detects_exactly_masked_rows():
    rng = np.random.default_rng(0)
    for data in (jnp.asarray(rng.integers(0, 255, (4, 6, 3), dtype=np.uint8)),
                 jnp.asarray(rng.normal(size=(4, 6, 3)).astype(np.float32))):
        mask = jnp.asarray(rng.random((4, 6)) < 0.4)
        flipped = flip_rows(data, mask)
        changed = np.asarray((row_checksum(flipped)
                              != row_checksum(data)))
        assert (changed == np.asarray(mask)).all()      # exact detection
        # the flip is an involution: re-flipping restores the payload
        assert (np.asarray(flip_rows(flipped, mask)) == np.asarray(data)).all()


@pytest.mark.parametrize("layout", ["dense", "compact"])
def test_checked_exchange_never_silently_dequantizes_corruption(layout):
    g, ew = _graph()
    pg = partition.partition_graph(g, 4, edge_weight=ew, layout=layout)
    plan = PlanArrays.from_plan(pg.plan)
    be = SimulatedBackend()
    qt = quantize(gather_boundary(jnp.asarray(pg.x), plan), bits=1,
                  stochastic=False)
    ref = exchange_quantized_halo(qt, plan, be)
    zeros = jnp.zeros((plan.n_parts, plan.halo_rows), bool)
    # fault-free: bitwise-identical wire payload, every row ok
    qr, ok = checked_exchange(qt, plan, be, zeros, zeros)
    assert (np.asarray(qr.data) == np.asarray(ref.data)).all()
    assert np.asarray(ok).all()
    # corrupted rows: each lands on exactly one receiver row, every one is
    # caught by the checksum, and the payload differs on exactly those rows
    rng = np.random.default_rng(1)
    corrupt = jnp.asarray(rng.random((plan.n_parts, plan.halo_rows)) < 0.3)
    qr, ok = checked_exchange(qt, plan, be, corrupt, zeros)
    bad = ~np.asarray(ok)
    assert bad.sum() == int(np.asarray(corrupt).sum()) > 0
    differs = (np.asarray(qr.data) != np.asarray(ref.data)).reshape(
        plan.n_parts, plan.halo_rows, -1).any(axis=-1)
    assert (differs == bad).all()
    # drops condemn their rows even though the payload is intact
    dropm = jnp.asarray(rng.random((plan.n_parts, plan.halo_rows)) < 0.3)
    _, ok = checked_exchange(qt, plan, be, zeros, dropm)
    assert (np.asarray(ok) == ~np.asarray(dropm)).all()


# ---------------------------------------------------------------------------
# trainer integration: transparency, accuracy, accounting
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_rate_zero_plan_bit_identical_to_no_plan(mode):
    a = _trainer(mode=mode)
    b = _trainer(mode=mode, fault_plan=FaultPlan())    # armed, all rates 0
    la = [a.train_epoch() for _ in range(3)]
    lb = [b.train_epoch() for _ in range(3)]
    assert [m.loss for m in la] == [m.loss for m in lb]          # exact
    assert all(m.faults_injected == m.halos_reused == m.forced_syncs == 0
               for m in lb)


def test_corruption_is_handled_as_drop_and_accounted():
    tr = _trainer(mode="async",
                  fault_plan=FaultPlan(seed=2, corrupt_rate=0.3))
    hist = [tr.train_epoch() for _ in range(3)]
    assert hist[0].faults_injected == 0                          # warmup
    assert sum(m.faults_injected for m in hist) > 0
    for m in hist:       # every corrupted unit recovered from the cache
        assert m.faults_injected == m.halos_reused + m.forced_syncs
        assert np.isfinite(m.loss)


def test_chaos_training_within_2pct_of_fault_free():
    """The headline acceptance run: >= 10% of exchanges dropped on
    ``yelp_like@smoke``, final test accuracy within 2% of the clean twin,
    accounting exact on every epoch."""
    epochs = 6
    plan = FaultPlan(seed=7, drop_rate=0.15, corrupt_rate=0.05)

    def run(fault_plan):
        pg, _ = datasets.load_partitioned("yelp_like@smoke", 4, seed=0)
        model = PAPER_ARCHS["gcn"](pg.x.shape[-1], pg.n_classes)
        tr = GNNTrainer(model, pg, SylvieConfig(mode="async"),
                        policy=Uniform(bits=1), seed=0, fault_plan=fault_plan)
        hist = [tr.train_epoch() for _ in range(epochs)]
        return tr, hist

    clean_tr, _ = run(None)
    tr, hist = run(plan)
    n_sites = tr.n_sites
    units = FaultPlan.n_units(n_sites, 4) * (epochs - 1)    # epoch 0 clean
    dropped = sum(int(plan.events(e, n_sites, 4).drop.sum())
                  for e in range(1, epochs))
    assert dropped / units >= 0.10, "schedule too mild for the claim"
    for m in hist:
        assert m.faults_injected == m.halos_reused + m.forced_syncs
    assert sum(m.faults_injected for m in hist) > 0
    acc_clean, acc_faulty = clean_tr.evaluate("test"), tr.evaluate("test")
    assert abs(acc_clean - acc_faulty) <= 0.02, \
        f"chaos run lost {acc_clean - acc_faulty:.3f} accuracy"


def test_escalation_forces_clean_sync_recovery_epoch():
    plan = FaultPlan(seed=0, drop_rate=1.0, escalate_after=2)
    tr = _trainer(mode="async", fault_plan=plan)
    hist = [tr.train_epoch() for _ in range(5)]
    # epoch 0 clean, 1-2 degrade (staleness 1, 2), 3 is the forced recovery,
    # 4 degrades again from a reset counter
    assert hist[0].faults_injected == 0
    for m in (hist[1], hist[2], hist[4]):
        assert m.mode == "async"
        assert m.faults_injected == m.halos_reused > 0
        assert m.forced_syncs == 0
    rec = hist[3]
    assert rec.mode == "sync"                        # forced synchronous
    assert all(b == (32, 32) for b in rec.bits_per_site)   # full precision
    assert rec.forced_syncs == rec.faults_injected > 0     # schedule suppressed
    assert rec.halos_reused == 0
    assert (tr._site_staleness == 1).all()           # reset at 3, rearmed at 4


def test_bounded_staleness_counts_fault_staleness():
    pol = BoundedStaleness(eps_s=3, bits=1)
    tel = Telemetry(epoch=5, n_parts=4, n_sites=2, site_dims=(16, 24))
    base = dataclasses.replace(tel, site_staleness=(0, 2))
    assert not pol.decide(base).sync                 # under the bound
    due = dataclasses.replace(tel, site_staleness=(3, 0))
    assert pol.decide(due).sync                      # fault staleness counts


def test_armed_faults_share_one_executable():
    """Masks ride as data: an armed trainer traces exactly ONE sync
    executable across the clean warmup epoch and every faulty epoch — the
    epoch's fault set only changes mask *values*, never program structure."""
    tr = _trainer(mode="sync", fault_plan=FaultPlan(seed=3, drop_rate=0.5))
    base = len(gnn_step.TRACE_LOG)
    for _ in range(4):
        tr.train_epoch()
    assert len(gnn_step.TRACE_LOG) - base == 1


# ---------------------------------------------------------------------------
# preemption-safe checkpointing
# ---------------------------------------------------------------------------
def test_latest_step_gcs_crash_orphans(tmp_path):
    (tmp_path / "step_00000003").mkdir()
    orphan = tmp_path / ".tmp_step_00000004"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial garbage")
    assert latest_step(tmp_path) == 3
    assert not orphan.exists()                       # GC'd, not trusted
    assert (tmp_path / "step_00000003").exists()


def _chaos(tmp_path, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.chaos", "--kill-resume",
           "--epochs", "4", "--out-dir", str(tmp_path), *extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    return json.loads(r.stdout)


@pytest.mark.slow
def test_kill_resume_bit_exact_uniform_sync(tmp_path):
    out = _chaos(tmp_path, "--policy", "uniform:1", "--mode", "sync")
    assert out["bit_exact"] and out["max_deviation"] == 0.0


@pytest.mark.slow
def test_kill_resume_bit_exact_uniform_sync_shard_map(tmp_path):
    env_extra = ("--runtime", "sharded")
    os.environ.setdefault("XLA_FLAGS", "")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", "")).strip()
    cmd = [sys.executable, "-m", "repro.launch.chaos", "--kill-resume",
           "--epochs", "4", "--out-dir", str(tmp_path), "--policy",
           "uniform:1", "--mode", "sync", *env_extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout)
    assert out["bit_exact"] and out["max_deviation"] == 0.0


@pytest.mark.slow
def test_kill_resume_within_tolerance_bounded_staleness(tmp_path):
    """BoundedStaleness/async under a live fault schedule: the resume path
    resets staleness counters (a deliberately conservative forced refresh),
    so bit-exactness is not guaranteed — final accuracy must still match the
    uninterrupted run within the chaos tolerance."""
    _chaos(tmp_path, "--policy", "bounded_staleness:4:1", "--mode", "async",
           "--fault", "drop=0.1,seed=3")
    ref = json.loads((tmp_path / "ref.json").read_text())
    res = json.loads((tmp_path / "resumed.json").read_text())
    assert res["epochs"] == ref["epochs"] == 4
    assert abs(ref["test_acc"] - res["test_acc"]) <= 0.02


# ---------------------------------------------------------------------------
# serving robustness: degraded mode, deadlines, typed rejections
# ---------------------------------------------------------------------------
def _engine(parts=4, n=240):
    g, ew = _graph(n=n)
    pg = partition.partition_graph(g, parts, edge_weight=ew, layout="compact")
    model = GCN(d_in=16, d_hidden=24, d_out=g.n_classes, n_layers=2)
    eng = InferenceEngine(model, pg, model.init(jax.random.PRNGKey(0)),
                          config=ServeConfig(bits=32))
    eng.full_sweep()
    return eng, pg


def test_degraded_serving_answers_all_in_deadline_with_stamps():
    eng, pg = _engine()
    srv = EmbeddingServer(eng, microbatch=64)
    before = eng.logits.copy()
    srv.mark_partition_down(1)
    assert srv.health == "degraded"
    eng.full_sweep()             # the sweep the partition missed
    part_of = np.asarray(pg.part_of)
    n = part_of.size
    answered = []
    for start in range(0, n, 64):
        ids = np.arange(start, min(start + 64, n))
        rid = srv.submit(ids, deadline_s=60.0)
        assert not isinstance(rid, Rejection)
        answered.extend(srv.step())
    assert srv.expired == 0
    assert sum(r.node_ids.size for r in answered) == n     # 100% answered
    for r in answered:
        # stamps: 1 sweep stale exactly for nodes on the downed partition
        assert (np.asarray(r.staleness)
                == (part_of[r.node_ids] == 1).astype(np.int64)).all()
        # downed partition serves its frozen (pre-sweep) cache rows
        frozen = part_of[r.node_ids] == 1
        assert np.array_equal(r.logits[frozen], before[r.node_ids][frozen])
    srv.mark_partition_up(1)
    assert srv.health == "healthy"
    eng.full_sweep()
    assert (eng.part_staleness == 0).all()


def test_deadline_expiry_with_injected_clock():
    eng, _ = _engine()
    now = [0.0]
    srv = EmbeddingServer(eng, microbatch=8, clock=lambda: now[0])
    rid = srv.submit([1, 2], deadline_s=0.5)
    assert not isinstance(rid, Rejection)
    now[0] = 1.0                                   # past the deadline
    assert srv.step() == []
    assert srv.expired == 1 and srv.depth == 0
    rid = srv.submit([3], deadline_s=5.0)          # in-deadline still serves
    [resp] = srv.step()
    assert resp.req_id == rid


def test_refresh_failure_degrades_and_recovers():
    eng, pg = _engine()
    srv = EmbeddingServer(eng)
    bad = np.zeros((2, 3), np.float32)             # wrong feature width
    assert srv.refresh(np.array([0, 1]), bad) is None
    assert srv.health == "degraded" and srv.refresh_failures == 1
    [resp] = (srv.submit([0]), srv.step())[1]      # still answering
    assert np.isfinite(resp.logits).all()
    good = np.zeros((1, pg.x.shape[-1]), np.float32)
    assert srv.refresh(np.array([0]), good) is not None
    assert srv.health == "healthy"


def test_loadgen_reports_rejections_backoff_and_draining():
    eng, _ = _engine()
    srv = EmbeddingServer(eng, microbatch=16, max_queue=1)
    rep = closed_loop(srv, n_nodes=200, clients=4, batch=8, requests=40,
                      seed=0)
    assert rep["requests"] == 40                   # retries win through
    assert rep["rejection_reasons"].get("queue_full", 0) > 0
    assert rep["backoff_s"] > 0.0
    drained = EmbeddingServer(eng)
    drained.start_draining()
    rep = closed_loop(drained, n_nodes=200, clients=2, batch=4, requests=10,
                      seed=0)
    assert rep["requests"] == 0
    assert rep["rejection_reasons"] == {"draining": 1}
