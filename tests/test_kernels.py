"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as qcore
from repro.kernels.quant import ref as qref
from repro.kernels.quant.quant import quantize_pack, unpack_dequantize
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmm.spmm import spmm

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("rows,d", [(7, 5), (300, 64), (257, 1433), (64, 288)])
def test_quant_kernel_matches_ref(bits, rows, d):
    h = jax.random.normal(jax.random.fold_in(KEY, rows * d + bits), (rows, d))
    u = jax.random.uniform(jax.random.fold_in(KEY, 1), (rows, d), jnp.float32)
    p, s, z = quantize_pack(h, u, bits=bits, interpret=True)
    pr, sr, zr = qref.quantize_pack_ref(h, u, bits)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6)
    out = unpack_dequantize(p, s, z, bits, d, interpret=True)
    outr = qref.unpack_dequantize_ref(pr, sr, zr, bits, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("bits", [1, 4])
def test_quant_kernel_matches_core_semantics(bits):
    """Kernel path == core/quantization.py path given the same uniforms."""
    rows, d = 96, 72
    h = jax.random.normal(KEY, (rows, d))
    u = jax.random.uniform(jax.random.fold_in(KEY, 2), (rows, d), jnp.float32)
    p, s, z = quantize_pack(h, u, bits=bits, interpret=True)
    out = unpack_dequantize(p, s, z, bits, d, interpret=True)

    big = 2.0**bits - 1.0
    lo = jnp.min(h, -1, keepdims=True)
    hi = jnp.max(h, -1, keepdims=True)
    hbar = (h - lo) / jnp.where(hi - lo > 0, hi - lo, 1.0) * big
    qv = jnp.floor(hbar) + (u < (hbar - jnp.floor(hbar)))
    expected = jnp.clip(qv, 0, big) * (hi - lo) / big + lo
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_dtypes(dtype):
    rows, d = 33, 48
    h = jax.random.normal(KEY, (rows, d)).astype(dtype)
    u = jax.random.uniform(KEY, (rows, d), jnp.float32)
    p, s, z = quantize_pack(h.astype(jnp.float32), u, bits=1, interpret=True)
    out = unpack_dequantize(p, s, z, 1, d, interpret=True)
    assert out.shape == (rows, d)
    assert not np.isnan(np.asarray(out)).any()


@pytest.mark.parametrize("n_src,n_rows,max_deg,d",
                         [(50, 40, 6, 16), (1000, 300, 12, 200),
                          (700, 700, 32, 75), (4000, 128, 64, 288)])
def test_spmm_kernel_matches_ref(n_src, n_rows, max_deg, d):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, n_src), 3)
    table = jax.random.normal(k1, (n_src, d))
    idx = jax.random.randint(k2, (n_rows, max_deg), 0, n_src)
    w = jax.random.normal(k3, (n_rows, max_deg)) \
        * (jax.random.uniform(k3, (n_rows, max_deg)) > 0.3)
    out = spmm(table, idx, w, interpret=True, src_tile=max(64, n_src // 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(spmm_ref(table, idx, w)),
                               rtol=2e-4, atol=1e-4)


def test_spmm_kernel_tiling_invariance():
    """Result must not depend on block sizes."""
    table = jax.random.normal(KEY, (500, 96))
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (200, 10), 0, 500)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (200, 10))
    ref = spmm_ref(table, idx, w)
    for rb, db, st in [(64, 32, 100), (256, 96, 500), (200, 128, 128)]:
        out = spmm(table, idx, w, rows_blk=rb, d_blk=db, src_tile=st,
                   interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=1e-4)


def test_spmm_gcn_aggregation_equivalence():
    """Kernel reproduces the runtime's segment_sum aggregation on a real
    partitioned graph (single partition)."""
    from repro.graph import formats, synthetic
    g = synthetic.planted_partition(n_nodes=300, d_feat=32)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    h = jnp.asarray(g.x)
    # runtime: gather + segment_sum
    src, dst = ei
    msgs = h[src] * ew[:, None]
    ref = jax.ops.segment_sum(msgs, jnp.asarray(dst), num_segments=g.n_nodes)
    # kernel: padded-CSR
    from repro.kernels.spmm.ref import csr_from_edges
    deg = np.bincount(dst, minlength=g.n_nodes)
    idx, w = csr_from_edges(ei.T, ew, g.n_nodes, int(deg.max()))
    out = spmm(h, jnp.asarray(idx), jnp.asarray(w), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# core/quantization dispatch seam: impl="pallas" == impl="jnp" bit-exactly
# (same PRNG key -> same uniform noise -> same packed payload)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("shape", [(37, 24), (4, 50, 64), (2, 96, 288)])
def test_quant_dispatch_pallas_matches_jnp(bits, shape):
    h = jax.random.normal(jax.random.fold_in(KEY, bits + sum(shape)), shape)
    key = jax.random.fold_in(KEY, 9)
    qp = qcore.quantize(h, bits, key, stochastic=True, impl="pallas")
    qj = qcore.quantize(h, bits, key, stochastic=True, impl="jnp")
    np.testing.assert_array_equal(np.asarray(qp.data), np.asarray(qj.data))
    np.testing.assert_array_equal(np.asarray(qp.scale), np.asarray(qj.scale))
    np.testing.assert_array_equal(np.asarray(qp.zero), np.asarray(qj.zero))
    dp = qcore.dequantize(qp, impl="pallas")
    dj = qcore.dequantize(qj, impl="jnp")
    assert dp.shape == h.shape
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dj), rtol=1e-6,
                               atol=1e-6)


def test_quant_dispatch_resolution_and_fallback():
    assert qcore.resolve_impl("jnp") == "jnp"
    assert qcore.resolve_impl("pallas") == "pallas"
    # auto: Pallas only on TPU
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert qcore.resolve_impl(None) == expect == qcore.resolve_impl("auto")
    with pytest.raises(ValueError):
        qcore.resolve_impl("cuda")
    # cases the kernel doesn't cover fall back to jnp silently:
    h = jax.random.normal(KEY, (16, 12))
    for bits, kw in [(3, dict(key=KEY)),                  # unpackable width
                     (1, dict(stochastic=False)),         # deterministic
                     (32, dict(key=KEY))]:                # passthrough
        qt = qcore.quantize(h, bits, impl="pallas", **kw)
        ref = qcore.quantize(h, bits, impl="jnp", **kw)
        np.testing.assert_array_equal(np.asarray(qt.data), np.asarray(ref.data))


# ---------------------------------------------------------------------------
# flash attention (kernels/flash) — the §Perf-identified LM memory lever
# ---------------------------------------------------------------------------
from repro.kernels.flash.ops import flash_attention, flash_ref  # noqa: E402


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
@pytest.mark.parametrize("bh,s,d,blkq,blkk", [(4, 64, 32, 16, 16),
                                              (2, 100, 64, 32, 32),
                                              (2, 128, 128, 128, 128),
                                              (3, 96, 16, 32, 16)])
def test_flash_matches_dense_reference(causal, window, bh, s, d, blkq, blkk):
    q = jax.random.normal(jax.random.fold_in(KEY, s), (bh, s, d))
    k = jax.random.normal(jax.random.fold_in(KEY, s + 1), (bh, s, d))
    v = jax.random.normal(jax.random.fold_in(KEY, s + 2), (bh, s, d))
    out = flash_attention(q, k, v, causal=causal, scale=d**-0.5,
                          window=window, blk_q=blkq, blk_k=blkk)
    ref = flash_ref(q, k, v, causal=causal, scale=d**-0.5, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


def test_flash_block_size_invariance():
    q = jax.random.normal(KEY, (2, 80, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 80, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 80, 32))
    ref = flash_attention(q, k, v, blk_q=80, blk_k=80, scale=32**-0.5)
    for bq, bk in [(16, 16), (40, 20), (80, 16)]:
        out = flash_attention(q, k, v, blk_q=bq, blk_k=bk, scale=32**-0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_flash_matches_model_blockwise_attention():
    """Kernel == the LM runtime's pure-JAX blockwise attention path."""
    from repro.models.lm import model as LM
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, d))
    ref = LM.blockwise_attention(q, k, v, causal=True, window=None,
                                 softcap=None, q_offset=0, kv_len=s,
                                 block=16, scale=d**-0.5)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = flash_attention(qf, kf, vf, causal=True, scale=d**-0.5,
                          blk_q=16, blk_k=16)
    out = out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)
