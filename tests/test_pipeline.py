"""Data pipeline: prefetch ordering, error propagation, synthetic streams."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as configlib
from repro.data.pipeline import Prefetcher, criteo_stream, token_stream


def test_prefetcher_preserves_order_and_values():
    batches = [(np.full((2, 2), i), np.full((2,), i)) for i in range(10)]
    out = list(Prefetcher(iter(batches)))
    assert len(out) == 10
    for i, (a, b) in enumerate(out):
        np.testing.assert_array_equal(np.asarray(a), i)


def test_prefetcher_overlaps_host_work():
    def slow_gen():
        for i in range(5):
            time.sleep(0.05)
            yield np.zeros(4)
    pf = Prefetcher(slow_gen(), depth=4)
    time.sleep(0.3)                       # producer fills the queue meanwhile
    t0 = time.time()
    for _ in pf:
        pass
    assert time.time() - t0 < 0.2         # consumption hits the buffer


def test_prefetcher_propagates_errors():
    def bad():
        yield np.zeros(2)
        raise RuntimeError("boom")
    it = Prefetcher(bad())
    next(it)
    with pytest.raises(RuntimeError, match="boom"):
        for _ in it:
            pass


def test_prefetcher_surfaces_midstream_error_after_buffered_batches():
    """A producer that dies mid-stream (after the queue is already full)
    must first deliver every batch it produced, then raise — not hang, not
    swallow the error, not reorder."""
    def bad():
        for i in range(4):
            yield np.full((2,), i)
        raise ValueError("died at batch 4")

    it = Prefetcher(bad(), depth=2)       # queue smaller than the stream
    time.sleep(0.2)                       # producer blocks on the full queue
    got = []
    with pytest.raises(ValueError, match="died at batch 4"):
        for batch in it:
            got.append(int(np.asarray(batch)[0]))
    assert got == [0, 1, 2, 3]            # all good batches arrived, in order
    with pytest.raises(StopIteration):    # the error is raised exactly once
        next(it)


def test_token_stream_shapes_and_determinism():
    a = list(token_stream(100, 4, 8, seed=3, n_batches=3))
    b = list(token_stream(100, 4, 8, seed=3, n_batches=3))
    for (t1, l1), (t2, l2) in zip(a, b):
        np.testing.assert_array_equal(t1, t2)
        assert t1.shape == (4, 8) and l1.shape == (4, 8)
        assert t1.max() < 100


def test_criteo_stream_ids_in_table_ranges():
    cfg = configlib.get("dlrm-mlperf").reduced()
    offs = cfg.row_offsets
    for dense, flat, label in criteo_stream(cfg, 8, n_batches=2):
        assert dense.shape == (8, cfg.n_dense)
        assert flat.shape == (8 * cfg.total_ids_per_sample,)
        ids = flat.reshape(8, -1)
        col = 0
        for f, h in enumerate(cfg.hots):
            part = ids[:, col:col + h]
            assert (part >= offs[f]).all() and (part < offs[f + 1]).all()
            col += h
        assert set(np.unique(label)) <= {0.0, 1.0}
