"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment: the
FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as configlib
from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn import blocks as B
from repro.models.lm import model as LM
from repro.models.recsys import dlrm as D
from repro.train import optimizer as opt
from repro.train.gnn_step import GNNTrainState, make_gnn_steps

KEY = jax.random.PRNGKey(0)
GNN_ARCHS = ["nequip", "schnet", "meshgraphnet", "pna", "gcn", "graphsage",
             "gat"]
# the two heaviest reduced configs dominate the fast lane's wall clock
# (>10s each even at smoke scale) — they ride in the slow suite instead
LM_ARCHS = ["granite-3-2b",
            pytest.param("gemma2-27b", marks=pytest.mark.slow),
            "yi-34b", "olmoe-1b-7b",
            pytest.param("deepseek-v2-236b", marks=pytest.mark.slow)]


def _geometric_graph(d_feat=8):
    g = synthetic.molecules(n_nodes=40, d_feat=d_feat, seed=1)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g2 = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                       g.test_mask, pos=g.pos, n_classes=g.n_classes)
    g2.edge_attr = B.geometry_edge_attr(g2)
    return g2, ew


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_arch_smoke(arch_id):
    spec = configlib.get(arch_id)
    arch = spec.reduced()
    g, ew = _geometric_graph()
    pg = partition.partition_graph(g, 2, edge_weight=ew)
    block = B.build_block(pg)
    model = arch.make(g.x.shape[1], g.n_classes)
    o = opt.adam(1e-2)
    ts, ta, ev = make_gnn_steps(model, SylvieConfig(mode="sync", bits=1), o)
    st = GNNTrainState.create(model, o, KEY, block.plan, stacked_parts=2)
    x = jnp.asarray(pg.x)
    y = jnp.asarray(pg.y)
    m = jnp.asarray(pg.train_mask)
    st2, loss = jax.jit(ts)(st, block, x, y, m, KEY)
    assert np.isfinite(float(loss))
    st3, loss_a = jax.jit(ta)(st2, block, x, y, m, KEY)     # async also runs
    assert np.isfinite(float(loss_a))
    for leaf in jax.tree.leaves(st3.params):
        assert not np.isnan(np.asarray(leaf)).any()


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id):
    spec = configlib.get(arch_id)
    cfg = spec.reduced()
    params = LM.init_params(KEY, cfg, dtype=jnp.float32)
    b, s = 2, 24
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0,
                                cfg.vocab)
    logits, aux, _ = LM.forward(params, tokens, cfg)
    assert logits.shape == (b, s, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    o = opt.adam(1e-3)
    ts = jax.jit(LM.make_train_step(cfg, o))
    state = (params, o.init(params), jnp.zeros((), jnp.int32))
    state, loss = ts(state, tokens, labels)
    state, loss2 = ts(state, tokens, labels)
    assert np.isfinite(float(loss2)) and float(loss2) < float(loss) + 1.0
    # serve: prefill + one decode token
    pf = jax.jit(LM.make_prefill_step(cfg, b, s))
    last, caches = pf(params, tokens)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               rtol=5e-2, atol=5e-2)
    dec = jax.jit(LM.make_decode_step(cfg))
    caches2 = LM.init_cache(cfg, b, 2 * s, dtype=jnp.float32)
    _, _, caches2 = LM.forward(params, tokens, cfg, caches=caches2,
                               cache_pos=0, kv_len=s)
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    lg, _ = dec(params, caches2, nxt, jnp.asarray(s, jnp.int32))
    assert lg.shape == (b, cfg.vocab)
    assert not np.isnan(np.asarray(lg)).any()


def test_dlrm_arch_smoke():
    cfg = configlib.get("dlrm-mlperf").reduced()
    dp = D.init_dense_params(KEY, cfg)
    tb = D.init_table(jax.random.fold_in(KEY, 1), cfg)
    rng = np.random.default_rng(0)
    B_ = 16
    offs = cfg.row_offsets
    ids = np.concatenate([rng.integers(offs[f], offs[f + 1], (B_, h))
                          for f, h in enumerate(cfg.hots)],
                         axis=1).reshape(-1).astype(np.int32)
    dx = jnp.asarray(rng.normal(0, 1, (B_, cfg.n_dense)), jnp.float32)
    lb = jnp.asarray(rng.integers(0, 2, B_), jnp.float32)
    o = opt.adam(1e-2)
    step = jax.jit(D.make_train_step(cfg, o, None))
    st = (dp, tb, o.init(dp), o.init(tb), jnp.zeros((), jnp.int32))
    losses = []
    for i in range(5):
        st, loss = step(st, dx, jnp.asarray(ids), lb, jax.random.fold_in(KEY, i))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    serve = jax.jit(D.make_serve_step(cfg, None))
    ctr = serve(st[0], st[1], dx, jnp.asarray(ids))
    assert ctr.shape == (B_,) and (np.asarray(ctr) >= 0).all() \
        and (np.asarray(ctr) <= 1).all()
    # retrieval
    ret = jax.jit(D.make_retrieval_step(cfg, None, top_k=8))
    cand = jnp.asarray(rng.permutation(int(cfg.table_sizes[0]))[:32].astype(np.int32))
    v, ids_out = ret(st[0], st[1], dx[:1],
                     jnp.asarray(ids[:cfg.total_ids_per_sample]), cand)
    assert v.shape == (8,)
    assert (np.diff(np.asarray(v)) <= 1e-6).all()   # sorted descending


def test_registry_complete():
    assert set(configlib.ASSIGNED) <= set(configlib.REGISTRY)
    assert len(configlib.ASSIGNED) == 10
    for a in configlib.ASSIGNED:
        spec = configlib.get(a)
        assert len(spec.shapes) == 4
        spec.config()     # constructible
        spec.reduced()
