"""Compact (ragged ring-bucket) halo plan vs dense pairwise plan.

Parity contract: with deterministic rounding (quantization is per-row, so the
buffer layout cannot change its numerics) the two layouts must produce
identical forward activations, losses, and parameter trajectories — in the
simulated stack and under shard_map — while the compact plan ships a fraction
of the dense wire bytes on skewed partitions. The `slow` test forks a
subprocess with 4 forced host devices (jax locks the device count at first
init); `test_shardmap_*_inline` runs the same check in-process when the
current session already has >= 4 devices (the CI `--halo` lane).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.exchange import (PlanArrays, exchange_bytes, exchange_halo,
                                 gather_boundary, wire_bytes)
from repro.core.sylvie import SylvieComm, SylvieConfig
from repro.dist.backend import SimulatedBackend
from repro.graph import formats, partition, synthetic
from repro.models.gnn import blocks as B
from repro.models.gnn.models import GCN
from repro.train import optimizer as opt
from repro.train.gnn_step import GNNTrainState, make_gnn_steps

SRC = str(Path(__file__).resolve().parents[1] / "src")
KEY = jax.random.PRNGKey(0)


def _skewed_graph(n=900, d=16, seed=0):
    """Power-law graph whose `skewed` partition has badly imbalanced pairs."""
    g = synthetic.powerlaw(n_nodes=n, d_feat=d, avg_degree=10, seed=seed)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _both_layouts(g, ew, p=8):
    return {layout: partition.partition_graph(g, p, method="skewed",
                                              edge_weight=ew, layout=layout)
            for layout in ("dense", "compact")}


# ---------------------------------------------------------------------------
# plan structure + accounting
# ---------------------------------------------------------------------------
def test_compact_plan_structure_and_wire_reduction():
    g, ew = _skewed_graph()
    pgs = _both_layouts(g, ew)
    pd, pc = pgs["dense"].plan, pgs["compact"].plan

    # the stress partition really is skewed: per-pair counts differ by >10x
    off = pc.pair_counts[~np.eye(pc.n_parts, dtype=bool)]
    nz = off[off > 0]
    assert nz.max() > 10 * nz.min(), (nz.min(), nz.max())

    assert pc.bucket_sizes[0] == 0                  # diagonal dropped
    assert (pc.bucket_sizes % pc.alignment == 0).all()
    # both layouts carry the same true halo set
    assert pc.real_rows() == pd.real_rows()
    assert pc.pad_efficiency() > pd.pad_efficiency()
    # acceptance: compact wire <= 60% of the dense (P, P*h_pad) layout
    assert pc.wire_rows() <= 0.6 * pd.wire_rows(), \
        (pc.wire_rows(), pd.wire_rows())

    # device-side accounting mirrors the host plan; true bytes are
    # layout-invariant, shipped bytes are not
    ad, ac = PlanArrays.from_plan(pd), PlanArrays.from_plan(pc)
    d_feat = 64
    assert exchange_bytes(ac, d_feat, 1) == exchange_bytes(ad, d_feat, 1)
    assert wire_bytes(ac, d_feat, 1)[0] <= 0.6 * wire_bytes(ad, d_feat, 1)[0]
    # payload ratio between bit-widths is padding-invariant (Table 3)
    assert exchange_bytes(ac, d_feat, 32)[0] == 32 * exchange_bytes(ac, d_feat, 1)[0]


def test_compact_exchange_ring_semantics_and_reverse():
    """recv[p][bucket k] == send[(p-k)%P][bucket k]; reverse undoes forward."""
    g, ew = _skewed_graph(n=400)
    plan = PlanArrays.from_plan(
        partition.partition_graph(g, 4, method="skewed", edge_weight=ew,
                                  layout="compact").plan)
    p, rows = plan.n_parts, plan.halo_rows
    x = jax.random.normal(KEY, (p, rows, 3))
    be = SimulatedBackend()
    y = exchange_halo(x, plan, be)
    start = 0
    for k, b in enumerate(plan.bucket_sizes):
        for pi in range(p):
            np.testing.assert_allclose(
                np.asarray(y[pi, start:start + b]),
                np.asarray(x[(pi - k) % p, start:start + b]))
        start += b
    back = exchange_halo(y, plan, be, reverse=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x))


def test_compact_gather_packs_live_rows():
    """The compaction permutation leaves no dead pairwise blocks: every
    unmasked row of the send buffer is a real boundary node."""
    g, ew = _skewed_graph(n=500)
    pg = partition.partition_graph(g, 4, method="skewed", edge_weight=ew,
                                   layout="compact")
    plan = PlanArrays.from_plan(pg.plan)
    h = jnp.asarray(pg.x)
    buf = gather_boundary(h, plan)
    mask = np.asarray(plan.send_mask)
    # masked (alignment-tail) rows are zeroed; live rows match the features
    assert (np.asarray(buf)[~mask] == 0).all()
    idx = np.asarray(plan.send_idx)
    for p in range(plan.n_parts):
        live = np.where(mask[p])[0]
        np.testing.assert_allclose(np.asarray(buf)[p, live],
                                   np.asarray(h)[p, idx[p, live]])


# ---------------------------------------------------------------------------
# numerics parity, simulated stack
# ---------------------------------------------------------------------------
def test_forward_parity_dense_vs_compact():
    """Vanilla and 1-bit deterministic halo: identical layer inputs."""
    g, ew = _skewed_graph()
    pgs = _both_layouts(g, ew)
    for cfg in (SylvieConfig(mode="vanilla", stochastic=False),
                SylvieConfig(mode="sync", bits=1, stochastic=False)):
        aggs = {}
        for layout, pg in pgs.items():
            blk = B.build_block(pg)
            x = jnp.asarray(pg.x)
            halo = SylvieComm(cfg, blk.plan, KEY).halo(x)
            table = B.halo_table(x, halo)
            msgs = B.gather_src(blk, table) * blk.edge_weight[..., None]
            aggs[layout] = pg.unpartition(np.asarray(B.agg_sum(blk, msgs)))
        np.testing.assert_allclose(aggs["dense"], aggs["compact"],
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_train_parity_dense_vs_compact(mode):
    """Same PRNG keys, deterministic rounding: losses and params match to
    fp32 tolerance through full forward/backward training steps."""
    g, ew = _skewed_graph(n=600)
    pgs = _both_layouts(g, ew)
    out = {}
    for layout, pg in pgs.items():
        blk = B.build_block(pg)
        model = GCN(d_in=g.x.shape[1], d_hidden=24, d_out=g.n_classes,
                    n_layers=2)
        o = opt.adam(1e-2)
        cfg = SylvieConfig(mode=mode, bits=1, stochastic=False)
        ts, ta, _ = make_gnn_steps(model, cfg, o)
        st = GNNTrainState.create(model, o, KEY, blk.plan, stacked_parts=8)
        x, y, m = jnp.asarray(pg.x), jnp.asarray(pg.y), jnp.asarray(pg.train_mask)
        losses = []
        st, loss = jax.jit(ts)(st, blk, x, y, m, KEY)   # warmup / sync step
        losses.append(float(loss))
        step = jax.jit(ta if mode == "async" else ts)
        for i in range(3):
            st, loss = step(st, blk, x, y, m, jax.random.fold_in(KEY, i))
            losses.append(float(loss))
        out[layout] = (losses, jax.tree.leaves(st.params))
    np.testing.assert_allclose(out["dense"][0], out["compact"][0], rtol=1e-5)
    for a, b in zip(out["dense"][1], out["compact"][1]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_quantized_backward_scatter_compact():
    """Gradient scatter through the reversed rings equals the analytic sum
    over receivers (Alg. 2 line 13) on a compact plan."""
    from repro.core.sylvie import quantized_halo
    g, ew = _skewed_graph(n=300)
    pg = partition.partition_graph(g, 4, method="skewed", edge_weight=ew,
                                   layout="compact")
    plan = PlanArrays.from_plan(pg.plan)
    x = jnp.asarray(pg.x)

    def f(h):
        halo = quantized_halo(h, plan, KEY, KEY, 32, 32, False, jnp.bfloat16,
                              None, "jnp")
        return (halo ** 2).sum() / 2

    grad = jax.grad(f)(x)
    sends = np.asarray(plan.send_mask)
    idx = np.asarray(plan.send_idx)
    expected = np.zeros_like(np.asarray(x))
    for p in range(plan.n_parts):
        for slot in range(idx.shape[1]):
            if sends[p, slot]:
                expected[p, idx[p, slot]] += np.asarray(x)[p, idx[p, slot]]
    np.testing.assert_allclose(np.asarray(grad), expected, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# shard_map parity
# ---------------------------------------------------------------------------
PARITY = """
import repro.api as repro
from repro.graph import synthetic
from repro.models.gnn.models import GCN
from repro.train import optimizer as opt

g = synthetic.powerlaw(n_nodes=500, d_feat=16, avg_degree=10, seed=0)
model = GCN(d_in=16, d_hidden=24, d_out=g.n_classes, n_layers=2)
rt_sim = repro.Runtime.simulated(4)
rt_sm = repro.Runtime.from_mesh(repro.make_gnn_mesh(4))
pgs = {lay: repro.partition(g, n_parts=4, method="skewed", layout=lay)
       for lay in ("dense", "compact")}


def run(runtime, pg, mode, epochs):
    cfg = repro.SylvieConfig(mode=mode, bits=1, stochastic=False)
    return repro.train(model, pg, cfg, runtime=runtime, opt=opt.sgd(1e-1),
                       epochs=epochs)


for mode, epochs in (("sync", 3), ("async", 4)):
    ref = run(rt_sim, pgs["compact"], mode, epochs)
    for lay in ("dense", "compact"):
        b = run(rt_sm, pgs[lay], mode, epochs)
        np.testing.assert_allclose([m.loss for m in ref.history],
                                   [m.loss for m in b.history], rtol=1e-5)
        for pa, pb in zip(jax.tree.leaves(ref.state.params),
                          jax.tree.leaves(jax.device_get(b.state.params))):
            np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                       rtol=1e-4, atol=1e-6)
print("OK")
"""


def test_shardmap_compact_parity_inline():
    """Runs when the session already has >= 4 devices (the CI --halo lane)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    env = {"repro": __import__("repro.api", fromlist=["api"]),
           "jax": jax, "np": np}
    exec(textwrap.dedent(PARITY), env)


@pytest.mark.slow
def test_shardmap_compact_parity_subprocess():
    """Dense and compact plans under shard_map both match the simulated
    compact reference — losses and params, sync and async."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
        import jax, numpy as np
    """) + textwrap.dedent(PARITY)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
