"""repro.datasets: registry round-trips, seeded determinism, plan cache."""
import numpy as np
import pytest

from repro import datasets
from repro.datasets import plans, registry
from repro.graph import synthetic
from repro.graph.formats import Graph


def _graph_equal(a, b) -> bool:
    if a.n_nodes != b.n_nodes or a.n_classes != b.n_classes:
        return False
    for f in ("edge_index", "x", "y", "train_mask", "val_mask", "test_mask",
              "pos", "edge_attr"):
        va, vb = getattr(a, f), getattr(b, f)
        if (va is None) != (vb is None):
            return False
        if va is not None and (va.shape != vb.shape or not (va == vb).all()):
            return False
    return True


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_names_cover_the_paper_graphs():
    names = datasets.names()
    for required in ("reddit_like", "yelp_like", "products_like",
                     "amazon_like", "mesh_like", "molecule_like"):
        assert required in names


@pytest.mark.parametrize("name", registry.names())
def test_every_tier_loads_and_is_deterministic_smoke(name):
    spec = registry.get(name)
    assert set(spec.tiers) == set(registry.TIERS)
    g1 = spec.load("smoke", seed=7)
    g2 = spec.load("smoke", seed=7)
    assert isinstance(g1, Graph)
    assert _graph_equal(g1, g2)
    # a different seed produces a different graph
    g3 = spec.load("smoke", seed=8)
    assert not _graph_equal(g1, g3)
    # calibration sanity: requested widths/classes survive generation
    kw = spec.tiers["smoke"]
    assert g1.x.shape[1] == kw["d_feat"]
    if "n_classes" in kw:
        assert g1.n_classes == kw["n_classes"]


@pytest.mark.slow
@pytest.mark.parametrize("tier", ["small", "paper"])
@pytest.mark.parametrize("name", registry.names())
def test_big_tiers_round_trip_deterministically(name, tier):
    spec = registry.get(name)
    g1 = spec.load(tier, seed=0)
    g2 = spec.load(tier, seed=0)
    assert _graph_equal(g1, g2)
    # tiers are ordered by size
    smaller = spec.load("smoke" if tier == "small" else "small", seed=0)
    assert g1.n_nodes > smaller.n_nodes


def test_parse_refs_and_errors():
    assert registry.parse("reddit_like@paper") == ("reddit_like", "paper")
    assert registry.parse("mesh_like") == ("mesh_like", "smoke")
    with pytest.raises(KeyError, match="tier"):
        registry.parse("reddit_like@huge")
    with pytest.raises(KeyError, match="unknown workload"):
        datasets.load("no_such_graph@smoke")
    with pytest.raises(KeyError, match="no tier"):
        registry.get("mesh_like").load("gigantic")


def test_load_ref_matches_explicit_tier():
    a = datasets.load("products_like@smoke", seed=1)
    b = datasets.load("products_like", tier="smoke", seed=1)
    assert _graph_equal(a, b)


def test_powerlaw_community_is_heavy_tailed_and_homophilous():
    g = synthetic.powerlaw_community(n_nodes=1500, n_classes=8, d_feat=16,
                                     avg_degree=16, p_in=0.8, seed=0)
    deg = g.degrees("in")
    assert deg.max() > 8 * deg.mean()          # hubs exist
    src, dst = g.edge_index
    same = (g.y[src] == g.y[dst]).mean()
    assert same > 0.5                          # homophily >> 1/8 random rate


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_miss_then_hit_round_trips(tmp_path):
    pg1, hit1 = datasets.load_partitioned("yelp_like@smoke", 4,
                                          cache_dir=tmp_path)
    assert not hit1
    pg2, hit2 = datasets.load_partitioned("yelp_like@smoke", 4,
                                          cache_dir=tmp_path)
    assert hit2
    assert pg2.plan.layout == pg1.plan.layout == "compact"
    assert pg2.plan.alignment == pg1.plan.alignment
    for f in ("send_idx", "send_mask", "recv_mask", "bucket_sizes",
              "pair_counts"):
        np.testing.assert_array_equal(getattr(pg1.plan, f),
                                      getattr(pg2.plan, f))
    for f in ("part_of", "global_ids", "node_mask", "x", "y", "train_mask",
              "val_mask", "test_mask", "edges", "edge_mask", "edge_weight"):
        np.testing.assert_array_equal(np.asarray(getattr(pg1, f)),
                                      np.asarray(getattr(pg2, f)))
    assert pg1.edges.dtype == pg2.edges.dtype
    assert pg2.plan.send_idx.dtype == pg1.plan.send_idx.dtype


def test_cached_partition_trains_identically(tmp_path):
    """A cache-loaded PartitionedGraph is a drop-in for a fresh one."""
    from repro.core.sylvie import SylvieConfig
    from repro.models.gnn.models import GCN
    from repro.train.trainer import GNNTrainer

    losses = []
    for _ in range(2):                          # miss, then hit
        pg, _ = datasets.load_partitioned("products_like@smoke", 4,
                                          cache_dir=tmp_path)
        model = GCN(pg.x.shape[-1], 16, pg.n_classes, n_layers=2)
        tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1))
        tr.fit(2)
        losses.append([m.loss for m in tr.history])
    assert losses[0] == losses[1]


def test_plan_cache_key_invalidation(tmp_path):
    g = datasets.load("yelp_like@smoke")
    base = plans.plan_key(g, 4)
    assert base == plans.plan_key(g, 4)                       # stable
    assert plans.plan_key(g, 4, alignment=16) != base         # alignment
    assert plans.plan_key(g, 8) != base                       # n_parts
    assert plans.plan_key(g, 4, layout="dense") != base       # layout
    assert plans.plan_key(g, 4, method="random") != base      # method
    g2 = datasets.load("yelp_like@smoke", seed=1)
    assert plans.plan_key(g2, 4) != base                      # graph content


def test_plan_cache_alignment_change_is_a_miss(tmp_path):
    _, hit = datasets.load_partitioned("yelp_like@smoke", 4,
                                       cache_dir=tmp_path)
    assert not hit
    pg16, hit = datasets.load_partitioned("yelp_like@smoke", 4, alignment=16,
                                          cache_dir=tmp_path)
    assert not hit                              # different key -> repartition
    assert pg16.plan.alignment == 16
    assert all(b % 16 == 0 for b in pg16.plan.bucket_sizes)
    # both entries coexist; the original still hits
    _, hit = datasets.load_partitioned("yelp_like@smoke", 4,
                                       cache_dir=tmp_path)
    assert hit


def test_plan_cache_corrupt_entry_is_rewritten(tmp_path):
    datasets.load_partitioned("mesh_like@smoke", 2, cache_dir=tmp_path)
    (entry,) = tmp_path.glob("*.npz")
    entry.write_bytes(b"not an npz")
    pg, hit = datasets.load_partitioned("mesh_like@smoke", 2,
                                        cache_dir=tmp_path)
    assert not hit                              # treated as a miss
    pg2, hit = datasets.load_partitioned("mesh_like@smoke", 2,
                                         cache_dir=tmp_path)
    assert hit                                  # and the entry was repaired
    np.testing.assert_array_equal(pg.edges, pg2.edges)


def test_dense_layout_round_trips_through_cache(tmp_path):
    pg, _ = datasets.load_partitioned("yelp_like@smoke", 4, layout="dense",
                                      cache_dir=tmp_path)
    pg2, hit = datasets.load_partitioned("yelp_like@smoke", 4, layout="dense",
                                         cache_dir=tmp_path)
    assert hit and pg2.plan.layout == "dense"
    assert pg2.plan.bucket_sizes is None and pg2.plan.pair_counts is not None
    np.testing.assert_array_equal(pg.plan.send_idx, pg2.plan.send_idx)
