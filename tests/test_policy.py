"""CommPolicy: per-site, per-epoch communication schedules.

Covers the acceptance contract of the policy API:
  * ``Uniform`` is bit-identical to the ``SylvieConfig(bits=...)`` shim path
    (sync + async, simulated always; shard_map inline when the session has
    >= 4 devices — the CI ``--policy`` lane — and in a slow subprocess);
  * ``BoundedStaleness`` reproduces ``use_sync_step``'s exact epoch pattern,
    including the forced synchronous epoch after an elastic resume;
  * ``AdaQPVariance`` assigns more bits to higher-variance sites and stays
    inside the uniform-budget byte envelope;
  * a 20-epoch adaptive run stays within the <= 3-recompile budget;
  * heterogeneous per-site bits are accounted per site and per direction.
"""
import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as repro
from repro.core.exchange import exchange_bytes
from repro.core.staleness import use_sync_step
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN
from repro.policy import (AdaQPVariance, BoundedStaleness, Chain,
                          EpochDecision, SiteDecision, SiteStats, Telemetry,
                          Uniform, Warmup, snap_bits, snap_sample_p)
from repro.train import gnn_step
from repro.train.trainer import GNNTrainer

SRC = str(Path(__file__).resolve().parents[1] / "src")
KEY = jax.random.PRNGKey(0)


def _graph(n=240, d=16, seed=0, flat_x=False):
    g = synthetic.planted_partition(n_nodes=n, d_feat=d, seed=seed)
    if flat_x:
        # plant a variance asymmetry between exchange sites: constant feature
        # rows have per-row range ~0 (losslessly 1-bit quantizable), while the
        # hidden-layer exchange keeps a normal spread — AdaQP should move the
        # byte budget to the hidden site.
        g.x[:] = g.x[:, :1]
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    return formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                         g.test_mask, n_classes=g.n_classes), ew


def _trainer(mode="sync", policy=None, parts=4, eps_s=None, ckpt_dir=None,
             flat_x=False, seed=0, **cfg_kw):
    g, ew = _graph(seed=seed, flat_x=flat_x)
    pg = partition.partition_graph(g, parts, edge_weight=ew)
    model = GCN(d_in=16, d_hidden=24, d_out=g.n_classes, n_layers=2)
    cfg = repro.SylvieConfig(mode=mode, **cfg_kw)
    return GNNTrainer(model, pg, cfg, policy=policy, eps_s=eps_s,
                      ckpt_dir=ckpt_dir, seed=seed)


def _tel(epoch=0, n_sites=2, dims=(16, 24), stats=None, needs_sync=False):
    return Telemetry(epoch=epoch, n_parts=4, n_sites=n_sites, site_dims=dims,
                     site_stats=stats, needs_sync=needs_sync)


# ---------------------------------------------------------------------------
# Uniform == the config shim, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode,epochs", [("sync", 4), ("async", 6)])
def test_uniform_bit_identical_to_config_shim(mode, epochs):
    a = _trainer(mode=mode, bits=1)
    b = _trainer(mode=mode, policy=Uniform(bits=1))
    la = [a.train_epoch() for _ in range(epochs)]
    lb = [b.train_epoch() for _ in range(epochs)]
    assert [m.loss for m in la] == [m.loss for m in lb]      # exact
    assert [m.mode for m in la] == [m.mode for m in lb]
    assert a.comm_bytes_per_epoch() == b.comm_bytes_per_epoch()
    assert la[0].policy == "uniform" and la[0].bits_per_site == ((1, 1),) * 2


SHARDMAP_PARITY = """
import repro.api as repro
from repro.graph import synthetic

g = synthetic.planted_partition(n_nodes=400, d_feat=16)
from repro.models.gnn.models import GCN
model = GCN(d_in=16, d_hidden=32, d_out=g.n_classes, n_layers=2)
rt = repro.Runtime.from_mesh(repro.make_gnn_mesh(4))
pg = repro.partition(g, n_parts=4)

for mode, epochs in (("sync", 3), ("async", 4)):
    a = repro.train(model, pg, mode=mode, bits=1, runtime=rt, epochs=epochs)
    b = repro.train(model, pg, mode=mode, policy=repro.Uniform(bits=1),
                    runtime=rt, epochs=epochs)
    assert [m.loss for m in a.history] == [m.loss for m in b.history], mode
    assert [m.mode for m in a.history] == [m.mode for m in b.history], mode
print("OK")
"""


def test_uniform_shim_parity_shard_map_inline():
    """Runs when the session already has >= 4 devices (CI --policy lane)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    exec(textwrap.dedent(SHARDMAP_PARITY), {"repro": repro})


@pytest.mark.slow
def test_uniform_shim_parity_shard_map_subprocess():
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, {SRC!r})
    """) + textwrap.dedent(SHARDMAP_PARITY)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# BoundedStaleness == use_sync_step, including resume's forced sync
# ---------------------------------------------------------------------------
def test_bounded_staleness_matches_use_sync_step():
    pol = BoundedStaleness(eps_s=3)
    got = [pol.decide(_tel(epoch=e)).sync for e in range(8)]
    assert got == [use_sync_step(e, 3) for e in range(8)]
    # pure Sylvie-A / always-sync corner cases
    assert [BoundedStaleness(None).decide(_tel(epoch=e)).sync
            for e in range(4)] == [True, False, False, False]
    assert all(BoundedStaleness(1).decide(_tel(epoch=e)).sync
               for e in range(4))
    # a cache-coherence flag forces sync mid-interval
    assert pol.decide(_tel(epoch=4, needs_sync=True)).sync


def test_bounded_staleness_trainer_schedule():
    tr = _trainer(mode="async", policy=BoundedStaleness(3), bits=1)
    modes = [tr.train_epoch().mode for _ in range(7)]
    assert modes == ["sync", "async", "async", "sync", "async", "async",
                     "sync"]


def test_elastic_resume_forces_sync_epoch(tmp_path):
    """The old trainer-internal forced-sync survives as Telemetry.needs_sync:
    an elastic repartition resume runs one synchronous refresh epoch even
    though the policy's schedule says async."""
    tr4 = _trainer(mode="async", policy=BoundedStaleness(5), parts=4,
                   ckpt_dir=str(tmp_path))
    for _ in range(3):
        tr4.train_epoch()
    tr4.save()

    tr2 = _trainer(mode="async", policy=BoundedStaleness(5), parts=2,
                   ckpt_dir=str(tmp_path))
    assert tr2.resume() and tr2._needs_sync
    assert tr2.train_epoch().mode == "sync"      # epoch 3: forced refresh
    assert tr2.train_epoch().mode == "async"     # epoch 4: pipeline resumes


def test_eps_s_kwarg_is_a_deprecation_shim():
    with pytest.warns(DeprecationWarning, match="eps_s"):
        a = _trainer(mode="async", eps_s=2, bits=1)
    b = _trainer(mode="async", policy=BoundedStaleness(2), bits=1)
    la = [a.train_epoch() for _ in range(5)]
    lb = [b.train_epoch() for _ in range(5)]
    assert [m.loss for m in la] == [m.loss for m in lb]
    assert [m.mode for m in la] == [m.mode for m in lb]
    with pytest.raises(ValueError, match="policy or eps_s"):
        _trainer(mode="async", eps_s=2, policy=Uniform())


# ---------------------------------------------------------------------------
# AdaQPVariance: variance-directed bits inside the byte budget
# ---------------------------------------------------------------------------
def test_adaqp_assigns_more_bits_to_higher_variance_site():
    rows = 800
    stats = (SiteStats(dim=16, rows=rows, mean_range_sq=100.0),
             SiteStats(dim=24, rows=rows, mean_range_sq=1e-4))
    pol = AdaQPVariance(budget_bits=4)
    d = pol.decide(_tel(epoch=3, stats=stats))
    (f0, _), (f1, _) = d.bits_per_site()
    assert f0 > f1, d.bits_per_site()
    # payload stays inside the uniform-budget envelope
    budget = sum(pol._payload(st, 4) for st in stats)
    spent = sum(pol._payload(st, sd.fwd_bits)
                for st, sd in zip(stats, d.sites))
    assert spent <= budget
    # no stats yet (epoch 0 / fresh resume): uniform at the budget width
    d0 = pol.decide(_tel(epoch=0))
    assert d0.sync and d0.bits_per_site() == ((4, 4),) * 2


def test_adaqp_trainer_integration_planted_variance():
    """Planted asymmetry: constant feature rows (site 0, range ~0) vs a
    normally-spread hidden exchange (site 1) -> AdaQP gives site 1 more
    bits while keeping site 0 at the 1-bit floor."""
    tr = _trainer(mode="sync", policy=AdaQPVariance(budget_bits=4),
                  flat_x=True)
    hist = [tr.train_epoch() for _ in range(4)]
    (f0, b0), (f1, b1) = hist[-1].bits_per_site
    assert f1 > f0, hist[-1].bits_per_site
    assert tr._site_stats[1].mean_range_sq > tr._site_stats[0].mean_range_sq
    assert hist[-1].policy == "adaqp_variance(4)"
    # budget respected by the trainer's heterogeneous accounting too
    uniform4 = _trainer(mode="sync", policy=Uniform(bits=4), flat_x=True)
    assert tr.comm_bytes_per_epoch()[0] <= uniform4.comm_bytes_per_epoch()[0]


def test_recompile_budget_20_epoch_adaptive_run():
    """<= 3 distinct jit traces of the train steps across a 20-epoch
    AdaQPVariance run (sync warmup + adaptive async + at most one shift)."""
    tr = _trainer(mode="async", policy=AdaQPVariance(budget_bits=4),
                  flat_x=True)
    gnn_step.TRACE_LOG.clear()
    for _ in range(20):
        tr.train_epoch()
    assert len(gnn_step.TRACE_LOG) <= 3, gnn_step.TRACE_LOG
    assert len(tr._step_cache) <= 2


# ---------------------------------------------------------------------------
# heterogeneous accounting + pluggability
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FixedPolicy:
    """Third-party policy: implements the protocol, nothing else."""

    @property
    def name(self) -> str:
        return "fixed"

    def decide(self, tel):
        return EpochDecision(
            sites=(SiteDecision(fwd_bits=8, bwd_bits=2),
                   SiteDecision(fwd_bits=1, bwd_bits=4)),
            sync=True)


def test_heterogeneous_bits_accounted_per_site_and_direction():
    tr = _trainer(mode="sync", policy=FixedPolicy())
    m = tr.train_epoch()
    assert m.bits_per_site == ((8, 2), (1, 4)) and m.policy == "fixed"
    plan, dims = tr.block.plan, tr.site_dims
    payload = ec = 0
    for d, (fb, bb) in zip(dims, m.bits_per_site):
        for bits in (fb, bb):
            pb, eb = exchange_bytes(plan, d, bits, tr.cfg.scale_dtype)
            payload += pb
            ec += eb
    pb, eb = tr.comm_bytes_per_epoch()
    assert (pb, eb) == (payload, ec)
    assert m.comm_payload_mb == pytest.approx(payload / 1e6)


def test_policy_with_wrong_site_count_rejected():
    @dataclasses.dataclass(frozen=True)
    class Bad:
        name = "bad"

        def decide(self, tel):
            return EpochDecision(sites=(SiteDecision(),), sync=True)

    tr = _trainer(mode="sync", policy=Bad())
    with pytest.raises(ValueError, match="exchange sites"):
        tr.train_epoch()


# ---------------------------------------------------------------------------
# Warmup / Chain / lattice snapping / EF bits
# ---------------------------------------------------------------------------
def test_warmup_schedule_and_payload_drop():
    tr = _trainer(mode="sync", policy=Warmup(epochs=2, bits=1))
    hist = [tr.train_epoch() for _ in range(4)]
    assert [m.bits_per_site[0][0] for m in hist] == [32, 32, 1, 1]
    assert hist[-1].comm_payload_mb < hist[0].comm_payload_mb / 16


def test_chain_merges_conservatively():
    pol = Chain(Warmup(epochs=2, bits=1), BoundedStaleness(3, bits=1))
    # warmup phase: widest bits win; staleness schedule still drives sync
    d1 = pol.decide(_tel(epoch=1))
    assert d1.bits_per_site() == ((32, 32),) * 2 and not d1.sync
    d3 = pol.decide(_tel(epoch=3))
    assert d3.bits_per_site() == ((1, 1),) * 2 and d3.sync
    assert pol.name.startswith("chain(")
    # ef_bits=None is the full-precision (widest) all-reduce: any member
    # keeping it wins over members that compress
    mixed = Chain(Warmup(epochs=2), Uniform(bits=1, ef_bits=1))
    assert mixed.decide(_tel(epoch=1)).ef_bits is None
    both = Chain(Uniform(bits=1, ef_bits=1), Uniform(bits=1, ef_bits=4))
    assert both.decide(_tel(epoch=1)).ef_bits == 4


def test_epoch0_sync_warmup_enforced_against_policy():
    """The zero-initialized halo caches must be warmed before any pipelined
    step: even a policy that never requests sync gets epoch 0 synchronous."""
    tr = _trainer(mode="async", policy=Uniform(bits=1, sync=False))
    assert tr.train_epoch().mode == "sync"       # forced warmup
    assert tr.train_epoch().mode == "async"


def test_decision_lattice_snapping():
    assert [snap_bits(b) for b in (1, 3, 5, 8, 9, 17, 64)] == \
        [1, 4, 8, 8, 16, 32, 32]
    assert snap_sample_p(0.43) == pytest.approx(0.45)
    assert snap_sample_p(1.7) == pytest.approx(0.95)
    d = EpochDecision(sites=(SiteDecision(fwd_bits=3, bwd_bits=5,
                                          boundary_sample_p=0.42),),
                      sync=False, ef_bits=3).snapped()
    assert d.sites[0].fwd_bits == 4 and d.sites[0].bwd_bits == 8
    assert d.ef_bits == 4
    assert hash(d) == hash(d)            # usable as a step-cache key
    assert d.step_key() == dataclasses.replace(d, sync=True).step_key()


def test_ef_bits_ride_the_decision():
    tr = _trainer(mode="sync", policy=Uniform(bits=1, ef_bits=2))
    hist = [tr.train_epoch() for _ in range(10)]
    assert hist[0].ef_bits == 2
    assert hist[-1].loss < hist[0].loss          # EF training converges
    assert all(np.isfinite(m.loss) for m in hist)
    # EF payload joins the byte accounting
    plain = _trainer(mode="sync", policy=Uniform(bits=1))
    assert tr.comm_bytes_per_epoch()[0] > plain.comm_bytes_per_epoch()[0]


def test_site_stats_telemetry_emitted():
    tr = _trainer(mode="sync", policy=Uniform(bits=1))
    tr.train_epoch()
    stats = tr._site_stats
    assert stats is not None and len(stats) == 2
    assert stats[0].dim == 16 and stats[1].dim == 24
    assert all(s.mean_range_sq > 0 for s in stats)
    assert all(s.rows == tr.block.plan.real_rows for s in stats)
