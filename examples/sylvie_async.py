"""Sylvie-A: asynchronous pipelined training + Bounded Staleness Adaptor.

    PYTHONPATH=src python examples/sylvie_async.py

The staleness schedule is a ``CommPolicy``: pure Sylvie-A is
``Uniform(bits=1)`` (one synchronous warmup epoch, pipelined afterwards), and
the Bounded Staleness Adaptor is ``BoundedStaleness(eps_s)`` (one synchronous
cache-refresh epoch every eps_s epochs). Compares the two at eps_s={2,5} and
shows checkpoint/restart with the staleness caches restored bit-exactly —
then an elastic resume at a different partition count, where the telemetry's
``needs_sync`` flag forces the policy into one refresh epoch. Uses the
``repro.api`` facade; swap ``Runtime.simulated(parts)`` for
``Runtime.from_mesh(mesh)`` to run one partition per device.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import repro.api as repro  # noqa: E402
from repro.graph import synthetic  # noqa: E402
from repro.models.gnn.models import GraphSAGE  # noqa: E402


def build(parts: int):
    g = synthetic.planted_partition(n_nodes=1500, d_feat=48, avg_degree=12,
                                    seed=7)
    pg = repro.partition(g, runtime=repro.Runtime.simulated(parts))
    model = GraphSAGE(d_in=48, d_hidden=96, d_out=g.n_classes, n_layers=2)
    return model, pg


def main() -> None:
    policies = (("pure Sylvie-A", repro.Uniform(bits=1)),
                ("eps_s=5", repro.BoundedStaleness(5)),
                ("eps_s=2", repro.BoundedStaleness(2)))
    for label, policy in policies:
        model, pg = build(4)
        tr = repro.train(model, pg, mode="async", policy=policy, epochs=30)
        sync_epochs = sum(1 for m in tr.history if m.mode == "sync")
        print(f"Sylvie-A {label:13s}: val acc {tr.evaluate('val'):.4f} "
              f"({sync_epochs}/30 synchronous refresh epochs)")

    with tempfile.TemporaryDirectory() as d:
        model, pg = build(4)
        tr = repro.train(model, pg, mode="async",
                         policy=repro.BoundedStaleness(5), ckpt_dir=d,
                         epochs=10)
        tr.save()
        ref = [tr.train_epoch().loss for _ in range(3)]

        tr2 = repro.train(model, pg, mode="async",
                          policy=repro.BoundedStaleness(5), ckpt_dir=d)
        tr2.resume()
        res = [tr2.train_epoch().loss for _ in range(3)]
        print(f"restart: losses match bit-exactly: "
              f"{all(abs(a-b) < 1e-6 for a, b in zip(ref, res))}")

        # elastic: same checkpoint, different partition count. The resume
        # sets Telemetry.needs_sync, so the policy's first decision is a
        # forced synchronous cache-refresh epoch.
        model8, pg8 = build(8)
        tr8 = repro.train(model8, pg8, mode="async",
                          policy=repro.BoundedStaleness(5), ckpt_dir=d)
        tr8.resume()
        m = tr8.train_epoch()
        print(f"elastic 4->8 parts: resumed at epoch {tr8.epoch-1}, first "
              f"epoch forced '{m.mode}' (halo cache refresh), "
              f"val acc {tr8.evaluate('val'):.4f}")


if __name__ == "__main__":
    main()
