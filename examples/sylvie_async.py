"""Sylvie-A: asynchronous pipelined training + Bounded Staleness Adaptor.

    PYTHONPATH=src python examples/sylvie_async.py

Compares pure Sylvie-A against Sylvie-A with eps_s={2,5} (one synchronous
cache-refresh epoch every eps_s epochs) and shows checkpoint/restart with the
staleness caches restored bit-exactly — then an elastic resume at a different
partition count.
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GraphSAGE
from repro.train.trainer import GNNTrainer


def build(parts: int):
    g = synthetic.planted_partition(n_nodes=1500, d_feat=48, avg_degree=12,
                                    seed=7)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)
    pg = partition.partition_graph(g, parts, edge_weight=ew)
    model = GraphSAGE(d_in=48, d_hidden=96, d_out=g.n_classes, n_layers=2)
    return model, pg


def main() -> None:
    for eps in (None, 5, 2):
        model, pg = build(4)
        tr = GNNTrainer(model, pg, SylvieConfig(mode="async", bits=1),
                        eps_s=eps)
        tr.fit(30)
        sync_epochs = sum(1 for m in tr.history if m.mode == "sync")
        print(f"Sylvie-A eps_s={eps!s:4s}: val acc {tr.evaluate('val'):.4f} "
              f"({sync_epochs}/30 synchronous refresh epochs)")

    with tempfile.TemporaryDirectory() as d:
        model, pg = build(4)
        tr = GNNTrainer(model, pg, SylvieConfig(mode="async", bits=1),
                        eps_s=5, ckpt_dir=d)
        tr.fit(10)
        tr.save()
        ref = [tr.train_epoch().loss for _ in range(3)]

        tr2 = GNNTrainer(model, pg, SylvieConfig(mode="async", bits=1),
                         eps_s=5, ckpt_dir=d)
        tr2.resume()
        res = [tr2.train_epoch().loss for _ in range(3)]
        print(f"restart: losses match bit-exactly: "
              f"{all(abs(a-b) < 1e-6 for a, b in zip(ref, res))}")

        # elastic: same checkpoint, different partition count
        model8, pg8 = build(8)
        tr8 = GNNTrainer(model8, pg8, SylvieConfig(mode="async", bits=1),
                         eps_s=5, ckpt_dir=d)
        tr8.resume()
        m = tr8.train_epoch()
        print(f"elastic 4->8 parts: resumed at epoch {tr8.epoch-1}, first "
              f"epoch forced '{m.mode}' (halo cache refresh), "
              f"val acc {tr8.evaluate('val'):.4f}")


if __name__ == "__main__":
    main()
