"""End-to-end driver across all three runtimes (~100M-scale on CPU budgets):

  1. GNN  — NequIP on batched molecules with Sylvie-S quantized halo exchange
  2. LM   — OLMoE-style MoE transformer trained on the synthetic token stream
            via the prefetching data pipeline, then served (prefill + decode)
  3. DLRM — reduced Criteo config with the model-parallel embedding path

    PYTHONPATH=src python examples/train_multiarch.py [--steps 50]
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np


def gnn_part(steps: int) -> None:
    from repro import configs as configlib
    from repro.core.sylvie import SylvieConfig
    from repro.graph import formats, partition, synthetic
    from repro.models.gnn import blocks as B
    from repro.train.trainer import GNNTrainer

    g = synthetic.molecules(n_nodes=120, d_feat=16, cutoff=1.6, seed=2)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, pos=g.pos, n_classes=g.n_classes)
    g.edge_attr = B.geometry_edge_attr(g)
    pg = partition.partition_graph(g, 2)
    arch = configlib.get("nequip").reduced()
    model = arch.make(16, g.n_classes)
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=1))
    tr.fit(steps)
    print(f"[gnn/nequip] loss {tr.history[-1].loss:.4f} "
          f"val acc {tr.evaluate('val'):.3f} "
          f"comm {tr.history[-1].comm_payload_mb:.3f}MB/epoch")


def lm_part(steps: int) -> None:
    from repro import configs as configlib
    from repro.data.pipeline import Prefetcher, token_stream
    from repro.models.lm import model as LM
    from repro.train import optimizer as optlib

    cfg = configlib.get("olmoe-1b-7b").reduced()
    opt = optlib.adam(3e-3)
    key = jax.random.PRNGKey(0)
    params = LM.init_params(key, cfg, dtype=jnp.float32)
    state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn = jax.jit(LM.make_train_step(cfg, opt))
    first = last = None
    for tok, lab in Prefetcher(token_stream(cfg.vocab, 8, 64,
                                            n_batches=steps)):
        state, loss = step_fn(state, tok, lab)
        first = first if first is not None else float(loss)
        last = float(loss)
    print(f"[lm/olmoe] loss {first:.3f} -> {last:.3f} over {steps} steps")

    b, ctx, new = 4, 32, 8
    prefill = jax.jit(LM.make_prefill_step(cfg, b, ctx + new))
    decode = jax.jit(LM.make_decode_step(cfg))
    prompts = jax.random.randint(key, (b, ctx), 0, cfg.vocab)
    padded = jnp.pad(prompts, ((0, 0), (0, new)))
    # prefill over the padded horizon; kv_len masks the tail
    caches = LM.init_cache(cfg, b, ctx + new, dtype=jnp.float32)
    logits, _, caches = LM.forward(state[0], prompts, cfg, caches=caches,
                                   cache_pos=0, kv_len=ctx)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    outs = [tok]
    for i in range(new - 1):
        lg, caches = decode(state[0], caches, tok,
                            jnp.asarray(ctx + i, jnp.int32))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    print(f"[lm/olmoe] served {b} seqs x {new} tokens "
          f"({b*(new-1)/(time.time()-t0):.0f} tok/s CPU); "
          f"sample: {np.asarray(jnp.concatenate(outs, 1))[0].tolist()}")


def dlrm_part(steps: int) -> None:
    from repro import configs as configlib
    from repro.data.pipeline import Prefetcher, criteo_stream
    from repro.models.recsys import dlrm as D
    from repro.train import optimizer as optlib

    cfg = configlib.get("dlrm-mlperf").reduced()
    opt = optlib.adam(1e-2)
    key = jax.random.PRNGKey(1)
    dp = D.init_dense_params(key, cfg)
    tb = D.init_table(jax.random.fold_in(key, 1), cfg)
    state = (dp, tb, opt.init(dp), opt.init(tb), jnp.zeros((), jnp.int32))
    step = jax.jit(D.make_train_step(cfg, opt, None))
    first = last = None
    for i, (dense, ids, label) in enumerate(
            Prefetcher(criteo_stream(cfg, 64, n_batches=steps))):
        state, loss = step(state, dense, ids, label,
                           jax.random.fold_in(key, i))
        first = first if first is not None else float(loss)
        last = float(loss)
    print(f"[recsys/dlrm] loss {first:.3f} -> {last:.3f} over {steps} steps")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()
    gnn_part(args.steps)
    lm_part(args.steps)
    dlrm_part(args.steps)


if __name__ == "__main__":
    main()
