"""Quickstart: train a GCN full-graph with Sylvie-S 1-bit halo exchange.

    PYTHONPATH=src python examples/quickstart.py

Partitions a synthetic community graph over 4 (simulated) partitions, trains
with quantized boundary communication, and prints the comm-volume cut and
final accuracy — the paper's core result at laptop scale.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.sylvie import SylvieConfig
from repro.graph import formats, partition, synthetic
from repro.models.gnn.models import GCN
from repro.train.trainer import GNNTrainer


def main() -> None:
    # 1. a graph (swap in your own formats.Graph here)
    g = synthetic.planted_partition(n_nodes=2000, d_feat=64, avg_degree=10)
    ei = formats.add_self_loops(g.edge_index, g.n_nodes)
    ew = formats.gcn_edge_weights(ei, g.n_nodes)
    g = formats.Graph(g.n_nodes, ei, g.x, g.y, g.train_mask, g.val_mask,
                      g.test_mask, n_classes=g.n_classes)

    # 2. Graph Engine: partition + halo plan (paper step 1)
    pg = partition.partition_graph(g, n_parts=4, edge_weight=ew)
    print(f"partitioned: {pg.plan.n_parts} parts, n_local={pg.plan.n_local}, "
          f"halo slots/pair={pg.plan.h_pad}, "
          f"pad efficiency={pg.plan.pad_efficiency():.2f}")

    # 3. model + Sylvie-S runtime (quantize -> exchange -> dequantize)
    model = GCN(d_in=64, d_hidden=128, d_out=g.n_classes, n_layers=2)
    for mode, bits in (("vanilla", 32), ("sync", 1)):
        tr = GNNTrainer(model, pg, SylvieConfig(mode=mode, bits=bits))
        pb, eb = tr.comm_bytes_per_epoch()
        tr.fit(40)
        print(f"{mode:8s} bits={bits:2d}  comm/epoch={pb/1e6:7.2f}MB "
              f"(+{eb/1e6:.3f}MB error-comp)  "
              f"test acc={tr.evaluate('test'):.4f}")


if __name__ == "__main__":
    main()
