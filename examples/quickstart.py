"""Quickstart: train a GCN full-graph with Sylvie-S 1-bit halo exchange.

    PYTHONPATH=src python examples/quickstart.py                       # simulated
    PYTHONPATH=src python examples/quickstart.py --runtime shard_map   # 1 part/device

Partitions the ``yelp_like`` named workload (repro.datasets) over 4
partitions, trains with
quantized boundary communication, and prints the comm-volume cut and final
accuracy — the paper's core result at laptop scale. Everything goes through
the ``repro.api`` facade: the *only* difference between the two invocations is
the :class:`Runtime` (simulated stacked semantics vs. shard_map over host
devices); model and training config are identical.

What each exchange does per epoch is a ``CommPolicy``: the vanilla baseline
and Sylvie-S are both ``Uniform`` schedules (32-bit / 1-bit everywhere), and
the ``Warmup`` row shows an adaptive schedule — full precision for the first
5 epochs, 1-bit afterwards — cutting almost all the bytes of the static
1-bit run while easing the early-training quantization noise.
"""
import argparse
import os
import pathlib
import sys

PARSER = argparse.ArgumentParser(description=__doc__)
PARSER.add_argument("--runtime", choices=("simulated", "shard_map"),
                    default="simulated")
PARSER.add_argument("--parts", type=int, default=4)
PARSER.add_argument("--epochs", type=int, default=40)
ARGS = PARSER.parse_args()

if ARGS.runtime == "shard_map":
    # must happen before jax initializes: give the host that many CPU devices
    # (append so a user-set XLA_FLAGS keeps its other flags)
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={ARGS.parts}"
            .strip())

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import repro.api as repro  # noqa: E402
from repro import datasets  # noqa: E402
from repro.models.gnn.models import GCN  # noqa: E402


def main() -> None:
    # 1. a graph — a named workload from the registry (any
    #    repro.graph.formats.Graph works; see datasets.names() for the rest)
    g = datasets.load("yelp_like@small")

    # 2. pick the execution mode — one object, nothing else changes
    if ARGS.runtime == "shard_map":
        runtime = repro.Runtime.from_mesh(repro.make_gnn_mesh(ARGS.parts))
    else:
        runtime = repro.Runtime.simulated(ARGS.parts)

    # 3. Graph Engine: partition + halo plan (paper step 1)
    pg = repro.partition(g, runtime=runtime)
    print(f"[{ARGS.runtime}] partitioned: {pg.plan.n_parts} parts, "
          f"n_local={pg.plan.n_local}, {pg.plan.layout} halo layout "
          f"({pg.plan.halo_rows} rows/part, worst pair={pg.plan.h_pad}), "
          f"pad efficiency={pg.plan.pad_efficiency():.2f}")

    # 4. model + Sylvie-S runtime (quantize -> exchange -> dequantize).
    #    The per-epoch communication schedule is a pluggable CommPolicy.
    model = GCN(d_in=64, d_hidden=128, d_out=g.n_classes, n_layers=2)
    rows = (("vanilla fp32", repro.Uniform(bits=32)),
            ("uniform 1-bit", repro.Uniform(bits=1)),
            ("warmup 5ep->1b", repro.Warmup(epochs=5, bits=1)))
    for label, policy in rows:
        tr = repro.train(model, pg, mode="sync", policy=policy,
                         runtime=runtime, epochs=ARGS.epochs)
        # heterogeneous-bits accounting: average the per-epoch payload the
        # epochs' actual decisions shipped (Warmup pays fp32 early on)
        pb = sum(m.comm_payload_mb for m in tr.history) / len(tr.history)
        eb = sum(m.comm_ec_mb for m in tr.history) / len(tr.history)
        print(f"{label:14s} comm/epoch={pb:7.2f}MB (+{eb:.3f}MB error-comp)  "
              f"test acc={tr.evaluate('test'):.4f}")


if __name__ == "__main__":
    main()
