"""repro.dist — the distributed runtime layer.

``backend`` defines the pluggable :class:`HaloBackend` communicator protocol
(SimulatedBackend / ShardMapBackend); ``api`` holds the mesh/spec helpers and
the shard_map step wrapping; ``runtime`` is the :class:`Runtime` facade that
the trainer, launch cells, and ``repro.api`` consume.
"""
from . import api  # noqa: F401
from .backend import (HaloBackend, ShardMapBackend, SimulatedBackend,  # noqa: F401
                      as_backend)
from .runtime import Runtime  # noqa: F401
