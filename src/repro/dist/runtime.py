"""The Runtime facade: one object that fixes the execution mode.

A :class:`Runtime` bundles a :class:`~repro.dist.backend.HaloBackend` with the
placement/compilation policy that goes with it, so callers (the trainer, the
launch cells, ``repro.api``) pick an execution mode in exactly one place:

    Runtime.simulated(n_parts=4)        # stacked reference semantics, 1 device
    Runtime.from_mesh(mesh)             # one partition per mesh device
    Runtime.sharded(n_parts=8)          # shorthand: 1-D mesh over host devices

Everything downstream — ``SylvieComm``'s exchanges, the weight-gradient
all-reduce, step compilation, array placement — is derived from the runtime's
backend; no ``axis_name`` threading.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from . import api
from .backend import HaloBackend, ShardMapBackend, SimulatedBackend


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution-mode facade: a backend + its placement/compilation policy.

    Frozen and hashable — safe to share across trainers and to close over in
    jitted code. The same model/config trains bit-compatibly under either
    backend (``tests/test_runtime.py``)::

        tr = GNNTrainer(model, pg, cfg, runtime=Runtime.simulated(4))
        tr = GNNTrainer(model, pg, cfg, runtime=Runtime.from_mesh(mesh))
    """

    backend: HaloBackend

    # -- constructors -------------------------------------------------------
    @staticmethod
    def simulated(n_parts: Optional[int] = None) -> "Runtime":
        """Whole partition stack in one program (tests / CPU training).

        ``Runtime.simulated(4)`` commits to 4 partitions;
        ``Runtime.simulated()`` accepts any partitioned graph.
        """
        return Runtime(SimulatedBackend(n_parts))

    @staticmethod
    def from_mesh(mesh) -> "Runtime":
        """One partition per device of ``mesh`` (the production path)::

            mesh = repro.make_gnn_mesh(8)        # or launch/mesh.py builders
            runtime = Runtime.from_mesh(mesh)
        """
        return Runtime(ShardMapBackend(mesh))

    @staticmethod
    def sharded(n_parts: Optional[int] = None, axis_name: str = "parts") -> "Runtime":
        """Shorthand: build a 1-D mesh over the host's devices and shard it.

        On CPU, force host devices first (before jax initializes)::

            XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
                python train.py        # then Runtime.sharded(8)
        """
        return Runtime.from_mesh(api.make_gnn_mesh(n_parts, axis_name))

    # -- introspection ------------------------------------------------------
    @property
    def mesh(self):
        return getattr(self.backend, "mesh", None)

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    @property
    def n_parts(self) -> Optional[int]:
        """Partition count this runtime is committed to (None = any)."""
        if self.is_sharded:
            return api.mesh_size(self.mesh)
        return getattr(self.backend, "n_parts", None)

    # -- GNN execution ------------------------------------------------------
    def shard_gnn_steps(self, train_sync, train_async, eval_step, state, block):
        """Compile the three step functions for this runtime."""
        if not self.is_sharded:
            return (jax.jit(train_sync), jax.jit(train_async),
                    jax.jit(eval_step))
        return api.shard_gnn_steps(train_sync, train_async, eval_step,
                                   self.mesh, state, block)

    def device_put_gnn(self, state, block, arrays=()):
        """Place training state + graph under this runtime's sharding."""
        if not self.is_sharded:
            return state, block, tuple(arrays)
        return api.device_put_gnn(self.mesh, state, block, arrays)

    # -- serving (repro.serve) ----------------------------------------------
    def shard_serve_fn(self, sweep_fn):
        """Compile the inference-engine sweep for this runtime (plain jit in
        the simulated stack; ``jit(shard_map(...))`` on a mesh)."""
        if not self.is_sharded:
            return jax.jit(sweep_fn)
        return api.shard_serve_fn(sweep_fn, self.mesh)

    def device_put_stacked(self, tree):
        """Place a stacked ``(P, ...)`` pytree under this runtime (one
        partition per device when sharded; identity otherwise)."""
        if not self.is_sharded:
            return tree
        from jax.sharding import PartitionSpec
        return self.backend.device_put(
            tree, PartitionSpec(api.flat_axes(self.mesh)))

    def device_put_replicated(self, tree):
        """Replicate a pytree across this runtime's devices."""
        if not self.is_sharded:
            return tree
        from jax.sharding import PartitionSpec
        return self.backend.device_put(tree, PartitionSpec())
