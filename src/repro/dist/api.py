"""Mesh-level GNN sharding: spec derivation, step wrapping, placement.

The production shard_map path. GNN runtime arrays are *stacked* with a leading
partition axis ``P`` (one partition per mesh device); this module derives the
``PartitionSpec`` trees for a :class:`~repro.train.gnn_step.GNNTrainState` /
:class:`~repro.models.gnn.blocks.GraphBlock` pair, wraps the three step
functions in ``jax.shard_map`` via :class:`~repro.dist.backend.ShardMapBackend`,
and places host arrays onto the mesh.

Sharding contract (one partition per device):
  * model params / optimizer state / step counter — replicated (``P()``).
    shard_map runs with replication checking OFF (see ``compat.shard_map``):
    nothing reduces the replicated params' cotangents at the boundary, so the
    step functions all-reduce weight gradients with an explicit
    ``backend.psum`` (Alg. 2 line 16) — do not remove that psum.
  * halo caches, graph block arrays, features/labels/masks — sharded on the
    leading partition axis over every mesh axis (``P(axes)``). This covers
    both halo-buffer layouts: dense ``(P, P*h_pad, d)`` and compact
    ``(P, sum(bucket_sizes), d)`` buffers shard identically (the layout lives
    in ``PlanArrays``' static metadata, not in the spec tree).
  * PRNG keys and scalar losses — replicated.

Structure-only: spec trees are built from the state/block *instances* (pytree
prefixes), so this module never imports the train or model layers and stays
import-cycle-free below ``core``/``train``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from . import compat
from .backend import (HaloBackend, ShardMapBackend, SimulatedBackend,  # noqa: F401
                      as_backend)

# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------


def flat_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis, flattened into one partition axis (paper: N devices =
    N partitions)."""
    return tuple(mesh.axis_names)


def mesh_size(mesh) -> int:
    out = 1
    for a in mesh.axis_names:
        out *= mesh.shape[a]
    return out


def make_gnn_mesh(n_parts: int | None = None, axis_name: str = "parts"):
    """A 1-D ``(n_parts,)`` mesh — the canonical GNN topology (one partition
    per device). Defaults to every visible device."""
    n = n_parts if n_parts is not None else len(jax.devices())
    return compat.make_mesh((n,), (axis_name,))


# ---------------------------------------------------------------------------
# spec derivation (pytree prefixes)
# ---------------------------------------------------------------------------


def gnn_state_specs(state, axes) -> Any:
    """Spec prefix for a GNNTrainState: params/opt/step replicated, halo
    caches sharded on the leading partition axis. The EF21 compressor state
    and the psum'd per-site comm telemetry are replicated (the compressor is
    deterministic on the already-reduced gradient; the stats are reduced
    inside the step)."""
    return type(state)(params=P(), opt_state=P(), halo=P(axes), step=P(),
                       ef=P(), site_stats=P(),
                       # fault masks are (P, rows) wire masks — stacked like
                       # the halo buffers they condemn; None stays None (the
                       # fault-free structure).
                       faults=None if state.faults is None else P(axes))


def gnn_block_spec(axes) -> P:
    """Every GraphBlock array (edges, masks, plan, weights) is stacked."""
    return P(axes)


def gnn_data_spec(axes) -> P:
    """Features ``(P, n_local, d)``, labels and masks ``(P, n_local)``."""
    return P(axes)


# ---------------------------------------------------------------------------
# step wrapping + placement
# ---------------------------------------------------------------------------


def shard_gnn_steps(train_sync, train_async, eval_step, mesh, state, block):
    """Wrap the three GNN step functions (see ``train.gnn_step``) in
    ``jit(shard_map(...))`` over ``mesh``. The steps must have been built with
    a :class:`ShardMapBackend` for the same mesh so their internal exchanges
    and psums name these axes.

    Returns ``(train_sync, train_async, eval_step)`` wrapped; call signatures
    are unchanged.
    """
    del block  # the block spec is a pure prefix — kept for API symmetry
    axes = flat_axes(mesh)
    backend = ShardMapBackend(mesh)
    st = gnn_state_specs(state, axes)
    blk = gnn_block_spec(axes)
    data = gnn_data_spec(axes)
    rep = P()
    train_in = (st, blk, data, data, data, rep)
    ts = backend.shard(train_sync, in_specs=train_in, out_specs=(st, rep))
    ta = backend.shard(train_async, in_specs=train_in, out_specs=(st, rep))
    ev = backend.shard(eval_step, in_specs=(rep, blk, data, data, data, rep),
                       out_specs=(rep, rep))
    return ts, ta, ev


def shard_serve_fn(sweep_fn, mesh):
    """Wrap the serving sweep (see ``repro.serve.engine``) in
    ``jit(shard_map(...))``. Signature contract:
    ``sweep_fn(params, block, x, halo_caches, send_masks, key) ->
    (logits, layer_inputs, halo_caches)`` — params/key replicated, everything
    else stacked on the leading partition axis (the specs are pytree
    prefixes, so the halo-cache / mask / layer tuples need no per-leaf
    spelling)."""
    axes = flat_axes(mesh)
    backend = ShardMapBackend(mesh)
    sh, rep = P(axes), P()
    return backend.shard(sweep_fn, in_specs=(rep, sh, sh, sh, sh, rep),
                         out_specs=(sh, sh, sh))


def device_put_gnn(mesh, state, block, arrays=()):
    """Place (state, block, *arrays) onto ``mesh`` under the GNN sharding
    contract. ``arrays`` are per-node stacked arrays (x, y, masks, ...).

    Returns ``(state, block, arrays)`` device-resident.
    """
    axes = flat_axes(mesh)
    backend = ShardMapBackend(mesh)
    sharded, rep = P(axes), P()
    state_d = type(state)(
        params=backend.device_put(state.params, rep),
        opt_state=backend.device_put(state.opt_state, rep),
        halo=backend.device_put(state.halo, sharded),
        step=backend.device_put(state.step, rep),
        ef=backend.device_put(state.ef, rep),
        site_stats=backend.device_put(state.site_stats, rep),
        faults=(None if state.faults is None
                else backend.device_put(state.faults, sharded)))
    block_d = backend.device_put(block, sharded)
    arrays_d = tuple(backend.device_put(a, sharded) for a in arrays)
    return state_d, block_d, arrays_d
