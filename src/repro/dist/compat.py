"""JAX version compatibility for the distributed runtime layer.

The runtime targets the modern spellings (``jax.shard_map(check_vma=...)``,
``jax.make_mesh(axis_types=...)``) but must also run on the 0.4.x series where
shard_map lives in ``jax.experimental`` (``check_rep=``) and meshes carry no
axis types. Everything in ``repro.dist`` and ``repro.launch`` builds meshes and
shard_maps through these two helpers; nothing else in the tree should call the
raw APIs.
"""
from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` on any supported JAX.

    ``check`` defaults to False: replication/VMA inference cannot see through
    the custom_vjp communication sites (quantized halo / embedding exchanges),
    so step functions reduce replicated-state gradients with explicit psums
    instead of relying on boundary insertion — identical semantics on every
    JAX version, verified by the equivalence tests.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def axis_size(name):
    """``jax.lax.axis_size`` (absent on 0.4.x, where ``psum(1, name)`` is
    constant-folded to the mapped axis size)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def use_mesh(mesh):
    """Context manager entering ``mesh``: ``jax.set_mesh`` on modern JAX; on
    0.4.x the Mesh object itself is the context manager (bare-PartitionSpec
    sharding constraints resolve against it either way)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict (0.4.x returns a
    one-element list of dicts; newer JAX returns the dict directly)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)
