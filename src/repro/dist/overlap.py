"""Overlapped (double-buffered) halo-exchange schedule.

The blocking schedule (``core/sylvie.py``) fuses issue and consumption of a
halo exchange into one dependency chain per site: ``gather -> quantize ->
exchange -> dequantize -> aggregate``. Nothing sits between the collective
and its consumer, so a scheduler has no room to hide the wire time — every
comm byte is *exposed*.

This module restructures each exchange site into the GNNPipe-style
issue/land protocol behind the exact same :class:`~repro.dist.backend
.HaloBackend` primitives:

* **issue** — the quantized send is emitted as early as the data allows
  (right after the boundary gather), exactly once per site per direction:
  the collective census is *identical* to the blocking schedule (contract
  RC209 — no duplicate sends, no extra collectives).
* **land** — the received buffer passes through ``backend.fence`` (an
  ``optimization_barrier``) before dequantize. The fence is the in-order
  consumption point: it keeps XLA from fusing the collective into its
  consumer, so the exchange stays a standalone op the latency-hiding
  scheduler can run concurrently with the site's *local* aggregation
  (intra-partition edges need no halo rows), while the halo-dependent
  boundary contribution consumes the landed values — the same values, in
  program order. The fence is the identity on data, which is why the
  sync/fresh overlap schedule is **bit-exact** to blocking (asserted by
  ``tests/test_overlap.py``).

Buffer lifetimes (the double buffer):

* sync/fresh (:func:`overlap_quantized_halo`) — ``inflight`` is issued and
  landed within the same layer step; the fence marks the land.
* async micro-step (:func:`overlap_stale_halo` + :func:`overlap_fresh_halo`)
  — the site consumes the *previous* layer-step's landed buffer
  (``feat_cache``, the Bounded Staleness contract) while this step's
  ``inflight`` is issued through the fence and becomes the next step's
  ``feat_cache``. Gradients ride the same ``gslot`` dataflow as the
  blocking async path.

The module also owns the DESIGN §8/§14 comm-time model extension: under the
overlap schedule each site's modeled comm time splits into an *overlapped*
share (hidden under that layer's local compute window) and an *exposed*
remainder; blocking exposes everything. Scenario reports and
``benchmarks/bench_overlap.py`` consume :func:`split_comm_time` /
:func:`site_comm_seconds`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import obs
from ..core import quantization as qlib
from ..core.exchange import (PlanArrays, exchange_bytes,
                             exchange_quantized_halo, gather_boundary,
                             scatter_boundary_grad)
from ..core.sylvie import SCHEDULES


def fence(backend, tree):
    """The landing fence: identity on data, a scheduling barrier in the
    lowered program. Backends may override (``HaloBackend.fence``) — e.g. a
    real async transport would resolve its in-flight handle here; both
    shipped backends lower to ``lax.optimization_barrier``."""
    f = getattr(backend, "fence", None)
    return f(tree) if f is not None else jax.lax.optimization_barrier(tree)


def _issue(buf, key, bits, stochastic, scale_dtype, backend, plan,
           reverse=False, impl="auto"):
    """Issue one direction's quantized exchange (same ops as the blocking
    ``_q_roundtrip`` up to the collective — identical census). The obs event
    fires at trace time (this body only runs when jit traces) — it marks a
    *compiled* issue site, same seam as the TRACE_LOG appends, and emits no
    traced op (RC210)."""
    obs.event("halo.issue", {"bits": int(bits), "reverse": bool(reverse)})
    qt = qlib.quantize(buf, bits, key, stochastic, scale_dtype, impl=impl)
    return exchange_quantized_halo(qt, plan, backend, reverse=reverse)


def _land(inflight, backend, impl="auto"):
    """Land an in-flight exchange: fence, then dequantize the received
    payload. The fence pins consumption after the issue in program order
    without touching the values. The obs event is trace-time, like
    ``_issue``'s."""
    obs.event("halo.land")
    return qlib.dequantize(fence(backend, inflight), impl=impl)


# ---------------------------------------------------------------------------
# sync/fresh schedule: issue early, land in-order, bit-exact to blocking
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def overlap_quantized_halo(h, plan: PlanArrays, fwd_key, bwd_key,
                           fwd_bits: int, bwd_bits: int, stochastic: bool,
                           scale_dtype, backend, impl):
    """Overlapped twin of :func:`repro.core.sylvie.quantized_halo` — same
    signature, same values, fenced issue/land structure."""
    buf = gather_boundary(h, plan)
    inflight = _issue(buf, fwd_key, fwd_bits, stochastic, scale_dtype,
                      backend, plan, impl=impl)
    out = _land(inflight, backend, impl=impl)
    return jnp.where(plan.recv_mask[..., None], out, 0)


def _oqh_fwd(h, plan, fwd_key, bwd_key, fwd_bits, bwd_bits, stochastic,
             scale_dtype, backend, impl):
    out = overlap_quantized_halo(h, plan, fwd_key, bwd_key, fwd_bits,
                                 bwd_bits, stochastic, scale_dtype, backend,
                                 impl)
    return out, (plan, bwd_key)


def _oqh_bwd(fwd_bits, bwd_bits, stochastic, scale_dtype, backend, impl, res,
             g):
    plan, bwd_key = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    inflight = _issue(g, bwd_key, bwd_bits, stochastic, scale_dtype, backend,
                      plan, reverse=True, impl=impl)
    back = _land(inflight, backend, impl=impl)
    grad_h = scatter_boundary_grad(back, plan)
    return (grad_h, None, None, None)


overlap_quantized_halo.defvjp(_oqh_fwd, _oqh_bwd)


# ---------------------------------------------------------------------------
# async micro-step: consume the previous layer-step's landed buffer
# ---------------------------------------------------------------------------
def overlap_fresh_halo(h, plan: PlanArrays, key, fwd_bits, stochastic,
                       scale_dtype, backend, impl="auto"):
    """Issue this layer-step's exchange through the fence; the landed result
    is the *next* step's ``feat_cache`` (the double buffer's inflight side).
    Detached like :func:`repro.core.sylvie.fresh_halo`."""
    buf = gather_boundary(jax.lax.stop_gradient(h), plan)
    inflight = _issue(buf, key, fwd_bits, stochastic, scale_dtype, backend,
                      plan, impl=impl)
    out = _land(inflight, backend, impl=impl)
    return jnp.where(plan.recv_mask[..., None], out, 0)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def overlap_stale_halo(h, feat_cache, grad_in, gslot, plan: PlanArrays,
                       bwd_key, bwd_bits: int, stochastic: bool, scale_dtype,
                       backend, impl):
    """Overlapped twin of :func:`repro.core.sylvie.stale_halo`: the primal
    consumes the previous layer-step's landed buffer under the Bounded
    Staleness contract; the backward issues this step's gradient exchange
    through the fence (it lands as the next step's ``grad_in``)."""
    del h, grad_in, gslot, plan, bwd_key
    return feat_cache


def _osh_fwd(h, feat_cache, grad_in, gslot, plan, bwd_key, bwd_bits,
             stochastic, scale_dtype, backend, impl):
    return feat_cache, (plan, grad_in, bwd_key)


def _osh_bwd(bwd_bits, stochastic, scale_dtype, backend, impl, res, g):
    plan, grad_in, bwd_key = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    inflight = _issue(g, bwd_key, bwd_bits, stochastic, scale_dtype, backend,
                      plan, reverse=True, impl=impl)
    fresh_grad = _land(inflight, backend, impl=impl)
    fresh_grad = jnp.where(plan.send_mask[..., None], fresh_grad, 0)
    grad_h = scatter_boundary_grad(grad_in, plan)
    return (grad_h, None, None, fresh_grad, None, None)


overlap_stale_halo.defvjp(_osh_fwd, _osh_bwd)


# ---------------------------------------------------------------------------
# DESIGN §8/§14 comm-time model: exposed vs overlapped split
# ---------------------------------------------------------------------------
def site_comm_seconds(plan: PlanArrays, site_dims, decision, ici_bw: float,
                      scale_dtype=jnp.bfloat16) -> tuple[float, ...]:
    """Per-site modeled comm seconds (payload + error compensation, forward
    + backward, per device): ``bytes_i / n_parts / ici_bw`` — the per-site
    decomposition of the scenario reports' ``modeled_tpu_comm_s``."""
    out = []
    for d, sd in zip(site_dims, decision.sites):
        total = 0.0
        for bits in (sd.fwd_bits, sd.bwd_bits):
            pb, eb = exchange_bytes(plan, d, bits, scale_dtype)
            total += pb + eb
        out.append(total / plan.n_parts / ici_bw)
    return tuple(out)


def split_comm_time(site_comm_s, site_compute_s, schedule: str
                    ) -> tuple[float, float]:
    """(exposed_s, overlapped_s) per step under ``schedule``.

    Blocking exposes every comm second. Overlap hides, per site, up to that
    site's local-compute window (the intra-partition aggregation the issued
    exchange runs under): ``overlapped_i = min(comm_i, compute_i)``; the
    remainder stays exposed on the critical path. Modeled step time is then
    ``sum(compute) + exposed`` (== compute + comm for blocking).
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; known: {SCHEDULES}")
    total = float(sum(site_comm_s))
    if schedule != "overlap":
        return total, 0.0
    overlapped = float(sum(min(c, w) for c, w
                           in zip(site_comm_s, site_compute_s)))
    return total - overlapped, overlapped


def modeled_step_seconds(site_comm_s, site_compute_s, schedule: str) -> float:
    """Modeled per-step seconds: local compute plus the exposed comm share."""
    exposed, _ = split_comm_time(site_comm_s, site_compute_s, schedule)
    return float(sum(site_compute_s)) + exposed
