"""Pluggable halo-communicator backends (the paper's Communicator, §3.2).

Sylvie's core claim is that the *halo exchange* — not gradient sync — is the
bottleneck of distributed full-graph training, so the communicator is a
first-class, swappable subsystem: every piece of runtime code that moves
boundary data goes through the :class:`HaloBackend` protocol instead of
hard-coding a collective. Two concrete backends implement it:

* :class:`SimulatedBackend` — the whole partition stack ``(P, ...)`` lives in
  one program on one device; the exchange is the pure transpose
  ``out[p, q*h+s] = in[q, p*h+s]``, ``psum`` is the identity (the stacked-axis
  contraction is already global). Reference semantics; used by tests, CPU
  benchmarks, and laptop-scale training.
* :class:`ShardMapBackend` — one partition per mesh device (the production
  path). The leading axis is locally size 1 inside ``jax.shard_map``; the
  exchange is a single tiled ``jax.lax.all_to_all`` over the halo-buffer axis,
  which implements exactly the same transpose across devices.

Both backends speak two buffer layouts:

* dense pairwise blocks ``(P, P*h_pad, ...)`` — ``exchange`` is the transpose
  ``out[p, q*h+s] = in[q, p*h+s]`` (simulated: a stacked reshape/swap; shard_map:
  one tiled ``all_to_all``). It is an involution, so forward and backward
  communication share it.
* compact ring buckets ``(P, sum(bucket_sizes), ...)`` — ``exchange_compact``
  moves bucket ``k`` from ``p`` to ``(p+k) % P`` (simulated: a stacked
  ``jnp.roll`` per bucket; shard_map: one ``ppermute`` per bucket). Ragged
  bucket sizes break the involution; ``reverse=True`` runs the inverted rings
  for the backward communication (Alg. 2).

Backends are frozen dataclasses: hashable and comparable, so they can ride
through ``jax.custom_vjp`` nondiff argnums and key jit caches (see
``core/sylvie.py``). Later communication strategies (pairwise NCCL-style
sends, adaptive per-message bit-widths à la AdaQP) plug in as new
implementations of this protocol without touching model code.

See DESIGN.md §1 for the full contract.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from . import compat

if TYPE_CHECKING:  # import-cycle guard: see _exchange_quantized
    from ..core.quantization import QuantizedTensor


@runtime_checkable
class HaloBackend(Protocol):
    """What the Sylvie runtime needs from a communicator.

    Traced (called inside jit / shard_map / custom_vjp):
      * ``exchange(buf)``            — the halo all-to-all on a pairwise-blocked
        buffer ``(P_local, P*h_pad, ...)``. An involution (a transpose), so the
        backward communication (Alg. 2) reuses the same primitive.
      * ``exchange_compact(buf, bucket_sizes, reverse)`` — the ragged ring
        exchange on a compacted buffer ``(P_local, sum(bucket_sizes), ...)``;
        ``reverse=True`` inverts the rings (backward communication).
      * ``exchange_quantized(qt)`` / ``exchange_quantized_compact(qt, ...)`` —
        exchange a quantized payload; data and error-compensation (scale,
        zero) move together.
      * ``psum(x)``                  — all-reduce across partitions (Alg. 2
        line 16); identity in the simulated stack.
      * ``fence(tree)``              — land an in-flight exchange: identity on
        the data, a scheduling barrier in the lowered program (the overlap
        schedule's in-order consumption point, ``dist/overlap.py``).
      * ``axis_index()``             — traced flat partition index, or ``None``
        when the whole stack is present (simulated).

    Untraced (host-side placement / compilation):
      * ``device_put(tree, spec)``   — place a pytree; ``spec`` is a single
        ``PartitionSpec`` applied to every leaf (ignored when unsharded).
      * ``shard(fn, in_specs, out_specs)`` — compile a step function for this
        backend (plain ``jax.jit`` or ``jit(shard_map(...))``).
    """

    def exchange(self, buf: jax.Array) -> jax.Array: ...

    def exchange_compact(self, buf: jax.Array, bucket_sizes: tuple[int, ...],
                         reverse: bool = False) -> jax.Array: ...

    def exchange_quantized(self, qt: QuantizedTensor) -> QuantizedTensor: ...

    def exchange_quantized_compact(self, qt: QuantizedTensor,
                                   bucket_sizes: tuple[int, ...],
                                   reverse: bool = False) -> QuantizedTensor: ...

    def psum(self, x: jax.Array) -> jax.Array: ...

    def fence(self, tree: Any) -> Any: ...

    def axis_index(self) -> Optional[jax.Array]: ...

    def device_put(self, tree: Any, spec: Optional[P] = None) -> Any: ...

    def shard(self, fn: Any, in_specs: Any = None,
              out_specs: Any = None) -> Any: ...


def _exchange_quantized(exch, qt: "QuantizedTensor") -> "QuantizedTensor":
    """Shared payload+error-compensation exchange (paper §3.2 Communicator).
    ``exch`` is the buffer-level exchange closure (dense or compact)."""
    # deferred import: this module must stay a leaf below repro.core so either
    # package can be imported first (core.exchange imports us at module level)
    from ..core.quantization import QuantizedTensor
    return QuantizedTensor(
        data=exch(qt.data),
        scale=exch(qt.scale) if qt.scale.size else qt.scale,
        zero=exch(qt.zero) if qt.zero.size else qt.zero,
        bits=qt.bits, feat_dim=qt.feat_dim)


def _bucket_slices(bucket_sizes: tuple[int, ...]):
    """(ring offset k, start, stop) for each non-empty bucket."""
    out, start = [], 0
    for k, b in enumerate(bucket_sizes):
        if b:
            out.append((k, start, start + b))
        start += b
    return out


@dataclasses.dataclass(frozen=True)
class SimulatedBackend:
    """Stacked single-program reference semantics (``P`` partitions, 1 device).

    ``n_parts`` is optional metadata for the :class:`~repro.dist.runtime.Runtime`
    facade (graph partitioning); the exchange itself reads ``P`` off the buffer.
    """

    n_parts: Optional[int] = None

    def exchange(self, buf: jax.Array) -> jax.Array:
        p = buf.shape[0]
        h = buf.shape[1] // p
        y = buf.reshape((p, p, h) + buf.shape[2:])
        y = jnp.swapaxes(y, 0, 1)
        return y.reshape((p, p * h) + buf.shape[2:])

    def exchange_compact(self, buf: jax.Array, bucket_sizes: tuple[int, ...],
                         reverse: bool = False) -> jax.Array:
        """Ring exchange on the stack: bucket k rolls k partitions forward
        (out[p] = in[(p-k) % P]), or backward when reversed."""
        parts = [jnp.roll(buf[:, s0:s1], -k if reverse else k, axis=0)
                 for k, s0, s1 in _bucket_slices(bucket_sizes)]
        return jnp.concatenate(parts, axis=1) if parts else buf

    def exchange_quantized(self, qt: QuantizedTensor) -> QuantizedTensor:
        return _exchange_quantized(self.exchange, qt)

    def exchange_quantized_compact(self, qt: QuantizedTensor,
                                   bucket_sizes: tuple[int, ...],
                                   reverse: bool = False) -> QuantizedTensor:
        return _exchange_quantized(
            lambda b: self.exchange_compact(b, bucket_sizes, reverse), qt)

    def psum(self, x: jax.Array) -> jax.Array:
        return x  # the stacked-axis contraction is already global

    def fence(self, tree: Any) -> Any:
        return jax.lax.optimization_barrier(tree)

    def axis_index(self) -> None:
        return None

    def device_put(self, tree: Any, spec: Optional[P] = None) -> Any:
        del spec  # single device — nothing to shard
        return tree

    def shard(self, fn: Any, in_specs: Any = None,
              out_specs: Any = None) -> Any:
        del in_specs, out_specs
        return jax.jit(fn)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _rep_psum(x, axes):
    """All-reduce whose output is *replicated*: the cotangent of a replicated
    value is itself replicated, so the transpose is the identity (what modern
    check_vma replication tracking infers; under ``check_rep=False`` the raw
    ``lax.psum`` would transpose to another psum and over-count by P)."""
    return jax.lax.psum(x, axes)


def _rep_psum_fwd(x, axes):
    return jax.lax.psum(x, axes), None


def _rep_psum_bwd(axes, _, g):
    return (g,)


_rep_psum.defvjp(_rep_psum_fwd, _rep_psum_bwd)


@dataclasses.dataclass(frozen=True)
class ShardMapBackend:
    """One partition per mesh device; collectives over the flattened mesh.

    Construct from a mesh (``ShardMapBackend(mesh)``) for the full protocol, or
    from bare axis names (``ShardMapBackend(axes=("parts",))``) when only the
    traced collectives are needed inside an externally-managed ``shard_map``.
    """

    mesh: Any = None
    axes: Optional[tuple[str, ...]] = None

    def __post_init__(self):
        if self.mesh is None and self.axes is None:
            raise ValueError("ShardMapBackend needs a mesh or axis names")
        if self.axes is not None and not isinstance(self.axes, tuple):
            object.__setattr__(self, "axes", tuple(self.axes))

    @property
    def axis_names(self) -> tuple[str, ...]:
        return self.axes if self.axes is not None else tuple(self.mesh.axis_names)

    def exchange(self, buf: jax.Array) -> jax.Array:
        return jax.lax.all_to_all(buf, self.axis_names, split_axis=1,
                                  concat_axis=1, tiled=True)

    def exchange_compact(self, buf: jax.Array, bucket_sizes: tuple[int, ...],
                         reverse: bool = False) -> jax.Array:
        """Ring exchange across devices: one ``ppermute`` per non-empty bucket
        (bucket k: p -> (p+k) % P; inverted rings when reversed). Only the
        aligned bucket rows ever hit the interconnect — no global-max padding,
        no diagonal self-block."""
        names = self.axis_names
        axis = names[0] if len(names) == 1 else names  # tuple = flattened axes
        p = len(bucket_sizes)
        parts = []
        for k, s0, s1 in _bucket_slices(bucket_sizes):
            kk = (p - k) % p if reverse else k
            perm = [(src, (src + kk) % p) for src in range(p)]
            parts.append(jax.lax.ppermute(buf[:, s0:s1], axis, perm))
        return jnp.concatenate(parts, axis=1) if parts else buf

    def exchange_quantized(self, qt: QuantizedTensor) -> QuantizedTensor:
        return _exchange_quantized(self.exchange, qt)

    def exchange_quantized_compact(self, qt: QuantizedTensor,
                                   bucket_sizes: tuple[int, ...],
                                   reverse: bool = False) -> QuantizedTensor:
        return _exchange_quantized(
            lambda b: self.exchange_compact(b, bucket_sizes, reverse), qt)

    def psum(self, x: jax.Array) -> jax.Array:
        return _rep_psum(x, self.axis_names)

    def fence(self, tree: Any) -> Any:
        return jax.lax.optimization_barrier(tree)

    def axis_index(self) -> jax.Array:
        names = self.axis_names
        idx = jax.lax.axis_index(names[0])
        for a in names[1:]:
            idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
        return idx

    def _require_mesh(self, what: str):
        if self.mesh is None:
            raise ValueError(f"{what} needs a mesh-backed ShardMapBackend")

    def device_put(self, tree: Any, spec: Optional[P] = None) -> Any:
        self._require_mesh("device_put")
        spec = P() if spec is None else spec
        return jax.device_put(tree, NamedSharding(self.mesh, spec))

    def shard(self, fn: Any, in_specs: Any = None,
              out_specs: Any = None) -> Any:
        # check=False: replication inference cannot see through the quantized
        # custom_vjp exchanges, so the steps reduce weight gradients with an
        # explicit self.psum (Alg. 2 line 16) instead of a boundary check.
        self._require_mesh("shard")
        return jax.jit(compat.shard_map(fn, self.mesh, in_specs=in_specs,
                                        out_specs=out_specs, check=False))


def as_backend(b: Any) -> HaloBackend:
    """Normalize legacy communicator designators to a backend.

    ``None`` -> :class:`SimulatedBackend`; an axis name (or tuple of names) ->
    a mesh-less :class:`ShardMapBackend`; a backend passes through.
    """
    if b is None:
        return SimulatedBackend()
    if isinstance(b, str):
        return ShardMapBackend(axes=(b,))
    if isinstance(b, (tuple, list)):
        return ShardMapBackend(axes=tuple(b))
    if not isinstance(b, HaloBackend):
        raise TypeError(f"not a HaloBackend: {b!r} (pass a backend, an axis "
                        "name, or None for the simulated stack)")
    return b
