"""Hot-node row cache: LRU tier + pinned tier, byte-accounted.

The cache fronts a :class:`~repro.store.backend.ShardedEmbeddingStore` shard
on the read path. Two tiers:

* **pinned** — rows explicitly marked hot (``pin``). They stay materialized
  for the lifetime of the pin: never evicted, refreshed *in place* on
  ``put_rows`` (write-through), and do not compete with the LRU tier for
  capacity. This is the "hot nodes of a skewed workload" tier — the serving
  counterpart of pinned-memory feature caches in sampling systems.
* **LRU** — everything else, bounded by ``capacity_bytes``. A lookup hit
  moves the row to most-recently-used; an insert evicts from the LRU end
  until the new row fits. Rows larger than the whole capacity are simply not
  cached (the store still serves them from the shard). A shard write
  *invalidates* LRU-resident rows instead of updating them — the next read
  takes the miss path and refetches, which keeps the cache's contents
  trivially coherent with the shard.

Keys are ``(table, part, slot)`` row coordinates. All accounting is in bytes
of row payload (``row.nbytes``), mirrored into
:class:`~repro.store.backend.StoreStats` by the owning store.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional

import numpy as np

Key = Hashable


class LRUCache:
    """Byte-bounded LRU with a separate pinned tier.

    Example::

        c = LRUCache(capacity_bytes=2 * row.nbytes)
        c.insert(("logits", 0, 7), row)
        c.lookup(("logits", 0, 7)) is not None     # hit, row now MRU
        c.pin(("logits", 0, 3), hot_row)           # never evicted
    """

    def __init__(self, capacity_bytes: int):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = int(capacity_bytes)
        self._lru: OrderedDict[Key, np.ndarray] = OrderedDict()
        self._pinned: dict[Key, np.ndarray] = {}
        self.lru_bytes = 0
        self.pinned_bytes = 0
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.evictions = 0
        self.evicted_bytes = 0

    # -- read path ----------------------------------------------------------
    def lookup(self, key: Key) -> Optional[np.ndarray]:
        """The cached row, or None on miss. Hits count bytes and bump the row
        to most-recently-used (pinned rows have no recency to maintain)."""
        row = self._pinned.get(key)
        if row is None:
            row = self._lru.get(key)
            if row is not None:
                self._lru.move_to_end(key)
        if row is None:
            self.misses += 1
            return None
        self.hits += 1
        self.hit_bytes += row.nbytes
        return row

    # -- write path ---------------------------------------------------------
    def insert(self, key: Key, row: np.ndarray) -> None:
        """Admit a row to the LRU tier (typically on a miss-path fetch),
        evicting least-recently-used rows until it fits. No-op for pinned
        keys (already materialized) and for rows larger than the capacity."""
        if key in self._pinned:
            return
        old = self._lru.pop(key, None)
        if old is not None:
            self.lru_bytes -= old.nbytes
        if row.nbytes > self.capacity_bytes:
            return
        while self._lru and self.lru_bytes + row.nbytes > self.capacity_bytes:
            _, evicted = self._lru.popitem(last=False)
            self.lru_bytes -= evicted.nbytes
            self.evictions += 1
            self.evicted_bytes += evicted.nbytes
        self._lru[key] = row
        self.lru_bytes += row.nbytes

    def invalidate(self, key: Key) -> bool:
        """Drop an LRU-tier row (shard write: the cached copy is stale). The
        pinned tier is never invalidated — callers refresh it via ``repin``.
        Returns True when a row was actually dropped."""
        row = self._lru.pop(key, None)
        if row is None:
            return False
        self.lru_bytes -= row.nbytes
        return True

    # -- pinned tier --------------------------------------------------------
    def pin(self, key: Key, row: np.ndarray) -> None:
        """Materialize a row in the pinned tier (and drop any LRU copy)."""
        self.invalidate(key)
        old = self._pinned.get(key)
        if old is not None:
            self.pinned_bytes -= old.nbytes
        self._pinned[key] = row
        self.pinned_bytes += row.nbytes

    def repin(self, key: Key, row: np.ndarray) -> bool:
        """Write-through refresh of an already-pinned row; False if not
        pinned (the caller should invalidate the LRU copy instead)."""
        old = self._pinned.get(key)
        if old is None:
            return False
        self.pinned_bytes += row.nbytes - old.nbytes
        self._pinned[key] = row
        return True

    def unpin(self, key: Key) -> bool:
        row = self._pinned.pop(key, None)
        if row is None:
            return False
        self.pinned_bytes -= row.nbytes
        return True

    def is_pinned(self, key: Key) -> bool:
        return key in self._pinned

    # -- introspection ------------------------------------------------------
    @property
    def bytes_cached(self) -> int:
        """Total materialized bytes across both tiers."""
        return self.lru_bytes + self.pinned_bytes

    def lru_keys(self) -> tuple[Key, ...]:
        """LRU-tier keys, least-recently-used first (the eviction order)."""
        return tuple(self._lru)

    def pinned_keys(self) -> tuple[Key, ...]:
        return tuple(self._pinned)

    def __contains__(self, key: Key) -> bool:
        return key in self._pinned or key in self._lru

    def __len__(self) -> int:
        return len(self._pinned) + len(self._lru)
