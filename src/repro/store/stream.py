"""Streaming graph mutations: a seeded, timestamped feed of node-feature and
edge events, consumed in batches that drive the engine's k-hop delta
refreshes.

The feed models a continuously-updating graph (the ``gdelt_like`` regime —
an event stream touching a heavy-tailed set of actors):

* arrivals are **Poisson** at ``rate`` events per (virtual) second, so batch
  sizes are bursty the way real update streams are;
* the touched node is drawn from a **Zipf-skewed** popularity (exponent
  ``skew`` over a seeded permutation) — the same hot nodes mutate again and
  again, which is exactly what the store's pinned hot tier banks on;
* an event is a **feature mutation** with probability ``feat_frac``
  (replacement feature row, seeded Gaussian) and an **edge event**
  otherwise (a new interaction between two drawn nodes).

Consumption contract (``batches``): events are grouped into fixed
``window_s`` consumption windows. Within a window, feature mutations
last-write-win per node; edge events *touch* both endpoints — under the
static partition plan a topology change cannot be incorporated without
repartitioning, so the conservative correct action is to re-ship the
endpoints' k-hop neighborhoods (their current feature rows re-enter the
changed set, invalidating every embedding the new edge could have reached).
Each batch is ``(t_due, changed_ids, rows)`` ready for
``engine.refresh``/``server.refresh`` — the engine's ``max_staleness`` bound
then decides delta vs forced full sweep exactly as for any other refresh.

Everything is a pure function of the constructor arguments: two streams with
the same ``(n_nodes, d_feat, kwargs, seed)`` are event-for-event identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One timestamped graph event.

    ``kind`` is ``"feat"`` (``row`` replaces node ``node``'s features) or
    ``"edge"`` (a new ``node -> dst`` interaction; ``row`` is None)."""

    t: float
    kind: str
    node: int
    dst: int = -1
    row: Optional[np.ndarray] = None


def zipf_popularity(n_nodes: int, skew: float, seed: int) -> np.ndarray:
    """Normalized Zipf-like popularity over a seeded node permutation
    (``skew=0`` is uniform). Shared by the stream and the skewed query
    workloads in ``loadgen``/``bench_store`` so both hammer the same hot
    set."""
    pop = 1.0 / (np.arange(1, n_nodes + 1, dtype=np.float64) ** float(skew))
    pop = pop[np.random.default_rng(seed).permutation(n_nodes)]
    return pop / pop.sum()


class MutationStream:
    """Seeded, timestamped node-feature/edge mutation feed.

    Example::

        g, stream = MutationStream.from_workload("gdelt_like@smoke")
        for t_due, ids, rows in stream.batches(200, window_s=0.25,
                                               rows_of=eng.feature_rows):
            server.refresh(ids, rows)
    """

    def __init__(self, n_nodes: int, d_feat: int, *, rate: float = 100.0,
                 feat_frac: float = 0.8, skew: float = 0.9, seed: int = 0):
        if not 0.0 <= feat_frac <= 1.0:
            raise ValueError("feat_frac must be in [0, 1]")
        if rate <= 0:
            raise ValueError("rate must be > 0 events/s")
        self.n_nodes = int(n_nodes)
        self.d_feat = int(d_feat)
        self.rate = float(rate)
        self.feat_frac = float(feat_frac)
        self.skew = float(skew)
        self.seed = int(seed)
        self._pop = zipf_popularity(self.n_nodes, self.skew, self.seed)

    @staticmethod
    def from_workload(ref: str, seed: int = 0):
        """Build the graph *and* its calibrated stream from a registry
        workload that declares per-tier ``stream`` kwargs (``gdelt_like``).
        Returns ``(graph, stream)``; raises KeyError for workloads without a
        stream calibration at that tier."""
        from ..datasets import registry
        name, tier = registry.parse(ref)
        spec = registry.get(name)
        if not spec.stream or tier not in spec.stream:
            raise KeyError(
                f"workload {name!r} declares no mutation stream at tier "
                f"{tier!r} (streaming tiers: "
                f"{sorted(spec.stream) if spec.stream else []})")
        g = spec.load(tier, seed=seed)
        return g, MutationStream(g.n_nodes, g.x.shape[1], seed=seed + 1,
                                 **spec.stream[tier])

    def events(self, n_events: int) -> list[Mutation]:
        """The first ``n_events`` events of the feed (deterministic — calling
        twice returns identical events, timestamps included)."""
        rng = np.random.default_rng(self.seed)
        ts = np.cumsum(rng.exponential(1.0 / self.rate, size=n_events))
        nodes = rng.choice(self.n_nodes, size=n_events, p=self._pop)
        is_feat = rng.random(n_events) < self.feat_frac
        dsts = rng.choice(self.n_nodes, size=n_events, p=self._pop)
        out = []
        for i in range(n_events):
            if is_feat[i]:
                row = rng.normal(0, 1, self.d_feat).astype(np.float32)
                out.append(Mutation(float(ts[i]), "feat", int(nodes[i]),
                                    row=row))
            else:
                out.append(Mutation(float(ts[i]), "edge", int(nodes[i]),
                                    dst=int(dsts[i])))
        return out

    def batches(self, n_events: int, window_s: float, *,
                rows_of: Callable[[np.ndarray], np.ndarray]
                ) -> list[tuple[float, np.ndarray, np.ndarray]]:
        """Group the first ``n_events`` events into ``window_s`` consumption
        windows. Per window: feature rows last-write-win per node; edge
        events touch their endpoints at current features (``rows_of`` maps
        node ids to their current rows — typically
        ``engine.feature_rows``). Returns ``(t_due, ids, rows)`` batches
        (``t_due`` = window close), empty windows skipped."""
        if window_s <= 0:
            raise ValueError("window_s must be > 0")
        out = []
        feats: dict[int, np.ndarray] = {}
        touched: set[int] = set()
        due = float(window_s)

        def flush(due_t: float):
            if not feats and not touched:
                return
            ids = np.array(sorted(set(feats) | touched), dtype=np.int64)
            rows = rows_of(ids).astype(np.float32).copy()
            for j, i in enumerate(ids.tolist()):
                if i in feats:
                    rows[j] = feats[i]
            out.append((due_t, ids, rows))
            feats.clear()
            touched.clear()

        for ev in self.events(n_events):
            while ev.t > due:
                flush(due)
                due += window_s
            if ev.kind == "feat":
                feats[ev.node] = ev.row
            else:
                touched.add(ev.node)
                touched.add(ev.dst)
        flush(due)
        return out
