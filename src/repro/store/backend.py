"""KV-store-style sharded embedding tables behind a ``StoreBackend`` protocol.

The serving tier's table is too big to assume resident: a
:class:`ShardedEmbeddingStore` splits every named table (logits, final-layer
embeddings, ...) into **per-partition shards** — shard ``p`` of a table holds
the rows of the nodes partition ``p`` owns, addressed by local slot, exactly
the ``(part, slot)`` coordinates the partition plan already uses. Reads go
through an :class:`~repro.store.cache.LRUCache` hot-node tier:

* **hit** — the row is served from cache (pinned or LRU), zero shard traffic;
* **miss** — the row is fetched from the shard (counted in ``miss_bytes`` —
  the modeled remote/disk tier traffic a production KV store would pay) and
  admitted to the LRU tier.

Writes (``put_rows``) land in the shard, refresh pinned rows in place, and
invalidate LRU-resident rows — read-your-writes coherence by construction
(``tests/test_store.py`` interleaves refreshes with reads to hold it).

Everything is host-side numpy: the store models the *memory/traffic*
contract (what stays materialized, what ships on a miss), not device
placement. The engine stays the single writer; any number of
:class:`~repro.serve.engine.StoreReader` replicas read concurrently.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from .. import obs
from .cache import LRUCache


@dataclasses.dataclass(frozen=True)
class StoreStats:
    """One read/write traffic snapshot (cumulative since construction).

    ``hit_rate`` is row-weighted; ``miss_bytes`` is the shard-fetch traffic a
    remote tier would have served — the number the hot-node cache exists to
    drive down (``BENCH_store.json`` gates it on the skewed workload)."""

    gets: int
    hits: int
    misses: int
    hit_bytes: int
    miss_bytes: int
    puts: int
    put_rows: int
    put_bytes: int
    evictions: int
    cached_bytes: int
    pinned_bytes: int
    capacity_bytes: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


@runtime_checkable
class StoreBackend(Protocol):
    """What the serving tier requires of an embedding store.

    ``get_rows``/``put_rows`` move ``(len(slots), d)`` row blocks addressed
    by ``(table, part, local slots)``; ``stats`` reports the byte-accounted
    read/write traffic. Implementations may cache, tier, or shard however
    they like — the engine and its readers only speak this protocol."""

    def get_rows(self, table: str, part: int,
                 slots: np.ndarray) -> np.ndarray: ...

    def put_rows(self, table: str, part: int, slots: np.ndarray,
                 rows: np.ndarray) -> None: ...

    def stats(self) -> StoreStats: ...


class ShardedEmbeddingStore:
    """Per-partition shards + hot-node cache. The reference ``StoreBackend``.

    Example::

        store = ShardedEmbeddingStore(cache_bytes=1 << 20)
        store.create_table("logits", part_rows=(300, 300, 299, 301), d=7)
        store.put_rows("logits", 0, np.arange(300), fresh_rows)
        store.pin("logits", 0, hot_slots)          # hot tier: never evicted
        rows = store.get_rows("logits", 0, np.array([5, 17]))
        store.stats().hit_rate
    """

    def __init__(self, cache_bytes: int = 1 << 20):
        self.cache = LRUCache(cache_bytes)
        self._shards: dict[str, list[np.ndarray]] = {}
        self._gets = 0
        self._miss_bytes = 0
        self._puts = 0
        self._put_rows = 0
        self._put_bytes = 0

    # -- schema -------------------------------------------------------------
    def create_table(self, table: str, part_rows: Sequence[int], d: int,
                     dtype=np.float32) -> None:
        """Allocate one shard per partition: shard ``p`` is a
        ``(part_rows[p], d)`` array. Idempotent only for a brand-new table —
        recreating an existing one is a schema error."""
        if table in self._shards:
            raise ValueError(f"table {table!r} already exists")
        self._shards[table] = [np.zeros((int(r), int(d)), dtype=dtype)
                               for r in part_rows]

    def has_table(self, table: str) -> bool:
        return table in self._shards

    def tables(self) -> tuple[str, ...]:
        return tuple(sorted(self._shards))

    def _shard(self, table: str, part: int) -> np.ndarray:
        if table not in self._shards:
            raise KeyError(f"unknown table {table!r}; "
                           f"known: {sorted(self._shards)}")
        return self._shards[table][part]

    # -- read path ----------------------------------------------------------
    def get_rows(self, table: str, part: int, slots) -> np.ndarray:
        """Rows ``slots`` of shard ``(table, part)``: cache hits are served
        materialized; misses fetch from the shard (miss bytes), then admit to
        the LRU tier. Returns a fresh ``(len(slots), d)`` array the caller
        owns."""
        shard = self._shard(table, part)
        slots = np.asarray(slots, dtype=np.int64).reshape(-1)
        self._gets += 1
        out = np.empty((slots.size, shard.shape[1]), dtype=shard.dtype)
        miss_j: list[int] = []
        for j, s in enumerate(slots.tolist()):
            row = self.cache.lookup((table, part, s))
            if row is None:
                miss_j.append(j)
            else:
                out[j] = row
        obs.count("store.hits", slots.size - len(miss_j))
        if miss_j:
            fetched = shard[slots[miss_j]]
            self._miss_bytes += fetched.nbytes
            obs.count("store.miss_bytes", fetched.nbytes)
            out[miss_j] = fetched
            for j in miss_j:
                self.cache.insert((table, part, int(slots[j])),
                                  out[j].copy())
        return out

    def peek_rows(self, table: str, part: int, slots) -> np.ndarray:
        """Read rows straight from the shard, bypassing the cache and all
        accounting — verification/debug only (``engine.verify_store`` uses it
        so the check neither churns the LRU nor skews the hit rate)."""
        shard = self._shard(table, part)
        return shard[np.asarray(slots, dtype=np.int64).reshape(-1)].copy()

    # -- write path ---------------------------------------------------------
    def put_rows(self, table: str, part: int, slots, rows) -> None:
        """Overwrite rows of a shard. Pinned rows are refreshed in place
        (write-through — the hot tier stays materialized *and* fresh); LRU
        rows are invalidated (next read refetches)."""
        shard = self._shard(table, part)
        slots = np.asarray(slots, dtype=np.int64).reshape(-1)
        rows = np.asarray(rows, dtype=shard.dtype)
        if rows.shape != (slots.size, shard.shape[1]):
            raise ValueError(f"rows must be {(slots.size, shard.shape[1])}, "
                             f"got {rows.shape}")
        shard[slots] = rows
        self._puts += 1
        self._put_rows += int(slots.size)
        self._put_bytes += rows.nbytes
        for j, s in enumerate(slots.tolist()):
            key = (table, part, s)
            if not self.cache.repin(key, rows[j].copy()):
                self.cache.invalidate(key)

    # -- hot tier -----------------------------------------------------------
    def pin(self, table: str, part: int, slots) -> None:
        """Pin rows into the hot tier (materialized from the shard now,
        write-through refreshed on every future ``put_rows``)."""
        shard = self._shard(table, part)
        for s in np.asarray(slots, dtype=np.int64).reshape(-1).tolist():
            self.cache.pin((table, part, s), shard[s].copy())

    def unpin(self, table: str, part: int, slots) -> None:
        for s in np.asarray(slots, dtype=np.int64).reshape(-1).tolist():
            self.cache.unpin((table, part, s))

    # -- introspection ------------------------------------------------------
    def stats(self) -> StoreStats:
        c = self.cache
        return StoreStats(
            gets=self._gets, hits=c.hits, misses=c.misses,
            hit_bytes=c.hit_bytes, miss_bytes=self._miss_bytes,
            puts=self._puts, put_rows=self._put_rows,
            put_bytes=self._put_bytes, evictions=c.evictions,
            cached_bytes=c.bytes_cached, pinned_bytes=c.pinned_bytes,
            capacity_bytes=c.capacity_bytes)

    def shard_bytes(self) -> int:
        """Total bytes resident in the shard tier (the full table size the
        cache is saving readers from touching)."""
        return sum(sh.nbytes for shards in self._shards.values()
                   for sh in shards)

    def check_coherence(self) -> int:
        """Assert every cached row (both tiers) is bit-identical to its shard
        row; returns the number of rows checked. The invariant behind the
        store-backed read path's bit-exactness guarantee."""
        checked = 0
        # private access on purpose: lookup() would count hits and reorder
        # the LRU — introspection must not perturb the traffic accounting
        rows = list(self.cache._pinned.items()) + list(self.cache._lru.items())
        for (table, part, slot), row in rows:
            if not np.array_equal(row, self._shard(table, part)[slot]):
                raise AssertionError(
                    f"cache row {(table, part, slot)} diverged from its shard")
            checked += 1
        return checked
