"""repro.store — sharded embedding store with a hot-node cache and a
streaming mutation feed (DESIGN.md §13).

The scale-out seam of the serving tier: per-partition shards of every
served table behind the :class:`~repro.store.backend.StoreBackend` protocol,
an :class:`~repro.store.cache.LRUCache` hot-node tier with pinned semantics,
and a :class:`~repro.store.stream.MutationStream` — the seeded, timestamped
node-feature/edge feed whose batches drive the engine's k-hop delta
refreshes under the ``max_staleness`` bound.

::

    from repro.store import ShardedEmbeddingStore, MutationStream

    store = ShardedEmbeddingStore(cache_bytes=1 << 20)
    eng = InferenceEngine(model, pg, params, store=store)   # store-backed reads
    eng.full_sweep()
    eng.pin_hot(hot_node_ids)                               # hot tier
    g, stream = MutationStream.from_workload("gdelt_like@smoke")
"""
from __future__ import annotations

from .backend import ShardedEmbeddingStore, StoreBackend, StoreStats  # noqa: F401
from .cache import LRUCache  # noqa: F401
from .stream import Mutation, MutationStream, zipf_popularity  # noqa: F401

__all__ = [
    "StoreBackend", "StoreStats", "ShardedEmbeddingStore", "LRUCache",
    "Mutation", "MutationStream", "zipf_popularity",
]
