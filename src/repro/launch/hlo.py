"""HLO analysis: collective-byte accounting + three-term roofline.

``collective_bytes`` parses the SPMD-partitioned (per-device) HLO from
``compiled.as_text()`` and sums, per collective opcode, the *wire bytes per
device* under the standard ring algorithms:

    all-gather          operand × (g-1)          (each shard forwarded g-1 times)
    reduce-scatter      operand × (g-1)/g
    all-reduce          operand × 2(g-1)/g       (RS + AG phases)
    all-to-all          operand × (g-1)/g
    collective-permute  operand × 1

``g`` is the replica-group size parsed per op. The roofline terms then follow
the assignment formulas with per-chip constants from ``mesh.py``:

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s
    memory     = HLO_bytes_per_device / 819 GB/s
    collective = wire_bytes_per_device / 50 GB/s
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from . import mesh as meshlib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.7 = bf16[16,512]{1,0} all-gather(%p), ..., replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return g - 1
    if op == "reduce-scatter":
        return (g - 1) / g
    if op == "all-reduce":
        return 2 * (g - 1) / g
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0   # collective-permute


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                 # per-device, algo-weighted
    payload_bytes: float = 0.0              # per-device, raw operand sizes
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op, payload, wire):
        self.count += 1
        self.payload_bytes += payload
        self.wire_bytes += wire
        ent = self.by_op.setdefault(op, dict(count=0, payload=0.0, wire=0.0))
        ent["count"] += 1
        ent["payload"] += payload
        ent["wire"] += wire


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_starts = set()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        shapes_bytes = None
        if m:
            dtype, dims, op = m.group(1), m.group(2), m.group(3)
            shapes_bytes = _shape_bytes(dtype, dims)
        else:
            mt = _TUPLE_RE.search(line)
            if not mt:
                continue
            op = mt.group(2)
            shapes_bytes = sum(_shape_bytes(d, s)
                               for d, s in _SHAPE_RE.findall(mt.group(1)))
        # async pairs appear as -start/-done; count the start only
        if "-done(" in line:
            continue
        name = line.split("=", 1)[0].strip()
        if name in seen_starts:
            continue
        seen_starts.add(name)
        g = _group_size(line, n_devices)
        # for all-gather the HLO result is the gathered buffer: operand
        # (per-shard) size = result / g
        payload = shapes_bytes / g if op == "all-gather" else shapes_bytes
        stats.add(op, payload, payload * _wire_factor(op, g))
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    n_devices: int
    model_flops_total: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / meshlib.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / meshlib.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / meshlib.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Modeled step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> Optional[float]:
        if not self.model_flops_total:
            return None
        return self.model_flops_total / (self.flops_per_device * self.n_devices)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """MODEL_FLOPS-per-chip-second over peak — the MFU-style score: what
        fraction of peak the *useful* math achieves at the modeled step time."""
        if not self.model_flops_total:
            return None
        per_chip = self.model_flops_total / self.n_devices
        return per_chip / self.step_s / meshlib.PEAK_FLOPS_BF16

    def as_dict(self) -> dict:
        return dict(
            flops_per_device=self.flops_per_device,
            hbm_bytes_per_device=self.hbm_bytes_per_device,
            wire_bytes_per_device=self.wire_bytes_per_device,
            n_devices=self.n_devices,
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, bottleneck=self.bottleneck,
            step_s=self.step_s,
            model_flops_total=self.model_flops_total,
            useful_flop_ratio=self.useful_flop_ratio,
            roofline_fraction=self.roofline_fraction)


def analyze(compiled, n_devices: int,
            model_flops_total: Optional[float] = None):
    """(compiled executable, mesh size) -> (Roofline, CollectiveStats, mem)."""
    from ..dist import compat
    cost = compat.cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text(), n_devices)
    try:
        ma = compiled.memory_analysis()
        mem = dict(
            argument_bytes=getattr(ma, "argument_size_in_bytes", None),
            output_bytes=getattr(ma, "output_size_in_bytes", None),
            temp_bytes=getattr(ma, "temp_size_in_bytes", None),
            peak_bytes=(getattr(ma, "argument_size_in_bytes", 0) or 0)
            + (getattr(ma, "temp_size_in_bytes", 0) or 0))
    except Exception as e:                                    # pragma: no cover
        mem = dict(error=str(e))
    roof = Roofline(flops, hbm, stats.wire_bytes, n_devices,
                    model_flops_total)
    return roof, stats, mem
