"""Per-(architecture × input-shape × mesh) lowerable cells.

``build_cell`` returns a :class:`Cell` whose ``lower()`` runs
``jax.jit(step).lower(*ShapeDtypeStruct args)`` — no parameter or input data
is ever materialized (the 236B-param and 62M-edge cells lower from specs).

Step selection per shape (base.py): LM ``train_4k`` lowers the train step,
``prefill_32k`` the prefill, ``decode_32k``/``long_500k`` the one-token decode
(serve) step; GNN shapes lower the partition-parallel Sylvie train step; DLRM
shapes lower train / serve / retrieval.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import configs as configlib
from ..configs.base import ArchSpec, ShapeCell
from ..core.staleness import HaloState
from ..core.sylvie import SylvieConfig
from ..dist import api as dist
from ..dist import compat
from ..graph.partition import analytic_partition_spec
from ..graph.sampling import SamplerShapes
from ..models.gnn import blocks as B
from ..models.lm import model as LM
from ..models.lm import sharding as lm_sharding
from ..models.recsys import dlrm as D
from ..train import optimizer as optlib
from ..train.gnn_step import GNNTrainState, make_gnn_steps
from . import mesh as meshlib

KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    step: str
    fn: Callable
    args: tuple
    n_devices: int
    model_flops: Optional[float]
    meta: dict = dataclasses.field(default_factory=dict)
    mesh: Any = None
    shard_ctx: Any = None        # LM activation-annotation context

    def lower(self):
        if self.shard_ctx is not None:
            LM.set_shard_ctx(self.shard_ctx)
            try:
                with compat.use_mesh(self.mesh):
                    return self.fn.lower(*self.args)
            finally:
                LM.set_shard_ctx(None)
        return self.fn.lower(*self.args)


def _sds(tree, mesh=None, specs=None):
    """Shape tree -> SDS tree, optionally with NamedShardings attached."""
    if specs is None:
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_model_flops(cfg, cell: ShapeCell) -> float:
    s, b = cell.params["seq_len"], cell.params["global_batch"]
    n_act = cfg.param_count(active_only=True)
    # causal attention math: 2 matmuls x 2 flops x (S^2/2) x H x dh per layer
    attn = 0.0
    for _, _, lc, cnt in cfg.sub_layers():
        a = lc.attn
        dh = a.d_nope + a.d_rope if a.kind == "mla" else a.d_head
        span = min(s, a.window) if a.window else s
        attn += cnt * 2 * b * a.n_heads * dh * s * span
    if cell.step == "train":
        return 6.0 * n_act * b * s + 3.0 * attn
    if cell.step == "prefill":
        return 2.0 * n_act * b * s + attn
    # decode: one token against an S-token cache
    attn_dec = 0.0
    for _, _, lc, cnt in cfg.sub_layers():
        a = lc.attn
        dh = a.d_nope + a.d_rope if a.kind == "mla" else a.d_head
        span = min(s, a.window) if a.window else s
        attn_dec += cnt * 4 * b * a.n_heads * dh * span
    return 2.0 * n_act * b + attn_dec


def _reduce_depth(cfg, depth: int):
    """Shrink every count>1 segment to ``depth`` (cost-extrapolation probes:
    costs are base + count x body, so two depths recover the full-depth
    numbers exactly — see dryrun.run_cell)."""
    segs = tuple(dataclasses.replace(s, count=min(s.count, depth))
                 for s in cfg.segments)
    return dataclasses.replace(cfg, segments=segs)


def lm_scaled_count(cfg) -> int:
    """The count of the (single) scaled segment."""
    return max(s.count for s in cfg.segments)


def _lm_cell(spec: ArchSpec, cell: ShapeCell, mesh, *,
             unroll: bool = False, depth: Optional[int] = None) -> Cell:
    # unroll=True + depth=1/2 are the cost-extrapolation probes (HLO cost
    # analysis tallies a `while` body once, not x trip count); the default
    # scanned full-depth program is what actually deploys.
    cfg = spec.config()
    if depth is not None:
        cfg = _reduce_depth(cfg, depth)
    fsdp, mdl = lm_sharding.axes(mesh)
    s, b = cell.params["seq_len"], cell.params["global_batch"]

    params_shape = jax.eval_shape(lambda k: LM.init_params(k, cfg), KEY_SDS)
    p_specs = lm_sharding.param_specs(params_shape, cfg, mesh)
    params = _sds(params_shape, mesh, p_specs)
    dspec = NamedSharding(mesh, lm_sharding.data_spec(mesh))

    if cell.step == "train":
        opt = optlib.adam(1e-4)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_specs = {"m": p_specs, "v": p_specs, "t": P()}
        opt_sds = _sds(opt_shape, mesh, o_specs)
        state = (params, opt_sds,
                 jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())))
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dspec)
        labels = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dspec)
        fn = jax.jit(LM.make_train_step(cfg, opt, unroll=unroll))
        args = (state, tokens, labels)
    elif cell.step == "prefill":
        cache_shape = LM.init_cache(cfg, b, s, as_spec=True)
        c_specs = lm_sharding.cache_specs(cache_shape, mesh, b)
        out_sh = (NamedSharding(mesh, lm_sharding.data_spec(mesh)),
                  jax.tree.map(lambda sp: NamedSharding(mesh, sp), c_specs))
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dspec)
        fn = jax.jit(LM.make_prefill_step(cfg, b, s, unroll=unroll),
                     out_shardings=out_sh)
        args = (params, tokens)
    else:  # decode
        cache_shape = LM.init_cache(cfg, b, s, as_spec=True)
        c_specs = lm_sharding.cache_specs(cache_shape, mesh, b)
        caches = _sds(cache_shape, mesh, c_specs)
        tok_spec = NamedSharding(mesh, P(fsdp if b > 1 else None, None))
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=tok_spec)
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        fn = jax.jit(LM.make_decode_step(cfg, unroll=unroll))
        args = (params, caches, token, pos)

    return Cell(spec.arch_id, cell.name, cell.step, fn, args,
                meshlib.n_devices(mesh), _lm_model_flops(cfg, cell),
                meta=dict(params=cfg.param_count(),
                          active_params=cfg.param_count(active_only=True)),
                mesh=mesh, shard_ctx=LM.shard_ctx_from_mesh(mesh))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def gnn_cell_sizes(cell: ShapeCell) -> tuple[int, int, int]:
    """(n_nodes, n_edges, d_feat) of the array the runtime actually trains."""
    p = cell.params
    if cell.name == "minibatch_lg":
        ss = SamplerShapes(p["batch_nodes"], tuple(p["fanout"]))
        return ss.max_nodes, ss.max_edges, p["d_feat"]
    if cell.name == "molecule":
        return p["n_nodes"] * p["batch"], p["n_edges"] * p["batch"] * 2, \
            p["d_feat"]
    return p["n_nodes"], p["n_edges"], p["d_feat"]


def _gnn_model_flops(arch_name: str, model, n: int, e: int, d_in: int,
                     train: bool) -> float:
    """Analytic 'useful' FLOPs of one forward pass (x3 for fwd+bwd)."""
    f = 0.0
    name = arch_name.split("-")[0]
    if name in ("gcn", "graphsage"):
        dims = [d_in] + [model.d_hidden] * (model.n_layers - 1) + [model.d_out]
        for i in range(model.n_layers):
            f += 2 * e * dims[i] + 2 * n * dims[i] * dims[i + 1]
            if name == "graphsage":
                f += 2 * n * dims[i] * dims[i + 1]
    elif name == "gat":
        d = model.heads * model.d_hidden
        din = d_in
        for _ in range(model.n_layers):
            f += 2 * n * din * d + 4 * e * d + 2 * e * model.heads
            din = d
        f += 2 * n * din * model.d_out
    elif name == "pna":
        d = model.d_hidden
        f += 2 * n * d_in * d
        for _ in range(model.n_layers):
            f += 2 * e * 2 * d * d + 8 * e * d + 2 * n * 12 * d * d
    elif name == "meshgraphnet":
        d = model.d_hidden
        f += 2 * n * d_in * d + 2 * e * model.d_edge_in * d
        for _ in range(model.n_layers):
            f += 2 * e * (3 * d * d + d * d) + 2 * n * (2 * d * d + d * d)
    elif name == "schnet":
        d = model.d_hidden
        f += 2 * n * d_in * d
        for _ in range(model.n_interactions):
            f += 2 * e * (model.n_rbf * d + d * d) + 2 * e * d \
                + 2 * n * 3 * d * d
    elif name == "nequip":
        mul = model.mul
        n_paths = len(model.paths)
        f += 2 * n * d_in * mul
        tp = sum((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1) * 2 * mul
                 for (l1, l2, l3) in model.paths)
        for _ in range(model.n_layers):
            f += e * tp + 2 * e * (model.n_rbf * mul + mul * n_paths * mul)
            f += 2 * n * 2 * mul * mul * (model.l_max + 1) ** 2
    else:
        f = 2 * e * 64 + 2 * n * d_in * 64
    return 3.0 * f if train else f


def _gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh, *,
              sylvie_mode: str = "sync", bits: int = 1,
              n_classes: int = 16) -> Cell:
    arch = spec.config()
    n, e, d_feat = gnn_cell_sizes(cell)
    p_n = meshlib.n_devices(mesh)
    pspec = analytic_partition_spec(n, e, p_n)

    block = B.block_spec(pspec, d_edge_attr=arch.d_edge_attr,
                         with_weight=True, stacked_parts=p_n)
    model = arch.make(d_feat, n_classes)
    opt = optlib.adam(1e-2)
    scfg = SylvieConfig(mode=sylvie_mode, bits=bits)
    backend = dist.ShardMapBackend(mesh)

    params_shape = jax.eval_shape(model.init, KEY_SDS)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    halo = HaloState.zeros_spec(block.plan, model.comm_dims(),
                                stacked_parts=p_n)
    from ..train.compression import EFState
    ef_shape = jax.eval_shape(EFState.zeros_like, params_shape)
    state = GNNTrainState(params=_sds(params_shape), opt_state=_sds(opt_shape),
                          halo=halo, step=jax.ShapeDtypeStruct((), jnp.int32),
                          ef=_sds(ef_shape),
                          site_stats=jax.ShapeDtypeStruct(
                              (len(model.comm_dims()), 2), jnp.float32))
    x = jax.ShapeDtypeStruct((p_n, pspec.n_local, d_feat), jnp.float32)
    y = jax.ShapeDtypeStruct((p_n, pspec.n_local), jnp.int32)
    m = jax.ShapeDtypeStruct((p_n, pspec.n_local), jnp.bool_)

    ts, ta, ev = make_gnn_steps(model, scfg, opt, backend=backend)
    ts_w, ta_w, _ = dist.shard_gnn_steps(ts, ta, ev, mesh, state, block)
    fn = ta_w if sylvie_mode == "async" else ts_w
    args = (state, block, x, y, m, KEY_SDS)

    from ..core.exchange import exchange_bytes
    dims = model.comm_dims()
    # exchange_bytes totals across partitions; the cell meta reports per-device
    payload = sum(exchange_bytes(block.plan, d, bits)[0] for d in dims) // p_n
    ec = sum(exchange_bytes(block.plan, d, bits)[1] for d in dims) // p_n
    return Cell(spec.arch_id, cell.name, cell.step, fn, args, p_n,
                _gnn_model_flops(arch.name, model, n, e, d_feat, True),
                meta=dict(n_local=pspec.n_local, e_pad=pspec.e_pad,
                          h_pad=pspec.h_pad, halo_rows=pspec.halo_rows,
                          exchange_payload_bytes_per_part=payload,
                          exchange_ec_bytes_per_part=ec,
                          sylvie_mode=sylvie_mode, bits=bits))


# ---------------------------------------------------------------------------
# DLRM cells
# ---------------------------------------------------------------------------


def _dlrm_model_flops(cfg: D.DLRMConfig, cell: ShapeCell) -> float:
    b = cell.params.get("n_candidates", cell.params["batch"])
    dims = [cfg.n_dense, *cfg.bot_mlp]
    f = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    fpf = cfg.n_sparse + 1
    f += 2 * fpf * fpf * cfg.embed_dim       # dot interaction
    dims = [cfg.interaction_dim, *cfg.top_mlp]
    f += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
    per_sample = f
    mult = 3.0 if cell.step == "train" else 1.0
    return mult * per_sample * b


def _dlrm_cell(spec: ArchSpec, cell: ShapeCell, mesh, *,
               qbits: Optional[int] = None) -> Cell:
    cfg = spec.config()
    if qbits is not None:
        cfg = dataclasses.replace(cfg, quantize_collective_bits=qbits)
    p_n = meshlib.n_devices(mesh)
    axes = meshlib.flat_axes(mesh)
    rpd = D.rows_per_device(cfg, p_n)
    table = jax.ShapeDtypeStruct((rpd * p_n, cfg.embed_dim), jnp.float32)
    dense_shape = jax.eval_shape(
        lambda k: D.init_dense_params(k, cfg), KEY_SDS)
    dense = _sds(dense_shape)
    shard, rep = P(axes), P()
    tspec = {"m": shard, "v": shard, "t": rep}

    if cell.step == "train":
        b = cell.params["batch"]
        opt = optlib.adam(1e-3)
        opt_d = _sds(jax.eval_shape(opt.init, dense_shape))
        opt_t = _sds(jax.eval_shape(opt.init, table))
        state = (dense, table, opt_d, opt_t,
                 jax.ShapeDtypeStruct((), jnp.int32))
        dx = jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32)
        ids = jax.ShapeDtypeStruct((b * cfg.total_ids_per_sample,), jnp.int32)
        lb = jax.ShapeDtypeStruct((b,), jnp.float32)
        step = D.make_train_step(cfg, opt, axes)
        fn = jax.jit(compat.shard_map(
            step, mesh,
            in_specs=((rep, shard, rep, tspec, rep), shard, shard, shard, rep),
            out_specs=((rep, shard, rep, tspec, rep), rep)))
        args = (state, dx, ids, lb, KEY_SDS)
    elif cell.step == "serve":
        b = cell.params["batch"]
        dx = jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32)
        ids = jax.ShapeDtypeStruct((b * cfg.total_ids_per_sample,), jnp.int32)
        fn = jax.jit(compat.shard_map(
            D.make_serve_step(cfg, axes), mesh,
            in_specs=(rep, shard, shard, shard), out_specs=shard))
        args = (dense, table, dx, ids)
    else:  # retrieval
        ncand = cell.params["n_candidates"]
        ncand = ((ncand + p_n - 1) // p_n) * p_n
        dx = jax.ShapeDtypeStruct((1, cfg.n_dense), jnp.float32)
        ids = jax.ShapeDtypeStruct((cfg.total_ids_per_sample,), jnp.int32)
        cand = jax.ShapeDtypeStruct((ncand,), jnp.int32)
        fn = jax.jit(compat.shard_map(
            D.make_retrieval_step(cfg, axes), mesh,
            in_specs=(rep, shard, rep, rep, shard), out_specs=(rep, rep)))
        args = (dense, table, dx, ids, cand)

    return Cell(spec.arch_id, cell.name, cell.step, fn, args, p_n,
                _dlrm_model_flops(cfg, cell),
                meta=dict(table_rows=cfg.total_rows, rows_per_device=rpd,
                          params=cfg.param_count()))


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh, **kw) -> Cell:
    spec = configlib.get(arch_id)
    cell = spec.shape(shape_name)
    if spec.kind == "lm":
        return _lm_cell(spec, cell, mesh, **kw)
    if spec.kind == "gnn":
        return _gnn_cell(spec, cell, mesh, **kw)
    if spec.kind == "recsys":
        return _dlrm_cell(spec, cell, mesh, **kw)
    raise ValueError(spec.kind)


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch_id in configlib.ASSIGNED:
        for cell in configlib.get(arch_id).shapes:
            out.append((arch_id, cell.name))
    return out
