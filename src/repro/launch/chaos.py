"""Kill-and-resume chaos harness: preemption-safe training, proven end-to-end.

Three entry points (one module so the subprocess worker ships with its
orchestrator):

* ``--worker`` — internal: build a :class:`~repro.train.trainer.GNNTrainer`
  with a per-epoch checkpoint cadence and train to ``--epochs``. With
  ``--kill-at K`` the worker SIGKILLs *itself* right after training epoch K,
  **before** saving it — and first drops a fake ``.tmp_step_*`` orphan in the
  checkpoint dir, so the resume leg also proves the crash-orphan GC
  (``checkpoint.latest_step``) end-to-end. With ``--resume`` it restores the
  latest checkpoint first.
* ``--kill-resume`` — orchestrate the proof: reference run (uninterrupted),
  chaos run killed at a *seeded* epoch, resumed run to completion; then
  compare the two final checkpoints leaf-by-leaf. Under ``uniform`` policy +
  ``sync`` mode the comparison is **bit-exact** (the policy lattice admits
  no path dependence: epoch keys are ``fold_in(seed, epoch)`` and the whole
  training state rides the checkpoint); other policy/mode points report the
  max leaf deviation instead of asserting zero.
* ``--ci`` — the ``tools/ci.sh --chaos`` gate: bit-exact kill-resume on
  ``yelp_like@smoke`` + the ``chaos_smoke`` scenario matrix with the fault
  accounting invariant (``faults_injected == halos_reused + forced_syncs``)
  asserted on every cell.

SIGKILL, not SIGTERM: the point is that *no* cleanup code runs — exactly a
preemption — and the atomic checkpoint layout plus orphan GC still recover.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np

SRC = Path(__file__).resolve().parents[2]


def _build_trainer(args):
    from .. import datasets
    from ..core.sylvie import SylvieConfig
    from ..dist.runtime import Runtime
    from ..models.gnn.models import PAPER_ARCHS as ARCHS
    from ..train.trainer import GNNTrainer
    from .scenarios import parse_fault, parse_policy

    pg, _ = datasets.load_partitioned(args.dataset, args.parts,
                                      seed=args.seed)
    model = ARCHS[args.arch](pg.x.shape[-1], pg.n_classes)
    runtime = (Runtime.sharded(args.parts) if args.runtime == "sharded"
               else Runtime.simulated(args.parts))
    return GNNTrainer(model, pg, SylvieConfig(mode=args.mode),
                      policy=parse_policy(args.policy), runtime=runtime,
                      seed=args.seed, ckpt_dir=args.ckpt, ckpt_every=1,
                      keep=args.keep, fault_plan=parse_fault(args.fault))


def _worker(args) -> int:
    tr = _build_trainer(args)
    if args.resume and not tr.resume():
        print("worker: --resume but no checkpoint found", file=sys.stderr)
        return 2
    while tr.epoch < args.epochs:
        tr.train_epoch()
        if args.kill_at is not None and tr.epoch == args.kill_at:
            # simulate a crash mid-save: leave a partial tmp dir behind (the
            # orphan the resume leg must GC), then die without cleanup.
            orphan = Path(args.ckpt) / f".tmp_step_{tr.epoch:08d}"
            orphan.mkdir(parents=True, exist_ok=True)
            (orphan / "arrays.npz").write_bytes(b"partial garbage")
            sys.stderr.flush()
            os.kill(os.getpid(), signal.SIGKILL)
        tr.save()
    result = dict(epochs=tr.epoch,
                  losses=[m.loss for m in tr.history],
                  test_acc=tr.evaluate("test"),
                  faults_injected=sum(m.faults_injected for m in tr.history),
                  halos_reused=sum(m.halos_reused for m in tr.history),
                  forced_syncs=sum(m.forced_syncs for m in tr.history))
    if args.out:
        Path(args.out).write_text(json.dumps(result, indent=1))
    return 0


def _worker_cmd(args, ckpt: str, extra: list[str]) -> list[str]:
    cmd = [sys.executable, "-m", "repro.launch.chaos", "--worker",
           "--ckpt", ckpt, "--dataset", args.dataset,
           "--arch", args.arch, "--parts", str(args.parts),
           "--epochs", str(args.epochs), "--mode", args.mode,
           "--policy", args.policy, "--seed", str(args.seed),
           "--runtime", args.runtime, "--keep", str(args.keep)]
    if args.fault:
        cmd += ["--fault", args.fault]
    return cmd + extra


def _run_worker(cmd: list[str]) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}{os.pathsep}" + env.get("PYTHONPATH", "")
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def _final_arrays(ckpt_dir: str) -> dict[str, np.ndarray]:
    from ..train.checkpoint import latest_step
    step = latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    with np.load(Path(ckpt_dir) / f"step_{step:08d}" / "arrays.npz") as z:
        return {k: z[k] for k in z.files}


def kill_resume(args) -> dict:
    """Run the reference / killed / resumed legs; return the comparison."""
    root = Path(args.out_dir) if args.out_dir else \
        Path(tempfile.mkdtemp(prefix="chaos_"))
    root.mkdir(parents=True, exist_ok=True)
    ref_dir, chaos_dir = str(root / "ref"), str(root / "chaos")
    kill_at = int(np.random.default_rng(args.seed).integers(
        2, max(3, args.epochs)))

    ref = _run_worker(_worker_cmd(args, ref_dir,
                                  ["--out", str(root / "ref.json")]))
    assert ref.returncode == 0, f"reference run failed:\n{ref.stderr}"

    killed = _run_worker(_worker_cmd(args, chaos_dir,
                                     ["--kill-at", str(kill_at)]))
    assert killed.returncode == -signal.SIGKILL, \
        f"expected SIGKILL death, got rc={killed.returncode}:\n{killed.stderr}"
    orphans = list(Path(chaos_dir).glob(".tmp_step_*"))
    assert orphans, "killed worker left no .tmp_step_* orphan"

    resumed = _run_worker(_worker_cmd(
        args, chaos_dir, ["--resume", "--out", str(root / "resumed.json")]))
    assert resumed.returncode == 0, f"resumed run failed:\n{resumed.stderr}"
    assert not list(Path(chaos_dir).glob(".tmp_step_*")), \
        "resume did not GC the crash orphan"

    a, b = _final_arrays(ref_dir), _final_arrays(chaos_dir)
    assert sorted(a) == sorted(b), "final checkpoints differ in structure"
    max_dev, exact = 0.0, True
    for k in a:
        if not np.array_equal(a[k], b[k]):
            exact = False
            if np.issubdtype(a[k].dtype, np.floating):
                max_dev = max(max_dev,
                              float(np.abs(a[k].astype(np.float64)
                                           - b[k].astype(np.float64)).max()))
            else:
                max_dev = float("inf")
    result = dict(kill_at=kill_at, bit_exact=exact, max_deviation=max_dev,
                  ref=json.loads((root / "ref.json").read_text()),
                  resumed=json.loads((root / "resumed.json").read_text()))
    print(json.dumps({k: result[k] for k in
                      ("kill_at", "bit_exact", "max_deviation")}, indent=1))
    return result


def _ci(args) -> int:
    from .scenarios import run_scenario

    # 1) bit-exact kill-and-resume where the policy lattice guarantees it.
    kr = argparse.Namespace(
        dataset="yelp_like@smoke", arch="gcn", parts=4, epochs=5,
        mode="sync", policy="uniform:1", seed=0, runtime="simulated",
        fault=None, keep=3, out_dir=args.out_dir)
    result = kill_resume(kr)
    assert result["bit_exact"], \
        f"uniform/sync kill-resume not bit-exact: {result['max_deviation']}"

    # 2) the chaos scenario matrix: completes under the seeded schedule and
    #    every injected fault is accounted for.
    for rep in run_scenario("chaos_smoke"):
        assert rep["faults_injected"] == \
            rep["halos_reused"] + rep["forced_syncs"], \
            f"{rep['cell']}: accounting broken ({rep['faults_injected']} != " \
            f"{rep['halos_reused']} + {rep['forced_syncs']})"
        assert rep["faults_injected"] > 0, f"{rep['cell']}: schedule inert"
    print("chaos ci: kill-resume bit-exact + scenario accounting OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.chaos",
        description="seeded kill-and-resume harness + chaos CI gate")
    ap.add_argument("--worker", action="store_true", help="internal")
    ap.add_argument("--kill-resume", action="store_true",
                    help="run the reference/killed/resumed proof")
    ap.add_argument("--ci", action="store_true",
                    help="the tools/ci.sh --chaos gate")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--dataset", default="yelp_like@smoke")
    ap.add_argument("--arch", default="gcn")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--mode", default="sync")
    ap.add_argument("--policy", default="uniform:1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runtime", default="simulated",
                    choices=("simulated", "sharded"))
    ap.add_argument("--fault", default=None,
                    help="scenarios.parse_fault spec, e.g. drop=0.15,seed=7")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args(argv)
    if args.worker:
        assert args.ckpt, "--worker requires --ckpt"
        return _worker(args)
    if args.ci:
        return _ci(args)
    if args.kill_resume:
        kill_resume(args)
        return 0
    ap.error("pick one of --worker / --kill-resume / --ci")


if __name__ == "__main__":
    raise SystemExit(main())
