"""End-to-end launcher: train or serve any registered architecture.

GNN archs (the paper's setting) train full-graph with Sylvie quantized halo
exchange; LM archs train on the synthetic token stream or serve batched
decode; DLRM trains on the synthetic Criteo stream.

``--scenario`` switches to the matrix runner (``launch/scenarios.py``): the
named arch x dataset x policy x runtime sweep runs end-to-end and writes one
report JSON per cell under ``artifacts/scenarios/<name>/``.

Examples (CPU-sized; production meshes via launch/dryrun.py):
    python -m repro.launch.train --arch gcn --mode sync --bits 1 --epochs 50
    python -m repro.launch.train --arch gcn --graph reddit_like@small --parts 8
    python -m repro.launch.train --arch olmoe-1b-7b --reduced --steps 50
    python -m repro.launch.train --arch dlrm-mlperf --reduced --steps 100
    python -m repro.launch.train --scenario smoke
    python -m repro.launch.train --scenario paper --only amazon_like
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_policy(args):
    """CLI -> CommPolicy. ``--eps-s`` maps onto the BoundedStaleness policy
    (the old trainer kwarg survives only as a deprecation shim)."""
    from .. import policy as P

    if args.eps_s is not None and args.policy not in ("uniform",
                                                      "bounded_staleness"):
        raise SystemExit(f"--eps-s conflicts with --policy {args.policy}; "
                         "it implies bounded_staleness")
    if args.policy == "warmup":
        return P.Warmup(epochs=args.warmup_epochs, bits=args.bits)
    if args.policy == "adaqp":
        return P.AdaQPVariance(budget_bits=args.bits)
    if args.policy == "bounded_staleness" or args.eps_s is not None:
        if args.eps_s is None:
            raise SystemExit("--policy bounded_staleness needs --eps-s N "
                             "(the cache-refresh period)")
        return P.BoundedStaleness(eps_s=args.eps_s, bits=args.bits)
    return None  # Uniform from the SylvieConfig


def train_gnn(args) -> None:
    from .. import configs as configlib
    from ..core.sylvie import SylvieConfig
    from ..graph import formats, partition, synthetic
    from ..models.gnn import blocks as B
    from ..train.trainer import GNNTrainer

    from .. import datasets

    spec = configlib.get(args.arch)
    arch = spec.reduced() if args.reduced else spec.config()
    if args.graph in synthetic.GENERATORS:     # raw generator, default kwargs
        g = synthetic.by_name(args.graph, seed=args.seed)
    else:                              # named workload ("reddit_like@small");
        # a typo raises the registry's KeyError listing the known names/tiers
        g = datasets.load(args.graph, seed=args.seed)
    g, ew = formats.gcn_normalize(g)
    if arch.d_edge_attr:
        if g.pos is None:
            rng = np.random.default_rng(0)
            g.pos = rng.normal(0, 1, (g.n_nodes, 3)).astype(np.float32)
        g.edge_attr = B.geometry_edge_attr(g)
    pg = partition.partition_graph(g, args.parts, edge_weight=ew)
    model = arch.make(g.x.shape[1], g.n_classes)
    cfg = SylvieConfig(mode=args.mode, bits=args.bits,
                       schedule=args.schedule or "blocking")
    tr = GNNTrainer(model, pg, cfg, policy=build_policy(args), seed=args.seed,
                    ckpt_dir=args.ckpt_dir)
    if args.resume and tr.resume():
        print(f"resumed at epoch {tr.epoch}")
    t0 = time.time()
    for _ in range(args.epochs):
        m = tr.train_epoch()
        if tr.epoch % args.log_every == 0:
            acc = tr.evaluate("val")
            print(f"epoch {m.epoch:4d} [{m.mode}] loss {m.loss:.4f} "
                  f"val {acc:.4f} comm {m.comm_payload_mb:.2f}MB "
                  f"(+{m.comm_ec_mb:.2f}MB ec) {m.seconds*1e3:.1f}ms")
    print(f"test acc {tr.evaluate('test'):.4f}  "
          f"({args.epochs} epochs in {time.time()-t0:.1f}s)")
    if args.ckpt_dir:
        tr.save()


def train_lm(args) -> None:
    from .. import configs as configlib
    from ..data.pipeline import Prefetcher, token_stream
    from ..models.lm import model as LM
    from ..train import optimizer as optlib

    spec = configlib.get(args.arch)
    cfg = spec.reduced() if args.reduced else spec.config()
    opt = optlib.adam(args.lr)
    key = jax.random.PRNGKey(args.seed)
    params = LM.init_params(key, cfg, dtype=jnp.float32)
    state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    step_fn = jax.jit(LM.make_train_step(cfg, opt))
    stream = Prefetcher(token_stream(cfg.vocab, args.batch, args.seq,
                                     args.seed, n_batches=args.steps))
    t0 = time.time()
    for i, (tok, lab) in enumerate(stream):
        state, loss = step_fn(state, tok, lab)
        if (i + 1) % args.log_every == 0:
            print(f"step {i+1:5d} loss {float(loss):.4f} "
                  f"({(i+1)*args.batch*args.seq/(time.time()-t0):.0f} tok/s)")
    print(f"final loss {float(loss):.4f}")


def serve_lm(args) -> None:
    from .. import configs as configlib
    from ..models.lm import model as LM

    spec = configlib.get(args.arch)
    cfg = spec.reduced() if args.reduced else spec.config()
    key = jax.random.PRNGKey(args.seed)
    params = LM.init_params(key, cfg, dtype=jnp.float32)
    b, s_ctx, new = args.batch, args.seq, args.decode_tokens
    prefill = jax.jit(LM.make_prefill_step(cfg, b, s_ctx + new))
    decode = jax.jit(LM.make_decode_step(cfg))
    prompts = jax.random.randint(key, (b, s_ctx), 0, cfg.vocab)
    pad = jnp.zeros((b, new), jnp.int32)
    last, caches = prefill(params, jnp.concatenate([prompts, pad], 1)[:, :s_ctx + new][:, :s_ctx + new])
    # NB: prefill cache is sized for the full horizon; positions >= s_ctx are
    # masked by kv_len during decode.
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(new - 1):
        lg, caches = decode(params, caches, tok,
                            jnp.asarray(s_ctx + i, jnp.int32))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    print(f"decoded {b}x{new} tokens, {b*(new-1)/dt:.1f} tok/s")
    print("sample:", np.asarray(jnp.concatenate(out, 1))[0][:16])


def train_dlrm(args) -> None:
    from .. import configs as configlib
    from ..data.pipeline import Prefetcher, criteo_stream
    from ..models.recsys import dlrm as D
    from ..train import optimizer as optlib

    spec = configlib.get(args.arch)
    cfg = spec.reduced() if args.reduced else spec.config()
    opt = optlib.adam(args.lr)
    key = jax.random.PRNGKey(args.seed)
    dp = D.init_dense_params(key, cfg)
    tb = D.init_table(jax.random.fold_in(key, 1), cfg, n_dev=1)
    state = (dp, tb, opt.init(dp), opt.init(tb), jnp.zeros((), jnp.int32))
    step = jax.jit(D.make_train_step(cfg, opt, None))
    stream = Prefetcher(criteo_stream(cfg, args.batch, args.seed,
                                      n_batches=args.steps))
    for i, (dense, ids, label) in enumerate(stream):
        state, loss = step(state, dense, ids, label,
                           jax.random.fold_in(key, i))
        if (i + 1) % args.log_every == 0:
            print(f"step {i+1:5d} loss {float(loss):.4f}")
    print(f"final loss {float(loss):.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (required unless --scenario)")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-sized)")
    ap.add_argument("--serve", action="store_true",
                    help="LM: batched prefill+decode instead of training")
    # scenario-matrix runner (repro.launch.scenarios)
    ap.add_argument("--scenario", default=None,
                    help="run a named arch x dataset x policy x runtime "
                         "matrix end-to-end (smoke | policies | paper); "
                         "writes artifacts/scenarios/<name>/*.json")
    ap.add_argument("--only", default=None,
                    help="with --scenario: substring filter over cell ids")
    ap.add_argument("--scenario-dir", default=None,
                    help="with --scenario: report directory override")
    ap.add_argument("--obs", action="store_true",
                    help="with --scenario: arm span tracing per cell and "
                         "write artifacts/obs/<name>/<cell>.{trace,metrics}"
                         ".json (render: python -m repro.obs summarize)")
    ap.add_argument("--obs-dir", default=None,
                    help="with --scenario --obs: obs artifact directory "
                         "override")
    # GNN
    ap.add_argument("--graph", default="planted",
                    help="named workload ref ('reddit_like@small', see "
                         "repro.datasets.names()) or raw generator name "
                         "(planted | powerlaw | powerlaw_community | grid | "
                         "molecule)")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--mode", default="sync",
                    choices=["vanilla", "sync", "async"])
    ap.add_argument("--bits", type=int, default=1)
    ap.add_argument("--schedule", default=None,
                    choices=["blocking", "overlap"],
                    help="halo-exchange schedule: blocking, or the fenced "
                         "issue/land overlap pipeline (dist/overlap.py; "
                         "bit-exact under sync). With --scenario, overrides "
                         "the scenario's schedule for every cell")
    ap.add_argument("--policy", default="uniform",
                    choices=["uniform", "warmup", "bounded_staleness",
                             "adaqp"],
                    help="per-epoch communication schedule (repro.policy); "
                         "adaqp treats --bits as the budget")
    ap.add_argument("--warmup-epochs", type=int, default=5)
    ap.add_argument("--eps-s", type=int, default=None,
                    help="cache-refresh period (implies bounded_staleness)")
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    # LM / DLRM
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scenario:
        from .scenarios import run_scenario
        run_scenario(args.scenario, only=args.only,
                     out_dir=args.scenario_dir, schedule=args.schedule,
                     obs_trace=args.obs, obs_dir=args.obs_dir)
        return
    if args.arch is None:
        ap.error("--arch is required (or pass --scenario)")

    from .. import configs as configlib
    kind = configlib.get(args.arch).kind
    if kind == "gnn":
        train_gnn(args)
    elif kind == "lm":
        serve_lm(args) if args.serve else train_lm(args)
    else:
        train_dlrm(args)


if __name__ == "__main__":
    main()
