"""Production mesh builders.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state; the dry-run entry point
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, smoke tests see the real single CPU device.

Topology: one v5e pod = 16x16 = 256 chips -> ("data", "model") axes; the
multi-pod mesh adds a leading "pod"=2 axis (512 chips) over DCN. The GNN
runtime flattens every axis into one partition axis (paper: N GPUs = N
partitions); the LM runtime uses FSDP over ("pod","data") and TP/EP over
"model"; DLRM row-shards tables over the flattened mesh.
"""
from __future__ import annotations

from ..dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (device count forced by caller)."""
    return compat.make_mesh(shape, axes)


def flat_axes(mesh) -> tuple[str, ...]:
    from ..dist import api as dist_api
    return dist_api.flat_axes(mesh)


def n_devices(mesh) -> int:
    from ..dist import api as dist_api
    return dist_api.mesh_size(mesh)


# TPU v5e hardware constants for the roofline terms (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (we model one active link/chip)
