"""Scenario-matrix runner: declarative arch x dataset x policy x runtime
sweeps over the named-workload registry.

A :class:`Scenario` declares the axes; :func:`run_scenario` expands the cross
product, drives one :class:`~repro.train.trainer.GNNTrainer` per cell (graphs
come from :func:`repro.datasets.load_partitioned`, so repeated runs hit the
partition-plan cache), and writes one machine-readable report JSON per cell
under ``artifacts/scenarios/<scenario>/`` plus a ``summary.json`` (schema:
DESIGN.md §9). CLI::

    PYTHONPATH=src python -m repro.launch.train --scenario smoke
    PYTHONPATH=src python -m repro.launch.train --scenario paper

Policy axis entries are compact specs (``parse_policy``): ``uniform:BITS``,
``warmup:EPOCHS:BITS``, ``bounded_staleness:EPS_S:BITS``, ``adaqp:BUDGET``.
Runtime axis entries are ``simulated`` (stacked reference, any machine) or
``sharded`` (one partition per host device — set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from pathlib import Path
from typing import Optional

from .. import datasets
from .. import obs
from .. import policy as P
from ..obs import export as obs_export
from ..core.sylvie import SylvieConfig
from ..dist.runtime import Runtime
from ..faults import FaultPlan
from ..models.gnn.models import PAPER_ARCHS as ARCHS
from ..train.trainer import GNNTrainer
from .cells import _gnn_model_flops
from .mesh import ICI_BW, PEAK_FLOPS_BF16


def parse_policy(spec: str):
    """Compact policy spec -> CommPolicy. ``uniform:32``, ``warmup:5:1``,
    ``bounded_staleness:4:1``, ``adaqp:4``."""
    kind, *args = spec.split(":")
    a = [int(x) for x in args]
    if kind == "uniform":
        return P.Uniform(bits=a[0] if a else 1)
    if kind == "warmup":
        return P.Warmup(epochs=a[0] if a else 5, bits=a[1] if len(a) > 1 else 1)
    if kind == "bounded_staleness":
        return P.BoundedStaleness(eps_s=a[0] if a else None,
                                  bits=a[1] if len(a) > 1 else 1)
    if kind == "adaqp":
        return P.AdaQPVariance(budget_bits=a[0] if a else 4)
    raise KeyError(f"unknown policy spec {spec!r}; known kinds: uniform, "
                   "warmup, bounded_staleness, adaqp")


def parse_fault(spec: Optional[str]) -> Optional[FaultPlan]:
    """Compact fault spec -> :class:`~repro.faults.FaultPlan` (None -> None).

    Comma-separated ``key=value`` pairs, e.g.
    ``"drop=0.15,corrupt=0.05,seed=7,escalate=3"``. Keys: ``drop``,
    ``corrupt``, ``delay``, ``preempt`` (rates), ``delay_s`` (seconds),
    ``seed``, ``escalate`` (epochs)."""
    if spec is None or spec == "":
        return None
    keys = {"drop": ("drop_rate", float), "corrupt": ("corrupt_rate", float),
            "delay": ("delay_rate", float), "preempt": ("preempt_rate", float),
            "delay_s": ("delay_s", float), "seed": ("seed", int),
            "escalate": ("escalate_after", int)}
    kw = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        if k not in keys:
            raise KeyError(f"unknown fault key {k!r} in {spec!r}; "
                           f"known: {sorted(keys)}")
        name, cast = keys[k]
        kw[name] = cast(v)
    return FaultPlan(**kw)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One point of the matrix. ``cell_id`` names the report file."""

    arch: str
    dataset: str                        # registry ref, "name@tier"
    policy: str                         # parse_policy spec
    mode: str                           # "sync" | "async" | "vanilla"
    runtime: str                        # "simulated" | "sharded"

    @property
    def cell_id(self) -> str:
        pol = self.policy.replace(":", "-")
        return f"{self.arch}__{self.dataset}__{pol}__{self.mode}" \
               f"__{self.runtime}"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A declarative arch x dataset x policy x mode x runtime matrix."""

    name: str
    archs: tuple[str, ...]
    datasets: tuple[str, ...]
    policies: tuple[str, ...]
    modes: tuple[str, ...] = ("sync",)
    runtimes: tuple[str, ...] = ("simulated",)
    parts: int = 4
    epochs: int = 3
    seed: int = 0
    # seeded chaos schedule applied to every cell (parse_fault spec string;
    # None = fault-free). A string, not a FaultPlan, so Scenario stays a
    # flat declarative record.
    fault: Optional[str] = None
    # exchange schedule for every cell ("blocking" | "overlap"). A scalar,
    # not an axis: cell ids stay stable, and every report carries the DESIGN
    # §8/§14 exposed/overlapped comm-time split either way.
    schedule: str = "blocking"

    def cells(self) -> tuple[Cell, ...]:
        """The expanded cross product, in deterministic order."""
        return tuple(Cell(a, d, p, m, r) for a, d, p, m, r
                     in itertools.product(self.archs, self.datasets,
                                          self.policies, self.modes,
                                          self.runtimes))


SCENARIOS: dict[str, Scenario] = {
    # CI-sized: 2 archs x 2 datasets x 2 policies, 8 cells, < ~2 min on CPU.
    "smoke": Scenario(
        name="smoke",
        archs=("gcn", "graphsage"),
        datasets=("yelp_like@smoke", "products_like@smoke"),
        policies=("uniform:1", "warmup:2:1"),
        parts=4, epochs=3),
    # Policy sweep on the two benchmark reference graphs.
    "policies": Scenario(
        name="policies",
        archs=("graphsage",),
        datasets=("yelp_like@small", "products_like@small"),
        policies=("uniform:32", "uniform:4", "uniform:1", "warmup:5:1",
                  "bounded_staleness:4:1", "adaqp:4"),
        modes=("sync", "async"),
        parts=8, epochs=40),
    # The paper-shaped full matrix (hours on CPU; run cells with --only).
    "paper": Scenario(
        name="paper",
        archs=("gcn", "graphsage", "gat"),
        datasets=("reddit_like@small", "yelp_like@small",
                  "products_like@small", "amazon_like@small"),
        policies=("uniform:32", "uniform:1", "adaqp:4"),
        modes=("sync", "async"),
        parts=8, epochs=40),
    # CI chaos gate: the smoke workload under a seeded fault schedule that
    # drops/corrupts well over 10% of halo exchanges. tools/ci.sh --chaos
    # runs it (via repro.launch.chaos --ci) and asserts the fault accounting
    # on every cell report.
    "chaos_smoke": Scenario(
        name="chaos_smoke",
        archs=("gcn",),
        datasets=("yelp_like@smoke",),
        policies=("uniform:1", "bounded_staleness:4:1"),
        modes=("sync", "async"),
        parts=4, epochs=6,
        fault="drop=0.15,corrupt=0.05,seed=7"),
}


def default_out_dir() -> Path:
    """``<repo>/artifacts/scenarios`` (tracked README explains the layout)."""
    return Path(__file__).resolve().parents[3] / "artifacts" / "scenarios"


# Cell reports are versioned: v2 = v1 + {schema_version, obs, trace_path}.
# tests/test_scenarios.py pins the exact key set so keys cannot silently
# drop (or silently appear untested).
REPORT_SCHEMA_VERSION = 2

REPORT_KEYS = frozenset({
    "schema_version", "scenario", "cell", "arch", "dataset", "policy",
    "policy_spec", "mode", "runtime", "n_parts", "epochs", "seed",
    "plan_cache_hit", "final_loss", "val_acc", "test_acc",
    "comm_payload_bytes_per_epoch", "comm_ec_bytes_per_epoch",
    "wire_payload_bytes_per_epoch", "wire_ec_bytes_per_epoch",
    "modeled_tpu_comm_s", "schedule", "modeled_tpu_comm_exposed_s",
    "modeled_tpu_comm_overlapped_s", "bits_per_site", "seconds", "fault",
    "faults_injected", "halos_reused", "forced_syncs", "stall_s",
    "obs", "trace_path",
})


def run_cell(scn: Scenario, cell: Cell, *,
             cache_dir: Optional[Path] = None,
             loaded: Optional[dict] = None,
             obs_dir: Optional[Path] = None) -> dict:
    """Train one cell and return its report dict (not yet written).

    ``loaded`` memoizes partitioned graphs within one run — cells sharing a
    dataset reuse the first load instead of re-generating and re-hashing the
    graph per cell; their ``plan_cache_hit`` reports that load's disk
    outcome.

    ``obs_dir`` arms span tracing for this cell: the metrics registry is
    reset, the tracer runs for the whole train/eval, and
    ``<obs_dir>/<cell_id>.trace.json`` (Perfetto) +
    ``<cell_id>.metrics.json`` (registry snapshot + modeled-vs-measured
    join) are written; the report's ``trace_path`` points at the trace. The
    ``obs`` block (measured wall per epoch vs modeled exposed/overlapped
    comm) is present in *every* report — the obs clock works untraced too.
    """
    key = (cell.dataset, scn.parts, scn.seed)
    if loaded is None or key not in loaded:
        entry = datasets.load_partitioned(
            cell.dataset, scn.parts, seed=scn.seed, cache_dir=cache_dir)
        if loaded is not None:
            loaded[key] = entry
    else:
        entry = loaded[key]
    pg, cache_hit = entry
    model = ARCHS[cell.arch](pg.x.shape[-1], pg.n_classes)
    if cell.runtime == "sharded":
        runtime = Runtime.sharded(scn.parts)
    elif cell.runtime == "simulated":
        runtime = Runtime.simulated(scn.parts)
    else:
        raise KeyError(f"unknown runtime {cell.runtime!r}")
    policy = parse_policy(cell.policy)
    cfg = SylvieConfig(mode=cell.mode, schedule=scn.schedule)
    tr = GNNTrainer(model, pg, cfg, policy=policy, runtime=runtime,
                    seed=scn.seed, fault_plan=parse_fault(scn.fault))
    traced = obs_dir is not None
    if traced:
        obs.reset_metrics()
        obs.enable()
    try:
        t0 = obs.clock()
        tr.fit(scn.epochs)
        seconds = obs.clock() - t0
        pb, eb = tr.comm_bytes_per_epoch()
        wb, web = tr.wire_bytes_per_epoch()
        # DESIGN §8/§14 comm-time split: per-partition analytic FLOPs bound
        # each site's overlappable window; blocking exposes every comm second
        # (exposed + overlapped == modeled_tpu_comm_s in both schedules).
        n_nodes = int(pg.part_of.shape[0])
        n_edges = int(pg.edge_mask.sum())
        flops_per_part = _gnn_model_flops(cell.arch, model, n_nodes, n_edges,
                                          pg.x.shape[-1], True) / scn.parts
        exposed_s, overlapped_s = tr.modeled_comm_split(
            flops_per_part, PEAK_FLOPS_BF16, ICI_BW)
        val_acc = float(tr.evaluate("val"))
        test_acc = float(tr.evaluate("test"))
    finally:
        events = obs.drain()
        if traced:
            obs.disable()
    mm = obs_export.modeled_vs_measured(
        [m.wall_s for m in tr.history], exposed_s, overlapped_s)
    trace_path = None
    if traced:
        run_name = f"{scn.name}/{cell.cell_id}"
        trace_path = str(obs_export.write_trace(
            Path(obs_dir) / f"{cell.cell_id}.trace.json", events))
        obs_export.write_metrics(
            Path(obs_dir) / f"{cell.cell_id}.metrics.json",
            metrics=obs.snapshot(), run=run_name, merge=mm,
            trace_path=trace_path)
    return {
        "schema_version": REPORT_SCHEMA_VERSION,
        "scenario": scn.name, "cell": cell.cell_id,
        "arch": cell.arch, "dataset": cell.dataset,
        "policy": tr.policy.name, "policy_spec": cell.policy,
        "mode": cell.mode, "runtime": cell.runtime,
        "n_parts": scn.parts, "epochs": scn.epochs, "seed": scn.seed,
        "plan_cache_hit": bool(cache_hit),
        "final_loss": float(tr.history[-1].loss),
        "val_acc": val_acc,
        "test_acc": test_acc,
        # exact true-wire bytes per epoch (hardware-independent) + what the
        # plan layout actually ships, and the DESIGN §8 modeled TPU comm time.
        "comm_payload_bytes_per_epoch": float(pb),
        "comm_ec_bytes_per_epoch": float(eb),
        "wire_payload_bytes_per_epoch": float(wb),
        "wire_ec_bytes_per_epoch": float(web),
        "modeled_tpu_comm_s": float((pb + eb) / scn.parts / ICI_BW),
        "schedule": scn.schedule,
        "modeled_tpu_comm_exposed_s": float(exposed_s),
        "modeled_tpu_comm_overlapped_s": float(overlapped_s),
        "bits_per_site": [list(b) for b in tr.history[-1].bits_per_site],
        "seconds": seconds,
        # chaos accounting (zeros when scn.fault is None); the invariant
        # faults_injected == halos_reused + forced_syncs is asserted by the
        # --chaos gate (repro.launch.chaos --ci), not silently trusted here.
        "fault": scn.fault,
        "faults_injected": int(sum(m.faults_injected for m in tr.history)),
        "halos_reused": int(sum(m.halos_reused for m in tr.history)),
        "forced_syncs": int(sum(m.forced_syncs for m in tr.history)),
        "stall_s": float(sum(m.stall_s for m in tr.history)),
        # measured-vs-modeled join (always present; the per-epoch rows live
        # in the metrics artifact, the report carries the headline numbers)
        "obs": {"enabled": traced, "n_epochs": mm["n_epochs"],
                "mean_wall_s": mm["mean_wall_s"], "drift_s": mm["drift_s"]},
        "trace_path": trace_path,
    }


def resolve(scenario) -> Scenario:
    """Accept a Scenario or a name from :data:`SCENARIOS`."""
    if isinstance(scenario, Scenario):
        return scenario
    if scenario not in SCENARIOS:
        raise KeyError(f"unknown scenario {scenario!r}; "
                       f"known: {sorted(SCENARIOS)}")
    return SCENARIOS[scenario]


def run_scenario(scenario, *, out_dir: Optional[Path] = None,
                 cache_dir: Optional[Path] = None,
                 only: Optional[str] = None,
                 schedule: Optional[str] = None,
                 obs_trace: bool = False,
                 obs_dir: Optional[Path] = None) -> list[dict]:
    """Expand + run a scenario; one report JSON per cell + a summary.

    ``only`` is a substring filter over cell ids (run a slice of a big
    matrix, e.g. ``only="gat"`` or ``only="amazon_like"``). A filtered run
    rewrites only its own cell reports; ``summary.json`` is rebuilt from
    *all* cell files on disk, so running a matrix slice by slice converges
    to the full summary instead of clobbering it. ``schedule`` overrides the
    scenario's exchange schedule for every cell (the ``--schedule`` CLI).
    ``obs_trace`` (the ``--obs`` CLI) arms span tracing per cell and writes
    ``<obs_dir>/<scenario>/<cell_id>.{trace,metrics}.json`` (default
    ``artifacts/obs/``) — render with ``python -m repro.obs summarize``.
    """
    scn = resolve(scenario)
    if schedule is not None:
        scn = dataclasses.replace(scn, schedule=schedule)
    cells = [c for c in scn.cells() if only is None or only in c.cell_id]
    if not cells:
        raise ValueError(f"--only {only!r} matched no cell of {scn.name!r}")
    out = (Path(out_dir) if out_dir is not None else default_out_dir()) \
        / scn.name
    out.mkdir(parents=True, exist_ok=True)
    obs_out = None
    if obs_trace:
        obs_out = (Path(obs_dir) if obs_dir is not None
                   else obs_export.default_obs_dir()) / scn.name
    reports = []
    loaded: dict = {}
    for i, cell in enumerate(cells):
        t0 = obs.clock()
        rep = run_cell(scn, cell, cache_dir=cache_dir, loaded=loaded,
                       obs_dir=obs_out)
        (out / f"{cell.cell_id}.json").write_text(
            json.dumps(rep, indent=1, default=float))
        reports.append(rep)
        print(f"[{i+1:3d}/{len(cells)}] {cell.cell_id:60s} "
              f"test={rep['test_acc']:.3f} "
              f"comm={rep['comm_payload_bytes_per_epoch']/1e6:7.2f}MB/ep "
              f"cache={'hit' if rep['plan_cache_hit'] else 'miss'} "
              f"{obs.clock()-t0:5.1f}s")
    if only is None:
        # a full run defines the matrix: drop cell files orphaned by a
        # scenario-definition change so the summary never resurrects them
        current = {f"{c.cell_id}.json" for c in cells}
        for f in out.glob("*.json"):
            if f.name != "summary.json" and f.name not in current:
                f.unlink()
    all_cells = [json.loads(f.read_text())
                 for f in sorted(out.glob("*.json")) if f.name != "summary.json"]
    (out / "summary.json").write_text(
        json.dumps({"scenario": scn.name, "n_cells": len(all_cells),
                    "cells": all_cells}, indent=1, default=float))
    print(f"wrote {len(reports)} cell reports; summary.json covers "
          f"{len(all_cells)} cells -> {out}")
    return reports
