import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) for the production
meshes and emit the roofline artifacts under artifacts/dryrun/ (aggregated
by benchmarks/roofline.py; CPU-measurement caveat: DESIGN.md §8).

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 placeholder host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
    python -m repro.launch.dryrun --arch pna --shape ogb_products \
        --sylvie-mode async --bits 2 --tag async2   # hillclimb variants

Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json with
cost/memory analysis, the per-opcode collective table and the three roofline
terms. ``--all`` forks one subprocess per cell so a pathological compile
cannot wedge the sweep (and compiles run in parallel, capped by --jobs).
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             sylvie_mode: str = "sync", bits: int = 1, tag: str = "",
             save_hlo: bool = False, attn_remat: bool = False,
             dlrm_qbits=None) -> dict:
    from . import cells as cellslib
    from . import hlo as hlolib
    from .mesh import make_production_mesh

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    from .. import configs as configlib
    kind = configlib.get(arch).kind
    kw = {}
    if kind == "gnn":
        kw = dict(sylvie_mode=sylvie_mode, bits=bits)
    if kind == "recsys" and dlrm_qbits is not None:
        kw = dict(qbits=dlrm_qbits)
    if attn_remat:
        from ..models.lm import model as LM
        LM.set_attn_scan_remat(True)
    cell = cellslib.build_cell(arch, shape, mesh, **kw)
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    roof, coll, mem = hlolib.analyze(compiled, cell.n_devices,
                                     cell.model_flops)
    extrapolated = False

    if kind == "lm" and mesh_kind == "pod":
        # The deployable program above scans its layers, and HLO cost
        # analysis tallies a `while` body once (not x trip count). Every
        # cost component is base + count x body, so two shallow UNROLLED
        # probes (depth 1 and 2) recover the exact full-depth numbers:
        #   cost(count) = cost(d1) + (count - 1) * (cost(d2) - cost(d1)).
        # Probes run on the single-pod mesh only — the multi-pod pass is the
        # compile proof (the roofline table aggregates single-pod only).
        probes = {}
        for d in (1, 2):
            c = cellslib.build_cell(arch, shape, mesh, unroll=True, depth=d)
            cc = c.lower().compile()
            r, s, _ = hlolib.analyze(cc, c.n_devices, None)
            probes[d] = (r, s)
        count = cellslib.lm_scaled_count(configlib.get(arch).config())
        (r1, s1), (r2, s2) = probes[1], probes[2]

        def ext(a, b):
            return max(a, a + (count - 1) * (b - a))

        roof = hlolib.Roofline(
            ext(r1.flops_per_device, r2.flops_per_device),
            ext(r1.hbm_bytes_per_device, r2.hbm_bytes_per_device),
            ext(s1.wire_bytes, s2.wire_bytes),
            cell.n_devices, cell.model_flops)
        by_op = {}
        for op in set(s1.by_op) | set(s2.by_op):
            o1 = s1.by_op.get(op, dict(count=0, payload=0.0, wire=0.0))
            o2 = s2.by_op.get(op, dict(count=0, payload=0.0, wire=0.0))
            by_op[op] = dict(count=int(ext(o1["count"], o2["count"])),
                             payload=ext(o1["payload"], o2["payload"]),
                             wire=ext(o1["wire"], o2["wire"]))
        coll = hlolib.CollectiveStats(
            wire_bytes=roof.wire_bytes_per_device,
            payload_bytes=ext(s1.payload_bytes, s2.payload_bytes),
            by_op=by_op, count=sum(o["count"] for o in by_op.values()))
        extrapolated = True

    rec = dict(
        arch=arch, shape=shape, mesh=mesh_kind, step=cell.step, tag=tag,
        n_devices=cell.n_devices, cost_extrapolated=extrapolated,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        roofline=roof.as_dict(),
        collectives=dict(count=coll.count, wire_bytes=coll.wire_bytes,
                         payload_bytes=coll.payload_bytes, by_op=coll.by_op),
        memory=mem, meta=cell.meta)
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (out_dir / f"{name}.hlo.txt").write_text(compiled.as_text())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--sylvie-mode", default="sync",
                    choices=["vanilla", "sync", "async"])
    ap.add_argument("--bits", type=int, default=1)
    ap.add_argument("--attn-remat", action="store_true",
                    help="§Perf: remat the attention KV-block scan")
    ap.add_argument("--dlrm-qbits", type=int, default=None,
                    help="§Perf: Sylvie-quantized DLRM embedding exchange")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    out_dir = Path(args.out)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk, out_dir,
                           args.sylvie_mode, args.bits, args.tag,
                           args.save_hlo, args.attn_remat, args.dlrm_qbits)
            r = rec["roofline"]
            print(f"{args.arch} x {args.shape} [{mk}] step={rec['step']} "
                  f"compute={r['compute_s']:.4g}s memory={r['memory_s']:.4g}s "
                  f"collective={r['collective_s']:.4g}s "
                  f"bottleneck={r['bottleneck']} "
                  f"roofline_frac={r['roofline_fraction']}")
        return

    from . import cells as cellslib
    todo = [(a, s, mk) for (a, s) in cellslib.all_cells() for mk in meshes]
    procs: list[tuple] = []
    failed = []

    def reap(block=False):
        for i, (p, a, s, mk) in enumerate(list(procs)):
            if p.poll() is not None or block:
                out, _ = p.communicate()
                ok = p.returncode == 0
                print(("OK   " if ok else "FAIL ") + f"{a} x {s} [{mk}]",
                      flush=True)
                if not ok:
                    failed.append((a, s, mk))
                    sys.stdout.write(out.decode()[-2000:] + "\n")
                procs.remove((p, a, s, mk))

    for a, s, mk in todo:
        while len(procs) >= args.jobs:
            reap()
            time.sleep(1)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", mk, "--out", str(out_dir)]
        p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT)
        procs.append((p, a, s, mk))
    while procs:
        reap()
        time.sleep(1)
    print(f"\n{len(todo) - len(failed)}/{len(todo)} cells passed")
    if failed:
        print("failed:", failed)
        sys.exit(1)


if __name__ == "__main__":
    main()
