"""GNN serving launcher: train -> checkpoint -> quantized inference engine ->
load-tested request path, in one command.

Default flow (``python -m repro.launch.serve --graph yelp_like@small``):

1. load the named workload + cached partition plan (``repro.datasets``);
2. restore the checkpoint under ``--ckpt-dir`` — or, when none exists, train
   ``--train-epochs`` epochs with the Sylvie trainer and save one (the
   train -> save -> serve handoff the checkpoint format-version guards);
3. build an :class:`~repro.serve.engine.InferenceEngine` at ``--bits``, run
   the full cache sweep, then drive the closed-loop load generator
   (``--clients`` x ``--requests`` seeded queries of ``--batch`` node ids,
   with a k-hop delta refresh of ``--refresh-nodes`` nodes interleaved every
   ``--refresh-every`` completions);
4. print + write the serving report JSON (QPS, p50/p99 ms, exact refresh
   wire bytes, delta-vs-full byte ratio) under ``artifacts/serve/``.

``--matrix NAME`` instead runs a serving scenario matrix — bits x refresh
mode cells over one workload, one report JSON per cell plus a summary, under
``artifacts/scenarios/serve_<NAME>/`` (the serving counterpart of
``launch/scenarios.py``).

``--store`` swaps the resident table for a sharded embedding store with a
hot-node cache (``--cache-kb``); ``--replicas N`` fronts the engine with N
load-balanced server replicas; ``--open-loop`` replaces the closed loop with
fixed-QPS Poisson arrivals (``--qps``, ``--slo-ms``, ``--skew``) and can
drive a seeded mutation stream through the refresh path while serving
(``--stream-events``). See DESIGN.md §13.

Examples::

    python -m repro.launch.serve --graph yelp_like@small
    python -m repro.launch.serve --graph yelp_like@small --bits 32 --requests 500
    python -m repro.launch.serve --matrix smoke
    python -m repro.launch.serve --graph gdelt_like@smoke --store --replicas 2 \\
        --open-loop --qps 300 --slo-ms 250 --skew 1.1 --stream-events 60
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        python -m repro.launch.serve --graph yelp_like@smoke --runtime sharded
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import time
from pathlib import Path
from typing import Optional

import numpy as np


def _root() -> Path:
    return Path(__file__).resolve().parents[3]


def _load(ref: str, parts: int, seed: int):
    from .. import datasets
    from ..models.gnn.models import PAPER_ARCHS
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    return pg, PAPER_ARCHS


def _ensure_checkpoint(ckpt_dir: Path, model, pg, *, train_epochs: int,
                       train_bits: int, seed: int) -> bool:
    """Train + save a checkpoint unless one already exists. Returns True when
    training ran."""
    from ..core.sylvie import SylvieConfig
    from ..train import checkpoint as ckpt
    from ..train.trainer import GNNTrainer
    if ckpt.latest_step(ckpt_dir) is not None:
        return False
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=train_bits),
                    seed=seed, ckpt_dir=str(ckpt_dir))
    tr.fit(train_epochs)
    tr.save()
    return True


def serve_once(args) -> dict:
    """The CLI's single-cell flow; returns the serving report dict."""
    from ..dist.runtime import Runtime
    from ..serve import (EmbeddingServer, InferenceEngine, ReplicaSet,
                         ServeConfig)
    from ..serve.loadgen import closed_loop, open_loop

    pg, archs = _load(args.graph, args.parts, args.seed)
    model = archs[args.arch](pg.x.shape[-1], pg.n_classes)
    ref_safe = args.graph.replace("@", "-")
    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else \
        _root() / "artifacts" / "serve" / f"{args.arch}-{ref_safe}-ckpt"
    trained = _ensure_checkpoint(ckpt_dir, model, pg,
                                 train_epochs=args.train_epochs,
                                 train_bits=args.train_bits, seed=args.seed)
    runtime = Runtime.sharded(args.parts) if args.runtime == "sharded" \
        else Runtime.simulated(args.parts)
    cfg = ServeConfig(bits=args.bits, max_staleness=args.max_staleness)
    store = None
    if args.store:
        from ..store import ShardedEmbeddingStore
        store = ShardedEmbeddingStore(cache_bytes=args.cache_kb << 10)
    engine, meta = InferenceEngine.from_checkpoint(
        ckpt_dir, model, pg, config=cfg, runtime=runtime, seed=args.seed,
        store=store)
    sweep = engine.full_sweep()
    n_nodes = int(pg.part_of.shape[0])

    if args.replicas > 1:
        server = ReplicaSet(engine, n_replicas=args.replicas,
                            microbatch=args.microbatch,
                            max_queue=args.max_queue)
    else:
        server = EmbeddingServer(engine, microbatch=args.microbatch,
                                 max_queue=args.max_queue)
    if args.open_loop:
        feed = None
        if args.stream_events:
            from ..datasets import registry
            from ..store import MutationStream
            name, tier = registry.parse(args.graph)
            stream_kw = dict(registry.get(name).stream.get(tier, {}))
            stream = MutationStream(n_nodes, pg.x.shape[-1],
                                    seed=args.seed + 2, **stream_kw)
            feed = stream.batches(args.stream_events, args.stream_window,
                                  rows_of=engine.feature_rows)
        load = open_loop(server, n_nodes, qps=args.qps,
                         requests=args.requests, batch=args.batch,
                         seed=args.seed, skew=args.skew,
                         slo_ms=args.slo_ms, feed=feed)
    else:
        load = closed_loop(server, n_nodes, clients=args.clients,
                           batch=args.batch, requests=args.requests,
                           seed=args.seed, refresh_every=args.refresh_every,
                           refresh_nodes=args.refresh_nodes)

    # one measured delta refresh for the byte comparison; the interleaved
    # load-phase refreshes may have run the staleness clock up to the bound,
    # so reset it first or the measurement could silently be a forced full
    engine.full_sweep()
    rng = np.random.default_rng(args.seed + 1)
    ids = rng.choice(n_nodes, size=max(1, args.refresh_nodes), replace=False)
    rows = rng.normal(0, 1, (ids.size, pg.x.shape[-1])).astype(np.float32)
    delta = engine.refresh(ids, rows)

    report = {
        "graph": args.graph, "arch": args.arch, "n_parts": args.parts,
        "bits": args.bits, "runtime": args.runtime, "seed": args.seed,
        "checkpoint": dict(dir=str(ckpt_dir), trained_now=trained, **meta),
        "sweep_seconds": sweep.seconds,
        "full_sweep_wire_bytes": engine.full_sweep_wire_bytes(),
        "load": load,
        "delta_refresh": dict(kind=delta.kind, changed=delta.changed,
                              affected_rows=list(delta.affected_rows),
                              wire_bytes=delta.wire_bytes,
                              seconds=delta.seconds),
        "delta_vs_full_bytes": delta.wire_bytes
        / max(engine.full_sweep_wire_bytes(), 1),
    }
    if store is not None:
        report["store"] = store.stats().as_dict()
        report["store"]["shard_bytes"] = store.shard_bytes()
    if args.replicas > 1:
        report["replicas"] = server.per_replica()
    print(f"== serve {args.arch} on {args.graph} (P={args.parts}, "
          f"{args.bits}-bit, {args.runtime}"
          + (f", store cache {args.cache_kb} kB" if store is not None else "")
          + (f", {args.replicas} replicas" if args.replicas > 1 else "")
          + ") ==")
    print(f"checkpoint: {'trained now' if trained else 'restored'} "
          f"(epoch {meta.get('epoch', '?')}, format v"
          f"{meta.get('format_version')})")
    print(f"sweep {sweep.seconds*1e3:.1f} ms, full refresh "
          f"{report['full_sweep_wire_bytes']/1e3:.1f} kB")
    if args.open_loop:
        print(f"open loop: offered {load['qps_offered']:.0f} qps, achieved "
              f"{load['qps_achieved']:.0f} qps  p50 {load['p50_ms']:.3f} ms  "
              f"p99 {load['p99_ms']:.3f} ms  ({load['completed']} completed, "
              f"{load['lost']} lost, {load['refreshes']} refreshes)")
        if load["slo_pass"] is not None:
            print(f"SLO {load['slo_ms']:.1f} ms: "
                  f"{'PASS' if load['slo_pass'] else 'FAIL'}")
    else:
        print(f"load: {load['qps']:.0f} qps  p50 {load['p50_ms']:.3f} ms  "
              f"p99 {load['p99_ms']:.3f} ms  ({load['requests']} requests, "
              f"{load['rejected']} rejected)")
    if store is not None:
        s = report["store"]
        print(f"store: hit rate {s['hit_rate']:.3f}, miss bytes "
              f"{s['miss_bytes']/1e3:.1f} kB, cached "
              f"{s['cached_bytes']/1e3:.1f} of {s['shard_bytes']/1e3:.1f} kB")
    print(f"delta refresh ({delta.changed} nodes): "
          f"{delta.wire_bytes/1e3:.2f} kB = "
          f"{100*report['delta_vs_full_bytes']:.1f}% of a full sweep")
    return report


# ---------------------------------------------------------------------------
# serving scenario matrix (bits x refresh cells over one workload)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeMatrix:
    """A serving sweep: every ``bits`` width x refresh mode on one workload,
    all cells sharing one trained checkpoint."""

    name: str
    dataset: str
    bits: tuple[int, ...] = (32, 1)
    refreshes: tuple[str, ...] = ("full", "delta")
    parts: int = 4
    train_epochs: int = 3
    requests: int = 80
    clients: int = 4
    batch: int = 16
    refresh_nodes: int = 8
    seed: int = 0

    def cells(self):
        return tuple(itertools.product(self.bits, self.refreshes))


SERVE_MATRICES: dict[str, ServeMatrix] = {
    "smoke": ServeMatrix(name="smoke", dataset="yelp_like@smoke"),
    "small": ServeMatrix(name="small", dataset="yelp_like@small",
                         train_epochs=5, requests=200, refresh_nodes=12),
}


def run_serve_matrix(name: str, out_dir: Optional[Path] = None) -> list[dict]:
    """Run every cell of a named serving matrix; one JSON per cell plus
    ``summary.json`` under ``artifacts/scenarios/serve_<name>/``."""
    from ..dist.runtime import Runtime
    from ..serve import EmbeddingServer, InferenceEngine, ServeConfig
    from ..serve.loadgen import closed_loop

    if name not in SERVE_MATRICES:
        raise KeyError(f"unknown serve matrix {name!r}; "
                       f"known: {sorted(SERVE_MATRICES)}")
    m = SERVE_MATRICES[name]
    out = (Path(out_dir) if out_dir is not None
           else _root() / "artifacts" / "scenarios") / f"serve_{m.name}"
    out.mkdir(parents=True, exist_ok=True)
    pg, archs = _load(m.dataset, m.parts, m.seed)
    model = archs["gcn"](pg.x.shape[-1], pg.n_classes)
    ref_safe = m.dataset.replace("@", "-")
    ckpt_dir = _root() / "artifacts" / "serve" / f"gcn-{ref_safe}-ckpt"
    _ensure_checkpoint(ckpt_dir, model, pg, train_epochs=m.train_epochs,
                       train_bits=1, seed=m.seed)
    n_nodes = int(pg.part_of.shape[0])
    rng = np.random.default_rng(m.seed + 1)
    ids = rng.choice(n_nodes, size=m.refresh_nodes, replace=False)
    rows = rng.normal(0, 1, (ids.size, pg.x.shape[-1])).astype(np.float32)

    reports = []
    for bits, refresh in m.cells():
        cell_id = f"gcn__{m.dataset}__bits{bits}__{refresh}"
        engine, meta = InferenceEngine.from_checkpoint(
            ckpt_dir, model, pg, runtime=Runtime.simulated(m.parts),
            config=ServeConfig(bits=bits), seed=m.seed)
        engine.full_sweep()
        t0 = time.time()
        load = closed_loop(EmbeddingServer(engine), n_nodes,
                           clients=m.clients, batch=m.batch,
                           requests=m.requests, seed=m.seed)
        rep = engine.refresh(ids, rows, full=(refresh == "full"))
        r = {
            "matrix": f"serve_{m.name}", "cell": cell_id,
            "dataset": m.dataset, "bits": bits, "refresh": refresh,
            "n_parts": m.parts, "seed": m.seed,
            "checkpoint_step": meta.get("step"),
            "refresh_wire_bytes": rep.wire_bytes,
            "refresh_affected_rows": list(rep.affected_rows),
            "full_sweep_wire_bytes": engine.full_sweep_wire_bytes(),
            "load": load, "seconds": time.time() - t0,
        }
        (out / f"{cell_id}.json").write_text(
            json.dumps(r, indent=1, default=float))
        print(f"[serve:{m.name}] {cell_id}: {load['qps']:.0f} qps, refresh "
              f"{rep.wire_bytes/1e3:.2f} kB")
        reports.append(r)
    summary = {"matrix": f"serve_{m.name}", "dataset": m.dataset,
               "cells": [r["cell"] for r in reports],
               "qps": {r["cell"]: r["load"]["qps"] for r in reports},
               "refresh_wire_bytes": {r["cell"]: r["refresh_wire_bytes"]
                                      for r in reports}}
    (out / "summary.json").write_text(json.dumps(summary, indent=1,
                                                 default=float))
    return reports


def main() -> None:
    ap = argparse.ArgumentParser(
        description="quantized full-graph GNN serving (repro.serve)")
    ap.add_argument("--graph", default="yelp_like@small",
                    help="named-workload ref, 'name@tier' "
                         "(see repro.datasets.names())")
    ap.add_argument("--arch", default="gcn",
                    choices=["gcn", "graphsage", "gat"])
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--bits", type=int, default=1,
                    help="serving halo bit-width (32 = full precision)")
    ap.add_argument("--runtime", default="simulated",
                    choices=["simulated", "sharded"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore from here; trains + saves when empty "
                         "(default artifacts/serve/<arch>-<graph>-ckpt)")
    ap.add_argument("--train-epochs", type=int, default=5)
    ap.add_argument("--train-bits", type=int, default=1)
    ap.add_argument("--max-staleness", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="interleave a delta refresh every N completions")
    ap.add_argument("--refresh-nodes", type=int, default=8)
    ap.add_argument("--store", action="store_true",
                    help="serve through a sharded embedding store "
                         "(repro.store) instead of the resident table")
    ap.add_argument("--cache-kb", type=int, default=4096,
                    help="store hot-node cache capacity (kB)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="front the engine with N load-balanced server "
                         "replicas (ReplicaSet) when > 1")
    ap.add_argument("--open-loop", action="store_true",
                    help="sustained open-loop load (fixed-QPS Poisson "
                         "arrivals) instead of the closed loop")
    ap.add_argument("--qps", type=float, default=500.0,
                    help="open-loop offered rate (arrivals/s)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="open-loop p99 latency SLO gate (ms)")
    ap.add_argument("--skew", type=float, default=0.0,
                    help="open-loop Zipf query skew (0 = uniform)")
    ap.add_argument("--stream-events", type=int, default=0,
                    help="open-loop: drive N mutation-stream events through "
                         "server.refresh while serving (uses the workload's "
                         "stream calibration when it declares one)")
    ap.add_argument("--stream-window", type=float, default=0.25,
                    help="mutation-stream consumption window (s)")
    ap.add_argument("--matrix", default=None,
                    help="run a named serving matrix instead "
                         f"({sorted(SERVE_MATRICES)})")
    ap.add_argument("--out", default=None, help="report JSON path override")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.matrix:
        run_serve_matrix(args.matrix)
        return
    report = serve_once(args)
    ref_safe = args.graph.replace("@", "-")
    out = Path(args.out) if args.out else \
        _root() / "artifacts" / "serve" / f"{args.arch}-{ref_safe}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=1, default=float))
    print(f"report -> {out}")


if __name__ == "__main__":
    main()
