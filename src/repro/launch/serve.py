"""GNN serving launcher: train -> checkpoint -> quantized inference engine ->
load-tested request path, in one command.

Default flow (``python -m repro.launch.serve --graph yelp_like@small``):

1. load the named workload + cached partition plan (``repro.datasets``);
2. restore the checkpoint under ``--ckpt-dir`` — or, when none exists, train
   ``--train-epochs`` epochs with the Sylvie trainer and save one (the
   train -> save -> serve handoff the checkpoint format-version guards);
3. build an :class:`~repro.serve.engine.InferenceEngine` at ``--bits``, run
   the full cache sweep, then drive the closed-loop load generator
   (``--clients`` x ``--requests`` seeded queries of ``--batch`` node ids,
   with a k-hop delta refresh of ``--refresh-nodes`` nodes interleaved every
   ``--refresh-every`` completions);
4. print + write the serving report JSON (QPS, p50/p99 ms, exact refresh
   wire bytes, delta-vs-full byte ratio) under ``artifacts/serve/``.

``--matrix NAME`` instead runs a serving scenario matrix — bits x refresh
mode cells over one workload, one report JSON per cell plus a summary, under
``artifacts/scenarios/serve_<NAME>/`` (the serving counterpart of
``launch/scenarios.py``).

Examples::

    python -m repro.launch.serve --graph yelp_like@small
    python -m repro.launch.serve --graph yelp_like@small --bits 32 --requests 500
    python -m repro.launch.serve --matrix smoke
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        python -m repro.launch.serve --graph yelp_like@smoke --runtime sharded
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import time
from pathlib import Path
from typing import Optional

import numpy as np


def _root() -> Path:
    return Path(__file__).resolve().parents[3]


def _load(ref: str, parts: int, seed: int):
    from .. import datasets
    from ..models.gnn.models import PAPER_ARCHS
    pg, _ = datasets.load_partitioned(ref, parts, seed=seed)
    return pg, PAPER_ARCHS


def _ensure_checkpoint(ckpt_dir: Path, model, pg, *, train_epochs: int,
                       train_bits: int, seed: int) -> bool:
    """Train + save a checkpoint unless one already exists. Returns True when
    training ran."""
    from ..core.sylvie import SylvieConfig
    from ..train import checkpoint as ckpt
    from ..train.trainer import GNNTrainer
    if ckpt.latest_step(ckpt_dir) is not None:
        return False
    tr = GNNTrainer(model, pg, SylvieConfig(mode="sync", bits=train_bits),
                    seed=seed, ckpt_dir=str(ckpt_dir))
    tr.fit(train_epochs)
    tr.save()
    return True


def serve_once(args) -> dict:
    """The CLI's single-cell flow; returns the serving report dict."""
    from ..dist.runtime import Runtime
    from ..serve import EmbeddingServer, InferenceEngine, ServeConfig
    from ..serve.loadgen import closed_loop

    pg, archs = _load(args.graph, args.parts, args.seed)
    model = archs[args.arch](pg.x.shape[-1], pg.n_classes)
    ref_safe = args.graph.replace("@", "-")
    ckpt_dir = Path(args.ckpt_dir) if args.ckpt_dir else \
        _root() / "artifacts" / "serve" / f"{args.arch}-{ref_safe}-ckpt"
    trained = _ensure_checkpoint(ckpt_dir, model, pg,
                                 train_epochs=args.train_epochs,
                                 train_bits=args.train_bits, seed=args.seed)
    runtime = Runtime.sharded(args.parts) if args.runtime == "sharded" \
        else Runtime.simulated(args.parts)
    cfg = ServeConfig(bits=args.bits, max_staleness=args.max_staleness)
    engine, meta = InferenceEngine.from_checkpoint(
        ckpt_dir, model, pg, config=cfg, runtime=runtime, seed=args.seed)
    sweep = engine.full_sweep()
    n_nodes = int(pg.part_of.shape[0])

    server = EmbeddingServer(engine, microbatch=args.microbatch,
                             max_queue=args.max_queue)
    load = closed_loop(server, n_nodes, clients=args.clients,
                       batch=args.batch, requests=args.requests,
                       seed=args.seed, refresh_every=args.refresh_every,
                       refresh_nodes=args.refresh_nodes)

    # one measured delta refresh for the byte comparison; the interleaved
    # load-phase refreshes may have run the staleness clock up to the bound,
    # so reset it first or the measurement could silently be a forced full
    engine.full_sweep()
    rng = np.random.default_rng(args.seed + 1)
    ids = rng.choice(n_nodes, size=max(1, args.refresh_nodes), replace=False)
    rows = rng.normal(0, 1, (ids.size, pg.x.shape[-1])).astype(np.float32)
    delta = engine.refresh(ids, rows)

    report = {
        "graph": args.graph, "arch": args.arch, "n_parts": args.parts,
        "bits": args.bits, "runtime": args.runtime, "seed": args.seed,
        "checkpoint": dict(dir=str(ckpt_dir), trained_now=trained, **meta),
        "sweep_seconds": sweep.seconds,
        "full_sweep_wire_bytes": engine.full_sweep_wire_bytes(),
        "load": load,
        "delta_refresh": dict(kind=delta.kind, changed=delta.changed,
                              affected_rows=list(delta.affected_rows),
                              wire_bytes=delta.wire_bytes,
                              seconds=delta.seconds),
        "delta_vs_full_bytes": delta.wire_bytes
        / max(engine.full_sweep_wire_bytes(), 1),
    }
    print(f"== serve {args.arch} on {args.graph} (P={args.parts}, "
          f"{args.bits}-bit, {args.runtime}) ==")
    print(f"checkpoint: {'trained now' if trained else 'restored'} "
          f"(epoch {meta.get('epoch', '?')}, format v"
          f"{meta.get('format_version')})")
    print(f"sweep {sweep.seconds*1e3:.1f} ms, full refresh "
          f"{report['full_sweep_wire_bytes']/1e3:.1f} kB")
    print(f"load: {load['qps']:.0f} qps  p50 {load['p50_ms']:.3f} ms  "
          f"p99 {load['p99_ms']:.3f} ms  ({load['requests']} requests, "
          f"{load['rejected']} rejected)")
    print(f"delta refresh ({delta.changed} nodes): "
          f"{delta.wire_bytes/1e3:.2f} kB = "
          f"{100*report['delta_vs_full_bytes']:.1f}% of a full sweep")
    return report


# ---------------------------------------------------------------------------
# serving scenario matrix (bits x refresh cells over one workload)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeMatrix:
    """A serving sweep: every ``bits`` width x refresh mode on one workload,
    all cells sharing one trained checkpoint."""

    name: str
    dataset: str
    bits: tuple[int, ...] = (32, 1)
    refreshes: tuple[str, ...] = ("full", "delta")
    parts: int = 4
    train_epochs: int = 3
    requests: int = 80
    clients: int = 4
    batch: int = 16
    refresh_nodes: int = 8
    seed: int = 0

    def cells(self):
        return tuple(itertools.product(self.bits, self.refreshes))


SERVE_MATRICES: dict[str, ServeMatrix] = {
    "smoke": ServeMatrix(name="smoke", dataset="yelp_like@smoke"),
    "small": ServeMatrix(name="small", dataset="yelp_like@small",
                         train_epochs=5, requests=200, refresh_nodes=12),
}


def run_serve_matrix(name: str, out_dir: Optional[Path] = None) -> list[dict]:
    """Run every cell of a named serving matrix; one JSON per cell plus
    ``summary.json`` under ``artifacts/scenarios/serve_<name>/``."""
    from ..dist.runtime import Runtime
    from ..serve import EmbeddingServer, InferenceEngine, ServeConfig
    from ..serve.loadgen import closed_loop

    if name not in SERVE_MATRICES:
        raise KeyError(f"unknown serve matrix {name!r}; "
                       f"known: {sorted(SERVE_MATRICES)}")
    m = SERVE_MATRICES[name]
    out = (Path(out_dir) if out_dir is not None
           else _root() / "artifacts" / "scenarios") / f"serve_{m.name}"
    out.mkdir(parents=True, exist_ok=True)
    pg, archs = _load(m.dataset, m.parts, m.seed)
    model = archs["gcn"](pg.x.shape[-1], pg.n_classes)
    ref_safe = m.dataset.replace("@", "-")
    ckpt_dir = _root() / "artifacts" / "serve" / f"gcn-{ref_safe}-ckpt"
    _ensure_checkpoint(ckpt_dir, model, pg, train_epochs=m.train_epochs,
                       train_bits=1, seed=m.seed)
    n_nodes = int(pg.part_of.shape[0])
    rng = np.random.default_rng(m.seed + 1)
    ids = rng.choice(n_nodes, size=m.refresh_nodes, replace=False)
    rows = rng.normal(0, 1, (ids.size, pg.x.shape[-1])).astype(np.float32)

    reports = []
    for bits, refresh in m.cells():
        cell_id = f"gcn__{m.dataset}__bits{bits}__{refresh}"
        engine, meta = InferenceEngine.from_checkpoint(
            ckpt_dir, model, pg, runtime=Runtime.simulated(m.parts),
            config=ServeConfig(bits=bits), seed=m.seed)
        engine.full_sweep()
        t0 = time.time()
        load = closed_loop(EmbeddingServer(engine), n_nodes,
                           clients=m.clients, batch=m.batch,
                           requests=m.requests, seed=m.seed)
        rep = engine.refresh(ids, rows, full=(refresh == "full"))
        r = {
            "matrix": f"serve_{m.name}", "cell": cell_id,
            "dataset": m.dataset, "bits": bits, "refresh": refresh,
            "n_parts": m.parts, "seed": m.seed,
            "checkpoint_step": meta.get("step"),
            "refresh_wire_bytes": rep.wire_bytes,
            "refresh_affected_rows": list(rep.affected_rows),
            "full_sweep_wire_bytes": engine.full_sweep_wire_bytes(),
            "load": load, "seconds": time.time() - t0,
        }
        (out / f"{cell_id}.json").write_text(
            json.dumps(r, indent=1, default=float))
        print(f"[serve:{m.name}] {cell_id}: {load['qps']:.0f} qps, refresh "
              f"{rep.wire_bytes/1e3:.2f} kB")
        reports.append(r)
    summary = {"matrix": f"serve_{m.name}", "dataset": m.dataset,
               "cells": [r["cell"] for r in reports],
               "qps": {r["cell"]: r["load"]["qps"] for r in reports},
               "refresh_wire_bytes": {r["cell"]: r["refresh_wire_bytes"]
                                      for r in reports}}
    (out / "summary.json").write_text(json.dumps(summary, indent=1,
                                                 default=float))
    return reports


def main() -> None:
    ap = argparse.ArgumentParser(
        description="quantized full-graph GNN serving (repro.serve)")
    ap.add_argument("--graph", default="yelp_like@small",
                    help="named-workload ref, 'name@tier' "
                         "(see repro.datasets.names())")
    ap.add_argument("--arch", default="gcn",
                    choices=["gcn", "graphsage", "gat"])
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--bits", type=int, default=1,
                    help="serving halo bit-width (32 = full precision)")
    ap.add_argument("--runtime", default="simulated",
                    choices=["simulated", "sharded"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore from here; trains + saves when empty "
                         "(default artifacts/serve/<arch>-<graph>-ckpt)")
    ap.add_argument("--train-epochs", type=int, default=5)
    ap.add_argument("--train-bits", type=int, default=1)
    ap.add_argument("--max-staleness", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="interleave a delta refresh every N completions")
    ap.add_argument("--refresh-nodes", type=int, default=8)
    ap.add_argument("--matrix", default=None,
                    help="run a named serving matrix instead "
                         f"({sorted(SERVE_MATRICES)})")
    ap.add_argument("--out", default=None, help="report JSON path override")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.matrix:
        run_serve_matrix(args.matrix)
        return
    report = serve_once(args)
    ref_safe = args.graph.replace("@", "-")
    out = Path(args.out) if args.out else \
        _root() / "artifacts" / "serve" / f"{args.arch}-{ref_safe}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=1, default=float))
    print(f"report -> {out}")


if __name__ == "__main__":
    main()
