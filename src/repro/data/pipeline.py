"""Host-side data pipeline: double-buffered prefetch + synthetic streams.

The GNN runtime is full-graph (data stays resident), but the LM/DLRM
substrates and the ``minibatch_lg`` sampled-training shape consume a stream
of host batches; ``Prefetcher`` overlaps host batch construction (sampling,
numpy packing) with device compute via a background thread + bounded queue,
and ``device_put``s ahead of consumption.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Wrap a host-batch iterator; keeps ``depth`` device-put batches ready."""

    def __init__(self, it: Iterator, depth: int = 2, sharding=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._done = object()
        self._err: Optional[BaseException] = None
        self._finished = False

        def work():
            try:
                for batch in it:
                    if self._sharding is not None:
                        batch = jax.tree.map(
                            lambda a: jax.device_put(a, self._sharding), batch)
                    else:
                        batch = jax.tree.map(jax.device_put, batch)
                    self._q.put(batch)
            except BaseException as e:       # surfaced on next __next__
                self._err = e
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        if self._finished:               # don't block on the drained queue
            raise StopIteration
        item = self._q.get()
        if item is self._done:
            self._finished = True
            if self._err is not None:
                # producer died mid-stream: every batch it finished was
                # delivered above; the error surfaces exactly once here
                # (generator semantics — later next() is StopIteration).
                raise self._err
            raise StopIteration
        return item


def token_stream(vocab: int, batch: int, seq: int, seed: int = 0,
                 n_batches: Optional[int] = None):
    """Synthetic LM batches: (tokens, labels) with a learnable bigram bias
    (labels = tokens shifted), so a few hundred steps show real loss drop."""
    rng = np.random.default_rng(seed)
    i = 0
    while n_batches is None or i < n_batches:
        base = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        # inject structure: every even position repeats (predictable)
        base[:, 2::2] = base[:, 1:-1:2]
        yield base[:, :-1], base[:, 1:]
        i += 1


def criteo_stream(cfg, batch: int, seed: int = 0,
                  n_batches: Optional[int] = None):
    """Synthetic Criteo-like batches for the DLRM substrate: power-law ids,
    label correlated with a hidden linear model for convergence tests."""
    rng = np.random.default_rng(seed)
    offs = cfg.row_offsets
    w = rng.normal(0, 1, cfg.n_dense)
    i = 0
    while n_batches is None or i < n_batches:
        dense = rng.normal(0, 1, (batch, cfg.n_dense)).astype(np.float32)
        ids = []
        for f, h in enumerate(cfg.hots):
            size = int(offs[f + 1] - offs[f])
            # zipf-ish popularity
            r = rng.pareto(1.5, (batch, h)).astype(np.int64) % size
            ids.append(offs[f] + r)
        flat = np.concatenate(ids, axis=1).reshape(-1).astype(np.int32)
        label = (dense @ w + rng.normal(0, 0.5, batch) > 0).astype(np.float32)
        yield dense, flat, label
        i += 1
