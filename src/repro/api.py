"""repro.api — the one-import facade over the Sylvie reproduction.

    import repro.api as repro

    g = synthetic.planted_partition(n_nodes=2000, d_feat=64)
    runtime = repro.Runtime.simulated(4)          # or Runtime.from_mesh(mesh)
    pg = repro.partition(g, runtime=runtime)      # Graph Engine (paper step 1)
    trainer = repro.train(model, pg, mode="sync", bits=1,
                          runtime=runtime, epochs=40)
    print(trainer.evaluate("test"))

Execution mode — simulated stack vs. shard_map over a device mesh — is fixed
by the :class:`Runtime` alone; model code and training config are identical in
both. See DESIGN.md for the Runtime / HaloBackend architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .core.sylvie import SylvieConfig
from .dist import (HaloBackend, Runtime, ShardMapBackend,  # noqa: F401
                   SimulatedBackend)
from .dist.api import make_gnn_mesh  # noqa: F401
from .graph import formats
from .graph import partition as partlib
from .policy import (AdaQPVariance, BoundedStaleness, Chain,  # noqa: F401
                     CommPolicy, EpochDecision, SiteDecision, SiteStats,
                     Telemetry, Uniform, Warmup)
from .train.trainer import GNNTrainer


def partition(g: formats.Graph, n_parts: Optional[int] = None, *,
              runtime: Optional[Runtime] = None, method: str = "block",
              self_loops: bool = True, gcn_weights: bool = True,
              seed: int = 0, layout: str = "compact",
              alignment: int = 8) -> partlib.PartitionedGraph:
    """Partition a host graph + build its static halo-exchange plan.

    ``n_parts`` may be given directly or inferred from ``runtime`` (mesh size /
    simulated partition count). By default the graph is GCN-normalized:
    self-loops added and symmetric-normalized edge weights attached. A graph
    carrying ``edge_attr`` keeps it; the appended self-loop edges get
    zero-valued attribute rows (matching the zero-length geometric edge).
    ``layout`` picks the halo buffer layout ("compact" ring buckets by default;
    "dense" pairwise blocks for comparison/debugging — see graph/partition.py).
    """
    if n_parts is None and runtime is not None:
        n_parts = runtime.n_parts
    if n_parts is None:
        raise ValueError("pass n_parts or a runtime that fixes it")
    ei = g.edge_index
    ea = g.edge_attr
    if self_loops:
        n_before = ei.shape[1]
        ei = formats.add_self_loops(ei, g.n_nodes)
        if ea is not None:
            pad = np.zeros((ei.shape[1] - n_before, ea.shape[1]), ea.dtype)
            ea = np.concatenate([ea, pad], axis=0)
    ew = formats.gcn_edge_weights(ei, g.n_nodes) if gcn_weights else None
    g = dataclasses.replace(g, edge_index=ei, edge_attr=ea)
    return partlib.partition_graph(g, n_parts, method=method,
                                   edge_weight=ew, seed=seed,
                                   layout=layout, alignment=alignment)


def train(model, pg: partlib.PartitionedGraph,
          cfg: Optional[SylvieConfig] = None, *,
          policy: Optional[CommPolicy] = None,
          runtime: Optional[Runtime] = None, epochs: int = 0,
          eps_s: Optional[int] = None, opt=None, seed: int = 0,
          ckpt_dir: Optional[str] = None, **cfg_kw) -> GNNTrainer:
    """Build a :class:`GNNTrainer` (and optionally run ``epochs`` of training).

    Either pass a full :class:`SylvieConfig` as ``cfg`` or its fields as
    keywords (``mode="async"``, ``bits=1``, ...). ``policy`` is a
    :class:`~repro.policy.base.CommPolicy` deciding the per-site, per-epoch
    communication schedule (default: the ``Uniform`` degenerate case built
    from the config — bit-identical to the static ``bits=`` path).
    ``runtime`` defaults to the simulated stack at the graph's partition
    count.

    .. deprecated:: ``eps_s=k`` — pass ``policy=BoundedStaleness(k)``
       instead; the kwarg builds exactly that policy and warns.
    """
    if cfg is None:
        cfg = SylvieConfig(**cfg_kw)
    elif cfg_kw:
        raise TypeError(f"pass cfg or config keywords, not both: {cfg_kw}")
    trainer = GNNTrainer(model, pg, cfg, opt=opt, policy=policy, eps_s=eps_s,
                         runtime=runtime, seed=seed, ckpt_dir=ckpt_dir)
    if epochs:
        trainer.fit(epochs)
    return trainer
