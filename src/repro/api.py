"""repro.api — the one-import facade over the Sylvie reproduction.

    import repro.api as repro
    from repro import datasets

    g = datasets.load("yelp_like@small")          # or any formats.Graph
    runtime = repro.Runtime.simulated(4)          # or Runtime.from_mesh(mesh)
    pg = repro.partition(g, runtime=runtime)      # Graph Engine (paper step 1)
    trainer = repro.train(model, pg, mode="sync", bits=1,
                          runtime=runtime, epochs=40)
    print(trainer.evaluate("test"))

Execution mode — simulated stack vs. shard_map over a device mesh — is fixed
by the :class:`Runtime` alone; model code and training config are identical in
both. The per-epoch communication schedule is a pluggable
:class:`~repro.policy.base.CommPolicy` (``policy=repro.Uniform(bits=1)``,
``repro.BoundedStaleness(eps_s=4)``, ...). See DESIGN.md §1/§4a for the
Runtime / HaloBackend / CommPolicy architecture, §9 for named workloads
(:mod:`repro.datasets`) and the scenario runner, §13 for the serving-side
embedding store (``repro.ShardedEmbeddingStore`` + ``repro.MutationStream``,
re-exported here).
"""
from __future__ import annotations

from typing import Optional

from . import datasets  # noqa: F401
from .core.sylvie import SylvieConfig
from .dist import (HaloBackend, Runtime, ShardMapBackend,  # noqa: F401
                   SimulatedBackend)
from .dist.api import make_gnn_mesh  # noqa: F401
from .graph import formats
from .graph import partition as partlib
from .policy import (AdaQPVariance, BoundedStaleness, Chain,  # noqa: F401
                     CommPolicy, EpochDecision, SiteDecision, SiteStats,
                     Telemetry, Uniform, Warmup)
from .store import (LRUCache, Mutation, MutationStream,  # noqa: F401
                    ShardedEmbeddingStore, StoreBackend, StoreStats)
from .train.trainer import GNNTrainer


def partition(g: formats.Graph, n_parts: Optional[int] = None, *,
              runtime: Optional[Runtime] = None, method: str = "block",
              self_loops: bool = True, gcn_weights: bool = True,
              seed: int = 0, layout: str = "compact",
              alignment: int = 8) -> partlib.PartitionedGraph:
    """Partition a host graph + build its static halo-exchange plan.

    ``n_parts`` may be given directly or inferred from ``runtime`` (mesh size /
    simulated partition count). By default the graph is GCN-normalized:
    self-loops added and symmetric-normalized edge weights attached. A graph
    carrying ``edge_attr`` keeps it; the appended self-loop edges get
    zero-valued attribute rows (matching the zero-length geometric edge).
    ``layout`` picks the halo buffer layout ("compact" ring buckets by default;
    "dense" pairwise blocks for comparison/debugging — see graph/partition.py).

    Example::

        pg = repro.partition(g, n_parts=8)                 # explicit count
        pg = repro.partition(g, runtime=Runtime.simulated(4))
        pg.plan.halo_rows, pg.plan.pad_efficiency()

    For registry workloads, :func:`repro.datasets.load_partitioned` performs
    the same normalization + partition behind the on-disk plan cache.
    """
    if n_parts is None and runtime is not None:
        n_parts = runtime.n_parts
    if n_parts is None:
        raise ValueError("pass n_parts or a runtime that fixes it")
    g, ew = formats.gcn_normalize(g, self_loops=self_loops,
                                  gcn_weights=gcn_weights)
    return partlib.partition_graph(g, n_parts, method=method,
                                   edge_weight=ew, seed=seed,
                                   layout=layout, alignment=alignment)


def train(model, pg: partlib.PartitionedGraph,
          cfg: Optional[SylvieConfig] = None, *,
          policy: Optional[CommPolicy] = None,
          runtime: Optional[Runtime] = None, epochs: int = 0,
          eps_s: Optional[int] = None, opt=None, seed: int = 0,
          ckpt_dir: Optional[str] = None, **cfg_kw) -> GNNTrainer:
    """Build a :class:`GNNTrainer` (and optionally run ``epochs`` of training).

    Either pass a full :class:`SylvieConfig` as ``cfg`` or its fields as
    keywords (``mode="async"``, ``bits=1``, ...). ``policy`` is a
    :class:`~repro.policy.base.CommPolicy` deciding the per-site, per-epoch
    communication schedule (default: the ``Uniform`` degenerate case built
    from the config — bit-identical to the static ``bits=`` path).
    ``runtime`` defaults to the simulated stack at the graph's partition
    count.

    Example::

        tr = repro.train(model, pg, mode="async", bits=1, epochs=40,
                         policy=repro.BoundedStaleness(eps_s=4))
        tr.evaluate("test"), tr.comm_bytes_per_epoch()

    .. deprecated:: ``eps_s=k`` — pass ``policy=BoundedStaleness(eps_s=k)``
       instead; the kwarg builds exactly that policy (same bits/rounding as
       the config) and warns. It will be removed once callers migrate.
    """
    if cfg is None:
        cfg = SylvieConfig(**cfg_kw)
    elif cfg_kw:
        raise TypeError(f"pass cfg or config keywords, not both: {cfg_kw}")
    trainer = GNNTrainer(model, pg, cfg, opt=opt, policy=policy, eps_s=eps_s,
                         runtime=runtime, seed=seed, ckpt_dir=ckpt_dir)
    if epochs:
        trainer.fit(epochs)
    return trainer
