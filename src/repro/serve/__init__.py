"""repro.serve — quantized full-graph inference with incremental refresh.

The serving-time counterpart of the Sylvie training stack (DESIGN.md §10):

* :class:`~repro.serve.engine.InferenceEngine` — restores a trained
  checkpoint and materializes per-layer embedding caches through the same
  quantized-halo machinery training uses; node queries are O(lookup);
* :mod:`~repro.serve.delta` — incremental k-hop delta refresh planning +
  exact wire accounting, with a staleness bound forcing periodic full sweeps;
* :class:`~repro.serve.server.EmbeddingServer` — microbatched,
  admission-controlled in-process request path —
  :class:`~repro.serve.server.ReplicaSet` runs N of them over one store
  behind the same interface;
* :mod:`~repro.serve.loadgen` — seeded load generators: closed-loop
  (offered load adapts to service rate) and open-loop (fixed-QPS Poisson
  arrivals with a latency-SLO pass/fail gate);
* :class:`~repro.serve.engine.StoreReader` — query-only replica view over a
  store-backed engine (DESIGN.md §13).

::

    from repro.serve import InferenceEngine, ServeConfig, EmbeddingServer
    from repro.serve.loadgen import closed_loop

    eng, meta = InferenceEngine.from_checkpoint(ckpt_dir, model, pg,
                                                config=ServeConfig(bits=1))
    eng.full_sweep()
    report = closed_loop(EmbeddingServer(eng), n_nodes=pg.part_of.size)
"""
from __future__ import annotations

from . import delta, loadgen  # noqa: F401
from .delta import RefreshPlan, RefreshReport  # noqa: F401
from .engine import (InferenceEngine, QueryResult, ServeComm,  # noqa: F401
                     ServeConfig, StoreReader)
from .loadgen import closed_loop, open_loop  # noqa: F401
from .server import (EmbeddingServer, Rejection, ReplicaSet,  # noqa: F401
                     Request, Response)

__all__ = [
    "InferenceEngine", "ServeConfig", "ServeComm", "QueryResult",
    "StoreReader", "RefreshPlan", "RefreshReport", "EmbeddingServer",
    "ReplicaSet", "Rejection", "Request", "Response", "closed_loop",
    "open_loop", "delta", "loadgen",
]
