"""Incremental k-hop delta refresh: host-side planning + wire accounting.

The serving insight mirrors training: the layer-wise halo exchange is the
bottleneck of the partitioned forward pass, so a feature update should ship as
little boundary data as possible. When the features of a batch of nodes
change, the layer-``h`` input embeddings that can change are exactly the nodes
within ``h`` directed hops of the changed set (each GNN layer pulls one hop) —
:func:`repro.graph.partition.khop_frontier`. A delta refresh therefore:

1. computes the frontier once per refresh (host-side, from the partition
   plan's boundary structure — no device work);
2. re-ships, at each exchange site ``i``, only the boundary rows whose owner
   node lies inside ``frontier[i]`` (the :class:`RefreshPlan` send masks);
   every other halo row is consumed from the engine's per-layer cache;
3. under deterministic rounding the cached rows are bit-identical to what a
   fresh exchange would deliver (unaffected owner => unchanged embedding =>
   identical quantization), so a delta refresh equals a full sweep *exactly*
   (tested) while shipping a fraction of the bytes.

Wire accounting is exact, not estimated: per site we count the quantized
payload + error-compensation bytes of the affected *real* rows (the same
:func:`repro.core.quantization.comm_bytes` rule Table 3 uses) plus a
1-bit-per-real-row bitmap per site — the metadata a ragged delta send needs so
the receiver knows which cached rows to overwrite.

Staleness bound (the serving analogue of the Bounded Staleness Adaptor §3.3):
the engine forces a full sweep after ``max_staleness`` consecutive delta
refreshes. Under deterministic rounding deltas are exact and the bound is
belt-and-braces; under stochastic serving (or future lossy deltas) it caps how
long any cached row can drift without a ground-truth refresh.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.quantization import comm_bytes
from ..graph.partition import PartitionedGraph, global_edges, khop_frontier
from ..policy.base import EpochDecision


@dataclasses.dataclass(frozen=True)
class RefreshPlan:
    """One refresh's communication schedule: which send-buffer rows each
    exchange site must re-ship. ``send_affected[i]`` is a (P, rows) bool mask
    over the site-``i`` send buffer (always a subset of the plan's
    ``send_mask``); ``affected_rows[i]`` its true row count totaled across
    partitions; ``changed`` the seed-set size. ``full`` plans re-ship every
    real row (a full sweep is the degenerate RefreshPlan)."""

    send_affected: tuple[np.ndarray, ...]
    affected_rows: tuple[int, ...]
    changed: int
    full: bool

    def device_masks(self) -> tuple[np.ndarray, ...]:
        """float32 masks for the traced sweep (data, not trace constants — one
        executable serves every refresh)."""
        return tuple(m.astype(np.float32) for m in self.send_affected)


def _send_globals(pg: PartitionedGraph) -> np.ndarray:
    """(P, rows) global node id owning each send-buffer row (-1 padding)."""
    plan = pg.plan
    idx = plan.send_idx.reshape(plan.n_parts, -1).astype(np.int64)
    mask = plan.send_mask.reshape(plan.n_parts, -1)
    rows = np.take_along_axis(pg.global_ids, idx, axis=1)
    return np.where(mask, rows, -1)


@dataclasses.dataclass(frozen=True)
class FrontierIndex:
    """Precomputed refresh-planning state for one immutable partition.

    Building the frontier needs the global edge list and the send-row
    ownership map — both O(E)/O(rows) reconstructions from the plan that
    never change between refreshes. The engine builds one index at
    construction; each ``plan_refresh`` is then O(frontier), not O(graph)."""

    pg: PartitionedGraph
    edges: tuple[np.ndarray, np.ndarray]     # global_edges(pg)
    send_globals: np.ndarray                 # (P, rows), -1 padding
    base_mask: np.ndarray                    # (P, rows) = plan.send_mask

    @staticmethod
    def build(pg: PartitionedGraph) -> "FrontierIndex":
        return FrontierIndex(
            pg=pg, edges=global_edges(pg), send_globals=_send_globals(pg),
            base_mask=pg.plan.send_mask.reshape(pg.plan.n_parts, -1))

    def plan_refresh(self, changed_global_ids, n_sites: int) -> RefreshPlan:
        """Delta plan for a changed-feature batch: site ``i`` re-ships the
        boundary rows owned by nodes within ``i`` hops of the changed set."""
        changed = np.asarray(changed_global_ids, dtype=np.int64).reshape(-1)
        # site i consumes the i-hop frontier; the logits frontier (n_sites
        # hops) is never shipped, so k = n_sites - 1 suffices for the masks
        frontier = khop_frontier(self.pg, changed, max(n_sites - 1, 0),
                                 edges=self.edges)
        sg = np.clip(self.send_globals, 0, None)
        masks, rows = [], []
        for i in range(n_sites):
            aff = self.base_mask & frontier[min(i, frontier.shape[0] - 1)][sg]
            masks.append(aff)
            rows.append(int(aff.sum()))
        return RefreshPlan(send_affected=tuple(masks),
                           affected_rows=tuple(rows),
                           changed=int(changed.size), full=False)


def plan_full(pg: PartitionedGraph, n_sites: int) -> RefreshPlan:
    """The full-sweep plan (no index needed — every real row ships)."""
    mask = pg.plan.send_mask.reshape(pg.plan.n_parts, -1)
    rows = int(mask.sum())
    return RefreshPlan(send_affected=(mask,) * n_sites,
                       affected_rows=(rows,) * n_sites,
                       changed=0, full=True)


def plan_refresh(pg: PartitionedGraph, changed_global_ids,
                 n_sites: int) -> RefreshPlan:
    """One-shot convenience over :meth:`FrontierIndex.plan_refresh` (builds
    the O(E) index each call — hold a :class:`FrontierIndex` when planning
    repeatedly, as the engine does)."""
    return FrontierIndex.build(pg).plan_refresh(changed_global_ids, n_sites)


@dataclasses.dataclass(frozen=True)
class RefreshReport:
    """What one refresh (full sweep or delta) cost on the wire."""

    kind: str                       # "full" | "delta"
    forced: bool                    # delta request escalated by the bound
    changed: int                    # seed nodes whose features changed
    affected_rows: tuple[int, ...]  # real rows shipped per site
    payload_bytes: int
    ec_bytes: int                   # error-compensation (scale/zero)
    meta_bytes: int                 # delta bitmap (which cached rows refresh)
    seconds: float = 0.0

    @property
    def wire_bytes(self) -> int:
        return self.payload_bytes + self.ec_bytes + self.meta_bytes


def refresh_wire_bytes(plan_real_rows: int, site_dims, decision: EpochDecision,
                       refresh: RefreshPlan, scale_dtype) -> tuple[int, int, int]:
    """(payload, ec, meta) exact wire bytes of one refresh under ``decision``.

    Payload/ec follow the Table-3 rule per site (affected real rows only,
    forward direction — serving has no backward pass). Delta refreshes add one
    bitmap of ``plan_real_rows`` bits per site; full sweeps need none (the
    receiver overwrites everything)."""
    payload = ec = 0
    for i, d in enumerate(site_dims):
        pb, eb = comm_bytes(refresh.affected_rows[i], int(d),
                            decision.sites[i].fwd_bits, scale_dtype)
        payload += pb
        ec += eb
    meta = 0 if refresh.full else len(tuple(site_dims)) * \
        math.ceil(plan_real_rows / 8)
    return payload, ec, meta
