"""Seeded closed-loop load generator for the serving request path.

Closed-loop: ``clients`` logical clients each keep exactly one request in
flight — a client issues, waits for its response, then immediately issues the
next (the standard closed-system model, so offered load adapts to service
rate instead of overrunning it). Queries are batches of node ids drawn from a
seeded RNG, so two runs offer byte-identical workloads.

The report is the serving row of ``BENCH_serve.json``: completed requests,
QPS, p50/p99 latency (measured queue-to-completion through the server's
microbatcher), admission rejections, and the id-distribution parameters that
produced it. Optionally interleaves a feature-refresh every
``refresh_every`` completed requests to measure the mixed read/refresh
regime.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from .server import EmbeddingServer, Rejection

# retry/backoff shape on admission rejection: exponential with full jitter,
# seeded (the backoff draws come from the workload RNG, so runs stay
# reproducible). The base/cap are tiny because the in-process server frees
# capacity per step() call, not per network round-trip.
BACKOFF_BASE_S = 1e-4
BACKOFF_CAP_S = 0.05


def percentiles_ms(latencies_s) -> dict:
    lat = np.asarray(sorted(latencies_s), dtype=np.float64) * 1e3
    if lat.size == 0:
        return dict(p50_ms=0.0, p99_ms=0.0, mean_ms=0.0)
    return dict(p50_ms=float(np.percentile(lat, 50)),
                p99_ms=float(np.percentile(lat, 99)),
                mean_ms=float(lat.mean()))


def closed_loop(server: EmbeddingServer, n_nodes: int, *, clients: int = 8,
                batch: int = 16, requests: int = 200, seed: int = 0,
                refresh_every: Optional[int] = None,
                refresh_nodes: int = 0) -> dict:
    """Drive ``server`` with ``clients`` closed-loop clients until
    ``requests`` responses complete; return the load report dict.

    ``refresh_every``/``refresh_nodes`` interleave an engine delta refresh
    (random nodes, re-seeded feature rows) every N completions — the mixed
    serving + incremental-update regime. Refresh wire bytes are totaled in
    the report, refresh time is *included* in the wall clock (it stalls the
    request path, exactly as it would in-process)."""
    rng = np.random.default_rng(seed)
    latencies: list[float] = []
    refresh_bytes = 0
    refreshes = refresh_failures = 0
    issued = completed = 0
    outstanding = 0
    attempts = 0            # consecutive rejected submits (backoff exponent)
    backoff_s = 0.0
    reject_reasons: dict[str, int] = {}
    d_feat = server.engine.pg.x.shape[-1]
    next_refresh = refresh_every if refresh_every else None
    t0 = time.perf_counter()
    while completed < requests:
        while outstanding < clients and issued < requests:
            ids = rng.integers(0, n_nodes, size=batch)
            r = server.submit(ids)
            if isinstance(r, Rejection):
                reject_reasons[r.reason] = reject_reasons.get(r.reason, 0) + 1
                if r.reason == "draining":
                    break   # not transient — nothing a retry can fix
                # exponential backoff with full jitter, floored by the
                # server's own capacity estimate
                delay = min(BACKOFF_CAP_S, BACKOFF_BASE_S * (2 ** attempts))
                delay = max(delay * rng.random(),
                            min(r.retry_after_hint, BACKOFF_CAP_S))
                attempts += 1
                backoff_s += delay
                time.sleep(delay)
                break       # let step() drain before re-offering load
            attempts = 0
            issued += 1
            outstanding += 1
        served = server.step()
        for resp in served:
            latencies.append(resp.latency_s)
            completed += 1
            outstanding -= 1
        # deadline expiry (none by default) silently retires in-flight work;
        # the queue *is* the outstanding set in this closed loop
        outstanding = server.depth
        if not served and not server.depth and \
                (issued >= requests or server.health == "draining"):
            break           # drained, or the server stopped admitting
        if next_refresh is not None and completed >= next_refresh:
            ids = rng.choice(n_nodes, size=max(1, refresh_nodes),
                             replace=False)
            rows = rng.normal(0, 1, size=(ids.size, d_feat)).astype(np.float32)
            rep = server.refresh(ids, rows)
            if rep is None:
                refresh_failures += 1
            else:
                refresh_bytes += rep.wire_bytes
                refreshes += 1
            next_refresh += refresh_every
    seconds = time.perf_counter() - t0
    report = dict(requests=int(completed), clients=int(clients),
                  batch=int(batch), seed=int(seed), seconds=float(seconds),
                  qps=float(completed / max(seconds, 1e-9)),
                  rejected=int(server.rejected),
                  rejection_reasons=dict(reject_reasons),
                  backoff_s=float(backoff_s),
                  expired=int(server.expired),
                  refreshes=int(refreshes),
                  refresh_failures=int(refresh_failures),
                  refresh_wire_bytes=int(refresh_bytes),
                  **percentiles_ms(latencies))
    return report
