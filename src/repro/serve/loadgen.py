"""Seeded load generators for the serving request path.

Two offered-load models, both byte-identical across runs with the same seed:

**Closed-loop** (:func:`closed_loop`): ``clients`` logical clients each keep
exactly one request in flight — a client issues, waits for its response, then
immediately issues the next (the standard closed-system model, so offered
load adapts to service rate instead of overrunning it).

**Open-loop** (:func:`open_loop`): Poisson arrivals at a *fixed* QPS,
independent of completions — the SLO-measurement regime. Latency is charged
from the scheduled arrival, rejected submits are lost requests, and an
optional mutation feed exercises the refresh path concurrently.

The report is the serving row of ``BENCH_serve.json``: completed requests,
QPS, p50/p99 latency (measured queue-to-completion through the server's
microbatcher), admission rejections, and the id-distribution parameters that
produced it. Optionally interleaves a feature-refresh every
``refresh_every`` completed requests to measure the mixed read/refresh
regime.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from .. import obs
from .server import EmbeddingServer, Rejection

# retry/backoff shape on admission rejection: exponential with full jitter,
# seeded (the backoff draws come from the workload RNG, so runs stay
# reproducible). The base/cap are tiny because the in-process server frees
# capacity per step() call, not per network round-trip.
BACKOFF_BASE_S = 1e-4
BACKOFF_CAP_S = 0.05


def _clock_and_sleep(server, clock):
    """Resolve the loop's time source: an explicit ``clock``, else the
    server's (both default to ``repro.obs.clock``). A clock that knows how to
    sleep (``FakeClock.sleep`` advances fake time) also replaces the real
    ``time.sleep`` — so SLO loops under a fake clock idle without wall waits."""
    if clock is None:
        clock = getattr(server, "clock", None) or obs.clock
    return clock, getattr(clock, "sleep", time.sleep)


def percentiles_ms(latencies_s) -> dict:
    lat = np.asarray(sorted(latencies_s), dtype=np.float64) * 1e3
    if lat.size == 0:
        return dict(p50_ms=0.0, p99_ms=0.0, mean_ms=0.0)
    return dict(p50_ms=float(np.percentile(lat, 50)),
                p99_ms=float(np.percentile(lat, 99)),
                mean_ms=float(lat.mean()))


def closed_loop(server: EmbeddingServer, n_nodes: int, *, clients: int = 8,
                batch: int = 16, requests: int = 200, seed: int = 0,
                refresh_every: Optional[int] = None, refresh_nodes: int = 0,
                clock: Optional[Callable[[], float]] = None) -> dict:
    """Drive ``server`` with ``clients`` closed-loop clients until
    ``requests`` responses complete; return the load report dict.

    ``refresh_every``/``refresh_nodes`` interleave an engine delta refresh
    (random nodes, re-seeded feature rows) every N completions — the mixed
    serving + incremental-update regime. Refresh wire bytes are totaled in
    the report, refresh time is *included* in the wall clock (it stalls the
    request path, exactly as it would in-process)."""
    rng = np.random.default_rng(seed)
    latencies: list[float] = []
    refresh_bytes = 0
    refreshes = refresh_failures = 0
    issued = completed = 0
    outstanding = 0
    attempts = 0            # consecutive rejected submits (backoff exponent)
    backoff_s = 0.0
    reject_reasons: dict[str, int] = {}
    d_feat = server.engine.pg.x.shape[-1]
    next_refresh = refresh_every if refresh_every else None
    clock, sleep = _clock_and_sleep(server, clock)
    t0 = clock()
    while completed < requests:
        while outstanding < clients and issued < requests:
            ids = rng.integers(0, n_nodes, size=batch)
            r = server.submit(ids)
            if isinstance(r, Rejection):
                reject_reasons[r.reason] = reject_reasons.get(r.reason, 0) + 1
                if r.reason == "draining":
                    break   # not transient — nothing a retry can fix
                # exponential backoff with full jitter, floored by the
                # server's own capacity estimate
                delay = min(BACKOFF_CAP_S, BACKOFF_BASE_S * (2 ** attempts))
                delay = max(delay * rng.random(),
                            min(r.retry_after_hint, BACKOFF_CAP_S))
                attempts += 1
                backoff_s += delay
                sleep(delay)
                break       # let step() drain before re-offering load
            attempts = 0
            issued += 1
            outstanding += 1
        served = server.step()
        for resp in served:
            latencies.append(resp.latency_s)
            completed += 1
            outstanding -= 1
        # deadline expiry (none by default) silently retires in-flight work;
        # the queue *is* the outstanding set in this closed loop
        outstanding = server.depth
        if not served and not server.depth and \
                (issued >= requests or server.health == "draining"):
            break           # drained, or the server stopped admitting
        if next_refresh is not None and completed >= next_refresh:
            ids = rng.choice(n_nodes, size=max(1, refresh_nodes),
                             replace=False)
            rows = rng.normal(0, 1, size=(ids.size, d_feat)).astype(np.float32)
            rep = server.refresh(ids, rows)
            if rep is None:
                refresh_failures += 1
            else:
                refresh_bytes += rep.wire_bytes
                refreshes += 1
            # advance past *completed*, not one notch: a microbatch can
            # retire many requests at once, and one fixed step would leave
            # next_refresh behind `completed` forever after — every loop
            # iteration would refresh, drowning the configured cadence
            while next_refresh <= completed:
                next_refresh += refresh_every
    seconds = clock() - t0
    report = dict(requests=int(completed), clients=int(clients),
                  batch=int(batch), seed=int(seed), seconds=float(seconds),
                  qps=float(completed / max(seconds, 1e-9)),
                  rejected=int(server.rejected),
                  rejection_reasons=dict(reject_reasons),
                  backoff_s=float(backoff_s),
                  expired=int(server.expired),
                  refreshes=int(refreshes),
                  refresh_failures=int(refresh_failures),
                  refresh_wire_bytes=int(refresh_bytes),
                  **percentiles_ms(latencies))
    return report


def open_loop(server: EmbeddingServer, n_nodes: int, *, qps: float,
              requests: int = 500, batch: int = 16, seed: int = 0,
              skew: float = 0.0, slo_ms: Optional[float] = None,
              deadline_s: Optional[float] = None,
              feed: Optional[list] = None,
              clock: Optional[Callable[[], float]] = None) -> dict:
    """Sustained open-loop load: seeded Poisson arrivals at a *fixed* offered
    rate, independent of service completions — the SLO-measurement regime
    (a closed loop can never overrun the server, an open loop can and should).

    Arrival times are drawn up front (``Exponential(1/qps)`` inter-arrivals,
    cumsum'd), so the offered schedule is byte-identical across runs with the
    same seed. Latency is measured **from the scheduled arrival**, not from
    the (possibly late) submit — generator lag counts against the server,
    exactly as queueing delay does in an open system. A rejected submit is a
    *lost* request (open-loop clients don't retry); losses fail the SLO
    accounting by never completing.

    ``skew > 0`` draws node ids from a :func:`repro.store.stream.zipf_popularity`
    distribution instead of uniformly — the hot-node workload the store's
    cache tier is gated on.

    ``feed`` is an optional list of ``(t_due, ids, rows)`` mutation batches
    (see :meth:`repro.store.stream.MutationStream.batches`, timestamps
    relative to the run start): each batch is applied through
    ``server.refresh`` as soon as the wall clock passes ``t_due``, and the
    report tracks refresh lag (apply time minus due time) plus how many
    deltas the staleness bound escalated to full sweeps.

    ``slo_ms`` arms the pass/fail gate: ``slo_pass`` is True iff p99 latency
    is within the SLO *and* nothing was lost to rejection or deadline expiry.
    """
    if qps <= 0:
        raise ValueError("qps must be > 0")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=requests))
    if skew > 0.0:
        from ..store.stream import zipf_popularity
        popularity = zipf_popularity(n_nodes, skew, seed)
        all_ids = rng.choice(n_nodes, size=(requests, batch), p=popularity)
    else:
        all_ids = rng.integers(0, n_nodes, size=(requests, batch))
    feed = sorted(feed, key=lambda b: b[0]) if feed else []
    latencies: list[float] = []
    arrival_of: dict[int, float] = {}
    lost = completed = 0
    reject_reasons: dict[str, int] = {}
    refreshes = refresh_failures = escalations = 0
    refresh_bytes = 0
    refresh_lags: list[float] = []
    i = j = 0               # next arrival / next feed batch
    clock, sleep = _clock_and_sleep(server, clock)
    t0 = clock()
    while True:
        now = clock() - t0
        # mutation feed: apply at most ONE due batch per iteration — a
        # refresh stalls the request path, so consecutive due batches are
        # interleaved with serving steps instead of stacking into one long
        # pause (the lag accounting below records how far behind we run)
        if j < len(feed) and feed[j][0] <= now:
            t_due, ids, rows = feed[j]
            j += 1
            rep = server.refresh(ids, rows)
            if rep is None:
                refresh_failures += 1
                continue
            refreshes += 1
            refresh_bytes += rep.wire_bytes
            refresh_lags.append((clock() - t0) - t_due)
            if rep.kind == "full" and rep.forced:
                escalations += 1
        # offered load: submit every arrival the clock has passed
        while i < requests and arrivals[i] <= now:
            r = server.submit(all_ids[i], deadline_s=deadline_s)
            if isinstance(r, Rejection):
                reject_reasons[r.reason] = reject_reasons.get(r.reason, 0) + 1
                lost += 1
            else:
                arrival_of[r] = float(arrivals[i])
            i += 1
        served = server.step()
        t_done = clock() - t0
        for resp in served:
            latencies.append(t_done - arrival_of.pop(resp.req_id))
            completed += 1
        if i >= requests and j >= len(feed) and server.depth == 0:
            break
        if not served and server.depth == 0:
            # idle: sleep to the next scheduled event instead of spinning
            upcoming = [arrivals[i]] if i < requests else []
            if j < len(feed):
                upcoming.append(feed[j][0])
            if upcoming:
                wait = min(upcoming) - (clock() - t0)
                if wait > 0:
                    sleep(wait)
    seconds = clock() - t0
    expired = len(arrival_of)       # submitted but never answered (deadline)
    stats = percentiles_ms(latencies)
    slo_pass = None
    if slo_ms is not None:
        slo_pass = bool(stats["p99_ms"] <= slo_ms and lost == 0
                        and expired == 0)
    return dict(mode="open", offered=int(requests),
                completed=int(completed), lost=int(lost),
                expired=int(expired), batch=int(batch), seed=int(seed),
                skew=float(skew), qps_offered=float(qps),
                qps_achieved=float(completed / max(seconds, 1e-9)),
                seconds=float(seconds),
                rejection_reasons=dict(reject_reasons),
                refreshes=int(refreshes),
                refresh_failures=int(refresh_failures),
                refresh_escalations=int(escalations),
                refresh_wire_bytes=int(refresh_bytes),
                refresh_lag_max_s=float(max(refresh_lags, default=0.0)),
                refresh_lag_mean_s=float(np.mean(refresh_lags))
                if refresh_lags else 0.0,
                slo_ms=None if slo_ms is None else float(slo_ms),
                slo_pass=slo_pass, **stats)
