"""In-process request path: admission queue + microbatched cache lookups.

``EmbeddingServer`` fronts an :class:`~repro.serve.engine.InferenceEngine`
with the mechanisms a real serving tier needs even when the per-query work
is a cache lookup:

* **admission queue** — ``submit`` enqueues a request or *rejects* it with a
  typed :class:`Rejection` (reason, queue depth, retry hint) when
  ``max_queue`` requests are already waiting or the server is draining;
  back-pressure instead of unbounded latency;
* **microbatching** — ``step`` drains whole requests until the next one would
  overflow ``microbatch`` node ids, answers them with a single engine lookup,
  and stamps each response with its queue-to-completion latency;
* **deadlines** — a request submitted with ``deadline_s`` is *expired* (never
  served) once the clock passes it; late answers are worthless answers;
* **health state machine** — ``healthy → degraded → draining``. Degraded
  (a failed delta refresh, or a partition marked down) keeps answering every
  in-deadline request from the stale embedding cache, with per-node staleness
  stamps on the responses; draining stops admitting but serves out the queue.

The server is deliberately synchronous and single-threaded: the load
generator (``loadgen.py``) drives ``submit``/``step`` as a closed loop, and
determinism (seeded ids, no thread scheduling, injectable ``clock``) keeps
the latency distribution reproducible enough to regression-track in
``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional, Union

import numpy as np

from .. import obs

# health states
HEALTHY = "healthy"
DEGRADED = "degraded"
DRAINING = "draining"


@dataclasses.dataclass(frozen=True)
class Rejection:
    """A typed admission rejection (the back-off contract).

    ``reason`` is ``"queue_full"`` or ``"draining"``; ``depth`` the queue
    occupancy at rejection; ``retry_after_hint`` a server-side estimate (s)
    of when capacity frees up (an EMA of recent ``step`` times — 0.0 before
    any batch has been served). Deliberately *no* ``__bool__``: request id 0
    is falsy too, so clients must discriminate with ``isinstance``."""

    reason: str
    depth: int
    retry_after_hint: float


@dataclasses.dataclass
class Request:
    req_id: int
    node_ids: np.ndarray
    t_submit: float
    # absolute clock time after which the answer is worthless (None = never)
    deadline: Optional[float] = None


@dataclasses.dataclass
class Response:
    req_id: int
    node_ids: np.ndarray
    logits: np.ndarray
    latency_s: float
    # per-node staleness stamps (sweeps since the node's partition was last
    # recomputed; see engine.QueryResult.staleness) — None from engines that
    # predate the stamp.
    staleness: Optional[np.ndarray] = None

    @property
    def predictions(self) -> np.ndarray:
        return np.argmax(self.logits, axis=-1)


class EmbeddingServer:
    """Microbatched, admission-controlled front end over an engine.

    Example::

        srv = EmbeddingServer(engine, microbatch=128, max_queue=256)
        rid = srv.submit([1, 2, 3])
        [resp] = srv.step()
        assert resp.req_id == rid and resp.logits.shape == (3, n_classes)
    """

    # EMA factor for the per-step service-time estimate behind
    # Rejection.retry_after_hint.
    STEP_EMA = 0.7

    def __init__(self, engine, microbatch: int = 128, max_queue: int = 1024,
                 clock: Optional[Callable[[], float]] = None,
                 id_start: int = 0, id_stride: int = 1):
        if microbatch < 1 or max_queue < 1:
            raise ValueError("microbatch and max_queue must be >= 1")
        if id_stride < 1:
            raise ValueError("id_stride must be >= 1")
        self.engine = engine
        self.microbatch = microbatch
        self.max_queue = max_queue
        # default to the obs clock: perf_counter normally, the injected
        # deterministic clock when a FakeClock-armed tracer is active
        self.clock = clock if clock is not None else obs.clock
        self._queue: deque[Request] = deque()
        # replicas in a ReplicaSet interleave id spaces (start=i, stride=N)
        # so request ids stay globally unique across the set
        self._next_id = id_start
        self._id_stride = id_stride
        self.accepted = 0
        self.rejected = 0
        self.served = 0
        self.expired = 0
        self.refresh_failures = 0
        self.health = HEALTHY
        self._ema_step_s = 0.0

    @property
    def depth(self) -> int:
        """Requests currently waiting."""
        return len(self._queue)

    def _reject(self, reason: str) -> Rejection:
        self.rejected += 1
        obs.count(f"serve.rejected.{reason}")
        return Rejection(reason=reason, depth=len(self._queue),
                         retry_after_hint=self._ema_step_s)

    def submit(self, node_ids,
               deadline_s: Optional[float] = None) -> Union[int, Rejection]:
        """Enqueue a query batch. Returns the request id, or a typed
        :class:`Rejection` when the admission queue is full or the server is
        draining (the caller should back off and retry — discriminate with
        ``isinstance(r, Rejection)``, request id 0 is falsy too). A single
        request larger than the microbatch can never be scheduled and is a
        caller error. ``deadline_s`` is a *relative* latency budget: the
        request expires (is never served) once the clock passes
        ``now + deadline_s``."""
        ids = np.asarray(node_ids, dtype=np.int64).reshape(-1)
        if ids.size == 0 or ids.size > self.microbatch:
            raise ValueError(
                f"request size must be in [1, microbatch={self.microbatch}], "
                f"got {ids.size}")
        with obs.span("admit", {"n": int(ids.size)}):
            if self.health == DRAINING:
                return self._reject("draining")
            if len(self._queue) >= self.max_queue:
                return self._reject("queue_full")
            rid = self._next_id
            self._next_id += self._id_stride
            now = self.clock()
            deadline = None if deadline_s is None else now + float(deadline_s)
            self._queue.append(Request(rid, ids, now, deadline))
            self.accepted += 1
            return rid

    def _expire(self, now: float) -> None:
        """Drop every queued request whose deadline has already passed —
        serving it would spend a microbatch slot on a worthless answer."""
        if not any(r.deadline is not None for r in self._queue):
            return
        live = deque(r for r in self._queue
                     if r.deadline is None or r.deadline >= now)
        self.expired += len(self._queue) - len(live)
        self._queue = live

    def step(self) -> list[Response]:
        """Serve one microbatch: expire past-deadline requests, drain whole
        requests up to ``microbatch`` ids, answer them with a single cache
        lookup, return the responses (possibly empty when the queue is)."""
        t_start = self.clock()
        self._expire(t_start)
        batch: list[Request] = []
        total = 0
        while self._queue and total + self._queue[0].node_ids.size \
                <= self.microbatch:
            req = self._queue.popleft()
            batch.append(req)
            total += req.node_ids.size
        if not batch:
            return []
        flat = np.concatenate([r.node_ids for r in batch])
        with obs.span("request", {"requests": len(batch),
                                  "nodes": int(total)}):
            with obs.span("lookup"):
                res = self.engine.query(flat)
        logits = res.logits
        stamps = getattr(res, "staleness", None)
        now = self.clock()
        self._ema_step_s = (now - t_start if self._ema_step_s == 0.0 else
                            self.STEP_EMA * self._ema_step_s
                            + (1.0 - self.STEP_EMA) * (now - t_start))
        out, start = [], 0
        for r in batch:
            stop = start + r.node_ids.size
            out.append(Response(
                r.req_id, r.node_ids, logits[start:stop], now - r.t_submit,
                staleness=None if stamps is None else stamps[start:stop]))
            start = stop
        self.served += len(out)
        return out

    def drain(self) -> list[Response]:
        """Serve until the queue is empty."""
        out = []
        while self._queue:
            got = self.step()
            if not got and self._queue:
                break       # everything left just expired
            out.extend(got)
        return out

    # ------------------------------------------------------------------
    # health state machine: healthy -> degraded -> draining
    # ------------------------------------------------------------------
    def _recompute_health(self) -> None:
        if self.health == DRAINING:
            return          # draining is terminal until start_draining ends
        down = getattr(self.engine, "down_partitions", lambda: ())()
        self.health = DEGRADED if len(down) else HEALTHY

    def refresh(self, changed_ids, rows, **kw):
        """Delta-refresh through the health machine: forwards to
        ``engine.refresh``; on failure counts it, degrades (stale caches keep
        serving, stamped), and returns ``None`` instead of raising — the
        request path must survive a bad update."""
        try:
            rep = self.engine.refresh(changed_ids, rows, **kw)
        except Exception:
            self.refresh_failures += 1
            if self.health != DRAINING:
                self.health = DEGRADED
            return None
        self._recompute_health()
        return rep

    def mark_partition_down(self, part: int) -> None:
        """A partition stopped answering: its cached rows keep serving with
        staleness stamps; the server is degraded until it returns."""
        self.engine.set_down([part])
        self._recompute_health()

    def mark_partition_up(self, part: int) -> None:
        self.engine.set_up([part])
        self._recompute_health()

    def start_draining(self) -> None:
        """Stop admitting (submit returns Rejection("draining", ...)); the
        queue still serves out via ``step``/``drain``."""
        self.health = DRAINING


class ReplicaSet:
    """N admission-queued server replicas over one engine/store, behind the
    single-server interface (``submit``/``step``/``drain``/``refresh``) so
    the load generators drive either transparently.

    Each replica is an :class:`EmbeddingServer` over ``engine.reader()`` — a
    query-only :class:`~repro.serve.engine.StoreReader` when the engine has a
    store attached (N replicas, one store), the engine itself otherwise.
    Admission is **load-balanced**: a submit goes to the least-loaded replica
    whose health admits it (draining replicas are skipped — the per-replica
    health state machine is the single-server one), so one slow or draining
    replica sheds load to its peers instead of rejecting it. Request ids are
    globally unique across the set (interleaved id spaces). Refreshes go to
    the one writer — the engine — through the same degrade-on-failure wrapper
    a single server uses, then every replica recomputes its health.

    Example::

        rs = ReplicaSet(engine, n_replicas=3, microbatch=64)
        rid = rs.submit([1, 2, 3])
        rs.replicas[1].start_draining()       # peers absorb its load
        responses = rs.drain()
    """

    def __init__(self, engine, n_replicas: int = 2, *, microbatch: int = 128,
                 max_queue: int = 1024,
                 clock: Optional[Callable[[], float]] = None):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.engine = engine
        # the set's clock is the replicas' clock (loadgen reads server.clock)
        self.clock = clock if clock is not None else obs.clock
        reader = getattr(engine, "reader", None)
        self.replicas = [
            EmbeddingServer(reader() if reader is not None else engine,
                            microbatch=microbatch, max_queue=max_queue,
                            clock=clock, id_start=i, id_stride=n_replicas)
            for i in range(n_replicas)]
        self.refresh_failures = 0
        self._rr = 0            # step() rotation so no replica starves

    # -- aggregate state ----------------------------------------------------
    @property
    def depth(self) -> int:
        return sum(s.depth for s in self.replicas)

    @property
    def health(self) -> str:
        """Worst-of: draining only when *every* replica drains (the set still
        admits while any replica does); degraded when any replica is."""
        states = [s.health for s in self.replicas]
        if all(h == DRAINING for h in states):
            return DRAINING
        if any(h == DEGRADED for h in states):
            return DEGRADED
        return HEALTHY

    @property
    def accepted(self) -> int:
        return sum(s.accepted for s in self.replicas)

    @property
    def rejected(self) -> int:
        return sum(s.rejected for s in self.replicas)

    @property
    def served(self) -> int:
        return sum(s.served for s in self.replicas)

    @property
    def expired(self) -> int:
        return sum(s.expired for s in self.replicas)

    # -- request path -------------------------------------------------------
    def submit(self, node_ids,
               deadline_s: Optional[float] = None) -> Union[int, Rejection]:
        """Route to the admitting replica with the shallowest queue (ties to
        the lowest index — deterministic). Rejected only when every replica
        is draining or the chosen queue is full."""
        live = [s for s in self.replicas if s.health != DRAINING]
        if not live:
            # count the turn-away on the first replica so aggregate stats
            # still see it
            return self.replicas[0]._reject("draining")
        target = min(live, key=lambda s: s.depth)
        return target.submit(node_ids, deadline_s=deadline_s)

    def step(self) -> list[Response]:
        """One microbatch from each replica, starting after the last replica
        served first (rotating order keeps service fair under load)."""
        out: list[Response] = []
        n = len(self.replicas)
        for k in range(n):
            out.extend(self.replicas[(self._rr + k) % n].step())
        self._rr = (self._rr + 1) % n
        return out

    def drain(self) -> list[Response]:
        out: list[Response] = []
        while self.depth:
            got = self.step()
            if not got and self.depth:
                break           # everything left just expired
            out.extend(got)
        return out

    # -- the one writer -----------------------------------------------------
    def refresh(self, changed_ids, rows, **kw):
        """Refresh through the engine (the single writer); on failure count
        it and degrade every replica — stale rows keep serving, stamped."""
        try:
            rep = self.engine.refresh(changed_ids, rows, **kw)
        except Exception:
            self.refresh_failures += 1
            for s in self.replicas:
                s.refresh_failures += 1
                if s.health != DRAINING:
                    s.health = DEGRADED
            return None
        for s in self.replicas:
            s._recompute_health()
        return rep

    def mark_partition_down(self, part: int) -> None:
        self.engine.set_down([part])
        for s in self.replicas:
            s._recompute_health()

    def mark_partition_up(self, part: int) -> None:
        self.engine.set_up([part])
        for s in self.replicas:
            s._recompute_health()

    def per_replica(self) -> list[dict]:
        """Per-replica accounting for reports (the load-balance evidence)."""
        return [dict(replica=i, health=s.health, accepted=s.accepted,
                     served=s.served, rejected=s.rejected, expired=s.expired,
                     depth=s.depth)
                for i, s in enumerate(self.replicas)]
