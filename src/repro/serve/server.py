"""In-process request path: admission queue + microbatched cache lookups.

``EmbeddingServer`` fronts an :class:`~repro.serve.engine.InferenceEngine`
with the two mechanisms a real serving tier needs even when the per-query work
is a cache lookup:

* **admission queue** — ``submit`` enqueues a request or *rejects* it
  (returns ``None``) when ``max_queue`` requests are already waiting;
  back-pressure instead of unbounded latency;
* **microbatching** — ``step`` drains whole requests until the next one would
  overflow ``microbatch`` node ids, answers them with a single engine lookup,
  and stamps each response with its queue-to-completion latency.

The server is deliberately synchronous and single-threaded: the load
generator (``loadgen.py``) drives ``submit``/``step`` as a closed loop, and
determinism (seeded ids, no thread scheduling) keeps the latency distribution
reproducible enough to regression-track in ``BENCH_serve.json``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    req_id: int
    node_ids: np.ndarray
    t_submit: float


@dataclasses.dataclass
class Response:
    req_id: int
    node_ids: np.ndarray
    logits: np.ndarray
    latency_s: float

    @property
    def predictions(self) -> np.ndarray:
        return np.argmax(self.logits, axis=-1)


class EmbeddingServer:
    """Microbatched, admission-controlled front end over an engine.

    Example::

        srv = EmbeddingServer(engine, microbatch=128, max_queue=256)
        rid = srv.submit([1, 2, 3])
        [resp] = srv.step()
        assert resp.req_id == rid and resp.logits.shape == (3, n_classes)
    """

    def __init__(self, engine, microbatch: int = 128, max_queue: int = 1024,
                 clock: Optional[Callable[[], float]] = None):
        if microbatch < 1 or max_queue < 1:
            raise ValueError("microbatch and max_queue must be >= 1")
        self.engine = engine
        self.microbatch = microbatch
        self.max_queue = max_queue
        self.clock = clock if clock is not None else time.perf_counter
        self._queue: deque[Request] = deque()
        self._next_id = 0
        self.accepted = 0
        self.rejected = 0
        self.served = 0

    @property
    def depth(self) -> int:
        """Requests currently waiting."""
        return len(self._queue)

    def submit(self, node_ids) -> Optional[int]:
        """Enqueue a query batch. Returns the request id, or ``None`` when
        the admission queue is full (the caller should back off and retry).
        A single request larger than the microbatch can never be scheduled
        and is a caller error."""
        ids = np.asarray(node_ids, dtype=np.int64).reshape(-1)
        if ids.size == 0 or ids.size > self.microbatch:
            raise ValueError(
                f"request size must be in [1, microbatch={self.microbatch}], "
                f"got {ids.size}")
        if len(self._queue) >= self.max_queue:
            self.rejected += 1
            return None
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, ids, self.clock()))
        self.accepted += 1
        return rid

    def step(self) -> list[Response]:
        """Serve one microbatch: drain whole requests up to ``microbatch``
        ids, answer them with a single cache lookup, return the responses
        (possibly empty when the queue is)."""
        batch: list[Request] = []
        total = 0
        while self._queue and total + self._queue[0].node_ids.size \
                <= self.microbatch:
            req = self._queue.popleft()
            batch.append(req)
            total += req.node_ids.size
        if not batch:
            return []
        flat = np.concatenate([r.node_ids for r in batch])
        logits = self.engine.query(flat).logits
        now = self.clock()
        out, start = [], 0
        for r in batch:
            stop = start + r.node_ids.size
            out.append(Response(r.req_id, r.node_ids, logits[start:stop],
                                now - r.t_submit))
            start = stop
        self.served += len(out)
        return out

    def drain(self) -> list[Response]:
        """Serve until the queue is empty."""
        out = []
        while self._queue:
            out.extend(self.step())
        return out
