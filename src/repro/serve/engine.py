"""Partitioned full-graph inference engine with per-layer embedding caches.

``InferenceEngine`` restores a trained checkpoint (or takes params directly)
and serves node queries off materialized caches:

* **one sweep executable** runs the model forward through the existing
  ``HaloBackend``/``SylvieComm`` quantized-halo machinery (simulated stack or
  shard_map — fixed by a :class:`~repro.dist.runtime.Runtime`, exactly like
  training). Per-site bit-widths come from an
  :class:`~repro.policy.base.EpochDecision` on the same lattice the training
  policies use;
* a **full sweep** and an incremental **delta refresh** are the *same traced
  function*: the sweep takes per-site "affected" send masks as data and blends
  freshly exchanged halo rows with the cached ones
  (``where(affected, fresh, cached)``). A full sweep is the all-rows mask; a
  delta refresh ships only the k-hop frontier of the changed nodes
  (``repro.serve.delta``). One executable means delta == full is a structural
  guarantee, not a numerical accident;
* after a sweep the engine holds, per exchange site, the embedding entering
  that site (``(P, n_local, d_i)``) and its dequantized halo buffer, plus the
  final logits — **node queries are O(lookup)**: global id -> (partition,
  slot) -> cached row, no graph compute on the request path.

Staleness bound: ``ServeConfig.max_staleness`` caps consecutive delta
refreshes; the next ``refresh()`` past the bound escalates to a forced full
sweep (the serving analogue of the Bounded Staleness Adaptor — see
``delta.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import quantization as qlib
from ..core.exchange import exchange_halo, exchange_quantized_halo, \
    gather_boundary
from ..core.staleness import HaloState
from ..core.sylvie import SylvieComm, SylvieConfig
from ..dist.runtime import Runtime
from ..graph.partition import PartitionedGraph, global_to_slot, khop_frontier
from ..models.gnn import blocks as B
from ..policy.base import EpochDecision, validate_decision
from ..train import checkpoint as ckpt
from . import delta as deltalib

# Trace instrumentation, mirroring train.gnn_step.TRACE_LOG: the sweep body
# appends once per jit trace. repro.analysis (RC204/RC207) counts entries to
# verify the single-sweep-executable guarantee instead of trusting it; the
# TraceLog shim additionally counts ``retrace.serve`` in the metrics registry.
TRACE_LOG = obs.TraceLog("serve")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-time communication + refresh policy.

    ``bits`` quantizes every halo exchange of the serving forward pass
    (32 = full precision; per-site widths via an explicit ``decision``).
    ``stochastic=False`` (the default) uses deterministic round-to-nearest —
    required for the delta-refresh exactness guarantee; stochastic rounding is
    allowed but makes deltas unbiased rather than exact. ``max_staleness`` is
    the number of consecutive delta refreshes served before the next refresh
    is forced to a full sweep. ``schedule`` picks the exchange schedule of the
    sweep executable (``"overlap"`` = fenced issue/land, ``dist/overlap.py``;
    bit-exact to blocking — the serving sweep is always synchronous/fresh)."""

    bits: int = 1
    stochastic: bool = False
    max_staleness: int = 8
    scale_dtype: jnp.dtype = jnp.bfloat16
    quant_impl: str = "auto"
    schedule: str = "blocking"


class ServeComm(SylvieComm):
    """Forward-only quantized halo with delta blending.

    At site ``i``: quantize the (full) send buffer, exchange, dequantize, then
    keep only the rows the refresh plan marked affected — every other row
    comes from ``cached_halos[i]``. The affected mask travels through the same
    exchange so each partition learns which *received* rows are fresh. Records
    the site-input embedding (the per-layer cache) and the blended halo (the
    next refresh's cache) as it goes. No custom_vjp: serving never
    differentiates."""

    def __init__(self, cfg, plan, key, backend, decision, cached_halos,
                 send_affected):
        super().__init__(cfg, plan, key, backend=backend, decision=decision)
        self.cached_halos = cached_halos
        self.send_affected = send_affected
        self.layer_inputs: list = []

    def halo(self, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        i = self._site
        self._site += 1
        sd = self._site_decision(i)
        kf = jax.random.fold_in(self._part_key(), 2 * i)
        self.layer_inputs.append(h)
        buf = gather_boundary(h, self.plan)
        qt = qlib.quantize(buf, sd.fwd_bits, kf, sd.stochastic,
                           cfg.scale_dtype, impl=cfg.quant_impl)
        inflight = exchange_quantized_halo(qt, self.plan, self.backend)
        # which received rows are fresh = the senders' affected masks, moved
        # through the same exchange as a uint8 bitmap (never fp32 on the
        # wire: the analysis wire-dtype audit, RC202, holds this path to the
        # same low-bit contract as the payload)
        aff = exchange_halo(
            self.send_affected[i][..., None].astype(jnp.uint8),
            self.plan, self.backend)
        if self.schedule == "overlap":
            # land both issued exchanges through one fence: the collectives
            # stay standalone ops the scheduler can overlap with the layer's
            # local aggregation; identity on data (bit-exact to blocking).
            inflight, aff = self.backend.fence((inflight, aff))
        fresh = qlib.dequantize(inflight, impl=cfg.quant_impl)
        fresh = jnp.where(self.plan.recv_mask[..., None], fresh, 0)
        halo = jnp.where(aff > 0, fresh, self.cached_halos[i])
        self.new_feat_caches.append(halo)
        return halo


@dataclasses.dataclass
class QueryResult:
    """One answered query batch.

    ``staleness[j]`` counts the sweeps served from cache for node ``j``'s
    partition since its rows were last recomputed — 0 everywhere while the
    engine is healthy, >0 for nodes on a partition marked down (degraded
    mode: answers come from the stale embedding cache, stamped, never
    refused)."""

    node_ids: np.ndarray
    logits: np.ndarray
    staleness: Optional[np.ndarray] = None

    @property
    def predictions(self) -> np.ndarray:
        return np.argmax(self.logits, axis=-1)


class InferenceEngine:
    """Quantized full-graph inference over a partitioned graph.

    Example::

        pg, _ = datasets.load_partitioned("yelp_like@small", n_parts=4)
        params, meta = checkpoint.restore_for_inference(ckpt_dir,
                                                        model.init(key))
        eng = InferenceEngine(model, pg, params,
                              config=ServeConfig(bits=1))
        eng.full_sweep()                        # materialize all caches
        out = eng.query([3, 17, 4242])          # O(lookup)
        rep = eng.refresh(changed_ids, new_rows)   # k-hop delta refresh
        print(rep.kind, rep.wire_bytes)
    """

    # store table names: cached logits + the deepest cached embedding layer
    # (what ``embeddings(site=-1)`` serves).
    STORE_TABLES = ("logits", "emb")

    def __init__(self, model, pg: PartitionedGraph, params,
                 config: Optional[ServeConfig] = None,
                 decision: Optional[EpochDecision] = None,
                 runtime: Optional[Runtime] = None, seed: int = 0,
                 store=None):
        self.model = model
        self.pg = pg
        self.config = cfg = config if config is not None else ServeConfig()
        p = pg.plan.n_parts
        if runtime is None:
            runtime = Runtime.simulated(p)
        if runtime.n_parts not in (None, p):
            raise ValueError(
                f"runtime is committed to {runtime.n_parts} partitions but "
                f"the graph was partitioned into {p}")
        self.runtime = runtime
        self.site_dims = tuple(int(d) for d in model.comm_dims())
        self.n_sites = len(self.site_dims)
        if decision is None:
            # the config owns the schedule for the default decision; an
            # explicit decision keeps its own (mirrors trainer semantics).
            decision = EpochDecision.uniform(self.n_sites, bits=cfg.bits,
                                             stochastic=cfg.stochastic,
                                             schedule=cfg.schedule)
        self.decision = validate_decision(decision.snapped(), self.n_sites)
        self._scfg = SylvieConfig(mode="sync", bits=cfg.bits,
                                  stochastic=cfg.stochastic,
                                  scale_dtype=cfg.scale_dtype,
                                  quant_impl=cfg.quant_impl,
                                  schedule=self.decision.schedule)
        self.block = B.build_block(pg)
        self.key = jax.random.PRNGKey(seed)

        # global id -> (partition, local slot): the O(lookup) request path
        self._part_of, self._slot_of = global_to_slot(pg)

        self._sweep = self._build_sweep()
        # refresh planning amortizes the O(E) edge/ownership reconstruction
        self._frontier = deltalib.FrontierIndex.build(pg)
        self.params = runtime.device_put_replicated(params)
        self.block = runtime.device_put_stacked(self.block)
        self._x_host = np.asarray(pg.x, dtype=np.float32).copy()
        self.x = runtime.device_put_stacked(jnp.asarray(self._x_host))
        self._halos = runtime.device_put_stacked(
            HaloState.zeros(self.block.plan, self.site_dims,
                            stacked_parts=p).feats)
        self._layers: Optional[tuple] = None
        self._logits_host: Optional[np.ndarray] = None
        self._since_full = 0
        self._refresh_count = 0
        # degraded mode: partitions marked down contribute no fresh halo
        # rows (their send-affected masks are zeroed — data, same sweep
        # executable) and their cached logits are frozen; per-partition
        # staleness counts sweeps served from the frozen cache.
        self._down = np.zeros(p, dtype=bool)
        self._part_staleness = np.zeros(p, dtype=np.int64)
        # optional sharded embedding store (repro.store): node lookups read
        # through it, sweeps publish into it (see attach_store)
        self.store = None
        if store is not None:
            self.attach_store(store)

    # ------------------------------------------------------------------
    # the sweep executable (shared by full sweeps and delta refreshes)
    # ------------------------------------------------------------------
    def _build_sweep(self):
        model, scfg, decision = self.model, self._scfg, self.decision
        backend = self.runtime.backend

        def sweep_fn(params, block, x, halos, masks, key):
            TRACE_LOG.append("sweep")
            comm = ServeComm(scfg, block.plan, key, backend, decision,
                             cached_halos=halos, send_affected=masks)
            logits = model.apply(params, block, x, comm)
            return logits, tuple(comm.layer_inputs), \
                tuple(comm.new_feat_caches)

        return self.runtime.shard_serve_fn(sweep_fn)

    def _run(self, refresh: deltalib.RefreshPlan, *, kind: str, forced: bool,
             changed_ids: Optional[np.ndarray] = None
             ) -> deltalib.RefreshReport:
        t0 = obs.clock()
        key = jax.random.fold_in(self.key, self._refresh_count)
        self._refresh_count += 1
        masks = refresh.device_masks()
        if self._down.any():
            # down partitions publish nothing fresh: zero their send-affected
            # rows so every receiver keeps its cached rows from them. Masks
            # are data — the sweep executable is unchanged.
            up = (~self._down)[:, None].astype(np.float32)
            masks = tuple(m * up for m in masks)
        with obs.span("sweep", {"kind": kind}):
            logits, layers, halos = self._sweep(self.params, self.block,
                                                self.x, self._halos, masks,
                                                key)
        self._layers = layers
        self._halos = halos
        fresh_logits = np.asarray(jax.device_get(logits))
        if self._logits_host is not None and self._down.any():
            # a down partition computes nothing: its served rows stay frozen
            # at the last sweep before it went down. (device_get may hand
            # back a read-only view — copy before patching.)
            fresh_logits = fresh_logits.copy()
            fresh_logits[self._down] = self._logits_host[self._down]
        self._logits_host = fresh_logits
        self._part_staleness = np.where(self._down,
                                        self._part_staleness + 1, 0)
        if self.store is not None:
            # full sweeps republish every row; deltas only the rows the
            # sweep could have changed (the logits-depth frontier)
            self._publish(None if kind == "full" else changed_ids)
        pb, eb, mb = deltalib.refresh_wire_bytes(
            self.block.plan.real_rows, self.site_dims, self.decision, refresh,
            self.config.scale_dtype)
        return deltalib.RefreshReport(
            kind=kind, forced=forced, changed=refresh.changed,
            affected_rows=refresh.affected_rows, payload_bytes=pb,
            ec_bytes=eb, meta_bytes=mb, seconds=obs.clock() - t0)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    @staticmethod
    def from_checkpoint(ckpt_dir, model, pg: PartitionedGraph,
                        config: Optional[ServeConfig] = None,
                        decision: Optional[EpochDecision] = None,
                        runtime: Optional[Runtime] = None,
                        step: Optional[int] = None, seed: int = 0,
                        store=None) -> tuple["InferenceEngine", dict]:
        """Train -> save -> serve handoff: restore only the model parameters
        (``checkpoint.restore_for_inference``) and build an engine. Returns
        ``(engine, checkpoint_meta)``."""
        example = model.init(jax.random.PRNGKey(0))
        params, meta = ckpt.restore_for_inference(ckpt_dir, example, step=step)
        return InferenceEngine(model, pg, params, config=config,
                               decision=decision, runtime=runtime,
                               seed=seed, store=store), meta

    def full_sweep(self) -> deltalib.RefreshReport:
        """Recompute every cache from the current features (all boundary rows
        ship). Resets the staleness clock."""
        rep = self._run(deltalib.plan_full(self.pg, self.n_sites),
                        kind="full", forced=False)
        self._since_full = 0
        return rep

    def refresh(self, changed_global_ids, new_rows, *,
                full: bool = False) -> deltalib.RefreshReport:
        """Apply a feature update and refresh the caches incrementally.

        ``new_rows`` are the replacement feature rows for
        ``changed_global_ids`` (same order). Ships only the k-hop-affected
        boundary rows per layer; escalates to a full sweep when ``full=True``
        is requested, the staleness bound is reached, or no sweep has run yet
        (a delta against the zero-initialized caches would serve garbage)."""
        ids = self._check_ids(changed_global_ids)
        rows = np.asarray(new_rows, dtype=np.float32)
        if rows.shape != (ids.size, self._x_host.shape[-1]):
            raise ValueError(
                f"new_rows must be ({ids.size}, {self._x_host.shape[-1]}), "
                f"got {rows.shape}")
        # scatter the changed rows on device — O(changed), never a full
        # O(N*d) re-upload — and mirror them into the host copy
        self._x_host[self._part_of[ids], self._slot_of[ids]] = rows
        self.x = self.runtime.device_put_stacked(
            self.x.at[self._part_of[ids], self._slot_of[ids]].set(
                jnp.asarray(rows)))
        never_swept = self._logits_host is None
        with obs.span("refresh", {"changed": int(ids.size)}):
            if full or never_swept or \
                    self._since_full >= self.config.max_staleness:
                rep = self._run(deltalib.plan_full(self.pg, self.n_sites),
                                kind="full", forced=not full)
                rep = dataclasses.replace(rep, changed=int(ids.size))
                self._since_full = 0
                return rep
            with obs.span("plan"):
                plan = self._frontier.plan_refresh(ids, self.n_sites)
            rep = self._run(plan, kind="delta", forced=False, changed_ids=ids)
            self._since_full += 1
            return rep

    # ------------------------------------------------------------------
    # degraded mode (partition down/up)
    # ------------------------------------------------------------------
    def set_down(self, parts) -> None:
        """Mark partitions down. Their cached rows keep serving (stamped with
        growing staleness); sweeps stop consuming their halo contributions."""
        self._down[np.asarray(parts, dtype=np.int64).reshape(-1)] = True

    def set_up(self, parts) -> None:
        """Bring partitions back. Staleness resets on their next sweep (the
        caller should run ``full_sweep``/``refresh`` to recompute their rows)."""
        self._down[np.asarray(parts, dtype=np.int64).reshape(-1)] = False

    def down_partitions(self) -> np.ndarray:
        return np.nonzero(self._down)[0]

    @property
    def part_staleness(self) -> np.ndarray:
        """(P,) sweeps served from frozen cache per partition (0 = fresh)."""
        return self._part_staleness.copy()

    # ------------------------------------------------------------------
    # sharded embedding store (repro.store)
    # ------------------------------------------------------------------
    def attach_store(self, store) -> None:
        """Serve node lookups through a :class:`repro.store.StoreBackend`.

        The engine stays the single *writer*: every sweep publishes the rows
        it could have changed into the store's per-partition shards (tables
        ``"logits"`` and ``"emb"``); ``query``/``embeddings(site=-1)`` then
        *read* through the store's hot-node cache instead of the
        materialized tables — bit-exact by construction (``verify_store``
        asserts it, ``BENCH_store.json`` gates it). Attach before the first
        sweep, or re-publish with ``full_sweep()``."""
        self.store = store
        if self._logits_host is not None:
            self._publish(None)

    def _publish(self, changed_ids: Optional[np.ndarray]) -> None:
        """Write the rows the last sweep could have changed into the store.

        ``changed_ids=None`` republishes every real row (full sweep). For a
        delta, the superset of rows whose cached values may differ is the
        ``n_sites``-hop frontier of the changed set — one hop per layer plus
        the logits readout (unaffected rows are bit-stable under
        deterministic rounding, the delta==full guarantee)."""
        st = self.store
        p_count = self.pg.plan.n_parts
        tables = {"logits": self._logits_host,
                  "emb": np.asarray(jax.device_get(self._layers[-1]))}
        for name, arr in tables.items():
            if not st.has_table(name):
                st.create_table(name, part_rows=(arr.shape[1],) * p_count,
                                d=arr.shape[2], dtype=arr.dtype)
        if changed_ids is None:
            for p in range(p_count):
                slots = np.nonzero(self.pg.node_mask[p])[0]
                for name, arr in tables.items():
                    st.put_rows(name, p, slots, arr[p, slots])
            return
        fr = khop_frontier(self.pg, changed_ids, self.n_sites,
                           edges=self._frontier.edges)[-1]
        ids = np.nonzero(fr)[0]
        parts, slots = self._part_of[ids], self._slot_of[ids]
        for p in np.unique(parts):
            sl = slots[parts == p]
            for name, arr in tables.items():
                st.put_rows(name, int(p), sl, arr[int(p), sl])

    def _store_lookup(self, table: str, ids: np.ndarray) -> np.ndarray:
        """Batched store read in request order (one ``get_rows`` per
        partition the batch touches)."""
        parts, slots = self._part_of[ids], self._slot_of[ids]
        out: Optional[np.ndarray] = None
        for p in np.unique(parts):
            sel = parts == p
            rows = self.store.get_rows(table, int(p), slots[sel])
            if out is None:
                out = np.empty((ids.size,) + rows.shape[1:], rows.dtype)
            out[sel] = rows
        return out

    def pin_hot(self, node_ids, tables: Optional[tuple] = None) -> None:
        """Pin the hot nodes' rows into the store's pinned tier (they stay
        materialized and are write-through refreshed by every publish)."""
        if self.store is None:
            raise RuntimeError("no store attached")
        self._require_swept()
        ids = self._check_ids(node_ids)
        parts, slots = self._part_of[ids], self._slot_of[ids]
        for p in np.unique(parts):
            for table in tables or self.STORE_TABLES:
                self.store.pin(table, int(p), slots[parts == p])

    def verify_store(self) -> int:
        """Assert the store-backed read path is bit-exact vs the materialized
        tables: every shard row equals the engine's row, and every cached row
        equals its shard row. Returns the number of rows verified."""
        if self.store is None:
            raise RuntimeError("no store attached")
        self._require_swept()
        st = self.store
        peek = getattr(st, "peek_rows", st.get_rows)
        tables = {"logits": self._logits_host,
                  "emb": np.asarray(jax.device_get(self._layers[-1]))}
        checked = 0
        for p in range(self.pg.plan.n_parts):
            slots = np.nonzero(self.pg.node_mask[p])[0]
            for name, arr in tables.items():
                if not np.array_equal(peek(name, p, slots), arr[p, slots]):
                    raise AssertionError(
                        f"store table {name!r} shard {p} diverged from the "
                        f"materialized path")
                checked += slots.size
        coherent = getattr(st, "check_coherence", None)
        if coherent is not None:
            checked += coherent()
        return checked

    def reader(self) -> "InferenceEngine | StoreReader":
        """A query-only replica view: a :class:`StoreReader` over the
        attached store, or the engine itself when none is attached (the
        materialized tables are then the only copy)."""
        return StoreReader(self) if self.store is not None else self

    def feature_rows(self, node_ids) -> np.ndarray:
        """Current feature rows for a batch of global node ids (what a
        mutation-stream edge *touch* re-submits — see repro.store.stream)."""
        ids = self._check_ids(node_ids)
        return self._x_host[self._part_of[ids], self._slot_of[ids]].copy()

    def _require_swept(self):
        if self._logits_host is None:
            raise RuntimeError("no caches yet — call full_sweep() first")

    def _check_ids(self, node_ids) -> np.ndarray:
        """Normalize + bounds-check global node ids *before* any state is
        touched (numpy's negative indexing would otherwise silently address
        the wrong node)."""
        ids = np.asarray(node_ids, dtype=np.int64).reshape(-1)
        n = self._slot_of.shape[0]
        if ids.size and (ids.min() < 0 or ids.max() >= n):
            raise ValueError(f"node ids must be in [0, {n})")
        return ids

    def query(self, node_ids) -> QueryResult:
        """Logits for a batch of global node ids — a cache lookup, no graph
        compute. With a store attached the rows come through its hot-node
        cache (miss -> shard fetch); otherwise from the materialized table.
        Both paths are bit-identical (``verify_store``)."""
        self._require_swept()
        ids = self._check_ids(node_ids)
        if self.store is not None and ids.size:
            out = self._store_lookup("logits", ids)
        else:
            out = self._logits_host[self._part_of[ids], self._slot_of[ids]]
        return QueryResult(node_ids=ids, logits=out,
                           staleness=self._part_staleness[
                               self._part_of[ids]].copy())

    def embeddings(self, node_ids, site: int = -1) -> np.ndarray:
        """Cached embeddings entering exchange site ``site`` for a batch of
        global node ids (``-1`` = last site, the deepest cached layer).
        The deepest layer is store-served when a store is attached (the
        ``"emb"`` table); other sites gather the requested rows on device —
        only O(batch * d) crosses to the host, never the full layer table."""
        self._require_swept()
        ids = self._check_ids(node_ids)
        if self.store is not None and ids.size and \
                site in (-1, self.n_sites - 1):
            return self._store_lookup("emb", ids)
        rows = self._layers[site][self._part_of[ids], self._slot_of[ids]]
        return np.asarray(jax.device_get(rows))

    @property
    def logits(self) -> np.ndarray:
        """The full cached logits table, reassembled into global node order."""
        self._require_swept()
        return self.pg.unpartition(self._logits_host)

    def full_sweep_wire_bytes(self) -> int:
        """What one full sweep ships (payload + ec), for comparison against a
        delta's :attr:`RefreshReport.wire_bytes`."""
        pb, eb, mb = deltalib.refresh_wire_bytes(
            self.block.plan.real_rows, self.site_dims, self.decision,
            deltalib.plan_full(self.pg, self.n_sites),
            self.config.scale_dtype)
        return pb + eb + mb


class StoreReader:
    """Query-only replica view over an engine's published store tables.

    A serving replica needs exactly three things: the ``(part, slot)`` index,
    the store's read path, and the writer's health/staleness stamps. A
    ``StoreReader`` carries nothing else — it cannot sweep, refresh, or mark
    partitions down, so any number of them can front one store while the
    engine remains the single writer (``ReplicaSet`` in ``server.py`` builds
    one per replica via ``engine.reader()``)."""

    def __init__(self, engine: InferenceEngine):
        if engine.store is None:
            raise ValueError("engine has no store attached")
        self._engine = engine
        self.store = engine.store
        self.pg = engine.pg

    def query(self, node_ids) -> QueryResult:
        """Store-backed logits lookup — same contract as ``engine.query``."""
        eng = self._engine
        eng._require_swept()
        ids = eng._check_ids(node_ids)
        out = eng._store_lookup("logits", ids) if ids.size else \
            np.empty((0, eng._logits_host.shape[-1]), np.float32)
        return QueryResult(node_ids=ids, logits=out,
                           staleness=eng._part_staleness[
                               eng._part_of[ids]].copy())

    def embeddings(self, node_ids, site: int = -1) -> np.ndarray:
        return self._engine.embeddings(node_ids, site=site)

    def down_partitions(self) -> np.ndarray:
        """Health rides the writer's state machine (servers fronting a
        reader recompute DEGRADED/HEALTHY from the same source)."""
        return self._engine.down_partitions()

    @property
    def part_staleness(self) -> np.ndarray:
        return self._engine.part_staleness
