"""Sylvie: one-bit quantized halo communication, synchronous and asynchronous.

Three communication modes (paper §3):

* ``vanilla``  — full-precision synchronous exchange (the DGL baseline). Same code
  path as Sylvie-S with ``bits=32`` (quantize is then the identity).
* ``sync``     — **Sylvie-S**: quantize -> all-to-all -> dequantize each layer, both
  passes. The backward pass communicates *quantized feature gradients*
  (Alg. 2 lines 10-12) via the custom_vjp below.
* ``async``    — **Sylvie-A**: layer compute consumes the *previous step's* halo
  features (``feat_cache``); the fresh quantized exchange is emitted as a
  new cache for the next step, so XLA can overlap it with compute.
  Backward mirrors it: the cotangent on the stale halo is exchanged and
  surfaces as the gradient of a zero-valued ``gslot`` input, becoming the
  next step's ``grad_in`` (one-step-stale boundary gradients).

What each exchange site does in a given epoch — forward/backward bit-widths,
stochastic vs deterministic rounding, BNS boundary sampling — is a
:class:`repro.policy.base.SiteDecision`: ``SylvieComm`` consumes
``decision.sites[i]`` at the i-th ``halo`` call, so a
:class:`~repro.policy.base.CommPolicy` can vary precision per site and per
epoch without touching this module. Every decision field is static (it rides
the ``custom_vjp`` nondiff argnums), so jit compiles one executable per
distinct decision. Constructing ``SylvieComm`` without a decision falls back
to the one global ``SylvieConfig`` choice (the Uniform degenerate case).

Buffer layout and quantizer implementation are both plan/config decisions made
here once for every site:

* the exchange direction matters for compact (ring-bucket) plans — the forward
  exchange and the backward communication run opposite ring directions
  (``exchange_halo(..., reverse=True)``); dense plans are involutions and
  ignore the flag;
* ``SylvieConfig.quant_impl`` picks the Low-bit-Module implementation
  ("auto" = fused Pallas kernel on TPU, jnp elsewhere) — only the live rows of
  the compacted buffer are quantized, so Low-bit-Module FLOPs track the actual
  boundary set, not the padded worst case (paper §4.4 overhead budget).

The *Bounded Staleness Adaptor* (paper §3.3) is the
``repro.policy.builtin.BoundedStaleness`` policy; the trainer runs the policy
loop (``train/trainer.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..dist.backend import as_backend
from ..policy.base import SiteDecision
from . import quantization as qlib
from .exchange import (PlanArrays, exchange_quantized_halo,
                       gather_boundary, scatter_boundary_grad)

Mode = str  # "vanilla" | "sync" | "async"

# Exchange schedules: "blocking" consumes each halo exchange where it is
# produced; "overlap" (dist/overlap.py) issues the quantized send early and
# lands it through a backend fence so the collective can run under the
# layer's local aggregation. Bit-exact under sync/fresh configs.
SCHEDULES = ("blocking", "overlap")


@dataclasses.dataclass(frozen=True)
class SylvieConfig:
    mode: Mode = "sync"
    bits: int = 1
    stochastic: bool = True
    scale_dtype: jnp.dtype = jnp.bfloat16
    # Low-bit Module implementation: "auto" (Pallas fused kernel on TPU, jnp
    # elsewhere) | "jnp" | "pallas" (interpret mode off-TPU).
    quant_impl: str = "auto"
    # BNS-GCN baseline (Wan et al. 2022a): random boundary-node sampling.
    # Each epoch keeps a (1-p) fraction of halo rows, scaled by 1/(1-p);
    # p=0 disables. Used by the Table-2 baseline comparison.
    boundary_sample_p: float = 0.0
    # Exchange schedule (see SCHEDULES above). An EpochDecision's schedule
    # overrides this when one is threaded into the step.
    schedule: str = "blocking"

    @property
    def effective_bits(self) -> int:
        return 32 if self.mode == "vanilla" else self.bits

    def replace(self, **kw) -> "SylvieConfig":
        return dataclasses.replace(self, **kw)


def _q_roundtrip(buf, key, bits, stochastic, scale_dtype, backend, plan,
                 reverse=False, impl="auto"):
    """quantize -> exchange -> dequantize (one direction of the Low-bit Module).
    ``reverse`` flips the ring direction for compact plans (backward comm)."""
    qt = qlib.quantize(buf, bits, key, stochastic, scale_dtype, impl=impl)
    qr = exchange_quantized_halo(qt, plan, backend, reverse=reverse)
    return qlib.dequantize(qr, impl=impl)


# ---------------------------------------------------------------------------
# Sylvie-S: synchronous quantized exchange with quantized backward communication
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def quantized_halo(h, plan: PlanArrays, fwd_key, bwd_key,
                   fwd_bits: int, bwd_bits: int, stochastic: bool,
                   scale_dtype, backend, impl):
    """(P, n_local, d) -> (P, halo_rows, d) dequantized halo features.

    ``fwd_bits`` quantizes the forward feature exchange, ``bwd_bits`` the
    backward gradient communication — per-site, per-direction decisions."""
    buf = gather_boundary(h, plan)
    out = _q_roundtrip(buf, fwd_key, fwd_bits, stochastic, scale_dtype,
                       backend, plan, impl=impl)
    return jnp.where(plan.recv_mask[..., None], out, 0)


def _qh_fwd(h, plan, fwd_key, bwd_key, fwd_bits, bwd_bits, stochastic,
            scale_dtype, backend, impl):
    out = quantized_halo(h, plan, fwd_key, bwd_key,
                         fwd_bits, bwd_bits, stochastic, scale_dtype, backend,
                         impl)
    return out, (plan, bwd_key)


def _qh_bwd(fwd_bits, bwd_bits, stochastic, scale_dtype, backend, impl, res,
            g):
    plan, bwd_key = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    back = _q_roundtrip(g, bwd_key, bwd_bits, stochastic, scale_dtype, backend,
                        plan, reverse=True, impl=impl)
    grad_h = scatter_boundary_grad(back, plan)
    return (grad_h, None, None, None)


quantized_halo.defvjp(_qh_fwd, _qh_bwd)


# ---------------------------------------------------------------------------
# Sylvie-A: stale halo consumption + fresh exchange emission
# ---------------------------------------------------------------------------
def fresh_halo(h, plan: PlanArrays, key, fwd_bits, stochastic, scale_dtype,
               backend, impl="auto"):
    """The concurrent forward exchange: quantize this step's boundary features and
    deliver them as *next* step's cache. Detached — no gradient flows (staleness
    is handled by the grad_in path)."""
    buf = gather_boundary(jax.lax.stop_gradient(h), plan)
    out = _q_roundtrip(buf, key, fwd_bits, stochastic, scale_dtype, backend,
                       plan, impl=impl)
    return jnp.where(plan.recv_mask[..., None], out, 0)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def stale_halo(h, feat_cache, grad_in, gslot, plan: PlanArrays, bwd_key,
               bwd_bits: int, stochastic: bool, scale_dtype, backend, impl):
    """Consume the stale halo; wire the staleness dataflow into autodiff.

    * primal output  = ``feat_cache`` (previous step's dequantized halo features)
    * grad wrt ``h``     = ``grad_in`` scattered onto boundary nodes (previous
      step's incoming boundary gradients — Alg. 2 line 13, one step stale)
    * grad wrt ``gslot`` = this step's outgoing quantized gradient exchange at
      ``bwd_bits`` (surfaces to the caller as the next step's ``grad_in``)
    """
    del h, grad_in, gslot, plan, bwd_key
    return feat_cache


def _sh_fwd(h, feat_cache, grad_in, gslot, plan, bwd_key,
            bwd_bits, stochastic, scale_dtype, backend, impl):
    return feat_cache, (plan, grad_in, bwd_key)


def _sh_bwd(bwd_bits, stochastic, scale_dtype, backend, impl, res, g):
    plan, grad_in, bwd_key = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    fresh_grad = _q_roundtrip(g, bwd_key, bwd_bits, stochastic, scale_dtype,
                              backend, plan, reverse=True, impl=impl)
    fresh_grad = jnp.where(plan.send_mask[..., None], fresh_grad, 0)
    grad_h = scatter_boundary_grad(grad_in, plan)
    return (grad_h, None, None, fresh_grad, None, None)


stale_halo.defvjp(_sh_fwd, _sh_bwd)


# ---------------------------------------------------------------------------
# Per-step orchestrator handed to the model
# ---------------------------------------------------------------------------
class SylvieComm:
    """Created inside each traced step; models call ``comm.halo(h)`` once per
    layer-exchange site. All communication goes through ``backend`` (a
    :class:`repro.dist.backend.HaloBackend`; the simulated stack by default).

    ``decision`` is an :class:`~repro.policy.base.EpochDecision` whose
    ``sites[i]`` drives the i-th ``halo`` call; ``None`` falls back to the one
    global ``SylvieConfig`` choice for every site (the Uniform shim).
    Collects fresh caches (async mode) and — when ``collect_stats`` — per-site
    boundary range statistics as it goes."""

    def __init__(self, cfg: SylvieConfig, plan: PlanArrays, key,
                 backend=None, decision=None, collect_stats=False,
                 feat_caches=None, grad_ins=None, gslots=None,
                 fault_sites=None):
        self.cfg = cfg
        self.plan = plan
        self.key = key
        self.backend = as_backend(backend)
        self.decision = decision
        self.collect_stats = collect_stats
        self.feat_caches = feat_caches
        self.grad_ins = grad_ins
        self.gslots = gslots
        # per-site fault masks (repro.faults.plan.SiteFaults tuple) riding as
        # data; None = fault-free, traces the exact legacy program.
        self.fault_sites = fault_sites
        self.new_feat_caches: list = []
        self.site_stats: list = []
        self._site = 0

    def _part_key(self):
        """Decorrelate stochastic-rounding noise across partitions: fold the
        partition index into the key under shard_map (the simulated mode's
        single batched uniform draw is already decorrelated)."""
        idx = self.backend.axis_index()
        if idx is None:
            return self.key
        return jax.random.fold_in(self.key, idx)

    def _bns_mask(self, key, p):
        """BNS-GCN-style boundary sampling: one Bernoulli keep-mask per halo
        row per epoch, shared by forward and backward (paper baseline)."""
        if p <= 0.0:
            return None
        rows = self.plan.recv_mask.shape
        return (jax.random.bernoulli(key, 1.0 - p, rows) / (1.0 - p))

    def _record_stats(self, h):
        """Per-site telemetry for adaptive policies: sum over live send rows
        of the squared per-row range, plus the live-row count (this
        partition's slice; the step psums across partitions)."""
        if not self.collect_stats:
            return
        buf = gather_boundary(jax.lax.stop_gradient(h), self.plan)
        rng = jnp.max(buf, axis=-1) - jnp.min(buf, axis=-1)
        live = self.plan.send_mask.astype(jnp.float32)
        self.site_stats.append(
            jnp.stack([(rng.astype(jnp.float32) ** 2 * live).sum(),
                       live.sum()]))

    def _site_decision(self, i) -> SiteDecision:
        if self.decision is not None:
            return self.decision.sites[i]
        return SiteDecision.from_config(self.cfg)

    @property
    def schedule(self) -> str:
        """Exchange schedule: the decision's choice when one is threaded in,
        else the config's (both default to ``"blocking"``)."""
        sched = (self.decision.schedule if self.decision is not None
                 else self.cfg.schedule)
        if sched not in SCHEDULES:
            raise ValueError(f"unknown schedule {sched!r}; known: {SCHEDULES}")
        return sched

    def halo(self, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        i = self._site
        self._site += 1
        sd = self._site_decision(i)
        key = self._part_key()
        kf = jax.random.fold_in(key, 2 * i)
        kb = jax.random.fold_in(key, 2 * i + 1)
        self._record_stats(h)
        sf = self.fault_sites[i] if self.fault_sites is not None else None
        if sf is not None:
            # lazy import: repro.core.__init__ imports this module, and
            # repro.faults.comm imports repro.core — a module-level import
            # here would cycle.
            from ..faults import comm as fcomm
        # Fault-armed sites always run the blocking faulty primitives: the
        # recovery blend needs the landed exchange immediately (DESIGN §14).
        overlap = self.schedule == "overlap" and sf is None
        if overlap:
            # lazy import for the same reason as faults.comm above.
            from ..dist import overlap as olap
        if cfg.mode in ("vanilla", "sync"):
            if sf is not None:
                halo = fcomm.faulty_quantized_halo(
                    h, self.feat_caches[i], sf, self.plan, kf, kb,
                    sd.fwd_bits, sd.bwd_bits, sd.stochastic, cfg.scale_dtype,
                    self.backend, cfg.quant_impl)
            elif overlap:
                halo = olap.overlap_quantized_halo(
                    h, self.plan, kf, kb, sd.fwd_bits, sd.bwd_bits,
                    sd.stochastic, cfg.scale_dtype, self.backend,
                    cfg.quant_impl)
            else:
                halo = quantized_halo(h, self.plan, kf, kb, sd.fwd_bits,
                                      sd.bwd_bits, sd.stochastic,
                                      cfg.scale_dtype, self.backend,
                                      cfg.quant_impl)
            bns = self._bns_mask(jax.random.fold_in(key, 999),
                                 sd.boundary_sample_p)
            if bns is not None:
                halo = halo * bns[..., None]
            # a synchronous step doubles as a cache refresh for Sylvie-A
            # (Bounded Staleness Adaptor); caller stop-gradients these.
            self.new_feat_caches.append(halo)
            return halo
        # async: consume stale, emit fresh
        if sf is not None:
            halo = fcomm.faulty_stale_halo(
                h, self.feat_caches[i], self.grad_ins[i], self.gslots[i], sf,
                self.plan, kb, sd.bwd_bits, sd.stochastic, cfg.scale_dtype,
                self.backend, cfg.quant_impl)
            self.new_feat_caches.append(fcomm.faulty_fresh_halo(
                h, self.feat_caches[i], sf, self.plan, kf, sd.fwd_bits,
                sd.stochastic, cfg.scale_dtype, self.backend, cfg.quant_impl))
            return halo
        if overlap:
            halo = olap.overlap_stale_halo(
                h, self.feat_caches[i], self.grad_ins[i], self.gslots[i],
                self.plan, kb, sd.bwd_bits, sd.stochastic, cfg.scale_dtype,
                self.backend, cfg.quant_impl)
            self.new_feat_caches.append(olap.overlap_fresh_halo(
                h, self.plan, kf, sd.fwd_bits, sd.stochastic,
                cfg.scale_dtype, self.backend, cfg.quant_impl))
            return halo
        halo = stale_halo(h, self.feat_caches[i], self.grad_ins[i], self.gslots[i],
                          self.plan, kb, sd.bwd_bits, sd.stochastic,
                          cfg.scale_dtype, self.backend, cfg.quant_impl)
        self.new_feat_caches.append(
            fresh_halo(h, self.plan, kf, sd.fwd_bits, sd.stochastic,
                       cfg.scale_dtype, self.backend, cfg.quant_impl))
        return halo

    @property
    def n_sites(self) -> int:
        return self._site
