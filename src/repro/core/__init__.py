from . import exchange, quantization, staleness, sylvie  # noqa: F401
