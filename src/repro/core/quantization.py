"""Low-bit Module: b-bit affine quantization with stochastic rounding (Sylvie §3.2).

Implements Equ. 3-5 of the paper:

    hbar = (h - min(h)) / (max(h) - min(h)) * B          with B = 2^b - 1
    q    = floor(hbar) + Bernoulli(hbar - floor(hbar))    (stochastic rounding, Equ. 4)
    h~   = q * (max - min) / B + min                      (dequantize, Equ. 5)

Per-*vector* (last axis) scale/zero-point — one (scale, zero) pair per node feature
vector, exactly as the paper's error-compensated information. Scale/zero are carried in
``scale_dtype`` (bf16 by default; the paper uses fp32 — see DESIGN.md §2).

Quantization is unbiased under stochastic rounding (Theorem 1):
    E[h~] = h,   Var(h~) = D * (max-min)^2 / (6 B^2).

Bit-widths:
  * b in {1, 2, 4}: values are packed 8//b per byte into uint8 (TPU-friendly payload).
  * b = 8: uint8, no packing.
  * b in {3, 5, 6, 7}: stored unpacked in uint8 (supported for the Fig.9 sweep).
  * b = 16: bf16 passthrough (no scale/zero).
  * b = 32: fp32 passthrough (identity — the "vanilla" baseline).

Implementation dispatch (the hot-path seam): :func:`quantize` / :func:`dequantize`
take an ``impl`` designator —

  * ``"jnp"``    — the pure-jnp reference path (always available, any bit-width);
  * ``"pallas"`` — the fused one-HBM-pass Pallas kernel (``repro.kernels.quant``:
    min/max reduce -> affine scale -> stochastic round -> bit-pack in one VMEM
    pass) for packable bit-widths {1, 2, 4, 8} with stochastic rounding; runs
    interpret mode off-TPU so tests/benchmarks can validate it anywhere;
  * ``"auto"`` / ``None`` — Pallas on a TPU backend, jnp elsewhere.

Both paths draw the same ``jax.random.uniform(key, h.shape)`` noise, so they are
bit-identical in interpret mode. Cases the kernel does not cover (passthrough or
odd bit-widths, deterministic rounding, scalar rows) silently fall back to jnp.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

PACKABLE_BITS = (1, 2, 4)
PASSTHROUGH_BITS = (16, 32)
PALLAS_BITS = (1, 2, 4, 8)        # widths the fused kernel implements
QUANT_IMPLS = ("auto", "jnp", "pallas")


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve an ``impl`` designator to a concrete path ("jnp" | "pallas")."""
    if impl in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown quantize impl {impl!r}; pick from {QUANT_IMPLS}")
    return impl


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Quantized payload + error-compensation info (scale, zero).

    ``data`` is uint8 (packed when bits in {1,2,4}) or bf16/fp32 for passthrough.
    ``scale``/``zero`` are per-leading-row (one per feature vector); empty arrays for
    passthrough bit-widths.
    """

    data: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    feat_dim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def payload_bits_per_value(self) -> float:
        return float(self.bits)


def _lanes_per_byte(bits: int) -> int:
    return 8 // bits if bits in PACKABLE_BITS else 1


def packed_width(feat_dim: int, bits: int) -> int:
    """Width of the uint8 payload row for a feat_dim-wide vector."""
    if bits in PASSTHROUGH_BITS:
        return feat_dim  # not bytes; dtype carries width
    k = _lanes_per_byte(bits)
    return (feat_dim + k - 1) // k


def comm_bytes(n_rows: int, feat_dim: int, bits: int,
               scale_dtype: jnp.dtype = jnp.bfloat16) -> tuple[int, int]:
    """(main payload bytes, error-compensation bytes) for one exchange buffer.

    Used by the Table-3 benchmark and the roofline collective-term accounting.
    """
    if bits == 32:
        return n_rows * feat_dim * 4, 0
    if bits == 16:
        return n_rows * feat_dim * 2, 0
    payload = n_rows * packed_width(feat_dim, bits)
    ec = 2 * n_rows * jnp.dtype(scale_dtype).itemsize  # scale + zero per row
    return payload, ec


def pack_bits(vals: jax.Array, bits: int) -> jax.Array:
    """Pack uint8 values in [0, 2^bits-1] along the last axis, 8//bits per byte."""
    if bits == 8 or bits not in PACKABLE_BITS:
        return vals.astype(jnp.uint8)
    k = _lanes_per_byte(bits)
    d = vals.shape[-1]
    pad = (-d) % k
    if pad:
        vals = jnp.pad(vals, [(0, 0)] * (vals.ndim - 1) + [(0, pad)])
    grouped = vals.reshape(*vals.shape[:-1], -1, k).astype(jnp.uint8)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    return jnp.bitwise_or.reduce(grouped << shifts, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, bits: int, feat_dim: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint8 values of width ``feat_dim``."""
    if bits == 8 or bits not in PACKABLE_BITS:
        return packed[..., :feat_dim]
    k = _lanes_per_byte(bits)
    mask = np.uint8((1 << bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    vals = (packed[..., :, None] >> shifts) & mask
    return vals.reshape(*packed.shape[:-1], -1)[..., :feat_dim]


def theoretical_variance(h: jax.Array, bits: int) -> jax.Array:
    """Theorem 1 variance of the dequantized vector: D (max-min)^2 / (6 B^2)."""
    b = 2.0 ** bits - 1.0
    rng = jnp.max(h, -1) - jnp.min(h, -1)
    return h.shape[-1] * rng**2 / (6.0 * b**2)


def _rows(h: jax.Array) -> int:
    n = 1
    for s in h.shape[:-1]:
        n *= s
    return n


def _pallas_can_quantize(h, bits, key, stochastic) -> bool:
    return (bits in PALLAS_BITS and stochastic and key is not None
            and h.ndim >= 2 and h.shape[-1] > 0 and _rows(h) > 0)


def _quantize_pallas(h, bits, key, scale_dtype) -> QuantizedTensor:
    """Fused quantize+bitpack: one HBM read of the buffer, one packed write."""
    from ..kernels.quant import ops as kops
    d = h.shape[-1]
    lead = h.shape[:-1]
    # same noise stream as the jnp path (drawn at the unflattened shape) so the
    # two impls are bit-identical given one key
    u = jax.random.uniform(key, h.shape, dtype=jnp.float32)
    packed, scale, zero = kops.quantize_pack_rows(
        h.astype(jnp.float32).reshape(-1, d), u.reshape(-1, d), bits)
    return QuantizedTensor(packed.reshape(lead + (packed.shape[-1],)),
                           scale.reshape(lead).astype(scale_dtype),
                           zero.reshape(lead).astype(scale_dtype), bits, d)


def _dequantize_pallas(qt: QuantizedTensor, out_dtype) -> jax.Array:
    from ..kernels.quant import ops as kops
    w = qt.data.shape[-1]
    lead = qt.data.shape[:-1]
    out = kops.dequantize_rows(qt.data.reshape(-1, w),
                               qt.scale.reshape(-1).astype(jnp.float32),
                               qt.zero.reshape(-1).astype(jnp.float32),
                               qt.bits, qt.feat_dim)
    return out.reshape(lead + (qt.feat_dim,)).astype(out_dtype)


def quantize(h: jax.Array, bits: int, key: Optional[jax.Array] = None,
             stochastic: bool = True,
             scale_dtype: jnp.dtype = jnp.bfloat16,
             impl: Optional[str] = None) -> QuantizedTensor:
    """Quantize ``h`` (..., D) to ``bits``-bit integers per Equ. 3-4.

    ``key`` is required when ``stochastic`` (training); deterministic
    round-to-nearest otherwise (eval / debugging). ``impl`` picks the
    implementation (see module docstring); unsupported cases fall back to jnp.
    """
    d = h.shape[-1]
    if bits == 32:
        return QuantizedTensor(h.astype(jnp.float32), jnp.zeros(h.shape[:-1] + (0,)),
                               jnp.zeros(h.shape[:-1] + (0,)), 32, d)
    if bits == 16:
        return QuantizedTensor(h.astype(jnp.bfloat16), jnp.zeros(h.shape[:-1] + (0,)),
                               jnp.zeros(h.shape[:-1] + (0,)), 16, d)
    if resolve_impl(impl) == "pallas" and _pallas_can_quantize(h, bits, key,
                                                               stochastic):
        return _quantize_pallas(h, bits, key, scale_dtype)

    big = 2.0 ** bits - 1.0
    h = h.astype(jnp.float32)
    lo = jnp.min(h, axis=-1, keepdims=True)
    hi = jnp.max(h, axis=-1, keepdims=True)
    rng = hi - lo
    safe = jnp.where(rng > 0, rng, 1.0)
    hbar = (h - lo) / safe * big                       # in [0, B]
    if stochastic:
        if key is None:
            raise ValueError("stochastic quantization requires a PRNG key")
        floor = jnp.floor(hbar)
        frac = hbar - floor
        u = jax.random.uniform(key, hbar.shape, dtype=jnp.float32)
        q = floor + (u < frac).astype(jnp.float32)     # Equ. 4
    else:
        q = jnp.round(hbar)
    q = jnp.clip(q, 0.0, big).astype(jnp.uint8)
    packed = pack_bits(q, bits)
    scale = (rng / big).astype(scale_dtype)[..., 0]
    zero = lo.astype(scale_dtype)[..., 0]
    return QuantizedTensor(packed, scale, zero, bits, d)


def dequantize(qt: QuantizedTensor, out_dtype: jnp.dtype = jnp.float32,
               impl: Optional[str] = None) -> jax.Array:
    """Recover full-precision values per Equ. 5 (unbiased given Equ. 4)."""
    if qt.bits in PASSTHROUGH_BITS:
        return qt.data.astype(out_dtype)
    if (resolve_impl(impl) == "pallas" and qt.bits in PALLAS_BITS
            and qt.data.ndim >= 2 and _rows(qt.data) > 0 and qt.feat_dim > 0):
        return _dequantize_pallas(qt, out_dtype)
    vals = unpack_bits(qt.data, qt.bits, qt.feat_dim).astype(jnp.float32)
    out = vals * qt.scale[..., None].astype(jnp.float32) \
        + qt.zero[..., None].astype(jnp.float32)
    return out.astype(out_dtype)


def fake_quantize(h: jax.Array, bits: int, key: Optional[jax.Array] = None,
                  stochastic: bool = True) -> jax.Array:
    """dequantize(quantize(h)) in one call — the simulated-communication value."""
    return dequantize(quantize(h, bits, key, stochastic), h.dtype)


# ---------------------------------------------------------------------------
# Straight-through wrapper: the *computation* treats quant/dequant as identity in
# the backward pass; Sylvie quantizes the backward *communication* separately
# (Alg. 2 lines 10-12). Exposed for the non-exchange uses (EF21 grad compression,
# quantized MoE dispatch) that need gradients to flow through.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(1, 3))
def straight_through_quantize(h, bits, key, stochastic=True):
    return fake_quantize(h, bits, key, stochastic)


def _stq_fwd(h, bits, key, stochastic=True):
    return fake_quantize(h, bits, key, stochastic), None


def _stq_bwd(bits, stochastic, _, g):
    return (g, None)


straight_through_quantize.defvjp(_stq_fwd, _stq_bwd)
