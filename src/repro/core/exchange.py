"""Halo exchange primitives: boundary gather/scatter + the exchange entry points.

All GNN runtime code operates on *stacked* arrays with a leading partition axis
``P`` — e.g. node features ``(P, n_local, d)``. *Which* collective moves the
halo buffers is a :class:`repro.dist.backend.HaloBackend` decision — the
simulated stacked transpose or the shard_map ``lax.all_to_all`` (or any future
communicator) — and this module is the seam: :func:`exchange` /
:func:`exchange_quantized` accept a backend (or a legacy axis-name designator,
normalized via ``as_backend``) and delegate to it.

The exchange permutation is an involution (a transpose), so the backward
communication (Alg. 2) reuses the same primitive.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.backend import as_backend
from .quantization import QuantizedTensor


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanArrays:
    """Device-side halo plan (stacked, leading axis P). See graph/partition.py."""

    send_idx: jax.Array   # (P, P*h_pad) int32 — local rows to send, pairwise blocks
    send_mask: jax.Array  # (P, P*h_pad) bool
    recv_mask: jax.Array  # (P, P*h_pad) bool
    n_local: int = dataclasses.field(metadata=dict(static=True))
    h_pad: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))

    @staticmethod
    def from_plan(plan) -> "PlanArrays":
        p = plan
        return PlanArrays(
            send_idx=jnp.asarray(p.send_idx.reshape(p.n_parts, -1), jnp.int32),
            send_mask=jnp.asarray(p.send_mask.reshape(p.n_parts, -1)),
            recv_mask=jnp.asarray(p.recv_mask),
            n_local=int(p.n_local), h_pad=int(p.h_pad), n_parts=int(p.n_parts))

    @staticmethod
    def from_spec(spec) -> "PlanArrays":
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        s = spec
        rows = s.n_parts * s.h_pad
        return PlanArrays(
            send_idx=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.int32),
            send_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            recv_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            n_local=int(s.n_local), h_pad=int(s.h_pad), n_parts=int(s.n_parts))


def gather_boundary(h: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, n_local, d) -> (P, P*h_pad, d) send buffer (masked)."""
    buf = jnp.take_along_axis(h, plan.send_idx[..., None], axis=1)
    return jnp.where(plan.send_mask[..., None], buf, 0)


def scatter_boundary_grad(g: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, P*h_pad, d) received grads -> (P, n_local, d) scatter-add onto owners.

    A node sent to multiple partitions accumulates all their gradients (sum) —
    Alg. 2 line 13."""
    g = jnp.where(plan.send_mask[..., None], g, 0)

    def one(gp, idx):
        return jnp.zeros((plan.n_local, g.shape[-1]), g.dtype).at[idx].add(gp)

    return jax.vmap(one)(g, plan.send_idx)


def exchange(x: jax.Array, backend=None) -> jax.Array:
    """The halo all-to-all. ``x``: (P_local, P*h_pad, ...) pairwise-blocked buffer.

    ``backend`` is a :class:`~repro.dist.backend.HaloBackend`; ``None`` (the
    simulated stacked transpose) and bare axis names are accepted for
    compatibility and normalized via ``as_backend``.
    """
    return as_backend(backend).exchange(x)


def exchange_quantized(qt: QuantizedTensor, backend=None) -> QuantizedTensor:
    """Exchange a quantized payload: data + error-compensation (scale, zero) move
    together (paper §3.2 Communicator)."""
    return as_backend(backend).exchange_quantized(qt)


def exchange_bytes(plan: PlanArrays, d: int, bits: int,
                   scale_dtype=jnp.bfloat16) -> tuple[int, int]:
    """(payload, error-compensation) bytes moved per exchange per partition —
    the Table-3 accounting and the roofline collective term."""
    from .quantization import comm_bytes
    rows = plan.n_parts * plan.h_pad
    return comm_bytes(rows, d, bits, scale_dtype)
