"""Halo exchange primitives: boundary gather/scatter + the all-to-all itself.

All GNN runtime code operates on *stacked* arrays with a leading partition axis
``P`` — e.g. node features ``(P, n_local, d)``. Two execution modes share this code:

* **simulated** (``axis_name=None``): the full stack lives on one device; the
  exchange is the pure transpose ``out[p, q*h+s] = in[q, p*h+s]``. Reference
  semantics; used by tests and CPU training runs.
* **shard_map** (``axis_name='parts'``): each device holds one partition — the
  leading axis is locally size 1 — and the exchange is a single
  ``jax.lax.all_to_all`` over the halo-buffer axis (axis 1, ``tiled=True``), which
  implements exactly the same transpose across devices.

The exchange permutation is an involution (a transpose), so the backward
communication (Alg. 2) reuses the same primitive.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .quantization import QuantizedTensor


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanArrays:
    """Device-side halo plan (stacked, leading axis P). See graph/partition.py."""

    send_idx: jax.Array   # (P, P*h_pad) int32 — local rows to send, pairwise blocks
    send_mask: jax.Array  # (P, P*h_pad) bool
    recv_mask: jax.Array  # (P, P*h_pad) bool
    n_local: int = dataclasses.field(metadata=dict(static=True))
    h_pad: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))

    @staticmethod
    def from_plan(plan) -> "PlanArrays":
        p = plan
        return PlanArrays(
            send_idx=jnp.asarray(p.send_idx.reshape(p.n_parts, -1), jnp.int32),
            send_mask=jnp.asarray(p.send_mask.reshape(p.n_parts, -1)),
            recv_mask=jnp.asarray(p.recv_mask),
            n_local=int(p.n_local), h_pad=int(p.h_pad), n_parts=int(p.n_parts))

    @staticmethod
    def from_spec(spec) -> "PlanArrays":
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        s = spec
        rows = s.n_parts * s.h_pad
        return PlanArrays(
            send_idx=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.int32),
            send_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            recv_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            n_local=int(s.n_local), h_pad=int(s.h_pad), n_parts=int(s.n_parts))


def gather_boundary(h: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, n_local, d) -> (P, P*h_pad, d) send buffer (masked)."""
    buf = jnp.take_along_axis(h, plan.send_idx[..., None], axis=1)
    return jnp.where(plan.send_mask[..., None], buf, 0)


def scatter_boundary_grad(g: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, P*h_pad, d) received grads -> (P, n_local, d) scatter-add onto owners.

    A node sent to multiple partitions accumulates all their gradients (sum) —
    Alg. 2 line 13."""
    g = jnp.where(plan.send_mask[..., None], g, 0)

    def one(gp, idx):
        return jnp.zeros((plan.n_local, g.shape[-1]), g.dtype).at[idx].add(gp)

    return jax.vmap(one)(g, plan.send_idx)


def exchange(x: jax.Array, axis_name: Optional[str]) -> jax.Array:
    """The halo all-to-all. ``x``: (P_local, P*h_pad, ...) pairwise-blocked buffer.

    simulated: transpose across the stacked leading axis.
    shard_map: tiled all_to_all over axis 1 (per-device leading axis is size 1).
    """
    if axis_name is None:
        p = x.shape[0]
        h = x.shape[1] // p
        y = x.reshape((p, p, h) + x.shape[2:])
        y = jnp.swapaxes(y, 0, 1)
        return y.reshape((p, p * h) + x.shape[2:])
    return jax.lax.all_to_all(x, axis_name, split_axis=1, concat_axis=1, tiled=True)


def exchange_quantized(qt: QuantizedTensor, axis_name: Optional[str]) -> QuantizedTensor:
    """Exchange a quantized payload: data + error-compensation (scale, zero) move
    together (paper §3.2 Communicator)."""
    return QuantizedTensor(
        data=exchange(qt.data, axis_name),
        scale=exchange(qt.scale, axis_name) if qt.scale.size else qt.scale,
        zero=exchange(qt.zero, axis_name) if qt.zero.size else qt.zero,
        bits=qt.bits, feat_dim=qt.feat_dim)


def exchange_bytes(plan: PlanArrays, d: int, bits: int,
                   scale_dtype=jnp.bfloat16) -> tuple[int, int]:
    """(payload, error-compensation) bytes moved per exchange per partition —
    the Table-3 accounting and the roofline collective term."""
    from .quantization import comm_bytes
    rows = plan.n_parts * plan.h_pad
    return comm_bytes(rows, d, bits, scale_dtype)
