"""Halo exchange primitives: boundary gather/scatter + the exchange entry points.

All GNN runtime code operates on *stacked* arrays with a leading partition axis
``P`` — e.g. node features ``(P, n_local, d)``. *Which* collective moves the
halo buffers is a :class:`repro.dist.backend.HaloBackend` decision — the
simulated stacked transpose/roll or the shard_map ``all_to_all``/``ppermute``
(or any future communicator) — and this module is the seam.

Two buffer layouts exist (see ``graph/partition.py``):

* dense pairwise blocks ``(P, P*h_pad, ...)`` — the exchange is a transpose
  (an involution), so forward and backward communication share one primitive;
* compact ring buckets ``(P, R, ...)`` with ``R = sum(bucket_sizes)`` — bucket
  ``k`` moves ``p -> (p+k) % P``. Reversing the rings undoes it, so the
  backward communication (Alg. 2) calls :func:`exchange_halo` with
  ``reverse=True``. The layout is carried statically on :class:`PlanArrays`
  (``bucket_sizes``), so one code path in ``core/sylvie.py`` serves both.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist.backend import as_backend
from .quantization import QuantizedTensor


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PlanArrays:
    """Device-side halo plan (stacked, leading axis P). See graph/partition.py.

    ``bucket_sizes`` is ``None`` for the dense pairwise layout and a static
    per-ring-offset row-count tuple for the compact layout. ``wire_rows`` /
    ``real_rows`` are exchange-accounting constants (totals across partitions):
    rows the layout actually ships vs. true unpadded off-diagonal halo rows.
    """

    send_idx: jax.Array   # (P, rows) int32 — local rows to send, blocked/bucketed
    send_mask: jax.Array  # (P, rows) bool
    recv_mask: jax.Array  # (P, rows) bool
    n_local: int = dataclasses.field(metadata=dict(static=True))
    h_pad: int = dataclasses.field(metadata=dict(static=True))
    n_parts: int = dataclasses.field(metadata=dict(static=True))
    bucket_sizes: Optional[tuple[int, ...]] = dataclasses.field(
        default=None, metadata=dict(static=True))
    wire_rows: int = dataclasses.field(default=0, metadata=dict(static=True))
    real_rows: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def halo_rows(self) -> int:
        """Rows of one partition's halo buffer (dense: P*h_pad; compact: R)."""
        return int(self.send_idx.shape[1])

    @staticmethod
    def from_plan(plan) -> "PlanArrays":
        p = plan
        buckets = None
        if getattr(p, "layout", "dense") == "compact":
            buckets = tuple(int(b) for b in p.bucket_sizes)
        return PlanArrays(
            send_idx=jnp.asarray(p.send_idx.reshape(p.n_parts, -1), jnp.int32),
            send_mask=jnp.asarray(p.send_mask.reshape(p.n_parts, -1)),
            recv_mask=jnp.asarray(p.recv_mask),
            n_local=int(p.n_local), h_pad=int(p.h_pad), n_parts=int(p.n_parts),
            bucket_sizes=buckets, wire_rows=int(p.wire_rows()),
            real_rows=int(p.real_rows()))

    @staticmethod
    def from_spec(spec) -> "PlanArrays":
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation). Analytic
        specs size the dense layout; wire/real rows fall back to the
        off-diagonal dense estimate (no masks exist to count real rows)."""
        s = spec
        rows = s.n_parts * s.h_pad
        wire = s.n_parts * (s.n_parts - 1) * s.h_pad
        return PlanArrays(
            send_idx=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.int32),
            send_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            recv_mask=jax.ShapeDtypeStruct((s.n_parts, rows), jnp.bool_),
            n_local=int(s.n_local), h_pad=int(s.h_pad), n_parts=int(s.n_parts),
            bucket_sizes=None, wire_rows=wire, real_rows=wire)


def gather_boundary(h: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, n_local, d) -> (P, rows, d) packed send buffer (masked).

    ``plan.send_idx`` is the compaction permutation: for the compact layout the
    output has no dead pairwise blocks, only per-bucket alignment tails."""
    buf = jnp.take_along_axis(h, plan.send_idx[..., None], axis=1)
    return jnp.where(plan.send_mask[..., None], buf, 0)


def scatter_boundary_grad(g: jax.Array, plan: PlanArrays) -> jax.Array:
    """(P, rows, d) received grads -> (P, n_local, d) scatter-add onto owners.

    A node sent to multiple partitions accumulates all their gradients (sum) —
    Alg. 2 line 13."""
    g = jnp.where(plan.send_mask[..., None], g, 0)

    def one(gp, idx):
        return jnp.zeros((plan.n_local, g.shape[-1]), g.dtype).at[idx].add(gp)

    return jax.vmap(one)(g, plan.send_idx)


def exchange(x: jax.Array, backend=None) -> jax.Array:
    """The dense halo all-to-all. ``x``: (P_local, P*h_pad, ...) pairwise-blocked
    buffer.

    ``backend`` is a :class:`~repro.dist.backend.HaloBackend`; ``None`` (the
    simulated stacked transpose) and bare axis names are accepted for
    compatibility and normalized via ``as_backend``.
    """
    return as_backend(backend).exchange(x)


def exchange_quantized(qt: QuantizedTensor, backend=None) -> QuantizedTensor:
    """Exchange a dense quantized payload: data + error-compensation (scale,
    zero) move together (paper §3.2 Communicator)."""
    return as_backend(backend).exchange_quantized(qt)


def exchange_halo(x: jax.Array, plan: PlanArrays, backend=None,
                  reverse: bool = False) -> jax.Array:
    """Layout-dispatching halo exchange. Dense plans use the transpose
    (self-inverse, ``reverse`` ignored); compact plans run the ring buckets,
    reversed for the backward communication."""
    be = as_backend(backend)
    if plan.bucket_sizes is None:
        return be.exchange(x)
    return be.exchange_compact(x, plan.bucket_sizes, reverse=reverse)


def exchange_quantized_halo(qt: QuantizedTensor, plan: PlanArrays, backend=None,
                            reverse: bool = False) -> QuantizedTensor:
    """Layout-dispatching quantized exchange (payload + scale/zero together)."""
    be = as_backend(backend)
    if plan.bucket_sizes is None:
        return be.exchange_quantized(qt)
    return be.exchange_quantized_compact(qt, plan.bucket_sizes, reverse=reverse)


def exchange_bytes(plan: PlanArrays, d: int, bits: int,
                   scale_dtype=jnp.bfloat16) -> tuple[int, int]:
    """(payload, error-compensation) *true wire* bytes per exchange, totaled
    across partitions: diagonal self-blocks and padding rows are excluded —
    the Table-3 accounting and the roofline collective term."""
    from .quantization import comm_bytes
    return comm_bytes(plan.real_rows, d, bits, scale_dtype)


def wire_bytes(plan: PlanArrays, d: int, bits: int,
               scale_dtype=jnp.bfloat16) -> tuple[int, int]:
    """(payload, error-compensation) bytes this plan's layout actually ships per
    exchange, totaled across partitions — includes per-bucket alignment tails
    (compact) or pairwise padding to the global max (dense), but never the
    diagonal. ``wire_bytes - exchange_bytes`` is the padding overhead the
    compact layout exists to eliminate."""
    from .quantization import comm_bytes
    return comm_bytes(plan.wire_rows, d, bits, scale_dtype)
