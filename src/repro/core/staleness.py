"""Staleness state for Sylvie-A + the Bounded Staleness Adaptor schedule.

``HaloState`` carries, per exchange site (one per GNN layer per direction):
  * ``feats[i]`` — the dequantized halo features received during the previous step
  * ``grads[i]`` — the dequantized boundary gradients received during the previous
    step's backward pass (pre-scatter, pairwise-block layout)

Both are ordinary pytree leaves of the training state: they checkpoint, shard
(leading partition axis), and donate like everything else.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .exchange import PlanArrays


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HaloState:
    feats: tuple
    grads: tuple

    def gslots(self):
        """Zero-valued dummies whose gradients carry the fresh outgoing boundary
        gradients out of ``jax.grad`` (see core/sylvie.py)."""
        return tuple(jnp.zeros_like(f) for f in self.feats)

    @staticmethod
    def zeros(plan: PlanArrays, dims: Sequence[int], dtype=jnp.float32,
              stacked_parts: int | None = None) -> "HaloState":
        p = stacked_parts if stacked_parts is not None else plan.n_parts
        rows = plan.halo_rows
        feats = tuple(jnp.zeros((p, rows, d), dtype) for d in dims)
        return HaloState(feats=feats, grads=tuple(jnp.zeros_like(f) for f in feats))

    @staticmethod
    def zeros_spec(plan: PlanArrays, dims: Sequence[int], dtype=jnp.float32,
                   stacked_parts: int | None = None) -> "HaloState":
        """ShapeDtypeStruct version for the dry-run."""
        p = stacked_parts if stacked_parts is not None else plan.n_parts
        rows = plan.halo_rows
        feats = tuple(jax.ShapeDtypeStruct((p, rows, d), dtype) for d in dims)
        return HaloState(feats=feats,
                         grads=tuple(jax.ShapeDtypeStruct(f.shape, f.dtype)
                                     for f in feats))


def use_sync_step(epoch: int, eps_s: int | None) -> bool:
    """Bounded Staleness Adaptor schedule (paper §3.3): one synchronous epoch every
    ``eps_s`` epochs (``None`` = pure Sylvie-A; 1 = always synchronous). Epoch 0 is
    always synchronous — it doubles as the cache warmup.

    The trainer no longer calls this directly: the schedule is owned by the
    ``repro.policy.builtin.BoundedStaleness`` policy (which delegates here —
    this function remains the single definition of the paper's pattern)."""
    if epoch == 0:
        return True
    if eps_s is None:
        return False
    return epoch % eps_s == 0
