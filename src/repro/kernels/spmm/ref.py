"""Pure-jnp oracle for the padded-CSR row-block SpMM aggregation kernel.

Contract (the GNN aggregation hot path, Alg. 1 line 15 / cuSPARSE SpMM in the
paper): for every destination row ``i``

    out[i, :] = sum_s  w[i, s] * table[idx[i, s], :]        (s < max_deg)

``idx``/``w`` are the padded-CSR neighbor lists (padding slots carry w = 0 and
idx pointing at row 0). ``table`` is the concatenated [local ; halo] feature
table. GCN normalization / mean aggregation are expressed through ``w``.
"""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ref(table: jnp.ndarray, idx: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(n_src, d), (n_rows, max_deg) int32, (n_rows, max_deg) -> (n_rows, d)."""
    gathered = table[idx]                                  # (n_rows, max_deg, d)
    return jnp.einsum("rs,rsd->rd", w, gathered.astype(w.dtype))


def csr_from_edges(edges, edge_w, n_rows: int, max_deg: int):
    """Host-side: (E, 2) [src, dst] + per-edge weight -> padded-CSR (idx, w).

    numpy utility used by benchmarks/tests to drive the kernel from the
    runtime's edge-list format.
    """
    import numpy as np
    idx = np.zeros((n_rows, max_deg), dtype=np.int32)
    w = np.zeros((n_rows, max_deg), dtype=np.float32)
    fill = np.zeros(n_rows, dtype=np.int64)
    for (s, dst), ew in zip(edges, edge_w):
        k = fill[dst]
        if k < max_deg:
            idx[dst, k] = s
            w[dst, k] = ew
            fill[dst] = k + 1
    return idx, w
