"""Jit'd wrapper for the Pallas SpMM kernel (interpret mode off-TPU)."""
from __future__ import annotations

import jax

from . import ref as _r
from . import spmm as _k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmm(table, idx, w, **kw):
    return _k.spmm(table, idx, w, interpret=_interpret(), **kw)


spmm_ref = _r.spmm_ref
csr_from_edges = _r.csr_from_edges
