"""Pallas TPU kernel: padded-CSR row-block SpMM (the GNN aggregation hot spot).

The paper leans on cuSPARSE SpMM for aggregation and cites its lack of low-
precision support as a reason to keep *compute* in fp32 (quantizing only the
wire). On TPU there is no cuSPARSE; the TPU-native adaptation is
a gather-accumulate over a padded-CSR neighbor list, tiled so each step works
entirely out of VMEM:

  grid = (row blocks, d blocks, source tiles)
  - the feature ``table`` is tiled along BOTH axes: a (src_tile, d_blk) tile of
    sources × features is resident per step;
  - each row block re-visits its (rows_blk, max_deg) neighbor lists once per
    source tile, accumulating   out += w * table[idx - tile_lo]   for the idx
    that fall inside the tile (mask kills the rest);
  - the d-axis is tiled in multiples of 128 (lane width), rows in sublane
    multiples.

This is the standard TPU SpMM schedule (row-block × src-tile two-level
blocking, as in GE-SpMM adapted to VMEM): HBM traffic is
 O(nnz/row_tiles · src_tiles)  index reads + one pass over the table per
row-block stripe — for the power-law graphs here with locality-aware
partitions, most neighbors land in the diagonal source tile.

Gathers inside the kernel use ``jnp.take`` along the sublane axis of the
VMEM-resident tile, which lowers to the TPU dynamic-gather path (and runs as a
plain gather in interpret mode on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(table_ref, idx_ref, w_ref, out_ref, *, src_tile: int):
    t = pl.program_id(2)
    tile_lo = t * src_tile
    table = table_ref[...]                        # (src_tile, d_blk)
    idx = idx_ref[...]                            # (rows_blk, max_deg)
    w = w_ref[...]                                # (rows_blk, max_deg)
    local = idx - tile_lo
    inside = (local >= 0) & (local < src_tile)
    local = jnp.where(inside, local, 0)
    wm = jnp.where(inside, w, 0.0)
    rows_blk, max_deg = idx.shape
    gathered = jnp.take(table, local.reshape(-1), axis=0)
    gathered = gathered.reshape(rows_blk, max_deg, table.shape[-1])
    acc = jnp.einsum("rs,rsd->rd", wm, gathered,
                     preferred_element_type=jnp.float32)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += acc


def _ceil(a: int, b: int) -> int:
    return (a + b - 1) // b


@functools.partial(jax.jit, static_argnames=("rows_blk", "d_blk", "src_tile",
                                             "interpret"))
def spmm(table: jax.Array, idx: jax.Array, w: jax.Array,
         rows_blk: int = 256, d_blk: int = 128, src_tile: int = 2048,
         interpret: bool = False) -> jax.Array:
    """Padded-CSR SpMM: out[r] = sum_s w[r,s] * table[idx[r,s]].

    table: (n_src, d) f32;  idx: (n_rows, max_deg) int32;  w: (n_rows, max_deg).
    """
    n_src, d = table.shape
    n_rows, max_deg = idx.shape
    rows_blk = min(rows_blk, n_rows)
    d_blk = min(d_blk, d)
    src_tile = min(src_tile, n_src)

    pr = _ceil(n_rows, rows_blk) * rows_blk - n_rows
    pd = _ceil(d, d_blk) * d_blk - d
    ps = _ceil(n_src, src_tile) * src_tile - n_src
    if pr:
        idx = jnp.pad(idx, ((0, pr), (0, 0)))
        w = jnp.pad(w, ((0, pr), (0, 0)))
    if pd or ps:
        table = jnp.pad(table, ((0, ps), (0, pd)))

    grid = (_ceil(n_rows + pr, rows_blk), _ceil(d + pd, d_blk),
            _ceil(n_src + ps, src_tile))
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, src_tile=src_tile),
        grid=grid,
        in_specs=[pl.BlockSpec((src_tile, d_blk), lambda i, j, t: (t, j)),
                  pl.BlockSpec((rows_blk, max_deg), lambda i, j, t: (i, 0)),
                  pl.BlockSpec((rows_blk, max_deg), lambda i, j, t: (i, 0))],
        out_specs=pl.BlockSpec((rows_blk, d_blk), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows + pr, d + pd), jnp.float32),
        interpret=interpret,
    )(table, idx, w)
    return out[:n_rows, :d]
