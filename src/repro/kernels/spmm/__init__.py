from . import ops, ref, spmm  # noqa: F401
