from . import flash, quant, spmm  # noqa: F401
