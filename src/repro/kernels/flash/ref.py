"""Pure-jnp oracle for the flash-attention forward kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_ref(q, k, v, *, causal: bool = True, scale: float = 1.0,
              window: int | None = None) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BH, Skv, D) -> (BH, Sq, D). O(S^2) reference."""
    logits = jnp.einsum("bqd,bkd->bqk", q * scale, k).astype(jnp.float32)
    sq, skv = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= qp - kp < window
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
