from . import flash, ops, ref  # noqa: F401
