"""Pallas TPU flash-attention forward (identified §Perf next-lever).

The dry-run's dominant LM memory term is the per-block f32 score tensors the
XLA path materializes to HBM. This kernel keeps each
(blk_q x blk_k) score tile in VMEM: per (batch-head, q-block) it sweeps KV
blocks on the innermost sequential grid axis, carrying the online-softmax
running (max, sum) and the output accumulator in the output refs — HBM sees
q/k/v exactly once plus one (Sq, D) output write.

Tiling: grid = (BH, Sq/blk_q, Skv/blk_k); the KV axis is the innermost
(sequential on TPU) so accumulation across it is race-free — same schedule as
kernels/spmm. blk sizes default to 128 x 128 (MXU-aligned); VMEM per step =
q tile + k/v tiles + score tile ~= (3*blk*D + blk^2) * 4B << 16 MB for
D <= 256. Causal q-blocks that lie entirely below the diagonal skip work via
``pl.when`` (the classic flash causal-block skip).

Normalization (acc / l) happens in the ops.py wrapper — keeping the kernel's
outputs (acc, m, l) raw makes the oracle comparison exact and the backward
(future work) reusable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  blk_q: int, blk_k: int, scale: float, causal: bool,
                  window, kv_len: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    q_lo = iq * blk_q
    k_lo = jk * blk_k

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: this kv block starts after the last q row
    run = True
    if causal:
        run = k_lo <= q_lo + blk_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale        # (blk_q, d)
        k = k_ref[0].astype(jnp.float32)                # (blk_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                     # (blk_q, blk_k) VMEM
        qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kp < kv_len                  # padded kv columns never win
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum(-1)
        acc_ref[0] = acc_ref[0] * corr[:, None] + p @ v
        m_ref[0] = m_new


@functools.partial(jax.jit, static_argnames=("blk_q", "blk_k", "causal",
                                             "scale", "window", "interpret"))
def flash_fwd(q, k, v, *, blk_q: int = 128, blk_k: int = 128,
              causal: bool = True, scale: float = 1.0, window=None,
              interpret: bool = False):
    """(BH, Sq, D) x (BH, Skv, D) -> (acc, m, l); out = acc / l."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    pq = (sq + blk_q - 1) // blk_q * blk_q - sq
    pk = (skv + blk_k - 1) // blk_k * blk_k - skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # padded kv columns must never win the max: rely on the causal/window
        # mask plus an explicit kv_len mask via window... simplest: pad k with
        # zeros and mask by position below
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    grid = (bh, (sq + pq) // blk_q, (skv + pk) // blk_k)

    kern = functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                             scale=scale, causal=causal, window=window,
                             kv_len=skv)
    acc, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0))],
        out_specs=(pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
                   pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
                   pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i))),
        out_shape=(jax.ShapeDtypeStruct((bh, sq + pq, d), jnp.float32),
                   jax.ShapeDtypeStruct((bh, sq + pq), jnp.float32),
                   jax.ShapeDtypeStruct((bh, sq + pq), jnp.float32)),
        interpret=interpret,
    )(q, k, v)
    return acc[:, :sq], m[:, :sq], l[:, :sq]
