"""Jit'd wrapper: normalized flash attention (interpret mode off-TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flash as _k
from . import ref as _r


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, scale: float = 1.0,
                    window=None, **kw):
    """q/k/v: (BH, S, D) -> (BH, Sq, D), numerically safe normalization."""
    acc, m, l = _k.flash_fwd(q, k, v, causal=causal, scale=scale,
                             window=window, interpret=_interpret(), **kw)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


flash_ref = _r.flash_ref
