"""Jit'd wrapper tying the Pallas quant kernels to the Sylvie runtime contract.

``quantize_pack_rows`` / ``dequantize_rows`` mirror ``repro.core.quantization``'s
(data, scale, zero) triple for the packable bit-widths {1, 2, 4, 8}; they are
the entry points ``core.quantization`` dispatches to (``impl="pallas"``). On a
CPU backend the wrappers run interpret mode automatically (TPU executes the
compiled kernel); correctness vs ``ref.py`` and vs ``core.quantization`` is
enforced in tests/test_kernels.py.
"""
from __future__ import annotations

import jax

from . import quant as _k
from . import ref as _r


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_pack_rows(h: jax.Array, u: jax.Array, bits: int = 1):
    """(rows, d) float + (rows, d) uniform[0,1) noise -> (packed uint8,
    scale f32, zero f32). The noise is caller-supplied so the dispatch seam in
    ``core.quantization`` draws it identically for both impls — the packed
    payload is bit-identical to the jnp path given one PRNG key."""
    return _k.quantize_pack(h, u, bits=bits, interpret=_interpret())


def dequantize_rows(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int, d: int) -> jax.Array:
    return _k.unpack_dequantize(packed, scale, zero, bits, d,
                                interpret=_interpret())


quantize_pack_rows_ref = _r.quantize_pack_ref
dequantize_rows_ref = _r.unpack_dequantize_ref
