"""Jit'd wrapper tying the Pallas quant kernels to the Sylvie runtime contract.

``quantize_rows`` / ``dequantize_rows`` mirror ``repro.core.quantization``'s
(data, scale, zero) triple for the packable bit-widths {1, 2, 4, 8}. On a CPU
backend the wrappers run interpret mode automatically (TPU executes the
compiled kernel); correctness vs ``ref.py`` and vs ``core.quantization`` is
enforced in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quant as _k
from . import ref as _r


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_rows(h: jax.Array, key: jax.Array, bits: int = 1):
    """(rows, d) float -> (packed uint8, scale f32, zero f32), stochastic rounding."""
    u = jax.random.uniform(key, h.shape, jnp.float32)
    return _k.quantize_pack(h, u, bits=bits, interpret=_interpret())


def dequantize_rows(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int, d: int) -> jax.Array:
    return _k.unpack_dequantize(packed, scale, zero, bits, d,
                                interpret=_interpret())


def quantize_rows_ref(h, key, bits: int = 1):
    u = jax.random.uniform(key, h.shape, jnp.float32)
    return _r.quantize_pack_ref(h, u, bits)


dequantize_rows_ref = _r.unpack_dequantize_ref
