"""Pure-jnp oracle for the fused quantize+bitpack / unpack+dequantize kernels.

Semantically identical to ``repro.core.quantization`` but with the kernel's exact
I/O contract (flat 2-D buffers, uniform noise passed in explicitly) so the Pallas
kernel can be validated bit-exactly in interpret mode.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lanes_per_byte(bits: int) -> int:
    assert bits in (1, 2, 4, 8)
    return 8 // bits


def packed_width(d: int, bits: int) -> int:
    k = lanes_per_byte(bits)
    return (d + k - 1) // k


def quantize_pack_ref(h: jnp.ndarray, u: jnp.ndarray, bits: int):
    """(rows, d) float32, (rows, d) uniform[0,1) -> (packed uint8, scale, zero).

    Per-row affine quantization (paper Equ. 3) with stochastic rounding (Equ. 4),
    packed 8//bits lanes per byte little-endian within the byte.
    """
    rows, d = h.shape
    big = np.float32(2.0**bits - 1.0)
    lo = jnp.min(h, axis=-1, keepdims=True)
    hi = jnp.max(h, axis=-1, keepdims=True)
    rng = hi - lo
    safe = jnp.where(rng > 0, rng, 1.0)
    hbar = (h - lo) / safe * big
    floor = jnp.floor(hbar)
    q = floor + (u < (hbar - floor)).astype(jnp.float32)
    q = jnp.clip(q, 0.0, big).astype(jnp.uint8)

    k = lanes_per_byte(bits)
    pad = (-d) % k
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    grouped = q.reshape(rows, -1, k)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    packed = jnp.bitwise_or.reduce(grouped << shifts, axis=-1).astype(jnp.uint8)
    scale = (rng[:, 0] / big).astype(jnp.float32)
    zero = lo[:, 0].astype(jnp.float32)
    return packed, scale, zero


def unpack_dequantize_ref(packed: jnp.ndarray, scale: jnp.ndarray,
                          zero: jnp.ndarray, bits: int, d: int) -> jnp.ndarray:
    """(rows, packed_width) uint8 + per-row (scale, zero) -> (rows, d) float32."""
    k = lanes_per_byte(bits)
    mask = np.uint8((1 << bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    vals = (packed[:, :, None] >> shifts) & mask
    vals = vals.reshape(packed.shape[0], -1)[:, :d].astype(jnp.float32)
    return vals * scale[:, None] + zero[:, None]
