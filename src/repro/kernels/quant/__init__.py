from . import ops, quant, ref  # noqa: F401
