"""Pallas TPU kernels: fused b-bit quantize+bitpack and unpack+dequantize.

The paper's Low-bit Module sits on the critical path of *every* layer (its §4.4
overhead analysis shows it must stay far below the communication savings). On
GPU Sylvie uses a CUDA kernel; on TPU we fuse the whole pipeline —

    per-row min/max reduce -> affine scale -> stochastic round -> bit-pack

— into one VMEM pass so the boundary buffer is read from HBM exactly once and
the packed payload written once (arithmetic intensity is tiny; the kernel is
HBM-bandwidth-bound, so one pass is the roofline).

Tiling: grid over row blocks. Each invocation holds a ``(block_rows, d)`` tile
of the send buffer plus the same-shape uniform-noise tile in VMEM, and emits a
``(block_rows, d // lanes)`` uint8 tile plus per-row ``(scale, zero)``. ``d`` is
the feature width of one GNN layer (32-1433 here) so a tile is <= a few hundred
KB — far under the ~16 MB VMEM budget; ``block_rows`` defaults to 256 rows to
keep the sublane dimension busy.

Stochastic-rounding noise is passed in as a uniform tensor generated with
``jax.random.uniform`` outside the kernel (counter-based, reproducible across
restarts) rather than via ``pltpu.prng_random_bits`` — keeping the kernel a
pure function of its inputs lets interpret-mode CPU validation be bit-exact
against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _quantize_kernel(h_ref, u_ref, packed_ref, scale_ref, zero_ref, *,
                     bits: int, d: int):
    h = h_ref[...].astype(jnp.float32)              # (br, d)
    u = u_ref[...]
    big = np.float32(2.0**bits - 1.0)
    lo = jnp.min(h, axis=-1, keepdims=True)
    hi = jnp.max(h, axis=-1, keepdims=True)
    rng = hi - lo
    safe = jnp.where(rng > 0, rng, 1.0)
    hbar = (h - lo) / safe * big
    floor = jnp.floor(hbar)
    q = floor + (u < (hbar - floor)).astype(jnp.float32)
    q = jnp.clip(q, 0.0, big).astype(jnp.uint8)

    k = 8 // bits
    pad = (-d) % k
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    grouped = q.reshape(q.shape[0], -1, k)          # (br, w, k)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    shifted = grouped << shifts                     # or-reduce over lane group
    packed_ref[...] = jax.lax.reduce(
        shifted, np.uint8(0), jax.lax.bitwise_or, dimensions=(2,))
    scale_ref[...] = (rng[:, 0] / big).astype(jnp.float32)
    zero_ref[...] = lo[:, 0].astype(jnp.float32)


def _dequantize_kernel(packed_ref, scale_ref, zero_ref, out_ref, *,
                       bits: int, d: int):
    packed = packed_ref[...]                        # (br, w) uint8
    k = 8 // bits
    mask = np.uint8((1 << bits) - 1)
    shifts = (jnp.arange(k, dtype=jnp.uint8) * np.uint8(bits)).astype(jnp.uint8)
    vals = (packed[:, :, None] >> shifts) & mask    # (br, w, k)
    vals = vals.reshape(packed.shape[0], -1)[:, :d].astype(jnp.float32)
    out_ref[...] = vals * scale_ref[...][:, None] + zero_ref[...][:, None]


def _grid(rows: int, block_rows: int) -> tuple[int, int]:
    br = min(block_rows, rows)
    return (rows + br - 1) // br, br


@functools.partial(jax.jit, static_argnames=("bits", "block_rows", "interpret"))
def quantize_pack(h: jax.Array, u: jax.Array, bits: int = 1,
                  block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False):
    """(rows, d) -> (packed (rows, d//lanes) uint8, scale (rows,), zero (rows,))."""
    rows, d = h.shape
    n_blocks, br = _grid(rows, block_rows)
    pad = n_blocks * br - rows
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    w = (d + (8 // bits) - 1) // (8 // bits)
    out_shapes = (
        jax.ShapeDtypeStruct((n_blocks * br, w), jnp.uint8),
        jax.ShapeDtypeStruct((n_blocks * br,), jnp.float32),
        jax.ShapeDtypeStruct((n_blocks * br,), jnp.float32),
    )
    packed, scale, zero = pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, d=d),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((br, w), lambda i: (i, 0)),
                   pl.BlockSpec((br,), lambda i: (i,)),
                   pl.BlockSpec((br,), lambda i: (i,))),
        out_shape=out_shapes,
        interpret=interpret,
    )(h, u)
    return packed[:rows], scale[:rows], zero[:rows]


@functools.partial(jax.jit, static_argnames=("bits", "d", "block_rows", "interpret"))
def unpack_dequantize(packed: jax.Array, scale: jax.Array, zero: jax.Array,
                      bits: int, d: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False) -> jax.Array:
    """(rows, d//lanes) uint8 + (rows,) scale/zero -> (rows, d) float32."""
    rows, w = packed.shape
    n_blocks, br = _grid(rows, block_rows)
    pad = n_blocks * br - rows
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, (0, pad))
        zero = jnp.pad(zero, (0, pad))
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits, d=d),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((br, w), lambda i: (i, 0)),
                  pl.BlockSpec((br,), lambda i: (i,)),
                  pl.BlockSpec((br,), lambda i: (i,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * br, d), jnp.float32),
        interpret=interpret,
    )(packed, scale, zero)
    return out[:rows]
