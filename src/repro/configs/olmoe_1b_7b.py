"""olmoe-1b-7b [arXiv:2409.02060]: 16L GQA + 64-expert top-8 MoE."""
from ..models.lm.config import (AttnConfig, LayerConfig, LMConfig, MoEConfig,
                                Segment)
from .base import ArchSpec, LM_SHAPES


def config() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=16, n_kv_heads=16, d_head=128,
                      rope_theta=10000.0)
    moe = MoEConfig(n_experts=64, top_k=8, d_ff=1024)
    return LMConfig(
        name="olmoe-1b-7b", d_model=2048, vocab=50304,
        segments=(Segment(16, (LayerConfig(attn, moe=moe),)),),
        tie_embeddings=False, max_seq=524288)


def reduced() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, d_head=16)
    moe = MoEConfig(n_experts=8, top_k=2, d_ff=96)
    return LMConfig(name="olmoe-smoke", d_model=64, vocab=173,
                    segments=(Segment(2, (LayerConfig(attn, moe=moe),)),),
                    tie_embeddings=False)


SPEC = ArchSpec("olmoe-1b-7b", "lm", "arXiv:2409.02060; hf", config, reduced,
                LM_SHAPES, notes="expert-parallel over the model axis")
