"""Config schema shared by all architecture entries.

Every ``src/repro/configs/<id>.py`` exports ``SPEC: ArchSpec`` with the exact
published configuration, a reduced same-family smoke config, and its assigned
input-shape set. ``kind`` selects the runtime (GNN partition-parallel runtime,
LM GSPMD runtime, DLRM shard_map runtime) and which step each shape lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    step: str                      # train | prefill | decode | serve | retrieval
    params: Mapping[str, Any]      # shape-specific sizes (seq_len, batch, ...)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    kind: str                      # "lm" | "gnn" | "recsys"
    source: str                    # citation tag from the assignment
    config: Callable[[], Any]      # full published config
    reduced: Callable[[], Any]     # small same-family config for CPU smoke tests
    shapes: tuple[ShapeCell, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeCell:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}")


LM_SHAPES = (
    ShapeCell("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeCell("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeCell("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeCell("minibatch_lg", "train",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout=(15, 10), d_feat=602)),
    ShapeCell("ogb_products", "train",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeCell("molecule", "train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", dict(batch=65536)),
    ShapeCell("serve_p99", "serve", dict(batch=512)),
    ShapeCell("serve_bulk", "serve", dict(batch=262144)),
    ShapeCell("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1000000)),
)
