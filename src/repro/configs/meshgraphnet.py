"""meshgraphnet [arXiv:2010.03409]: 15 processor layers, d=128, sum agg."""
from ..models.gnn.models import MeshGraphNet
from .base import ArchSpec, GNN_SHAPES
from .gnn_common import GNNArch


def config() -> GNNArch:
    return GNNArch(
        "meshgraphnet",
        make=lambda d_in, d_out: MeshGraphNet(d_in=d_in, d_out=d_out,
                                              d_hidden=128, n_layers=15,
                                              mlp_layers=2),
        d_edge_attr=13, needs_weights=False)


def reduced() -> GNNArch:
    return GNNArch(
        "meshgraphnet-smoke",
        make=lambda d_in, d_out: MeshGraphNet(d_in=d_in, d_out=d_out,
                                              d_hidden=24, n_layers=3,
                                              mlp_layers=2),
        d_edge_attr=13, needs_weights=False)


SPEC = ArchSpec("meshgraphnet", "gnn", "arXiv:2010.03409; unverified", config,
                reduced, GNN_SHAPES)
