"""Shared GNN arch descriptor: how to build the model + what the block needs."""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class GNNArch:
    name: str
    make: Callable[[int, int], object]   # (d_in, d_out) -> model
    d_edge_attr: int = 0                 # 0 = no geometry; 13 = dist+unit+sh(l<=2)
    needs_weights: bool = True           # GCN-normalized A+I edge weights
