"""The paper's own evaluation models: GCN, GraphSAGE, GAT (Sylvie §4).

These are not in the assigned-architecture pool but are the models every
paper-reproduction benchmark (Tables 2-4, Figs 1/5-10) trains.
"""
from ..models.gnn.models import GAT, GCN, GraphSAGE
from .base import ArchSpec, GNN_SHAPES
from .gnn_common import GNNArch


def _make(name, ctor, **kw):
    def config() -> GNNArch:
        return GNNArch(name, make=lambda d_in, d_out: ctor(
            d_in=d_in, d_out=d_out, **kw))

    def reduced() -> GNNArch:
        small = dict(kw)
        small["d_hidden"] = 16
        small["n_layers"] = 2
        return GNNArch(name + "-smoke", make=lambda d_in, d_out: ctor(
            d_in=d_in, d_out=d_out, **small))

    return ArchSpec(name, "gnn", "paper (Sylvie §4)", config, reduced,
                    GNN_SHAPES)


GCN_SPEC = _make("gcn", GCN, d_hidden=256, n_layers=2)
SAGE_SPEC = _make("graphsage", GraphSAGE, d_hidden=256, n_layers=2)
GAT_SPEC = _make("gat", GAT, d_hidden=64, n_layers=2, heads=4)
