"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM (Criteo 1TB), 26 sparse + 13
dense features, dim-128 tables, bot 13-512-256-128, top 1024-1024-512-256-1,
dot interaction."""
from ..models.recsys.dlrm import CRITEO_TABLE_SIZES, DLRMConfig
from .base import ArchSpec, RECSYS_SHAPES


def config() -> DLRMConfig:
    return DLRMConfig(n_dense=13, embed_dim=128,
                      table_sizes=CRITEO_TABLE_SIZES,
                      bot_mlp=(512, 256, 128),
                      top_mlp=(1024, 1024, 512, 256, 1), hot=1)


def reduced() -> DLRMConfig:
    return DLRMConfig(n_dense=13, embed_dim=16,
                      table_sizes=(64, 32, 100, 16, 48, 8),
                      bot_mlp=(32, 16), top_mlp=(64, 32, 1),
                      hot=(2, 1, 1, 3, 1, 1))


SPEC = ArchSpec("dlrm-mlperf", "recsys", "arXiv:1906.00091; paper", config,
                reduced, RECSYS_SHAPES,
                notes="row-sharded tables + psum_scatter embedding exchange; "
                      "Sylvie Low-bit Module optionally quantizes the exchange")
