"""nequip [arXiv:2101.03164]: E(3)-equivariant, 5 layers, mul=32, l_max=2,
8 RBF, cutoff 5."""
from ..models.gnn.nequip import NequIP
from .base import ArchSpec, GNN_SHAPES
from .gnn_common import GNNArch


def config() -> GNNArch:
    return GNNArch(
        "nequip",
        make=lambda d_in, d_out: NequIP(d_in=d_in, d_out=d_out, mul=32,
                                        n_layers=5, l_max=2, n_rbf=8,
                                        cutoff=5.0),
        d_edge_attr=13, needs_weights=False)


def reduced() -> GNNArch:
    return GNNArch(
        "nequip-smoke",
        make=lambda d_in, d_out: NequIP(d_in=d_in, d_out=d_out, mul=4,
                                        n_layers=2, l_max=2, n_rbf=4,
                                        cutoff=3.0),
        d_edge_attr=13, needs_weights=False)


SPEC = ArchSpec("nequip", "gnn", "arXiv:2101.03164; paper", config, reduced,
                GNN_SHAPES,
                notes="halo wire format = flat irrep features (32x0e+32x1o+32x2e)")
