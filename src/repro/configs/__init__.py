"""Architecture registry: ``--arch <id>`` resolution for launch/ and tests."""
from __future__ import annotations

from . import (deepseek_v2_236b, dlrm_mlperf, gemma2_27b, granite_3_2b,
               meshgraphnet, nequip, olmoe_1b_7b, paper_gnn, pna, schnet,
               yi_34b)
from .base import ArchSpec, ShapeCell  # noqa: F401

REGISTRY: dict[str, ArchSpec] = {
    s.arch_id: s for s in (
        granite_3_2b.SPEC, gemma2_27b.SPEC, yi_34b.SPEC, olmoe_1b_7b.SPEC,
        deepseek_v2_236b.SPEC,
        nequip.SPEC, schnet.SPEC, meshgraphnet.SPEC, pna.SPEC,
        dlrm_mlperf.SPEC,
        paper_gnn.GCN_SPEC, paper_gnn.SAGE_SPEC, paper_gnn.GAT_SPEC,
    )
}

ASSIGNED = ("granite-3-2b", "gemma2-27b", "yi-34b", "olmoe-1b-7b",
            "deepseek-v2-236b", "nequip", "schnet", "meshgraphnet", "pna",
            "dlrm-mlperf")


def get(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
