"""pna [arXiv:2004.05718]: 4 layers, d=75, mean/max/min/std aggregators,
identity/amplification/attenuation scalers."""
from ..models.gnn.models import PNA
from .base import ArchSpec, GNN_SHAPES
from .gnn_common import GNNArch


def config() -> GNNArch:
    return GNNArch(
        "pna",
        make=lambda d_in, d_out: PNA(d_in=d_in, d_out=d_out, d_hidden=75,
                                     n_layers=4),
        d_edge_attr=0, needs_weights=False)


def reduced() -> GNNArch:
    return GNNArch(
        "pna-smoke",
        make=lambda d_in, d_out: PNA(d_in=d_in, d_out=d_out, d_hidden=16,
                                     n_layers=2),
        d_edge_attr=0, needs_weights=False)


SPEC = ArchSpec("pna", "gnn", "arXiv:2004.05718; paper", config, reduced,
                GNN_SHAPES)
