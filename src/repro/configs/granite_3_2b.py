"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: 40L GQA dense LM."""
from ..models.lm.config import AttnConfig, LayerConfig, LMConfig, Segment
from .base import ArchSpec, LM_SHAPES


def config() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=32, n_kv_heads=8, d_head=64,
                      rope_theta=10000.0)
    return LMConfig(
        name="granite-3-2b", d_model=2048, vocab=49155,
        segments=(Segment(40, (LayerConfig(attn, d_ff=8192),)),),
        tie_embeddings=True, max_seq=524288)


def reduced() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16)
    return LMConfig(name="granite-3-2b-smoke", d_model=64, vocab=211,
                    segments=(Segment(3, (LayerConfig(attn, d_ff=256),)),),
                    tie_embeddings=True)


SPEC = ArchSpec("granite-3-2b", "lm", "hf:ibm-granite/granite-3.0-2b-base; hf",
                config, reduced, LM_SHAPES)
