"""yi-34b [arXiv:2403.04652]: llama-architecture 60L GQA dense LM."""
from ..models.lm.config import AttnConfig, LayerConfig, LMConfig, Segment
from .base import ArchSpec, LM_SHAPES


def config() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=56, n_kv_heads=8, d_head=128,
                      rope_theta=5000000.0)
    return LMConfig(
        name="yi-34b", d_model=7168, vocab=64000,
        segments=(Segment(60, (LayerConfig(attn, d_ff=20480),)),),
        tie_embeddings=False, max_seq=524288)


def reduced() -> LMConfig:
    attn = AttnConfig(kind="gqa", n_heads=8, n_kv_heads=2, d_head=8)
    return LMConfig(name="yi-34b-smoke", d_model=64, vocab=199,
                    segments=(Segment(3, (LayerConfig(attn, d_ff=192),)),),
                    tie_embeddings=False)


SPEC = ArchSpec("yi-34b", "lm", "arXiv:2403.04652; hf", config, reduced,
                LM_SHAPES)
