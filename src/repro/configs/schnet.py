"""schnet [arXiv:1706.08566]: 3 interactions, d=64, 300 RBF, cutoff 10."""
from ..models.gnn.models import SchNet
from .base import ArchSpec, GNN_SHAPES
from .gnn_common import GNNArch


def config() -> GNNArch:
    return GNNArch(
        "schnet",
        make=lambda d_in, d_out: SchNet(d_in=d_in, d_out=d_out, d_hidden=64,
                                        n_interactions=3, n_rbf=300,
                                        cutoff=10.0),
        d_edge_attr=13, needs_weights=False)


def reduced() -> GNNArch:
    return GNNArch(
        "schnet-smoke",
        make=lambda d_in, d_out: SchNet(d_in=d_in, d_out=d_out, d_hidden=16,
                                        n_interactions=2, n_rbf=8, cutoff=3.0),
        d_edge_attr=13, needs_weights=False)


SPEC = ArchSpec("schnet", "gnn", "arXiv:1706.08566; paper", config, reduced,
                GNN_SHAPES)
