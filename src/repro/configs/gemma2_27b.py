"""gemma2-27b [arXiv:2408.00118]: alternating local(4096)/global GQA layers,
attention-logit + final-logit softcaps, sandwich (pre+post) norms."""
from ..models.lm.config import AttnConfig, LayerConfig, LMConfig, Segment
from .base import ArchSpec, LM_SHAPES


def config() -> LMConfig:
    common = dict(kind="gqa", n_heads=32, n_kv_heads=16, d_head=128,
                  rope_theta=10000.0, softcap=50.0)
    local = AttnConfig(window=4096, **common)
    glob = AttnConfig(window=None, **common)
    layer = dict(d_ff=36864, post_norm=True, act="gelu")
    return LMConfig(
        name="gemma2-27b", d_model=4608, vocab=256000,
        segments=(Segment(23, (LayerConfig(local, **layer),
                               LayerConfig(glob, **layer))),),
        logit_softcap=30.0, tie_embeddings=True, embed_scale=True,
        max_seq=524288)


def reduced() -> LMConfig:
    common = dict(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16, softcap=50.0)
    local = AttnConfig(window=8, **common)
    glob = AttnConfig(window=None, **common)
    return LMConfig(
        name="gemma2-27b-smoke", d_model=64, vocab=223,
        segments=(Segment(2, (LayerConfig(local, d_ff=192, post_norm=True),
                              LayerConfig(glob, d_ff=192, post_norm=True))),),
        logit_softcap=30.0, tie_embeddings=True, embed_scale=True)


SPEC = ArchSpec("gemma2-27b", "lm", "arXiv:2408.00118; hf", config, reduced,
                LM_SHAPES,
                notes="local layers ring-buffer their KV cache at window=4096")
