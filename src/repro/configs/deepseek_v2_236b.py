"""deepseek-v2-236b [arXiv:2405.04434]: MLA (kv_lora 512, decoupled RoPE 64) +
2-shared/160-routed top-6 MoE; first layer dense (d_ff 12288)."""
from ..models.lm.config import (AttnConfig, LayerConfig, LMConfig, MoEConfig,
                                Segment)
from .base import ArchSpec, LM_SHAPES


def config() -> LMConfig:
    mla = AttnConfig(kind="mla", n_heads=128, n_kv_heads=128,
                     rope_theta=10000.0, q_lora=1536, kv_lora=512,
                     d_rope=64, d_nope=128, d_v=128)
    moe = MoEConfig(n_experts=160, top_k=6, d_ff=1536,
                    n_shared=2, d_ff_shared=3072)
    return LMConfig(
        name="deepseek-v2-236b", d_model=5120, vocab=102400,
        segments=(Segment(1, (LayerConfig(mla, d_ff=12288),)),
                  Segment(59, (LayerConfig(mla, moe=moe),))),
        tie_embeddings=False, max_seq=524288)


def reduced() -> LMConfig:
    mla = AttnConfig(kind="mla", n_heads=4, n_kv_heads=4, q_lora=48,
                     kv_lora=32, d_rope=8, d_nope=16, d_v=16)
    moe = MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1, d_ff_shared=96)
    return LMConfig(
        name="deepseek-v2-smoke", d_model=64, vocab=151,
        segments=(Segment(1, (LayerConfig(mla, d_ff=128),)),
                  Segment(2, (LayerConfig(mla, moe=moe),))),
        tie_embeddings=False)


SPEC = ArchSpec("deepseek-v2-236b", "lm", "arXiv:2405.04434; hf", config,
                reduced, LM_SHAPES,
                notes="MLA compressed-latent cache makes long_500k cheapest")
