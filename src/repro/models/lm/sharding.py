"""GSPMD sharding rules for the LM stack (FSDP over data/pod, TP over model).

Parameter rules (2-D weights listed as (in, out)):
  embed (V, d)            -> P(mdl, fsdp)        vocab-sharded table
  wq (d, H*Dh)            -> P(fsdp, mdl)        head-sharded TP
  wk/wv (d, Hkv*Dh)       -> P(fsdp, mdl) if n_kv %% tp == 0 else P(fsdp, None)
                             (n_kv < tp would split inside a head; replicating
                              the small KV projections is the MaxText choice)
  wo (H*Dh, d)            -> P(mdl, fsdp)
  MLA: down-projections replicated on the lora dim, up-projections head-sharded
  ffn gate/up (d, f)      -> P(fsdp, mdl);  down (f, d) -> P(mdl, fsdp)
  MoE experts (E, d, f)   -> P(mdl, fsdp, None)  expert-parallel over TP axis
  norms                   -> replicated

Scanned segments carry a leading ``count`` axis -> ``None`` prepended.

KV caches shard the *sequence* axis over the model axis (decode): attention's
max/sum reductions over S then lower to partial-reduce + all-reduce — the
flash-decoding split, derived by GSPMD instead of hand-written collectives.
``long_500k`` (batch=1) spreads S over the whole mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .config import LMConfig


def axes(mesh) -> tuple:
    """(fsdp_axes, model_axis) from mesh axis names."""
    names = mesh.axis_names
    fsdp = tuple(n for n in names if n != "model")
    return fsdp, "model"


def _param_spec(path, leaf, cfg: LMConfig, fsdp, mdl, tp: int):
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    scanned = any(k.startswith("seg") for k in keys)

    def wrap(*spec):
        return P(*((None,) + spec if scanned else spec))

    if name in ("ln_attn", "ln_ffn", "ln_attn_post", "ln_ffn_post", "ln_final",
                "kv_norm", "q_norm"):
        return wrap(None)
    if name == "embed":
        return P(mdl, fsdp)
    if name == "unembed":
        return P(fsdp, mdl)
    if name == "wq" or name == "q_b" or name == "kv_b":
        return wrap(None if name != "wq" else fsdp, mdl)
    if name in ("wk", "wv"):
        n_kv = next(lc.attn.n_kv_heads for _, _, lc, _ in cfg.sub_layers())
        return wrap(fsdp, mdl if n_kv % tp == 0 else None)
    if name == "wo":
        return wrap(mdl, fsdp)
    if name in ("q_a", "kv_a"):
        return wrap(fsdp, None)
    if name == "router":
        return wrap(fsdp, None)
    if name in ("e_gate", "e_up"):
        # FSDP-only (§Perf A3): mdl-sharded expert weights force buffer-sized
        # gradient all-reduces across the model axis in the backward pass
        # (d(buf) sums contributions from every expert shard). Weight-sized
        # all-gathers over fsdp are orders of magnitude smaller.
        return wrap(None, fsdp, None)
    if name == "e_down":
        return wrap(None, None, fsdp)
    if name in ("gate", "up"):
        return wrap(fsdp, mdl)
    if name == "down":
        return wrap(mdl, fsdp)
    raise ValueError(f"no sharding rule for param {'/'.join(keys)}")


def param_specs(params_shape, cfg: LMConfig, mesh):
    fsdp, mdl = axes(mesh)
    tp = mesh.shape[mdl]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(path, leaf, cfg, fsdp, mdl, tp),
        params_shape)


def opt_specs(opt_state_shape, params_specs):
    """Adam m/v mirror the param specs; scalars replicate."""
    def spec_for(leaf):
        return P() if getattr(leaf, "ndim", 0) == 0 else None
    m = params_specs
    return {"m": m, "v": m, "t": P()} if isinstance(opt_state_shape, dict) \
        else jax.tree_util.tree_map(spec_for, opt_state_shape)


def cache_specs(cache_shape, mesh, batch: int):
    """(count, B, S, ...) caches: B over fsdp when it shards, S over model
    (and over everything when B == 1)."""
    fsdp, mdl = axes(mesh)
    fsdp_size = 1
    for a in fsdp:
        fsdp_size *= mesh.shape[a]

    def spec(leaf):
        nd = len(leaf.shape)
        if batch % fsdp_size == 0 and batch > 1:
            s = (None, fsdp, mdl) + (None,) * (nd - 3)
        else:
            s = (None, None, fsdp + (mdl,)) + (None,) * (nd - 3)
        return P(*s)

    return jax.tree_util.tree_map(spec, cache_shape)


def data_spec(mesh) -> P:
    fsdp, _ = axes(mesh)
    return P(fsdp, None)
