from . import config, model, sharding  # noqa: F401
