"""LM architecture configs: GQA/MLA attention, dense/MoE FFN, layer segments.

A model is a sequence of *segments*; each segment scans ``count`` repetitions of
a tuple of sub-layer configs (e.g. Gemma-2 = 23 x (local, global)). All five
assigned LM architectures are expressible in this schema (see repro/configs/).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    kind: str = "gqa"                  # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 64
    rope_theta: float = 10000.0
    window: Optional[int] = None       # sliding-window (local) attention
    softcap: Optional[float] = None    # attention-logit softcap (Gemma-2)
    # MLA (DeepSeek-V2):
    q_lora: int = 0
    kv_lora: int = 512
    d_rope: int = 64
    d_nope: int = 128
    d_v: int = 128

    @property
    def q_out(self) -> int:
        if self.kind == "mla":
            return self.n_heads * (self.d_nope + self.d_rope)
        return self.n_heads * self.d_head

    @property
    def kv_cache_width(self) -> int:
        """Per-token KV cache floats (both K and V; MLA = compressed latent)."""
        if self.kind == "mla":
            return self.kv_lora + self.d_rope
        return 2 * self.n_kv_heads * self.d_head


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                          # per-expert hidden
    n_shared: int = 0
    d_ff_shared: int = 0               # total shared-expert hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LayerConfig:
    attn: AttnConfig
    d_ff: int = 0                      # dense (gated) FFN hidden; 0 if MoE
    moe: Optional[MoEConfig] = None
    post_norm: bool = False            # Gemma-2 pre+post sandwich norms
    act: str = "silu"                  # "silu" | "gelu"


@dataclasses.dataclass(frozen=True)
class Segment:
    count: int
    layers: Tuple[LayerConfig, ...]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    vocab: int
    segments: Tuple[Segment, ...]
    norm_eps: float = 1e-6
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = True
    embed_scale: bool = False          # Gemma: scale embeddings by sqrt(d)
    max_seq: int = 8192

    @property
    def n_layers(self) -> int:
        return sum(s.count * len(s.layers) for s in self.segments)

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab axis
        shards evenly over any TP degree <= 256 (logits are sliced back)."""
        return (self.vocab + 255) // 256 * 256

    def sub_layers(self):
        """Yield (segment_idx, layer_cfg, repeat_count) for every sub-layer."""
        for si, seg in enumerate(self.segments):
            for li, lc in enumerate(seg.layers):
                yield si, li, lc, seg.count

    # ---- parameter / FLOP accounting (roofline MODEL_FLOPS) -----------------
    def _attn_params(self, a: AttnConfig) -> int:
        d = self.d_model
        if a.kind == "mla":
            p = 0
            dq = a.q_lora or d
            if a.q_lora:
                p += d * a.q_lora
            p += dq * a.n_heads * (a.d_nope + a.d_rope)      # q up
            p += d * a.kv_lora + d * a.d_rope                # kv down + k_rope
            p += a.kv_lora * a.n_heads * (a.d_nope + a.d_v)  # kv up
            p += a.n_heads * a.d_v * d                       # out
            return p
        return d * a.n_heads * a.d_head + 2 * d * a.n_kv_heads * a.d_head \
            + a.n_heads * a.d_head * d

    def _ffn_params(self, lc: LayerConfig, active_only: bool) -> int:
        d = self.d_model
        if lc.moe is None:
            return 3 * d * lc.d_ff
        m = lc.moe
        n_e = m.top_k if active_only else m.n_experts
        p = n_e * 3 * d * m.d_ff + d * m.n_experts  # experts + router
        if m.n_shared:
            p += 3 * d * m.d_ff_shared
        return p

    def param_count(self, active_only: bool = False) -> int:
        n = self.vocab * self.d_model
        if not self.tie_embeddings:
            n += self.vocab * self.d_model
        for _, _, lc, cnt in self.sub_layers():
            n += cnt * (self._attn_params(lc.attn)
                        + self._ffn_params(lc, active_only)
                        + (4 if lc.post_norm else 2) * self.d_model)
        n += self.d_model
        return n

    def model_flops(self, n_tokens: int) -> float:
        """6 * N_active * D (dense) — the §Roofline 'useful FLOPs' reference."""
        return 6.0 * self.param_count(active_only=True) * n_tokens

    def kv_cache_bytes(self, batch: int, seq: int, dtype_bytes: int = 2) -> int:
        w = sum(cnt * lc.attn.kv_cache_width
                for _, _, lc, cnt in self.sub_layers())
        return batch * seq * w * dtype_bytes
