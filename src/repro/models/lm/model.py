"""Transformer LM covering all five assigned architectures.

One parameterized stack expresses:
  * granite-3-2b / yi-34b     — GQA + RoPE, gated-SiLU FFN
  * gemma2-27b                — alternating local(window 4096)/global layers,
                                attn-logit + final-logit softcaps, sandwich norms
  * olmoe-1b-7b               — GQA + MoE (64 experts, top-8)
  * deepseek-v2-236b          — MLA (kv_lora 512, decoupled RoPE) + MoE
                                (2 shared + 160 routed, top-6)

Layers are ``lax.scan``'d per segment (stacked params, leading ``count`` axis)
with a remat policy, so HLO size is O(#distinct sub-layers), not O(depth).

Attention is *online-softmax blockwise* over KV chunks (Rabe-Staats): scores
are never materialized at (S, S) — required for the 32k-prefill cells to fit
HBM, and the memory-roofline-friendly form on TPU. Decode keeps a KV cache
(ring-buffered at ``window`` for local layers) and runs one-token attention
over the cache; with the cache sequence-sharded this is exactly the
flash-decoding parallel split (partial max/sum + all-reduce), which GSPMD
derives from the shardings.

MoE uses capacity-based scatter dispatch (tokens -> (E, C, d) buffers ->
per-expert GEMMs -> combine), so compiled FLOPs track *active* parameters —
the dense-compute shortcut would inflate HLO_FLOPs by E/top_k and wreck the
MODEL_FLOPS/HLO_FLOPs ratio the roofline reports.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from .config import AttnConfig, LayerConfig, LMConfig, MoEConfig

# ---------------------------------------------------------------------------
# sharding context: explicit activation annotations (GSPMD alone mis-places
# the batch axis in the attention scan without them)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    dp: tuple          # data-parallel axes, e.g. ("data",) or ("pod", "data")
    mdl: str           # tensor-parallel axis
    mdl_size: int

    def head(self, n: int):
        """The model axis iff it divides the head count, else unsharded."""
        return self.mdl if n % self.mdl_size == 0 else None


_CTX: Optional[ShardCtx] = None


def set_shard_ctx(ctx: Optional[ShardCtx]):
    """Set by the distributed launchers before tracing; None (default) keeps
    single-device smoke tests annotation-free."""
    global _CTX
    _CTX = ctx


def shard_ctx_from_mesh(mesh) -> ShardCtx:
    dp = tuple(n for n in mesh.axis_names if n != "model")
    return ShardCtx(dp=dp, mdl="model", mdl_size=mesh.shape["model"])


def _cst(x, *spec):
    if _CTX is None:
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _init(key, shape, scale_axis=0, dtype=jnp.bfloat16):
    scale = 1.0 / np.sqrt(max(1, shape[scale_axis]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * (1.0 + gamma.astype(x.dtype))


def rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# blockwise attention (shared by all attn kinds once q/k/v are formed)
# ---------------------------------------------------------------------------
NEG = -2.0e38

# §Perf iteration flag: remat the KV-block scan step so backward recomputes
# the per-block score tensor instead of saving all nb f32 logits blocks
# (flash-attention's memory behavior without the kernel). CONFIRMED in §Perf
# (granite train memory term -10.5%, compute +0.7%) and promoted to default.
_ATTN_SCAN_REMAT = True


def set_attn_scan_remat(on: bool):
    global _ATTN_SCAN_REMAT
    _ATTN_SCAN_REMAT = on


def blockwise_attention(q, k, v, *, causal: bool, window: Optional[int],
                        softcap: Optional[float], q_offset, kv_len: int,
                        block: int = 1024, scale: float = 1.0):
    """Online-softmax attention, expanded-head form.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D|Dv). KV heads are repeated to H
    *inside* each block step, so every score/accumulator tensor carries a
    plain head axis that shards cleanly over the model axis (GQA's folded
    (Hkv, G) axes do not — GSPMD then replicates the scores; §Perf it. 1).
    Supports causal masking at absolute positions (q position = q_offset+i),
    sliding window, logit softcap. Scans KV blocks carrying running
    (max, sum, acc) — O(Sq x block) live scores.
    """
    b, sq, h, d = q.shape
    _, skv, hkv, dv = v.shape
    g = h // hkv
    q = q * scale
    nb = (skv + block - 1) // block
    pad = nb * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block, hkv, d)
    vb = v.reshape(b, nb, block, hkv, dv)
    q_pos = q_offset + jnp.arange(sq)
    dp = _CTX.dp if _CTX else None
    hsp = _CTX.head(h) if _CTX else None

    def step(carry, blk):
        m, s, acc = carry
        kc, vc, j = blk
        kv_pos = j * block + jnp.arange(block)
        if g > 1:
            kc = jnp.repeat(kc, g, axis=2)           # (b, blk, H, d)
            vc = jnp.repeat(vc, g, axis=2)
        kc = _cst(kc, dp, None, hsp, None)
        vc = _cst(vc, dp, None, hsp, None)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32)
        logits = _softcap(logits, softcap)
        logits = _cst(logits, dp, hsp, None, None)
        mask = kv_pos[None, :] < kv_len
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        logits = jnp.where(mask[None, None], logits, NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        acc_new = _cst(acc_new, dp, hsp, None, None)
        return (m_new, s_new, acc_new), None

    m0 = _cst(jnp.full((b, h, sq), NEG, jnp.float32), dp, hsp, None)
    s0 = _cst(jnp.zeros((b, h, sq), jnp.float32), dp, hsp, None)
    a0 = _cst(jnp.zeros((b, h, sq, dv), jnp.float32), dp, hsp, None, None)
    if nb == 1:
        (m, s, acc), _ = step((m0, s0, a0), (kb[:, 0], vb[:, 0], 0))
    else:
        body = jax.checkpoint(step) if _ATTN_SCAN_REMAT else step
        (m, s, acc), _ = jax.lax.scan(
            body, (m0, s0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nb)))
    out = acc / jnp.maximum(s[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k, v, *, softcap, kv_len, scale: float = 1.0):
    """One-token attention over the full cache. q: (B, 1, H, D);
    k/v: (B, S, Hkv, D|Dv). Positions beyond ``kv_len`` are masked. When the
    cache S axis is sharded, XLA lowers the max/sum reductions to partial
    reduce + all-reduce — the flash-decoding split."""
    b, _, h, d = q.shape
    _, s, hkv, dv = v.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, d) * scale
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    mask = jnp.arange(s)[None, :] < kv_len
    logits = jnp.where(mask[:, None, None], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# sub-layer parameter init
# ---------------------------------------------------------------------------


def attn_params(key, cfg: LMConfig, a: AttnConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if a.kind == "mla":
        p = {"kv_a": _init(ks[0], (d, a.kv_lora + a.d_rope), 0, dtype),
             "kv_norm": jnp.zeros((a.kv_lora,), dtype),
             "kv_b": _init(ks[1], (a.kv_lora, a.n_heads * (a.d_nope + a.d_v)),
                           0, dtype),
             "wo": _init(ks[2], (a.n_heads * a.d_v, d), 0, dtype)}
        if a.q_lora:
            p["q_a"] = _init(ks[3], (d, a.q_lora), 0, dtype)
            p["q_norm"] = jnp.zeros((a.q_lora,), dtype)
            p["q_b"] = _init(ks[4], (a.q_lora, a.q_out), 0, dtype)
        else:
            p["wq"] = _init(ks[4], (d, a.q_out), 0, dtype)
        return p
    return {"wq": _init(ks[0], (d, a.n_heads * a.d_head), 0, dtype),
            "wk": _init(ks[1], (d, a.n_kv_heads * a.d_head), 0, dtype),
            "wv": _init(ks[2], (d, a.n_kv_heads * a.d_head), 0, dtype),
            "wo": _init(ks[3], (a.n_heads * a.d_head, d), 0, dtype)}


def ffn_params(key, cfg: LMConfig, lc: LayerConfig, dtype):
    d = cfg.d_model
    if lc.moe is None:
        k1, k2, k3 = jax.random.split(key, 3)
        return {"gate": _init(k1, (d, lc.d_ff), 0, dtype),
                "up": _init(k2, (d, lc.d_ff), 0, dtype),
                "down": _init(k3, (lc.d_ff, d), 0, dtype)}
    m = lc.moe
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {"router": _init(k1, (d, m.n_experts), 0, jnp.float32),
         "e_gate": _init(k2, (m.n_experts, d, m.d_ff), 1, dtype),
         "e_up": _init(k3, (m.n_experts, d, m.d_ff), 1, dtype),
         "e_down": _init(k4, (m.n_experts, m.d_ff, d), 1, dtype)}
    if m.n_shared:
        ks1, ks2, ks3 = jax.random.split(k5, 3)
        p["shared"] = {"gate": _init(ks1, (d, m.d_ff_shared), 0, dtype),
                       "up": _init(ks2, (d, m.d_ff_shared), 0, dtype),
                       "down": _init(ks3, (m.d_ff_shared, d), 0, dtype)}
    return p


def layer_params(key, cfg: LMConfig, lc: LayerConfig, dtype):
    ka, kf = jax.random.split(key)
    d = cfg.d_model
    p = {"attn": attn_params(ka, cfg, lc.attn, dtype),
         "ffn": ffn_params(kf, cfg, lc, dtype),
         "ln_attn": jnp.zeros((d,), dtype),
         "ln_ffn": jnp.zeros((d,), dtype)}
    if lc.post_norm:
        p["ln_attn_post"] = jnp.zeros((d,), dtype)
        p["ln_ffn_post"] = jnp.zeros((d,), dtype)
    return p


def init_params(key, cfg: LMConfig, dtype=jnp.bfloat16):
    """Stacked per-segment params: segments[i] has leading axis ``count``."""
    ke, kf, key = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": _init(ke, (cfg.vocab_padded, cfg.d_model), 1, dtype),
        "ln_final": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = _init(kf, (cfg.d_model, cfg.vocab_padded), 0, dtype)
    for si, seg in enumerate(cfg.segments):
        def make(i, si=si, seg=seg):
            k = jax.random.fold_in(key, si * 1000 + i)
            return {f"sub{li}": layer_params(jax.random.fold_in(k, li), cfg, lc,
                                             dtype)
                    for li, lc in enumerate(seg.layers)}
        params[f"seg{si}"] = jax.vmap(make)(jnp.arange(seg.count))
    return params


# ---------------------------------------------------------------------------
# MoE dispatch (capacity scatter)
# ---------------------------------------------------------------------------


MOE_GROUP = 8192          # dispatch-group length in token-assignments


def moe_ffn(p, x, m: MoEConfig, capacity: Optional[int] = None):
    """x: (T, d) -> (T, d). Grouped capacity dispatch:

    Assignments are split into fixed-length groups with per-group capacity
    (like per-rank dispatch in real expert-parallel systems; group boundaries
    are token-count-determined, so semantics do not depend on the mesh). The
    rank-within-expert uses a log-depth ``associative_scan`` over the group —
    a naive ``cumsum`` over all T*k assignments lowers to an O(n^2)
    reduce-window AND serializes across data shards (§Perf iteration 2:
    396 TFLOP/device of dispatch overhead at deepseek scale, ~0 after).
    """
    t, d = x.shape
    # router matmul in the stream dtype (bf16), softmax in f32 — upcasting
    # the whole (T, d) stream to f32 costs a full extra pass over it
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_w, gate_i = jax.lax.top_k(probs, m.top_k)              # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    n_assign = t * m.top_k
    gl = min(MOE_GROUP, n_assign)                               # group length
    ng = (n_assign + gl - 1) // gl
    pad = ng * gl - n_assign
    c = capacity or int(m.capacity_factor * gl / m.n_experts + 1)

    e_flat = gate_i.reshape(-1)                                 # (T*k,)
    if pad:
        e_flat = jnp.pad(e_flat, (0, pad), constant_values=m.n_experts - 1)
    dp = _CTX.dp if _CTX else None
    e_g = _cst(e_flat.reshape(ng, gl), dp, None)
    oh = jax.nn.one_hot(e_g, m.n_experts, dtype=jnp.int32)      # (G, L, E)
    oh = _cst(oh, dp, None, None)                    # groups follow the batch
    pos = jax.lax.associative_scan(jnp.add, oh, axis=1) - oh
    pos = jnp.take_along_axis(pos, e_g[..., None], 2)[..., 0]   # (G, L)
    keep = pos < c
    if pad:
        keep = keep.reshape(-1).at[n_assign:].set(False).reshape(ng, gl)
    slot = jnp.where(keep, pos, 0)

    # token -> assignment expansion is STRUCTURED (each token's k assignments
    # are contiguous): jnp.repeat, not x[src] — a dynamic gather with global
    # indices makes GSPMD all-reduce (T*k, d)-sized tensors across the mesh
    # every layer because it cannot prove shard alignment (§Perf A4).
    x_rep = jnp.repeat(x, m.top_k, axis=0)                      # (T*k, d)
    if pad:
        x_rep = jnp.pad(x_rep, ((0, pad), (0, 0)))
    vals = jnp.where(keep.reshape(-1)[:, None], x_rep, 0).reshape(ng, gl, d)
    vals = _cst(vals, dp, None, None)
    # batched (vmap'd) segment-sum: the group axis becomes an explicit scatter
    # batching dim, so the scatter stays group-local under the dp sharding
    # (a triple-indexed .at[g, e, c].add makes GSPMD all-reduce partial
    # buffers across the mesh — §Perf A5)
    flat_idx = e_g * c + slot                                   # (G, L)
    buf = jax.vmap(partial(jax.ops.segment_sum,
                           num_segments=m.n_experts * c))(vals, flat_idx)
    buf = buf.reshape(ng, m.n_experts, c, d)

    # Tokens-stay-put dispatch (§Perf A2, confirmed): the buffer shards only
    # on the batch-aligned group axis, so the scatter is shard-LOCAL (no
    # collective at all); the expert weights all-gather over the model axis
    # instead — orders of magnitude fewer bytes than moving token buffers
    # (A1's 2-D sharding and the E-sharded baseline both made GSPMD
    # all-reduce whole dispatch buffers across dp: 1.4-12.9 TB/device wire).
    buf = _cst(buf, dp, None, None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["e_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["e_up"])
    h = _cst(h, dp, None, None, None)
    out_e = _cst(jnp.einsum("gecf,efd->gecd", h, p["e_down"]),
                 dp, None, None, None)

    out_flat = out_e.reshape(ng, m.n_experts * c, d)
    back = jax.vmap(lambda o, i: jnp.take(o, i, axis=0))(out_flat, flat_idx)
    back = jnp.where(keep.reshape(-1)[:, None], back.reshape(-1, d),
                     0)[:n_assign]
    w_flat = gate_w.reshape(-1, 1).astype(back.dtype)
    # assignment -> token combine is a reshape+sum (contiguous k), not a
    # scatter-add over global indices (§Perf A4)
    y = (back * w_flat).reshape(t, m.top_k, d).sum(1)

    # auxiliary load-balance loss (Switch-style), returned for the trainer
    me = probs.mean(0)
    ce = jax.nn.one_hot(gate_i, m.n_experts,
                        dtype=jnp.float32).sum(1).mean(0)
    aux = m.n_experts * jnp.sum(me * ce)
    if m.n_shared:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["gate"]) * (x @ sp["up"])) @ sp["down"]
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_forward(p, x, a: AttnConfig, cfg: LMConfig, *, positions, kv_len,
                  cache=None, cache_pos=None):
    """Returns (attn_out, new_cache_entry). Cache entry layout:
    GQA: {"k": (B, S, Hkv, D), "v": ...}; MLA: {"ckv": (B, S, kv_lora+d_rope)}.
    """
    b, s, d = x.shape
    decode = cache is not None and s == 1
    if a.kind == "mla":
        if a.q_lora:
            q = rms_norm(x @ p["q_a"], p["q_norm"], cfg.norm_eps) @ p["q_b"]
        else:
            q = x @ p["wq"]
        q = q.reshape(b, s, a.n_heads, a.d_nope + a.d_rope)
        if _CTX:
            q = _cst(q, _CTX.dp, None, _CTX.head(a.n_heads), None)
        q_nope, q_rope = q[..., :a.d_nope], q[..., a.d_nope:]
        q_rope = rope(q_rope, positions, a.rope_theta)
        ckv_new = x @ p["kv_a"]                                  # (B,S,lora+dr)
        k_rope_new = rope(ckv_new[..., a.kv_lora:][:, :, None, :], positions,
                          a.rope_theta)[:, :, 0, :]
        ckv_new = jnp.concatenate([ckv_new[..., :a.kv_lora], k_rope_new], -1)
        if cache is not None:
            cache_upd = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv_new.astype(cache["ckv"].dtype),
                (0, cache_pos, 0))
            ckv = cache_upd if decode else ckv_new
        else:
            cache_upd = ckv = ckv_new
        c_lat = rms_norm(ckv[..., :a.kv_lora], p["kv_norm"], cfg.norm_eps)
        kv = c_lat @ p["kv_b"]
        kv = kv.reshape(b, -1, a.n_heads, a.d_nope + a.d_v)
        if _CTX:
            kv = _cst(kv, _CTX.dp, None, _CTX.head(a.n_heads), None)
        k_nope, v = kv[..., :a.d_nope], kv[..., a.d_nope:]
        k_rope = jnp.broadcast_to(ckv[..., None, a.kv_lora:],
                                  k_nope.shape[:-1] + (a.d_rope,))
        k = jnp.concatenate([k_nope, k_rope], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        scale = 1.0 / np.sqrt(a.d_nope + a.d_rope)
        if decode:
            o = decode_attention(qf, k, v, softcap=a.softcap, kv_len=kv_len,
                                 scale=scale)
        else:
            o = blockwise_attention(qf, k, v, causal=True, window=a.window,
                                    softcap=a.softcap, q_offset=0,
                                    kv_len=kv_len, scale=scale)
        out = o.reshape(b, s, -1) @ p["wo"]
        return out, {"ckv": cache_upd}

    dp = _CTX.dp if _CTX else None
    q = (x @ p["wq"]).reshape(b, s, a.n_heads, a.d_head)
    q = _cst(q, dp, None, _CTX.head(a.n_heads) if _CTX else None, None)
    k_new = (x @ p["wk"]).reshape(b, s, a.n_kv_heads, a.d_head)
    v_new = (x @ p["wv"]).reshape(b, s, a.n_kv_heads, a.d_head)
    q = rope(q, positions, a.rope_theta)
    k_new = rope(k_new, positions, a.rope_theta)
    if cache is not None:
        cs = cache["k"].shape[1]
        cdt = cache["k"].dtype
        if decode:
            slot = cache_pos % cs if a.window else cache_pos
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cdt), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cdt), (0, slot, 0, 0))
            k, v = kc, vc
        elif s >= cs:
            # prefill overflowing a ring (windowed) cache: keep the last ``cs``
            # tokens, rotated so token p lands in slot p % cs.
            shift = (cache_pos + s) % cs
            kc = jnp.roll(k_new[:, -cs:], shift, axis=1).astype(cdt)
            vc = jnp.roll(v_new[:, -cs:], shift, axis=1).astype(cdt)
            k, v = k_new, v_new
        else:
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cdt), (0, cache_pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cdt), (0, cache_pos, 0, 0))
            k, v = k_new, v_new
    else:
        kc = vc = None
        k, v = k_new, v_new
    scale = 1.0 / np.sqrt(a.d_head)
    if decode:
        o = decode_attention(q, k, v, softcap=a.softcap,
                             kv_len=jnp.minimum(kv_len, k.shape[1]),
                             scale=scale)
    else:
        o = blockwise_attention(q, k, v, causal=True, window=a.window,
                                softcap=a.softcap, q_offset=0, kv_len=kv_len,
                                scale=scale)
    out = o.reshape(b, s, -1) @ p["wo"]
    return out, ({"k": kc, "v": vc} if cache is not None
                 else {"k": k_new, "v": v_new})


def _sub_layer(p, x, lc: LayerConfig, cfg: LMConfig, *, positions, kv_len,
               cache=None, cache_pos=None):
    dtype = x.dtype
    dp = _CTX.dp if _CTX else None
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    h, new_cache = _attn_forward(p["attn"], h, lc.attn, cfg,
                                 positions=positions, kv_len=kv_len,
                                 cache=cache, cache_pos=cache_pos)
    if lc.post_norm:
        h = rms_norm(h, p["ln_attn_post"], cfg.norm_eps)
    x = _cst((x + h).astype(dtype), dp, None, None)
    h = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    aux = 0.0
    if lc.moe is not None:
        b, s, d = h.shape
        h2, aux = moe_ffn(p["ffn"], h.reshape(-1, d), lc.moe)
        h = h2.reshape(b, s, d)
    else:
        f = p["ffn"]
        hid = _cst(jax.nn.silu(h @ f["gate"]) * (h @ f["up"]),
                   dp, None, _CTX.mdl if _CTX else None)
        h = hid @ f["down"]
    if lc.post_norm:
        h = rms_norm(h, p["ln_ffn_post"], cfg.norm_eps)
    return _cst((x + h).astype(dtype), dp, None, None), aux, new_cache


def forward(params, tokens, cfg: LMConfig, *, positions=None, kv_len=None,
            caches=None, cache_pos=None, remat: bool = True,
            unroll: bool = False):
    """tokens (B, S) -> logits (B, S, V). ``caches``: per-segment pytrees with
    leading ``count`` axis (present => fill/update them).

    ``unroll=True`` fully unrolls the layer scans — used by the dry-run so
    XLA cost analysis counts every layer's FLOPs and collectives (it tallies
    a ``while`` body once, not x trip-count)."""
    b, s = tokens.shape
    dtype = params["embed"].dtype
    x = params["embed"][tokens]
    x = _cst(x, _CTX.dp if _CTX else None, None, None)
    if cfg.embed_scale:
        x = x * np.asarray(np.sqrt(cfg.d_model), dtype)
    if positions is None:
        positions = jnp.arange(s)
    if kv_len is None:
        kv_len = s
    total_aux = 0.0
    new_caches = {} if caches is not None else None

    for si, seg in enumerate(cfg.segments):
        seg_p = params[f"seg{si}"]
        seg_cache = caches.get(f"seg{si}") if caches is not None else None

        def body(x, inp, seg=seg):
            p_i, cache_i = inp
            aux_i = 0.0
            new_cache_i = {}
            for li, lc in enumerate(seg.layers):
                x, aux, nc = _sub_layer(
                    p_i[f"sub{li}"], x, lc, cfg, positions=positions,
                    kv_len=kv_len,
                    cache=None if cache_i is None else cache_i[f"sub{li}"],
                    cache_pos=cache_pos)
                aux_i = aux_i + aux
                new_cache_i[f"sub{li}"] = nc
            return x, (aux_i, new_cache_i)

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, (auxs, ncs) = jax.lax.scan(body, x, (seg_p, seg_cache),
                                      unroll=seg.count if unroll else 1)
        total_aux = total_aux + jnp.sum(auxs)
        if new_caches is not None:
            new_caches[f"seg{si}"] = ncs

    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ unembed
    logits = _cst(logits, _CTX.dp if _CTX else None, None,
                  _CTX.mdl if _CTX else None)        # vocab-sharded logits
    if cfg.vocab_padded != cfg.vocab:
        logits = logits[..., :cfg.vocab]             # drop padded entries
    logits = _softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits, total_aux, new_caches


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, seq: int, dtype=jnp.bfloat16,
               as_spec: bool = False):
    """Per-segment stacked KV caches. Local (windowed) layers ring-buffer at
    ``window`` instead of ``seq`` — the Gemma-2 memory saving."""
    def make(shape):
        return (jax.ShapeDtypeStruct(shape, dtype) if as_spec
                else jnp.zeros(shape, dtype))

    caches = {}
    for si, seg in enumerate(cfg.segments):
        sub = {}
        for li, lc in enumerate(seg.layers):
            a = lc.attn
            s_eff = min(seq, a.window) if a.window else seq
            if a.kind == "mla":
                sub[f"sub{li}"] = {"ckv": make(
                    (seg.count, batch, s_eff, a.kv_lora + a.d_rope))}
            else:
                sub[f"sub{li}"] = {
                    "k": make((seg.count, batch, s_eff, a.n_kv_heads, a.d_head)),
                    "v": make((seg.count, batch, s_eff, a.n_kv_heads, a.d_head))}
        caches[f"seg{si}"] = sub
    return caches


# ---------------------------------------------------------------------------
# train / serve steps
# ---------------------------------------------------------------------------


def lm_loss(params, tokens, labels, cfg: LMConfig, unroll: bool = False):
    """CE over vocab-sharded padded logits. The label log-prob is picked out
    with an iota-compare + max reduce (not take_along_axis, whose gather
    would force an all-gather of the full logits over the model axis); the
    logsumexp ignores padded vocab entries via the same mask."""
    logits, aux, _ = forward(params, tokens, cfg, unroll=unroll)
    vp = logits.shape[-1]
    valid = jnp.arange(vp) < cfg.vocab
    logits = jnp.where(valid, logits, NEG)
    logz = jax.nn.logsumexp(logits, -1)
    is_label = jnp.arange(vp)[None, None, :] == labels[..., None]
    ll = jnp.max(jnp.where(is_label, logits, NEG), -1)
    return (logz - ll).mean() + 0.01 * aux


def make_train_step(cfg: LMConfig, optimizer, unroll: bool = False):
    def train_step(state, tokens, labels):
        params, opt_state, step = state
        loss, grads = jax.value_and_grad(lm_loss)(params, tokens, labels, cfg,
                                                  unroll)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        from ...train.optimizer import apply_updates
        params = apply_updates(params, updates)
        return (params, opt_state, step + 1), loss
    return train_step


def make_prefill_step(cfg: LMConfig, batch: int, seq: int,
                      unroll: bool = False):
    def prefill(params, tokens):
        caches = init_cache(cfg, batch, seq)
        logits, _, caches = forward(params, tokens, cfg, caches=caches,
                                    cache_pos=0, kv_len=seq, unroll=unroll)
        return logits[:, -1], caches
    return prefill


def make_decode_step(cfg: LMConfig, unroll: bool = False):
    def decode(params, caches, token, pos):
        """token (B, 1) int32; pos scalar int32 (current length)."""
        logits, _, caches = forward(
            params, token, cfg, positions=pos[None], kv_len=pos + 1,
            caches=caches, cache_pos=pos, remat=False, unroll=unroll)
        return logits[:, 0], caches
    return decode
