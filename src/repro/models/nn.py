"""Minimal pure-JAX NN layer library (params = nested dicts; init/apply fns).

No flax/haiku dependency — keeps the distributed runtime's pytree handling
transparent (sharding specs mirror the param tree 1:1).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def linear_init(key, d_in, d_out, bias=True, dtype=jnp.float32):
    p = {"w": glorot(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    return y + p["b"] if "b" in p else y


def mlp_init(key, dims: Sequence[int], bias=True, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": linear_init(keys[i], dims[i], dims[i + 1], bias, dtype)
            for i in range(len(dims) - 1)}


def mlp(p, x, act=jax.nn.relu, final_act=None):
    n = len(p)
    for i in range(n):
        x = linear(p[f"l{i}"], x)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def layer_norm(x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def rms_norm(x, gamma=None, eps=1e-6):
    var = (x.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y if gamma is None else y * gamma


def cross_entropy(logits, labels, mask):
    """Masked mean CE. Returns (sum_loss, count) so callers can psum across shards."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    ce = (logz - ll) * mask
    return ce.sum(), mask.sum()


def accuracy_counts(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    return ((pred == labels) * mask).sum(), mask.sum()
