from .models import GCN, GAT, GraphSAGE, MeshGraphNet, PNA, SchNet  # noqa: F401
from .nequip import NequIP  # noqa: F401
from . import blocks, so3  # noqa: F401
