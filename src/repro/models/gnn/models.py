"""GNN model zoo.

Paper models (evaluated in Sylvie): GCN, GraphSAGE, GAT.
Assigned architectures:  PNA, MeshGraphNet, SchNet (NequIP lives in nequip.py).

Uniform contract::

    model.comm_dims()                 -> feature width at each halo-exchange site
    model.init(key, d_in)             -> params pytree
    model.apply(params, block, x, comm) -> (P, n_local, d_out)

``comm`` is a :class:`repro.core.sylvie.SylvieComm`; every layer calls
``comm.halo(h)`` exactly once per site, in ``comm_dims`` order. Models never see
the communication mode — vanilla / Sylvie-S / Sylvie-A / bit-width are runtime
config, which is what makes the Low-bit Module a first-class framework feature.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .. import nn
from . import blocks as B


def _exchange_and_table(comm, block, h):
    halo = comm.halo(h)
    return B.halo_table(h, halo)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GCN:
    """Kipf-Welling GCN, Alg. 1 form: H^{l} = sigma(A_hat^T H~^{l-1} W^{l})."""
    d_in: int
    d_hidden: int
    d_out: int
    n_layers: int = 2

    def comm_dims(self):
        return [self.d_in] + [self.d_hidden] * (self.n_layers - 1)

    def init(self, key):
        dims = [self.d_in] + [self.d_hidden] * (self.n_layers - 1) + [self.d_out]
        keys = jax.random.split(key, self.n_layers)
        return {f"layer{i}": nn.linear_init(keys[i], dims[i], dims[i + 1])
                for i in range(self.n_layers)}

    def apply(self, params, block, x, comm):
        h = x
        for i in range(self.n_layers):
            table = _exchange_and_table(comm, block, h)
            src = B.gather_src(block, table) * block.edge_weight[..., None]
            z = B.agg_sum(block, src)
            h = nn.linear(params[f"layer{i}"], z)
            if i < self.n_layers - 1:
                h = jax.nn.relu(h)
        return h


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GraphSAGE:
    """SAGE-mean: h' = sigma(W_self h + W_nb mean_{u in N(v)} h_u)."""
    d_in: int
    d_hidden: int
    d_out: int
    n_layers: int = 2

    def comm_dims(self):
        return [self.d_in] + [self.d_hidden] * (self.n_layers - 1)

    def init(self, key):
        dims = [self.d_in] + [self.d_hidden] * (self.n_layers - 1) + [self.d_out]
        keys = jax.random.split(key, 2 * self.n_layers)
        return {f"layer{i}": {"self": nn.linear_init(keys[2 * i], dims[i], dims[i + 1]),
                              "nb": nn.linear_init(keys[2 * i + 1], dims[i], dims[i + 1],
                                                   bias=False)}
                for i in range(self.n_layers)}

    def apply(self, params, block, x, comm):
        h = x
        for i in range(self.n_layers):
            table = _exchange_and_table(comm, block, h)
            src = B.gather_src(block, table)
            agg = B.agg_mean(block, src)
            h = nn.linear(params[f"layer{i}"]["self"], h) \
                + nn.linear(params[f"layer{i}"]["nb"], agg)
            if i < self.n_layers - 1:
                h = jax.nn.relu(h)
        return h


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GAT:
    """Multi-head GAT. We exchange the *projected* features Wh (width H*dh),
    halving comm vs raw features when d_in is wide; scores use the split-form
    a = [a_src ; a_dst] so each side is a local dot product."""
    d_in: int
    d_hidden: int          # per-head
    d_out: int
    n_layers: int = 2
    heads: int = 4

    def comm_dims(self):
        return [self.d_hidden * self.heads] * self.n_layers

    def init(self, key):
        p = {}
        d = self.d_in
        for i in range(self.n_layers):
            k1, k2, k3, key = jax.random.split(key, 4)
            p[f"layer{i}"] = {
                "w": nn.linear_init(k1, d, self.heads * self.d_hidden, bias=False),
                "a_src": jax.random.normal(k2, (self.heads, self.d_hidden)) * 0.1,
                "a_dst": jax.random.normal(k3, (self.heads, self.d_hidden)) * 0.1,
            }
            d = self.heads * self.d_hidden
        p["out"] = nn.linear_init(key, d, self.d_out)
        return p

    def apply(self, params, block, x, comm):
        h = x
        for i in range(self.n_layers):
            lp = params[f"layer{i}"]
            hw = nn.linear(lp["w"], h)                       # (P, n, H*dh) local
            table = _exchange_and_table(comm, block, hw)
            nh, dh = self.heads, self.d_hidden
            t4 = table.reshape(table.shape[:-1] + (nh, dh))
            s_all = jnp.einsum("...hd,hd->...h", t4, lp["a_src"])
            hw4 = hw.reshape(hw.shape[:-1] + (nh, dh))
            s_dst = jnp.einsum("...hd,hd->...h", hw4, lp["a_dst"])
            e_src = B.gather_src(block, s_all)               # (P, E, H)
            e_dst = B.gather_dst(block, s_dst)
            score = jax.nn.leaky_relu(e_src + e_dst, 0.2)
            alpha = B.edge_softmax(block, score)             # (P, E, H)
            v = B.gather_src(block, table).reshape(alpha.shape[:2] + (nh, dh))
            msg = (alpha[..., None] * v).reshape(alpha.shape[:2] + (nh * dh,))
            h = B.agg_sum(block, msg)
            if i < self.n_layers - 1:
                h = jax.nn.elu(h)
        return nn.linear(params["out"], h)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PNA:
    """Principal Neighbourhood Aggregation: 4 aggregators x 3 degree scalers.
    [arXiv:2004.05718] — assigned config: 4 layers, d=75."""
    d_in: int
    d_hidden: int = 75
    d_out: int = 0
    n_layers: int = 4
    delta: float = 2.5     # E[log(deg+1)] normalizer (dataset statistic)

    def comm_dims(self):
        return [self.d_hidden] * self.n_layers

    def init(self, key):
        ke, key = jax.random.split(key)
        p = {"encoder": nn.linear_init(ke, self.d_in, self.d_hidden)}
        d = self.d_hidden
        for i in range(self.n_layers):
            k1, k2, key = jax.random.split(key, 3)
            p[f"layer{i}"] = {"pre": nn.linear_init(k1, 2 * d, d),
                              "post": nn.linear_init(k2, 12 * d, d)}
        p["out"] = nn.linear_init(key, d, self.d_out)
        return p

    def apply(self, params, block, x, comm):
        h = jax.nn.relu(nn.linear(params["encoder"], x))
        deg = B.degrees(block)
        logd = jnp.log1p(deg)[..., None]
        for i in range(self.n_layers):
            lp = params[f"layer{i}"]
            table = _exchange_and_table(comm, block, h)
            src = B.gather_src(block, table)
            dst = B.gather_dst(block, h)
            msg = jax.nn.relu(nn.linear(lp["pre"], jnp.concatenate([src, dst], -1)))
            aggs = [B.agg_mean(block, msg), B.agg_max(block, msg),
                    B.agg_min(block, msg), B.agg_std(block, msg)]
            a = jnp.concatenate(aggs, axis=-1)               # (P, n, 4d)
            amp = logd / self.delta
            att = self.delta / jnp.maximum(logd, 1e-6)
            scaled = jnp.concatenate([a, a * amp, a * att], axis=-1)  # (P, n, 12d)
            h = jax.nn.relu(h + nn.linear(lp["post"], scaled))
        return nn.linear(params["out"], h)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MeshGraphNet:
    """Encode-process-decode with edge+node MLPs and residuals
    [arXiv:2010.03409] — assigned config: 15 layers, d=128, sum aggregator.
    ``edge_attr`` carries [dist, unit_vec] (4) computed host-side."""
    d_in: int
    d_hidden: int = 128
    d_out: int = 0
    n_layers: int = 15
    mlp_layers: int = 2
    d_edge_in: int = 4

    def comm_dims(self):
        return [self.d_hidden] * self.n_layers

    def _mlp_dims(self, d_in):
        return [d_in] + [self.d_hidden] * self.mlp_layers

    def init(self, key):
        kn, ke, ko, key = jax.random.split(key, 4)
        d = self.d_hidden
        p = {"enc_node": nn.mlp_init(kn, self._mlp_dims(self.d_in)),
             "enc_edge": nn.mlp_init(ke, self._mlp_dims(self.d_edge_in)),
             "decoder": nn.mlp_init(ko, [d, d, self.d_out])}
        for i in range(self.n_layers):
            k1, k2, key = jax.random.split(key, 3)
            p[f"proc{i}"] = {"edge": nn.mlp_init(k1, self._mlp_dims(3 * d)),
                             "node": nn.mlp_init(k2, self._mlp_dims(2 * d))}
        return p

    def apply(self, params, block, x, comm):
        h = nn.mlp(params["enc_node"], x)
        e = nn.mlp(params["enc_edge"], block.edge_attr[..., :self.d_edge_in])
        for i in range(self.n_layers):
            lp = params[f"proc{i}"]
            table = _exchange_and_table(comm, block, h)
            src = B.gather_src(block, table)
            dst = B.gather_dst(block, h)
            e = e + nn.mlp(lp["edge"], jnp.concatenate([e, src, dst], -1))
            agg = B.agg_sum(block, e)
            h = h + nn.mlp(lp["node"], jnp.concatenate([h, agg], -1))
        return nn.mlp(params["decoder"], h)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SchNet:
    """SchNet continuous-filter convolutions [arXiv:1706.08566] — assigned
    config: 3 interactions, d=64, 300 RBFs, cutoff 10. ``edge_attr[..., 0]`` is
    the edge distance (host-side geometry)."""
    d_in: int
    d_hidden: int = 64
    d_out: int = 0
    n_interactions: int = 3
    n_rbf: int = 300
    cutoff: float = 10.0

    def comm_dims(self):
        return [self.d_hidden] * self.n_interactions

    def init(self, key):
        ke, ko, key = jax.random.split(key, 3)
        d = self.d_hidden
        p = {"embed": nn.linear_init(ke, self.d_in, d),
             "out": nn.mlp_init(ko, [d, d, self.d_out])}
        for i in range(self.n_interactions):
            k1, k2, k3, k4, key = jax.random.split(key, 5)
            p[f"int{i}"] = {
                "filter": nn.mlp_init(k1, [self.n_rbf, d, d]),
                "in": nn.linear_init(k2, d, d, bias=False),
                "dense1": nn.linear_init(k3, d, d),
                "dense2": nn.linear_init(k4, d, d),
            }
        return p

    def _rbf(self, dist):
        centers = jnp.linspace(0.0, self.cutoff, self.n_rbf)
        gamma = 0.5 * (self.n_rbf / self.cutoff) ** 2
        return jnp.exp(-gamma * (dist[..., None] - centers) ** 2)

    def apply(self, params, block, x, comm):
        h = nn.linear(params["embed"], x)
        rbf = self._rbf(block.edge_attr[..., 0])
        act = jax.nn.softplus
        for i in range(self.n_interactions):
            lp = params[f"int{i}"]
            w = nn.mlp(lp["filter"], rbf, act=act)           # (P, E, d)
            table = _exchange_and_table(comm, block, nn.linear(lp["in"], h))
            src = B.gather_src(block, table)
            agg = B.agg_sum(block, src * w)
            v = nn.linear(lp["dense2"], act(nn.linear(lp["dense1"], agg)))
            h = h + v
        return nn.mlp(params["out"], h, act=act)


# Canonical benchmark-scale factories for the paper's three architectures —
# the single definition the benchmark harness (benchmarks/common.MODELS) and
# the scenario runner (launch/scenarios.ARCHS) both resolve "gcn" /
# "graphsage" / "gat" through, so a scenario report and a fig/table row with
# the same arch name are always the same model.
PAPER_ARCHS = {
    "gcn": lambda d_in, d_out: GCN(d_in, 64, d_out, n_layers=2),
    "graphsage": lambda d_in, d_out: GraphSAGE(d_in, 64, d_out, n_layers=2),
    "gat": lambda d_in, d_out: GAT(d_in, 16, d_out, n_layers=2, heads=4),
}
