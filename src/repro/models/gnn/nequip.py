"""NequIP: E(3)-equivariant interatomic-potential GNN [arXiv:2101.03164].

Assigned config: 5 layers, hidden multiplicity 32, l_max=2, 8 radial basis
functions, cutoff 5. Node features are irreps 32x0e + 32x1o + 32x2e stored flat
(width 32*(1+3+5) = 288); each interaction layer:

  1. halo-exchange the flat irrep features (this is the Sylvie-quantized wire
     format — see DESIGN.md on equivariance-vs-quantization noise),
  2. per-edge tensor product  h_u (x) Y(r_uv)  over all coupled (l1,l2,l3) paths
     (Gaunt tensors from ``so3.py``), weighted by a radial MLP on the RBF of the
     edge length with a smooth cosine cutoff envelope,
  3. scatter-sum to destination nodes, per-l self-interaction (mul-mixing linear),
  4. gate nonlinearity: SiLU on scalars; l>0 irreps gated by sigmoids of scalars.

``edge_attr`` carries [dist(1), unit(3), sh(9)] computed host-side on the global
graph (geometry is static during training).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import nn
from . import blocks as B
from . import so3

LS = (0, 1, 2)


def _l_slice(l: int, mul: int) -> slice:
    start = sum(mul * (2 * k + 1) for k in LS if k < l)
    return slice(start, start + mul * (2 * l + 1))


@dataclasses.dataclass(frozen=True)
class NequIP:
    d_in: int
    d_out: int = 0
    mul: int = 32            # hidden multiplicity per l
    n_layers: int = 5
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0

    @property
    def width(self) -> int:
        return self.mul * (self.l_max + 1) ** 2

    @property
    def paths(self):
        ls = tuple(range(self.l_max + 1))
        return so3.coupled_paths(ls, ls, ls)

    def comm_dims(self):
        return [self.width] * self.n_layers

    def init(self, key):
        ke, ko, key = jax.random.split(key, 3)
        p = {"embed": nn.linear_init(ke, self.d_in, self.mul),
             "out": nn.linear_init(ko, self.mul, self.d_out)}
        n_paths = len(self.paths)
        for i in range(self.n_layers):
            kr, ks, ka, kg, key = jax.random.split(key, 5)
            scale = 1.0 / np.sqrt(self.mul)
            p[f"layer{i}"] = {
                "radial": nn.mlp_init(kr, [self.n_rbf, self.mul,
                                           n_paths * self.mul]),
                "w_self": {l: jax.random.normal(jax.random.fold_in(ks, l),
                                                (self.mul, self.mul)) * scale
                           for l in range(self.l_max + 1)},
                "w_agg": {l: jax.random.normal(jax.random.fold_in(ka, l),
                                               (self.mul, self.mul)) * scale
                          for l in range(self.l_max + 1)},
                "gate": nn.linear_init(kg, self.mul, self.l_max * self.mul),
            }
        return p

    def _rbf(self, dist):
        centers = jnp.linspace(0.0, self.cutoff, self.n_rbf)
        gamma = 0.5 * (self.n_rbf / self.cutoff) ** 2
        env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / self.cutoff, 0, 1)) + 1.0)
        return jnp.exp(-gamma * (dist[..., None] - centers) ** 2) * env[..., None]

    def _split(self, h):
        """flat (..., width) -> {l: (..., mul, 2l+1)}"""
        return {l: h[..., _l_slice(l, self.mul)].reshape(
                    h.shape[:-1] + (self.mul, 2 * l + 1))
                for l in range(self.l_max + 1)}

    def _flat(self, parts):
        return jnp.concatenate(
            [parts[l].reshape(parts[l].shape[:-2] + (-1,))
             for l in range(self.l_max + 1)], axis=-1)

    def apply(self, params, block, x, comm):
        p0 = x.shape[0]
        scal = nn.linear(params["embed"], x)                     # (P, n, mul)
        h = jnp.concatenate(
            [scal, jnp.zeros(scal.shape[:-1] + (self.width - self.mul,))], -1)
        dist = block.edge_attr[..., 0]
        sh = block.edge_attr[..., 4:4 + (self.l_max + 1) ** 2]   # (P, E, 9)
        rbf = self._rbf(dist)
        paths = self.paths
        for i in range(self.n_layers):
            lp = params[f"layer{i}"]
            table = B.halo_table(h, comm.halo(h))
            src = B.gather_src(block, table)                     # (P, E, width)
            src_l = self._split(src)
            w = nn.mlp(lp["radial"], rbf, act=jax.nn.silu)
            w = w.reshape(w.shape[:-1] + (len(paths), self.mul)) # (P,E,paths,mul)
            msg = {l: 0.0 for l in range(self.l_max + 1)}
            for pi, (l1, l2, l3) in enumerate(paths):
                c = jnp.asarray(so3.gaunt(l1, l2, l3))
                y2 = sh[..., so3.sh_slice(l2)]
                m = jnp.einsum("abc,peua,peb->peuc", c, src_l[l1], y2)
                msg[l3] = msg[l3] + m * w[..., pi, :, None]
            agg = {l: B.agg_sum(block, msg[l].reshape(msg[l].shape[:2] + (-1,)))
                      .reshape((p0, block.n_local, self.mul, 2 * l + 1))
                   for l in range(self.l_max + 1)}
            h_l = self._split(h)
            out = {l: jnp.einsum("pnum,uv->pnvm", agg[l], lp["w_agg"][l])
                      + jnp.einsum("pnum,uv->pnvm", h_l[l], lp["w_self"][l])
                   for l in range(self.l_max + 1)}
            scal = jax.nn.silu(out[0][..., 0])                    # (P, n, mul)
            gates = jax.nn.sigmoid(nn.linear(lp["gate"], scal))
            gated = {0: scal[..., None]}
            for l in range(1, self.l_max + 1):
                g = gates[..., (l - 1) * self.mul: l * self.mul]
                gated[l] = out[l] * g[..., None]
            h = self._flat(gated)
        return nn.linear(params["out"], h[..., :self.mul])
