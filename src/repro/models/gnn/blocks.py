"""GraphBlock: the device-side partitioned graph + message-passing primitives.

JAX is BCOO-only for sparse — all message passing here is explicit
gather-over-edge-index -> ``jax.ops.segment_sum``/``segment_max`` scatter, vmapped
over the leading partition axis (size 1 per device under shard_map; size P in the
simulated single-process mode). This IS the SpMM/SDDMM layer of the system; the
Pallas kernel in ``repro/kernels/spmm`` implements the same contract for the TPU
hot path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core.exchange import PlanArrays
from ...graph.partition import PartitionedGraph, PartitionShapeSpec
from . import so3

NEG = -1e30


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBlock:
    """Static per-partition graph data (stacked leading axis P)."""

    edges: jax.Array                      # (P, E, 2) int32 [src_ext, dst_local]
    edge_mask: jax.Array                  # (P, E) bool
    node_mask: jax.Array                  # (P, n_local) bool
    plan: PlanArrays
    edge_weight: Optional[jax.Array] = None   # (P, E) — GCN-normalized A+I weights
    edge_attr: Optional[jax.Array] = None     # (P, E, d_e) — [dist | unit | sh...]
    n_local: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def n_parts(self):
        return self.plan.n_parts


def geometry_edge_attr(g, l_max: int = 2) -> np.ndarray:
    """Per-edge [dist, unit(3), sh((l_max+1)^2)] computed on the *global* graph
    (host-side, before partitioning — halo positions never move at runtime)."""
    src, dst = g.edge_index
    vec = g.pos[src] - g.pos[dst]
    dist = np.linalg.norm(vec, axis=-1, keepdims=True)
    unit = vec / np.maximum(dist, 1e-9)
    sh = so3.real_sh_np(unit, l_max)
    return np.concatenate([dist, unit, sh], axis=-1).astype(np.float32)


def build_block(pg: PartitionedGraph) -> GraphBlock:
    return GraphBlock(
        edges=jnp.asarray(pg.edges), edge_mask=jnp.asarray(pg.edge_mask),
        node_mask=jnp.asarray(pg.node_mask),
        plan=PlanArrays.from_plan(pg.plan),
        edge_weight=None if pg.edge_weight is None else jnp.asarray(pg.edge_weight),
        edge_attr=None if pg.edge_attr is None else jnp.asarray(pg.edge_attr),
        n_local=pg.plan.n_local)


def block_spec(spec: PartitionShapeSpec, d_edge_attr: int = 0,
               with_weight: bool = True, stacked_parts: int | None = None) -> GraphBlock:
    """ShapeDtypeStruct GraphBlock for the dry-run (no allocation)."""
    p = stacked_parts if stacked_parts is not None else spec.n_parts
    sds = jax.ShapeDtypeStruct
    return GraphBlock(
        edges=sds((p, spec.e_pad, 2), jnp.int32),
        edge_mask=sds((p, spec.e_pad), jnp.bool_),
        node_mask=sds((p, spec.n_local), jnp.bool_),
        plan=PlanArrays.from_spec(spec),
        edge_weight=sds((p, spec.e_pad), jnp.float32) if with_weight else None,
        edge_attr=sds((p, spec.e_pad, d_edge_attr), jnp.float32) if d_edge_attr else None,
        n_local=spec.n_local)


# --- message-passing primitives -------------------------------------------------
def halo_table(h: jax.Array, halo: jax.Array) -> jax.Array:
    """[local ; halo] feature table addressed by extended src indices."""
    return jnp.concatenate([h, halo], axis=1)


def gather_src(block: GraphBlock, table: jax.Array) -> jax.Array:
    return jnp.take_along_axis(table, block.edges[..., 0:1], axis=1)


def gather_dst(block: GraphBlock, h: jax.Array) -> jax.Array:
    return jnp.take_along_axis(h, block.edges[..., 1:2], axis=1)


def _seg(fn, msgs, dst, n_local):
    return jax.vmap(partial(fn, num_segments=n_local))(msgs, dst)


def agg_sum(block: GraphBlock, msgs: jax.Array) -> jax.Array:
    msgs = jnp.where(block.edge_mask[..., None], msgs, 0)
    return _seg(jax.ops.segment_sum, msgs, block.edges[..., 1], block.n_local)


def agg_max(block: GraphBlock, msgs: jax.Array) -> jax.Array:
    msgs = jnp.where(block.edge_mask[..., None], msgs, NEG)
    out = _seg(jax.ops.segment_max, msgs, block.edges[..., 1], block.n_local)
    return jnp.where(out <= NEG / 2, 0.0, out)


def agg_min(block: GraphBlock, msgs: jax.Array) -> jax.Array:
    return -agg_max(block, -msgs)


def degrees(block: GraphBlock) -> jax.Array:
    ones = block.edge_mask.astype(jnp.float32)
    return jax.vmap(partial(jax.ops.segment_sum, num_segments=block.n_local))(
        ones, block.edges[..., 1])


def agg_mean(block: GraphBlock, msgs: jax.Array) -> jax.Array:
    s = agg_sum(block, msgs)
    d = degrees(block)
    return s / jnp.maximum(d, 1.0)[..., None]


def agg_std(block: GraphBlock, msgs: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = agg_mean(block, msgs)
    mu2 = agg_mean(block, msgs * msgs)
    return jnp.sqrt(jnp.maximum(mu2 - mu * mu, 0.0) + eps)


def edge_softmax(block: GraphBlock, scores: jax.Array) -> jax.Array:
    """Per-dst softmax over incoming edges; scores (P, E, H) -> alphas (P, E, H)."""
    dst = block.edges[..., 1]
    s = jnp.where(block.edge_mask[..., None], scores, NEG)
    smax = _seg(jax.ops.segment_max, s, dst, block.n_local)
    smax = jnp.where(smax <= NEG / 2, 0.0, smax)
    e = jnp.exp(s - jnp.take_along_axis(smax, dst[..., None], axis=1))
    e = jnp.where(block.edge_mask[..., None], e, 0.0)
    z = _seg(jax.ops.segment_sum, e, dst, block.n_local)
    return e / jnp.maximum(jnp.take_along_axis(z, dst[..., None], axis=1), 1e-16)
