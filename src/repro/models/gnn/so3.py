r"""Real spherical harmonics + equivariant bilinear (Gaunt/CG) coefficients, l <= 2.

NequIP needs, per edge, the tensor product  (node irreps) x (edge SH)  projected
onto output irreps. For each triple (l1, l2, l3) the space of equivariant bilinear
maps  l1 (x) l2 -> l3  is 1-dimensional; we compute a basis tensor numerically as
the *Gaunt coefficients*

    C[m1, m2, m3] = \int  Y_{l1 m1}  Y_{l2 m2}  Y_{l3 m3}  dOmega,

evaluated exactly by Gauss-Legendre x uniform-phi product quadrature (the
integrand is a spherical polynomial of degree <= 6 for l <= 2), then normalized to
unit Frobenius norm. This is equivalent to the real Clebsch-Gordan tensor up to
the per-path scale, which NequIP's learned radial weights absorb. Equivariance is
verified numerically in tests via least-squares Wigner-D matrices.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np
import jax.numpy as jnp

# orthonormal real spherical harmonics (Condon-Shortley-free real convention)
_C0 = 0.28209479177387814          # 1/sqrt(4 pi)
_C1 = 0.4886025119029199           # sqrt(3/(4 pi))
_C2A = 1.0925484305920792          # sqrt(15/(4 pi))
_C2B = 0.31539156525252005         # sqrt(5/(16 pi))
_C2C = 0.5462742152960396          # sqrt(15/(16 pi))


def real_sh_np(vec: np.ndarray, l_max: int = 2) -> np.ndarray:
    """Real SH of *unit* vectors. vec: (..., 3) -> (..., (l_max+1)^2).
    Order: [Y00 | Y1,-1 Y1,0 Y1,1 | Y2,-2 .. Y2,2] with (x,y,z) components."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    out = [np.full(x.shape, _C0)]
    if l_max >= 1:
        out += [_C1 * y, _C1 * z, _C1 * x]
    if l_max >= 2:
        out += [_C2A * x * y, _C2A * y * z, _C2B * (3 * z ** 2 - 1),
                _C2A * x * z, _C2C * (x ** 2 - y ** 2)]
    return np.stack(out, axis=-1)


def real_sh(vec, l_max: int = 2):
    """jnp version of :func:`real_sh_np` (for in-model evaluation)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    out = [jnp.full(x.shape, _C0)]
    if l_max >= 1:
        out += [_C1 * y, _C1 * z, _C1 * x]
    if l_max >= 2:
        out += [_C2A * x * y, _C2A * y * z, _C2B * (3 * z ** 2 - 1),
                _C2A * x * z, _C2C * (x ** 2 - y ** 2)]
    return jnp.stack(out, axis=-1)


def sh_slice(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


@lru_cache(maxsize=None)
def _quad_points(n_theta: int = 12, n_phi: int = 25):
    ct, wt = np.polynomial.legendre.leggauss(n_theta)   # cos(theta) nodes
    phi = 2 * np.pi * np.arange(n_phi) / n_phi
    wphi = 2 * np.pi / n_phi
    st = np.sqrt(1 - ct ** 2)
    x = st[:, None] * np.cos(phi)[None, :]
    y = st[:, None] * np.sin(phi)[None, :]
    z = np.broadcast_to(ct[:, None], x.shape)
    w = np.broadcast_to(wt[:, None] * wphi, x.shape)
    pts = np.stack([x, y, z], axis=-1).reshape(-1, 3)
    return pts, w.reshape(-1).copy()


@lru_cache(maxsize=None)
def gaunt(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Unit-Frobenius equivariant bilinear tensor (2l1+1, 2l2+1, 2l3+1), or None
    if the triple is not coupled (selection rules / vanishing integral)."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2) or (l1 + l2 + l3) % 2 == 1:
        return None
    pts, w = _quad_points()
    sh = real_sh_np(pts, max(l1, l2, l3))
    y1 = sh[:, sh_slice(l1)]
    y2 = sh[:, sh_slice(l2)]
    y3 = sh[:, sh_slice(l3)]
    c = np.einsum("q,qa,qb,qc->abc", w, y1, y2, y3)
    norm = np.linalg.norm(c)
    if norm < 1e-10:
        return None
    return (c / norm).astype(np.float32)


def coupled_paths(l_in: tuple[int, ...], l_sh: tuple[int, ...],
                  l_out: tuple[int, ...]) -> list[tuple[int, int, int]]:
    """All (l1, l2, l3) triples with a nonzero Gaunt tensor."""
    out = []
    for a in l_in:
        for b in l_sh:
            for c in l_out:
                if gaunt(a, b, c) is not None:
                    out.append((a, b, c))
    return out


def wigner_d_numeric(rot: np.ndarray, l: int) -> np.ndarray:
    """(2l+1, 2l+1) real Wigner-D of rotation matrix ``rot`` via least squares over
    sample directions: Y_l(R r) = D_l(R) Y_l(r). Test-only utility."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(max(64, 4 * (2 * l + 1) ** 2), 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    a = real_sh_np(v, l)[:, sh_slice(l)]
    b = real_sh_np(v @ rot.T, l)[:, sh_slice(l)]
    d, *_ = np.linalg.lstsq(a, b, rcond=None)
    return d.T
