"""DLRM (MLPerf config) with model-parallel embedding tables in pure JAX.

JAX has no ``nn.EmbeddingBag`` and no CSR sparse — the embedding lookup layer
here IS part of the system (kernel_taxonomy §RecSys):

  * all 26 tables live concatenated in one ``(total_rows, d)`` array,
    **row-sharded over the whole mesh** (the tables dominate memory: the
    MLPerf Criteo sizes sum to ~188M rows -> ~96 GB fp32);
  * lookup is the classic model-parallel exchange, written explicitly under
    ``shard_map``: replicate the flat id vector (all_gather, ints are tiny),
    partial-gather each device's resident rows with ``jnp.take``, then
    ``psum_scatter`` the partial embeddings — summing the one non-zero
    contribution per row *and* landing the result batch-sharded for the
    data-parallel MLPs in a single fused collective. Backward is the mirrored
    all_gather (autodiff of the collective), which routes each row-gradient
    back to its owner — no parameter all-reduce ever touches the tables;
  * multi-hot bags reduce with ``jax.ops.segment_sum`` over static segment
    ids (sum mode), matching ``EmbeddingBag`` semantics.

The dense substrate (bottom/top MLP, dot interaction) is data-parallel over
the full flattened mesh with replicated weights + gradient ``psum``.

This file also hosts the *beyond-paper* Sylvie tie-in: the embedding exchange
is an activation collective with exactly the halo-exchange structure, so the
Low-bit Module can quantize it (``quantize_collective`` flag; off by
default).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..nn import mlp, mlp_init
from ...core import quantization as qlib
from ...dist import compat

# MLPerf DLRM (Criteo Terabyte) per-field vocabulary sizes.
CRITEO_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36)


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    embed_dim: int = 128
    table_sizes: Sequence[int] = CRITEO_TABLE_SIZES
    bot_mlp: Sequence[int] = (512, 256, 128)
    top_mlp: Sequence[int] = (1024, 1024, 512, 256, 1)
    hot: Sequence[int] | int = 1          # per-field multi-hot bag size
    quantize_collective_bits: Optional[int] = None   # beyond-paper Sylvie

    @property
    def n_sparse(self) -> int:
        return len(self.table_sizes)

    @property
    def hots(self) -> tuple[int, ...]:
        if isinstance(self.hot, int):
            return (self.hot,) * self.n_sparse
        return tuple(self.hot)

    @property
    def total_ids_per_sample(self) -> int:
        return sum(self.hots)

    @property
    def total_rows(self) -> int:
        return int(sum(self.table_sizes))

    @property
    def row_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.table_sizes)]).astype(np.int64)

    @property
    def interaction_dim(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2 + self.embed_dim

    def param_count(self) -> int:
        n = self.total_rows * self.embed_dim
        dims = [self.n_dense, *self.bot_mlp]
        n += sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        dims = [self.interaction_dim, *self.top_mlp]
        n += sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        return n


def rows_per_device(cfg: DLRMConfig, n_dev: int) -> int:
    return (cfg.total_rows + n_dev - 1) // n_dev


def init_dense_params(key, cfg: DLRMConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"bot": mlp_init(k1, [cfg.n_dense, *cfg.bot_mlp], dtype=dtype),
            "top": mlp_init(k2, [cfg.interaction_dim, *cfg.top_mlp], dtype=dtype)}


def init_table(key, cfg: DLRMConfig, n_dev: int = 1, dtype=jnp.float32):
    """(n_dev * rows_per_device, d) — padded so the row shard is even."""
    rows = rows_per_device(cfg, n_dev) * n_dev
    return (jax.random.uniform(key, (rows, cfg.embed_dim), jnp.float32,
                               -0.05, 0.05)).astype(dtype)


# ---------------------------------------------------------------------------
# model-parallel embedding-bag
# ---------------------------------------------------------------------------


def _axis_index(axis_name):
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    idx = jax.lax.axis_index(names[0])
    for a in names[1:]:
        idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _exchange_fwd_wire(x, axis_name, bits):
    """Forward wire of the embedding exchange. Every output row has exactly
    ONE non-zero contributor (its owner), so summing is lossless even in a
    narrower dtype: ``bits=16`` runs the psum_scatter itself in bf16
    (wire /2 vs f32; the single contributing value is bf16-rounded once)."""
    if bits is not None and bits <= 16:
        y = jax.lax.psum_scatter(x.astype(jnp.bfloat16), axis_name,
                                 scatter_dimension=0, tiled=True)
        return y.astype(x.dtype)
    return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def sylvie_embedding_exchange(part, axis_name, bits, key):
    """psum_scatter whose BACKWARD all-gather carries a b-bit packed payload
    (beyond-paper: the paper's Low-bit Module applied to DLRM's dominant
    collective). Forward: bf16 wire (lossless-in-expectation here — one
    contributor per row). Backward: the cotangent is quantized with
    stochastic rounding, the PACKED uint8 payload + bf16 scales cross the
    all-gather, and owners dequantize — unbiased, exactly Alg. 2's gradient
    communication."""
    del key
    return _exchange_fwd_wire(part, axis_name, bits)


def _see_fwd(part, axis_name, bits, key):
    return sylvie_embedding_exchange(part, axis_name, bits, key), key


def _see_bwd(axis_name, bits, key, g):
    if bits is None or bits > 16:
        gg = jax.lax.all_gather(g, axis_name, tiled=True)
        return (gg, None)
    if bits == 16:
        gg = jax.lax.all_gather(g.astype(jnp.bfloat16), axis_name,
                                tiled=True)
        return (gg.astype(g.dtype), None)
    qt = qlib.quantize(g, bits, key)
    data = jax.lax.all_gather(qt.data, axis_name, tiled=True)
    scale = jax.lax.all_gather(qt.scale, axis_name, tiled=True)
    zero = jax.lax.all_gather(qt.zero, axis_name, tiled=True)
    from ...core.quantization import QuantizedTensor
    gg = qlib.dequantize(QuantizedTensor(data, scale, zero, qt.bits,
                                         qt.feat_dim), g.dtype)
    return (gg, None)


sylvie_embedding_exchange.defvjp(_see_fwd, _see_bwd)


def _maybe_quantized_psum_scatter(x, axis_name, bits, key):
    """The embedding exchange; optionally Sylvie-quantized (beyond-paper)."""
    if bits is None:
        return jax.lax.psum_scatter(x, axis_name, scatter_dimension=0,
                                    tiled=True)
    if key is None:
        key = jax.random.PRNGKey(0)
    return sylvie_embedding_exchange(x, axis_name, bits, key)


def embedding_bag(table, flat_ids_local, cfg: DLRMConfig, axis_name,
                  key=None):
    """flat_ids_local: (n_local,) int32 *global* row ids for this device's
    batch slice -> (n_local, d) bag-input rows, batch-sharded.

    Single-process (axis_name=None): plain take. Distributed: all_gather ids,
    partial local gather, psum_scatter partials (see module docstring)."""
    if axis_name is None:
        return jnp.take(table, flat_ids_local, axis=0)
    ids = jax.lax.all_gather(flat_ids_local, axis_name, tiled=True)  # (n_glob,)
    rpd = table.shape[0]
    lo = _axis_index(axis_name) * rpd
    loc = ids - lo
    ok = (loc >= 0) & (loc < rpd)
    part = jnp.where(ok[:, None], jnp.take(table, jnp.where(ok, loc, 0), axis=0),
                     0)
    return _maybe_quantized_psum_scatter(
        part, axis_name, cfg.quantize_collective_bits, key)


def bag_reduce(rows, cfg: DLRMConfig, batch: int):
    """(batch * total_ids, d) -> (batch, n_sparse, d) sum-bags via segment_sum."""
    seg_field = np.repeat(np.arange(cfg.n_sparse), cfg.hots)      # (ids/sample,)
    seg = (np.arange(batch)[:, None] * cfg.n_sparse + seg_field[None, :])
    seg = jnp.asarray(seg.reshape(-1), jnp.int32)
    out = jax.ops.segment_sum(rows, seg, num_segments=batch * cfg.n_sparse)
    return out.reshape(batch, cfg.n_sparse, cfg.embed_dim)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def dot_interaction(bot_out, emb):
    """bot_out (B, d); emb (B, F, d) -> (B, F+1 choose 2 + d)."""
    z = jnp.concatenate([bot_out[:, None, :], emb], axis=1)       # (B, F+1, d)
    g = jnp.einsum("bfd,bgd->bfg", z, z)
    f = z.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    pairs = g[:, iu, ju]
    return jnp.concatenate([bot_out, pairs], axis=-1)


def dlrm_forward(dense_params, table, dense_x, flat_ids, cfg: DLRMConfig,
                 axis_name=None, key=None):
    """dense_x (B_local, n_dense); flat_ids (B_local * total_ids,) -> logits."""
    b = dense_x.shape[0]
    bot = mlp(dense_params["bot"], dense_x)                       # (B, d)
    rows = embedding_bag(table, flat_ids, cfg, axis_name, key)
    emb = bag_reduce(rows, cfg, b)
    feats = dot_interaction(bot, emb)
    return mlp(dense_params["top"], feats)[:, 0]                  # (B,)


def bce_loss(logits, labels):
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_train_step(cfg: DLRMConfig, optimizer, axis_name=None):
    """State: (dense_params, table, opt_dense, opt_table, step).

    The loss is sum-form normalized by the *global* batch, so per-device
    gradients are exact global-mean contributions; the replicated dense
    params' gradients are explicitly psummed (shard_map runs with replication
    checking off — see repro.dist.compat.shard_map), and the table grads stay
    local — each device owns its rows (the embedding collective's backward
    routes contributions to owners)."""
    def train_step(state, dense_x, flat_ids, labels, key):
        dense_params, table, opt_d, opt_t, step = state
        n_dev = 1
        if axis_name is not None:
            names = ((axis_name,) if isinstance(axis_name, str)
                     else tuple(axis_name))
            for a in names:
                n_dev *= compat.axis_size(a)

        def loss_fn(dp, tb):
            logits = dlrm_forward(dp, tb, dense_x, flat_ids, cfg, axis_name,
                                  key)
            return bce_loss(logits, labels) / n_dev

        loss, (gd, gt) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            dense_params, table)
        if axis_name is not None:
            loss = jax.lax.psum(loss, axis_name)
            gd = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), gd)
        upd_d, opt_d = optimizer.update(gd, opt_d, dense_params)
        upd_t, opt_t = optimizer.update(gt, opt_t, table)
        from ...train.optimizer import apply_updates
        dense_params = apply_updates(dense_params, upd_d)
        table = apply_updates(table, upd_t)
        return (dense_params, table, opt_d, opt_t, step + 1), loss

    return train_step


def make_serve_step(cfg: DLRMConfig, axis_name=None):
    def serve(dense_params, table, dense_x, flat_ids):
        logits = dlrm_forward(dense_params, table, dense_x, flat_ids, cfg,
                              axis_name)
        return jax.nn.sigmoid(logits)
    return serve


def make_retrieval_step(cfg: DLRMConfig, axis_name=None, top_k: int = 64,
                        cand_field: int = 0):
    """Score one query against n_cand candidates (batch of candidate ids for
    field ``cand_field``; the other 25 fields + dense features come from the
    query). Candidates stay sharded; per-shard top-k then a gathered merge."""
    def retrieval(dense_params, table, dense_x, flat_ids, cand_ids):
        # query embedding context: (1, F, d) + bottom output (1, d)
        bot = mlp(dense_params["bot"], dense_x)                   # (1, d)
        rows = embedding_bag(table, flat_ids, cfg, axis_name)
        emb = bag_reduce(rows, cfg, 1)                            # (1, F, d)
        # candidate rows (n_local, d): ids are already batch-sharded
        cand = embedding_bag(table, cand_ids, cfg, axis_name)
        n = cand.shape[0]
        embn = jnp.broadcast_to(emb, (n,) + emb.shape[1:])
        embn = embn.at[:, cand_field, :].set(cand)
        feats = dot_interaction(jnp.broadcast_to(bot, (n, bot.shape[-1])), embn)
        scores = mlp(dense_params["top"], feats)[:, 0]            # (n_local,)
        v, i = jax.lax.top_k(scores, min(top_k, n))
        ids = cand_ids[i]
        if axis_name is not None:
            v = jax.lax.all_gather(v, axis_name, tiled=True)
            ids = jax.lax.all_gather(ids, axis_name, tiled=True)
            # gathered copies are identical on every device; pmean/pmax make
            # that replication *provable* to shard_map's VMA checker so the
            # merged top-k can leave with out_specs=P()
            v = jax.lax.pmean(v, axis_name)
            ids = jax.lax.pmax(ids, axis_name)
            v, sel = jax.lax.top_k(v, top_k)
            ids = ids[sel]
        return v, ids
    return retrieval
