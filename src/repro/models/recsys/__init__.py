from . import dlrm  # noqa: F401
