"""Exporters: Chrome/Perfetto trace JSON, flat metrics JSON, renderers.

Artifacts live under ``artifacts/obs/<run>/`` (untracked; the directory's
tracked README documents the layout), one pair per traced cell/run:

* ``<name>.trace.json`` — Chrome ``trace_event`` format (open in Perfetto or
  ``chrome://tracing``): ``{"traceEvents": [{"name", "ph", "ts", "dur",
  "pid", "tid", "args"}], "displayTimeUnit": "ms"}``, timestamps in µs.
* ``<name>.metrics.json`` — the metrics-registry snapshot plus the
  **modeled-vs-measured join**: each epoch's measured wall-clock span against
  the scenario report's ``modeled_tpu_comm_exposed_s`` / ``overlapped_s``, so
  modeled-vs-reality drift is a single queryable number (``drift_s``) instead
  of two JSON files someone has to correlate by hand. The file is
  self-contained — :func:`render_summary` needs no scenario report.

The CLI (``python -m repro.obs``) renders these: ``summarize`` tabulates
every metrics file in a directory, ``timeline`` draws a trace as an ASCII
gantt, ``diff`` compares two metrics snapshots counter by counter.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Sequence

SCHEMA = "repro.obs/1"


def default_obs_dir() -> Path:
    """``<repo>/artifacts/obs`` (tracked README explains the layout)."""
    return Path(__file__).resolve().parents[3] / "artifacts" / "obs"


# ---------------------------------------------------------------------------
# writers
# ---------------------------------------------------------------------------
def to_trace_events(events: Sequence[dict], pid: int = 0) -> list[dict]:
    """Tracer events (seconds) -> Chrome ``trace_event`` dicts (µs ints)."""
    out = []
    for ev in events:
        te = {"name": ev["name"], "ph": ev["ph"],
              "ts": int(round(ev["ts"] * 1e6)),
              "pid": pid, "tid": ev.get("tid", 0)}
        if ev["ph"] == "X":
            te["dur"] = max(int(round(ev["dur"] * 1e6)), 0)
        if ev.get("args"):
            te["args"] = ev["args"]
        out.append(te)
    return out


def write_trace(path, events: Sequence[dict], pid: int = 0) -> Path:
    """Write a Perfetto-loadable trace file; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    body = {"traceEvents": to_trace_events(events, pid=pid),
            "displayTimeUnit": "ms"}
    path.write_text(json.dumps(body, indent=1, default=float))
    return path


def modeled_vs_measured(epoch_wall_s: Sequence[float], exposed_s: float,
                        overlapped_s: float) -> dict:
    """Join measured per-epoch wall time against the modeled comm split.

    The modeled numbers are per-epoch constants (bytes/BW under the traced
    decision; DESIGN §8/§14); the measured walls vary. ``drift_s`` =
    mean measured wall − modeled exposed comm: the single number that says
    how far the comm model sits from this machine's reality (large positive
    on CPU, where compute dwarfs the modeled TPU wire time — that gap *is*
    the §8 caveat, now queryable per run)."""
    walls = [float(w) for w in epoch_wall_s]
    mean_wall = sum(walls) / len(walls) if walls else 0.0
    return {
        "epochs": [{"epoch": i, "wall_s": w,
                    "modeled_exposed_s": float(exposed_s),
                    "modeled_overlapped_s": float(overlapped_s),
                    "drift_s": w - float(exposed_s)}
                   for i, w in enumerate(walls)],
        "n_epochs": len(walls),
        "mean_wall_s": mean_wall,
        "modeled_exposed_s": float(exposed_s),
        "modeled_overlapped_s": float(overlapped_s),
        "drift_s": mean_wall - float(exposed_s),
    }


def write_metrics(path, *, metrics: dict, run: Optional[str] = None,
                  merge: Optional[dict] = None,
                  trace_path: Optional[str] = None) -> Path:
    """Write the flat metrics JSON (registry snapshot + optional
    modeled-vs-measured join); returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    body = {"schema": SCHEMA, "run": run, "metrics": metrics,
            "modeled_vs_measured": merge, "trace_path": trace_path}
    path.write_text(json.dumps(body, indent=1, default=float))
    return path


# ---------------------------------------------------------------------------
# readers / renderers (the CLI's meat — pure functions returning strings)
# ---------------------------------------------------------------------------
def load_metrics(path) -> dict:
    body = json.loads(Path(path).read_text())
    if body.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} metrics file "
                         f"(schema={body.get('schema')!r})")
    return body


def metrics_files(directory) -> list[Path]:
    return sorted(Path(directory).glob("*.metrics.json"))


def render_summary(directory) -> str:
    """One line per metrics file: measured epoch wall joined against the
    modeled exposed/overlapped split, plus the headline counters."""
    files = metrics_files(directory)
    if not files:
        raise FileNotFoundError(
            f"no *.metrics.json under {directory} — run a scenario with "
            "--obs first (e.g. python -m repro.launch.train --scenario "
            "smoke --obs)")
    lines = [f"obs summary: {directory} ({len(files)} run(s))",
             f"{'run':58s} {'epochs':>6s} {'wall/ep':>10s} "
             f"{'exposed':>10s} {'overlap':>10s} {'drift':>10s} "
             f"{'retrace':>7s}"]
    for f in files:
        body = load_metrics(f)
        run = body.get("run") or f.name[:-len(".metrics.json")]
        mm = body.get("modeled_vs_measured") or {}
        counters = body.get("metrics", {}).get("counters", {})
        retraces = sum(v for k, v in counters.items()
                       if k.startswith("retrace."))
        lines.append(
            f"{run:58s} {mm.get('n_epochs', 0):6d} "
            f"{mm.get('mean_wall_s', 0.0):9.4f}s "
            f"{mm.get('modeled_exposed_s', 0.0):9.6f}s "
            f"{mm.get('modeled_overlapped_s', 0.0):9.6f}s "
            f"{mm.get('drift_s', 0.0):9.4f}s {retraces:7d}")
    return "\n".join(lines)


def load_trace(path) -> list[dict]:
    body = json.loads(Path(path).read_text())
    events = body.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents array — not a "
                         "trace_event JSON")
    return events


def render_timeline(path, width: int = 64,
                    limit: Optional[int] = None) -> str:
    """ASCII gantt of a trace file: one row per event, bar position/length
    proportional to ts/dur over the trace's span. Instant events render as a
    single tick. ``limit`` caps the rows (traces can hold thousands)."""
    events = [e for e in load_trace(path) if e["ph"] in ("X", "i")]
    if not events:
        return f"{path}: empty trace"
    t0 = min(e["ts"] for e in events)
    t1 = max(e["ts"] + e.get("dur", 0) for e in events)
    span = max(t1 - t0, 1)
    shown = events if limit is None else events[:limit]
    lines = [f"timeline: {path} ({len(events)} events, "
             f"{span / 1e3:.3f} ms)"]
    for e in shown:
        off = int((e["ts"] - t0) / span * width)
        if e["ph"] == "i":
            bar = " " * off + "|"
        else:
            n = max(int(e.get("dur", 0) / span * width), 1)
            bar = " " * off + "#" * min(n, width - off or 1)
        dur_ms = e.get("dur", 0) / 1e3
        lines.append(f"{e['name']:24.24s} [{bar:<{width}s}] {dur_ms:9.3f} ms")
    if limit is not None and len(events) > limit:
        lines.append(f"... {len(events) - limit} more (raise --limit)")
    return "\n".join(lines)


def render_diff(path_a, path_b) -> str:
    """Counter-by-counter delta between two metrics snapshots (b − a)."""
    a, b = load_metrics(path_a), load_metrics(path_b)
    ca = a.get("metrics", {}).get("counters", {})
    cb = b.get("metrics", {}).get("counters", {})
    names = sorted(set(ca) | set(cb))
    lines = [f"diff: {path_a} -> {path_b}",
             f"{'counter':40s} {'a':>12s} {'b':>12s} {'delta':>12s}"]
    for n in names:
        va, vb = ca.get(n, 0), cb.get(n, 0)
        lines.append(f"{n:40s} {va:12g} {vb:12g} {vb - va:+12g}")
    ma = (a.get("modeled_vs_measured") or {})
    mb = (b.get("modeled_vs_measured") or {})
    if ma or mb:
        da, db = ma.get("drift_s", 0.0), mb.get("drift_s", 0.0)
        lines.append(f"{'drift_s':40s} {da:12.4f} {db:12.4f} "
                     f"{db - da:+12.4f}")
    return "\n".join(lines)
