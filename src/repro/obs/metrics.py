"""Typed metrics registry: counters, gauges, histograms, TRACE_LOG shims.

One process-global :class:`MetricsRegistry` (module functions below) absorbs
the scattered ad-hoc accounting the layers used to keep privately:

* **retraces** — the two historical ``TRACE_LOG`` lists (``train/gnn_step``,
  ``serve/engine``) are now :class:`TraceLog` instances: list subclasses
  whose ``append`` *also* bumps ``retrace.<scope>`` and emits a ``retrace``
  instant event when tracing is armed. Everything that counted entries
  (``tests/test_policy``'s recompile guards, RC204/RC207/RC209) keeps
  working — ``len``/``clear``/iteration are untouched list semantics;
* **faults** — ``faults.injected`` / ``faults.halos_reused`` /
  ``faults.forced_syncs`` from the trainer's arming seam;
* **store** — ``store.hits`` / ``store.miss_bytes`` from the sharded
  embedding store's read path;
* **serve** — ``serve.rejected.<reason>`` per typed admission rejection.

Unlike the span tracer, the registry is *always on*: a counter bump is one
dict lookup and an integer add on host code that is already Python — cheap
enough to leave armed, and the accounting must not silently vanish when
tracing is off. :func:`reset` zeroes everything in place (instruments are
looked up by name at each seam, so no stale handle survives a reset).

Pure stdlib; no jax, no repro imports except :mod:`repro.obs.spans`.
"""
from __future__ import annotations

import threading
from typing import Optional

from . import spans as _spans


class Counter:
    """Monotonic counter (ints or floats)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Streaming summary: count/sum/min/max (no buckets — the exporters
    report the summary, the trace carries the raw spans)."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min, "max": self.max,
                "mean": self.total / self.count if self.count else 0.0}


class MetricsRegistry:
    """Name -> instrument maps, created on first touch, thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def _get(self, table: dict, name: str, factory):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, factory())
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(self._hists, name, Histogram)

    def reset(self) -> None:
        """Zero every instrument in place (names survive, values reset)."""
        with self._lock:
            for c in self._counters.values():
                c.value = 0
            for g in self._gauges.values():
                g.value = 0.0
            for h in self._hists.values():
                h.count, h.total, h.min, h.max = 0, 0.0, None, None

    def snapshot(self) -> dict:
        """Flat JSON-ready view: {"counters": {...}, "gauges": {...},
        "histograms": {name: summary}}. Zero-valued counters are kept — a
        zero is evidence the seam ran and saw nothing, absence is not."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._hists.items())},
        }


REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def count(name: str, n=1) -> None:
    """Bump a named counter (the one-line instrumentation seam)."""
    REGISTRY.counter(name).inc(n)


def observe(name: str, v) -> None:
    REGISTRY.histogram(name).observe(v)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset_metrics() -> None:
    REGISTRY.reset()


class TraceLog(list):
    """Drop-in replacement for the bare ``TRACE_LOG: list[str]`` lists.

    A real ``list`` — ``len``/``clear``/slicing/equality all behave — whose
    ``append`` additionally counts a ``retrace.<scope>`` metric and, when
    tracing is armed, emits a ``retrace`` instant event. The append happens
    at *trace time* (the step body's Python runs only when jit traces), so
    each entry marks one freshly compiled executable — the recompile-budget
    contracts (RC204/RC207/RC209) and ``tests/test_policy`` count exactly
    these."""

    def __init__(self, scope: str):
        super().__init__()
        self.scope = scope

    def append(self, tag) -> None:
        super().append(tag)
        REGISTRY.counter(f"retrace.{self.scope}").inc()
        _spans.event("retrace", {"scope": self.scope, "tag": str(tag)})
