"""repro.obs — unified tracing, metrics, and timeline export (DESIGN §15).

Three small pieces, all stdlib-only (no jax, no other repro imports — every
layer may depend on this one):

* :mod:`.spans` — the zero-overhead-when-disabled span/event tracer with an
  injectable monotonic clock (arm with :func:`enable`, read time through
  :func:`clock`);
* :mod:`.metrics` — the always-on typed counter/gauge/histogram registry,
  plus the :class:`TraceLog` list shims that superseded the two historical
  ``TRACE_LOG``s;
* :mod:`.export` — Chrome/Perfetto ``trace_event`` JSON + flat metrics JSON
  writers and the modeled-vs-measured drift join, rendered by
  ``python -m repro.obs summarize|timeline|diff``.
"""
from .spans import (  # noqa: F401
    NULL_SPAN,
    FakeClock,
    Tracer,
    clock,
    current,
    disable,
    drain,
    enable,
    enabled,
    event,
    span,
)
from .metrics import (  # noqa: F401
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    TraceLog,
    count,
    counter,
    gauge,
    histogram,
    observe,
    reset_metrics,
    snapshot,
)
from .export import (  # noqa: F401
    default_obs_dir,
    modeled_vs_measured,
    write_metrics,
    write_trace,
)

__all__ = [
    "NULL_SPAN", "FakeClock", "Tracer",
    "clock", "current", "disable", "drain", "enable", "enabled", "event",
    "span",
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TraceLog", "count", "counter", "gauge", "histogram", "observe",
    "reset_metrics", "snapshot",
    "default_obs_dir", "modeled_vs_measured", "write_metrics", "write_trace",
]
