"""CLI: render observability artifacts.

    python -m repro.obs summarize [DIR]            # default artifacts/obs
    python -m repro.obs timeline TRACE.json [--width N] [--limit N]
    python -m repro.obs diff A.metrics.json B.metrics.json

Exit codes: 0 on success, 2 on missing/invalid artifacts — so CI lanes can
gate on "the smoke run actually produced renderable telemetry".
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import export


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs",
                                description=__doc__.splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    ps = sub.add_parser("summarize",
                        help="tabulate every *.metrics.json in a directory")
    ps.add_argument("dir", nargs="?", default=None,
                    help="directory of metrics files "
                         "(default: artifacts/obs, searched recursively)")

    pt = sub.add_parser("timeline",
                        help="render a trace file as an ASCII gantt")
    pt.add_argument("trace", help="a *.trace.json file")
    pt.add_argument("--width", type=int, default=64)
    pt.add_argument("--limit", type=int, default=80,
                    help="max rows (0 = unlimited)")

    pd = sub.add_parser("diff",
                        help="counter-by-counter delta of two metrics files")
    pd.add_argument("a")
    pd.add_argument("b")

    args = p.parse_args(argv)
    try:
        if args.cmd == "summarize":
            if args.dir is not None:
                print(export.render_summary(args.dir))
            else:
                # default: every scenario subdirectory under artifacts/obs
                root = export.default_obs_dir()
                dirs = sorted({f.parent
                               for f in root.rglob("*.metrics.json")})
                if not dirs:
                    raise FileNotFoundError(
                        f"no *.metrics.json under {root} — run a scenario "
                        "with --obs first")
                print("\n\n".join(export.render_summary(d) for d in dirs))
        elif args.cmd == "timeline":
            limit = None if args.limit == 0 else args.limit
            print(export.render_timeline(Path(args.trace),
                                         width=args.width, limit=limit))
        elif args.cmd == "diff":
            print(export.render_diff(Path(args.a), Path(args.b)))
    except (FileNotFoundError, ValueError, OSError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
