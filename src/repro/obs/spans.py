"""Span/event tracer: zero-overhead when disabled, injectable clock.

One process-global :class:`Tracer` (armed via :func:`enable`, torn down via
:func:`disable`) collects **spans** (named intervals with a start and a
duration) and **instant events** into thread-local buffers. The taxonomy the
instrumented layers emit:

* training — ``epoch > decide > step`` (+ ``halo.issue``/``halo.land``
  trace-time events from ``dist/overlap.py``, and ``retrace`` events from the
  :class:`~repro.obs.metrics.TraceLog` shims);
* serving — ``request > lookup`` on the request path, ``admit`` on submit,
  ``refresh > plan > sweep`` on the update path.

Design rules (DESIGN.md §15):

* **disabled = free.** :func:`span` with no tracer armed returns one shared
  :class:`_NullSpan` singleton — no allocation, no clock read, no branch
  beyond the ``None`` check. ``args`` is a positional optional (never
  ``**kwargs``) so the disabled call builds no dict.
* **host-side only.** Instrumentation lives in host orchestration code or at
  trace time (the same seams as the ``TRACE_LOG`` appends); it must never
  lower into a traced program — contract RC210 holds training and serving
  jaxprs identical with tracing on and off.
* **injectable clock.** Every timestamp comes from the tracer's monotonic
  ``clock`` (default ``time.perf_counter``); :class:`FakeClock` substitutes a
  deterministic one for tests, with a ``sleep`` that advances fake time so
  load generators idle without real waits.

Thread safety: each thread appends to its own buffer (created under a lock,
appended to lock-free — list.append is atomic under the GIL); :func:`drain`
merges and time-sorts all buffers.

This module is pure stdlib — it imports neither jax nor any repro layer, so
every layer may import it without cycles.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional


class _NullSpan:
    """The disabled-tracer span: a shared, stateless context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One live span: clocks itself on enter/exit, records on exit."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer.clock()
        self._tracer._record(self.name, self._t0, t1 - self._t0, self.args)
        return False


class FakeClock:
    """Deterministic injectable clock for tests.

    Calling it returns the current fake time; ``sleep`` advances it (so code
    that idles via ``clock.sleep`` makes progress without wall waits);
    ``advance`` moves it explicitly. ``tick`` (optional) auto-advances every
    read, guaranteeing strictly increasing stamps for code that polls."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        now = self.t
        self.t += self.tick
        return now

    def sleep(self, seconds: float) -> None:
        self.t += max(float(seconds), 0.0)

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)


class Tracer:
    """Span/event collector with per-thread buffers and an injectable clock.

    Events are dicts in the Chrome ``trace_event`` shape (``ph``: ``"X"`` =
    complete span, ``"i"`` = instant), timestamps in *seconds* on the
    tracer's clock — ``repro.obs.export`` converts to the format's µs."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock: Callable[[], float] = \
            clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._buffers: dict[int, list[dict]] = {}

    def _buf(self) -> list[dict]:
        tid = threading.get_ident()
        buf = self._buffers.get(tid)
        if buf is None:
            with self._lock:
                buf = self._buffers.setdefault(tid, [])
        return buf

    def _record(self, name: str, ts: float, dur: float,
                args: Optional[dict]) -> None:
        ev: dict[str, Any] = {"name": name, "ph": "X", "ts": ts, "dur": dur,
                              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        self._buf().append(ev)

    def span(self, name: str, args: Optional[dict] = None) -> _Span:
        return _Span(self, name, args)

    def event(self, name: str, args: Optional[dict] = None) -> None:
        ev: dict[str, Any] = {"name": name, "ph": "i", "ts": self.clock(),
                              "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        self._buf().append(ev)

    def drain(self) -> list[dict]:
        """All recorded events, merged across threads and time-sorted;
        buffers are cleared."""
        with self._lock:
            bufs = list(self._buffers.values())
            self._buffers = {}
        out = [ev for buf in bufs for ev in buf]
        out.sort(key=lambda e: e["ts"])
        return out


# ---------------------------------------------------------------------------
# the process-global tracer (module functions are the instrumentation API)
# ---------------------------------------------------------------------------
_TRACER: Optional[Tracer] = None


def enable(clock: Optional[Callable[[], float]] = None) -> Tracer:
    """Arm tracing (replacing any active tracer). Returns the new tracer."""
    global _TRACER
    _TRACER = Tracer(clock=clock)
    return _TRACER


def disable() -> None:
    global _TRACER
    _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def current() -> Optional[Tracer]:
    return _TRACER


def span(name: str, args: Optional[dict] = None):
    """A span context manager — :data:`NULL_SPAN` when tracing is off (the
    allocation-free hot path)."""
    t = _TRACER
    return t.span(name, args) if t is not None else NULL_SPAN


def event(name: str, args: Optional[dict] = None) -> None:
    """Record an instant event; a no-op when tracing is off."""
    t = _TRACER
    if t is not None:
        t.event(name, args)


def clock() -> float:
    """The observability clock: the active tracer's (injectable,
    deterministic under :class:`FakeClock`) or ``time.perf_counter``.
    Instrumented modules read time through this — lint rule RA108 keeps raw
    ``time.time``/``time.perf_counter`` calls out of them."""
    t = _TRACER
    return t.clock() if t is not None else time.perf_counter()


def drain() -> list[dict]:
    """Drain the active tracer's events ([] when tracing is off)."""
    t = _TRACER
    return t.drain() if t is not None else []
