"""Seeded fault schedules and their expansion to per-row wire masks.

The chaos contract has two halves with very different trace disciplines:

* **scheduling is host-side and exactly reproducible** — a :class:`FaultPlan`
  is a frozen bag of rates plus a seed; ``plan.events(epoch, ...)`` draws the
  epoch's fault set from ``np.random.default_rng([seed, epoch])``, keyed on
  (site, direction, src partition, dst partition). Same plan, same epoch →
  the same faults, on any machine, in any process — the property every chaos
  test and the kill-and-resume harness lean on.
* **injection is traced data, never traced code** — an epoch's events are
  expanded (here, on the host) into per-site boolean row masks over the wire
  buffers (:class:`SiteFaults` / :class:`FaultCtl`, registered pytrees) that
  ride into the step as part of ``GNNTrainState.faults``. Two epochs with
  different fault sets therefore share one executable; the fault-free case
  (``faults=None``) traces the exact legacy program (``repro.analysis``
  contract RC208 pins both properties).

Fault taxonomy (DESIGN.md §12): ``drop`` (message lost → receiver reuses its
stale cached halo), ``corrupt`` (payload bit-flipped on the wire → detected by
the per-row checksum in ``faults/wire.py`` and handled exactly like a drop),
``delay`` (straggler: delivered, but stalls the epoch's critical path —
modeled, see :meth:`FaultEvents.stall_s`), and ``preempt`` (a whole partition
down for the epoch: every message to/from it folds into ``drop``).

Geometry: an event names an ordered message ``src → dst``; the masks must
land on the *rows* of each partition's send/recv buffers, which differ by
layout (dense pairwise blocks vs compact ring buckets) and by direction (the
backward gradient exchange runs the rings in reverse, so its send buffer has
recv-geometry and vice versa). :class:`RowGeometry` owns those maps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# direction indices into the (S, 2, P, P) event arrays
FWD, BWD = 0, 1


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, rate-parameterized chaos schedule. Frozen and hashable (it
    rides on :class:`~repro.faults.backend.FaultyBackend`, which keys jit
    caches and custom_vjp nondiff argnums).

    Rates are per ordered (site, direction, src, dst) message per epoch.
    ``escalate_after`` is the staleness-as-recovery escalation threshold: a
    site faulted for that many *consecutive* epochs forces one clean
    full-precision synchronous retry epoch (the trainer suppresses that
    epoch's schedule and counts its units as ``forced_syncs``).
    ``warmup_clean`` keeps epoch 0 fault-free — the halo caches a drop would
    fall back to do not exist before the first synchronous warmup epoch.
    """

    seed: int = 0
    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 0.05
    preempt_rate: float = 0.0
    escalate_after: int = 3
    warmup_clean: bool = True

    def events(self, epoch: int, n_sites: int, n_parts: int) -> "FaultEvents":
        """The epoch's fault set — deterministic in (seed, epoch) alone."""
        shape = (n_sites, 2, n_parts, n_parts)
        if epoch == 0 and self.warmup_clean:
            return FaultEvents(drop=np.zeros(shape, bool),
                               corrupt=np.zeros(shape, bool),
                               delay=np.zeros(shape, bool),
                               preempted=np.zeros(n_parts, bool))
        rng = np.random.default_rng([int(self.seed), int(epoch)])
        drop = rng.random(shape) < self.drop_rate
        preempted = rng.random(n_parts) < self.preempt_rate
        if preempted.any():
            # a preempted partition neither sends nor receives this epoch
            drop[:, :, preempted, :] = True
            drop[:, :, :, preempted] = True
        # corrupt/delay are drawn over all pairs but made disjoint from drop:
        # a lost message cannot also arrive corrupted or late, and the
        # accounting (`faults_injected == halos_reused + forced_syncs`)
        # counts each message unit at most once.
        corrupt = (rng.random(shape) < self.corrupt_rate) & ~drop
        delay = (rng.random(shape) < self.delay_rate) & ~drop
        off_diag = ~np.eye(n_parts, dtype=bool)
        return FaultEvents(drop=drop & off_diag, corrupt=corrupt & off_diag,
                           delay=delay & off_diag, preempted=preempted)

    @staticmethod
    def n_units(n_sites: int, n_parts: int) -> int:
        """Message units per epoch: ordered off-diagonal pairs, both
        directions, every site — the denominator of any drop-fraction claim."""
        return n_sites * 2 * n_parts * (n_parts - 1)


@dataclasses.dataclass(frozen=True)
class FaultEvents:
    """One epoch's fault set, keyed (site, direction, src, dst). Host arrays."""

    drop: np.ndarray        # (S, 2, P, P) bool — message lost
    corrupt: np.ndarray     # (S, 2, P, P) bool — payload bit-flipped (≠ drop)
    delay: np.ndarray       # (S, 2, P, P) bool — delivered late (≠ drop)
    preempted: np.ndarray   # (P,) bool — partition down this epoch

    @property
    def n_injected(self) -> int:
        """Injected fault units this epoch (drops + corruptions; a corrupted
        payload is detected and recovered exactly like a drop)."""
        return int(self.drop.sum() + self.corrupt.sum())

    def faulty_sites(self) -> np.ndarray:
        """(S,) bool — sites with at least one injected fault this epoch
        (the per-site staleness counters the escalation rule watches)."""
        return (self.drop | self.corrupt).any(axis=(1, 2, 3))

    def stall_s(self, delay_s: float) -> float:
        """Modeled straggler stall: every partition waits for its slowest
        inbound edge, so the epoch extends by ``delay_s`` times the deepest
        per-destination pile-up of delayed messages (the critical path), not
        the total count."""
        if not self.delay.any():
            return 0.0
        per_dst = self.delay.sum(axis=(0, 1, 2))
        return float(delay_s) * float(per_dst.max())


@dataclasses.dataclass(frozen=True)
class RowGeometry:
    """Host-side map from (src, dst) message pairs to wire-buffer rows.

    Built once per plan from :class:`~repro.core.exchange.PlanArrays` static
    metadata; both layouts reduce to two ``(P, rows)`` peer tables:

    * ``peer_recv[p, r]`` — the partition row ``r`` of ``p``'s *recv* buffer
      arrived from (dense: the pairwise block index ``r // h_pad``; compact:
      ``(p - k) % P`` for bucket ``k``);
    * ``peer_send[p, r]`` — where row ``r`` of ``p``'s *send* buffer goes
      (dense: the block index again; compact: ``(p + k) % P``).

    The backward gradient exchange runs the same wires in reverse, so its
    outgoing-gradient buffer has recv geometry and the returned-gradient
    buffer has send geometry — :func:`expand_events` encodes that flip.
    """

    n_parts: int
    halo_rows: int
    h_pad: int
    bucket_sizes: Optional[tuple[int, ...]]

    @staticmethod
    def from_plan(plan) -> "RowGeometry":
        return RowGeometry(
            n_parts=int(plan.n_parts), halo_rows=int(plan.halo_rows),
            h_pad=int(plan.h_pad),
            bucket_sizes=None if plan.bucket_sizes is None
            else tuple(int(b) for b in plan.bucket_sizes))

    def peers(self) -> tuple[np.ndarray, np.ndarray]:
        """(peer_recv, peer_send), each ``(P, rows)`` int64. Cached — the
        trainer expands masks against the same geometry every epoch."""
        return _peers_cached(self)

    def _peers(self) -> tuple[np.ndarray, np.ndarray]:
        p, rows = self.n_parts, self.halo_rows
        if self.bucket_sizes is None:
            block = np.arange(rows, dtype=np.int64) // self.h_pad
            peer = np.broadcast_to(block, (p, rows))
            return peer, peer
        offsets = np.concatenate(
            [np.full(b, k, dtype=np.int64)
             for k, b in enumerate(self.bucket_sizes)]
        ) if sum(self.bucket_sizes) else np.zeros(0, np.int64)
        part = np.arange(p, dtype=np.int64)[:, None]
        peer_recv = (part - offsets[None, :]) % p
        peer_send = (part + offsets[None, :]) % p
        return peer_recv, peer_send


@functools.lru_cache(maxsize=None)
def _peers_cached(geom: RowGeometry) -> tuple[np.ndarray, np.ndarray]:
    return geom._peers()


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SiteFaults:
    """One exchange site's fault masks, as data over the wire buffers.

    * ``drop_fwd``    — (P, rows) bool on the *recv* buffer: rows whose
      forward message was lost; the receiver keeps its cached halo row.
    * ``corrupt_fwd`` — (P, rows) bool on the *send* buffer: rows whose
      forward payload is bit-flipped before the exchange.
    * ``drop_bwd``    — (P, rows) bool on the *send* buffer (the returned
      gradients align with send rows): backward messages lost.
    * ``corrupt_bwd`` — (P, rows) bool on the *recv* buffer (the outgoing
      gradients align with recv rows): backward payloads bit-flipped.
    """

    drop_fwd: jax.Array
    corrupt_fwd: jax.Array
    drop_bwd: jax.Array
    corrupt_bwd: jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FaultCtl:
    """The per-epoch fault control block, carried in
    ``GNNTrainState.faults``. One leaf: every site's masks stacked into a
    single ``(P, S, 4, rows)`` bool array (partition axis leading, so the
    shard_map spec that shards every stacked state leaf on axis 0 applies
    unchanged; the 4-axis is [drop_fwd, corrupt_fwd, drop_bwd, corrupt_bwd]).
    One leaf means one host->device transfer per epoch — arming is on the
    epoch critical path and the per-leaf transfer dispatch dominated when the
    masks shipped as 4 x n_sites separate arrays. Always the same pytree
    structure for a given model/plan — an all-false :meth:`clean` block (a
    suppressed recovery epoch) runs the very same executable as a faulty one.
    """

    masks: jax.Array

    @property
    def sites(self) -> tuple:
        """Per-site :class:`SiteFaults` views. Sliced lazily (inside the
        trace these are free reshapes of the one shipped leaf)."""
        return tuple(
            SiteFaults(drop_fwd=self.masks[:, s, 0],
                       corrupt_fwd=self.masks[:, s, 1],
                       drop_bwd=self.masks[:, s, 2],
                       corrupt_bwd=self.masks[:, s, 3])
            for s in range(self.masks.shape[1]))

    @staticmethod
    def expand(events: FaultEvents, geom: RowGeometry,
               n_sites: int) -> "FaultCtl":
        """Pairwise (S, 2, P, P) events → per-row wire masks, per layout."""
        peer_recv, peer_send = geom.peers()
        part = np.arange(geom.n_parts, dtype=np.int64)[:, None]
        # vectorized over sites: A[:, X, Y] with X,Y (P, rows)/(P, 1)
        # broadcasts to (S, P, rows)
        stacked = np.stack([
            events.drop[:, FWD][:, peer_recv, part],
            events.corrupt[:, FWD][:, part, peer_send],
            events.drop[:, BWD][:, peer_send, part],
            events.corrupt[:, BWD][:, part, peer_recv],
        ], axis=1)                                   # (S, 4, P, rows)
        return FaultCtl(masks=jnp.asarray(stacked.transpose(2, 0, 1, 3)))

    @staticmethod
    def clean(geom: RowGeometry, n_sites: int) -> "FaultCtl":
        """All-false masks — same structure, zero faults (recovery epochs)."""
        return FaultCtl(masks=jnp.zeros(
            (geom.n_parts, n_sites, 4, geom.halo_rows), bool))
