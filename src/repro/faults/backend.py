"""FaultyBackend: a HaloBackend wrapper that carries a chaos schedule.

The wrapper is deliberately *transparent on the wire*: every protocol method
delegates to the wrapped backend unchanged. Fault injection does not happen
here — the stacked/sharded collectives are all-or-nothing, so per-row drops
and bit-flips are applied as traced data inside ``faults/comm.py`` (masks in
``GNNTrainState.faults``), never by mutating the collective itself. What the
wrapper *does* do is bind a :class:`~repro.faults.plan.FaultPlan` to a
runtime: ``GNNTrainer`` discovers the plan on its ``Runtime``'s backend and
arms the per-epoch schedule, so a single constructor argument
(``Runtime(FaultyBackend(base, plan))``) turns any existing launch path into
a chaos run.

Frozen and hashable (it keys jit caches and rides custom_vjp nondiff
argnums, exactly like the backends it wraps), and satisfies the runtime-
checkable ``HaloBackend`` protocol so ``as_backend``/``Runtime`` accept it.
"""
from __future__ import annotations

import dataclasses

from ..dist.backend import HaloBackend
from .plan import FaultPlan


@dataclasses.dataclass(frozen=True)
class FaultyBackend:
    """Delegating wrapper binding a :class:`FaultPlan` to a backend."""

    base: HaloBackend
    plan: FaultPlan = FaultPlan()

    # --- passthroughs Runtime introspects (mesh => sharded, n_parts) ---
    @property
    def mesh(self):
        return getattr(self.base, "mesh", None)

    @property
    def n_parts(self):
        return getattr(self.base, "n_parts", None)

    # --- HaloBackend protocol: pure delegation ---
    def exchange(self, send_bufs, h_pad):
        return self.base.exchange(send_bufs, h_pad)

    def exchange_compact(self, buf, bucket_sizes, reverse=False):
        return self.base.exchange_compact(buf, bucket_sizes, reverse=reverse)

    def exchange_quantized(self, qt, h_pad):
        return self.base.exchange_quantized(qt, h_pad)

    def exchange_quantized_compact(self, qt, bucket_sizes, reverse=False):
        return self.base.exchange_quantized_compact(qt, bucket_sizes,
                                                    reverse=reverse)

    def psum(self, x):
        return self.base.psum(x)

    def fence(self, tree):
        return self.base.fence(tree)

    def axis_index(self):
        return self.base.axis_index()

    def device_put(self, tree, sharded: bool):
        return self.base.device_put(tree, sharded)

    def shard(self, fn, state_specs, data_specs, out_specs):
        return self.base.shard(fn, state_specs, data_specs, out_specs)
