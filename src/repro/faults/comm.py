"""Fault-tolerant halo communication: staleness-as-recovery variants.

These mirror the three primitives of ``core/sylvie.py`` —
``quantized_halo`` / ``fresh_halo`` / ``stale_halo`` — with two changes and
no others:

* every quantized exchange goes through ``wire.checked_exchange`` (per-row
  checksum, injected corruption/drops from a :class:`~repro.faults.plan.SiteFaults`
  mask block that rides as *data*);
* a condemned row (dropped or checksum-failed) falls back to the staleness
  contract instead of crashing or silently dequantizing garbage:

  - forward features: keep the previous step's cached halo row
    (``feat_cache``) — an unintentional Sylvie-A step for that row;
  - backward gradients, sync step: a dropped returned-gradient row
    contributes zero — exactly what the synchronous step's drained grad
    cache holds for every row;
  - backward gradients, async step: a dropped row keeps the previous
    in-flight ``grad_in`` row — one epoch staler, still bounded-stale.

With all-false masks every blend reduces to the legacy expression
(``where(True & recv, fresh, cache)`` on rows the legacy path also fills, 0
elsewhere), so a clean :class:`~repro.faults.plan.FaultCtl` is bit-identical
to the untouched primitives — tested, and the reason the legacy custom_vjps
stay byte-for-byte unmodified.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import quantization as qlib
from ..core.exchange import (PlanArrays, gather_boundary,
                             scatter_boundary_grad)
from .wire import checked_exchange


# ---------------------------------------------------------------------------
# Sylvie-S under faults: blend with the cache wherever the wire failed
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11))
def faulty_quantized_halo(h, feat_cache, sf, plan: PlanArrays, fwd_key,
                          bwd_key, fwd_bits: int, bwd_bits: int,
                          stochastic: bool, scale_dtype, backend, impl):
    """``quantized_halo`` with checksummed exchange and stale fallback.

    ``feat_cache`` is the previous step's halo for this site (the row-level
    fallback); ``sf`` the site's fault masks. Both are data — no cotangents
    (the cache is already stop-gradient'd by the caller's step)."""
    buf = gather_boundary(h, plan)
    qt = qlib.quantize(buf, fwd_bits, fwd_key, stochastic, scale_dtype,
                       impl=impl)
    qr, ok = checked_exchange(qt, plan, backend, sf.corrupt_fwd, sf.drop_fwd)
    fresh = qlib.dequantize(qr, impl=impl)
    # single blend: outside recv_mask the condition is False and the cache is
    # zero there by construction (caches start zero and are only ever written
    # by these recv-masked outputs), so no extra zeroing pass is needed —
    # arming must stay inside the <= 5% step-overhead budget (bench_chaos).
    return jnp.where((ok & plan.recv_mask)[..., None], fresh, feat_cache)


def _fqh_fwd(h, feat_cache, sf, plan, fwd_key, bwd_key, fwd_bits, bwd_bits,
             stochastic, scale_dtype, backend, impl):
    out = faulty_quantized_halo(h, feat_cache, sf, plan, fwd_key, bwd_key,
                                fwd_bits, bwd_bits, stochastic, scale_dtype,
                                backend, impl)
    return out, (plan, bwd_key, sf)


def _fqh_bwd(fwd_bits, bwd_bits, stochastic, scale_dtype, backend, impl, res,
             g):
    plan, bwd_key, sf = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    qt = qlib.quantize(g, bwd_bits, bwd_key, stochastic, scale_dtype,
                       impl=impl)
    qr, ok = checked_exchange(qt, plan, backend, sf.corrupt_bwd, sf.drop_bwd,
                              reverse=True)
    back = qlib.dequantize(qr, impl=impl)
    # a lost returned-gradient row contributes zero — the synchronous step's
    # grad caches are drained (all-zero), so zero *is* its stale value
    back = jnp.where((ok & plan.send_mask)[..., None], back, 0)
    grad_h = scatter_boundary_grad(back, plan)
    return (grad_h, None, None, None, None, None)


faulty_quantized_halo.defvjp(_fqh_fwd, _fqh_bwd)


# ---------------------------------------------------------------------------
# Sylvie-A under faults
# ---------------------------------------------------------------------------
def faulty_fresh_halo(h, old_cache, sf, plan: PlanArrays, key, fwd_bits,
                      stochastic, scale_dtype, backend, impl):
    """``fresh_halo`` with checksummed exchange: a condemned row leaves the
    *old* cache row in place (one step staler) instead of refreshing it.
    Detached like the original — staleness gradients ride the grad_in path."""
    buf = gather_boundary(jax.lax.stop_gradient(h), plan)
    qt = qlib.quantize(buf, fwd_bits, key, stochastic, scale_dtype, impl=impl)
    qr, ok = checked_exchange(qt, plan, backend, sf.corrupt_fwd, sf.drop_fwd)
    fresh = qlib.dequantize(qr, impl=impl)
    # old_cache is zero outside recv_mask (see faulty_quantized_halo) — one
    # blend suffices.
    return jnp.where((ok & plan.recv_mask)[..., None], fresh,
                     jax.lax.stop_gradient(old_cache))


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def faulty_stale_halo(h, feat_cache, grad_in, gslot, sf, plan: PlanArrays,
                      bwd_key, bwd_bits: int, stochastic: bool, scale_dtype,
                      backend, impl):
    """``stale_halo`` with a checksummed backward gradient exchange.

    Primal is the cached halo, as in the original. The outgoing gradient
    communication is checksummed; a condemned row keeps the previous
    ``grad_in`` row as the next step's in-flight gradient (one epoch staler)
    rather than dropping to garbage or zero."""
    del h, grad_in, gslot, sf, plan, bwd_key
    return feat_cache


def _fsh_fwd(h, feat_cache, grad_in, gslot, sf, plan, bwd_key, bwd_bits,
             stochastic, scale_dtype, backend, impl):
    return feat_cache, (plan, grad_in, bwd_key, sf)


def _fsh_bwd(bwd_bits, stochastic, scale_dtype, backend, impl, res, g):
    plan, grad_in, bwd_key, sf = res
    g = jnp.where(plan.recv_mask[..., None], g, 0)
    qt = qlib.quantize(g, bwd_bits, bwd_key, stochastic, scale_dtype,
                       impl=impl)
    qr, ok = checked_exchange(qt, plan, backend, sf.corrupt_bwd, sf.drop_bwd,
                              reverse=True)
    fresh_grad = qlib.dequantize(qr, impl=impl)
    # grad_in is zero outside send_mask (initialized zero, only ever written
    # by this send-masked blend) — one blend suffices.
    fresh_grad = jnp.where((ok & plan.send_mask)[..., None], fresh_grad,
                           grad_in)
    grad_h = scatter_boundary_grad(grad_in, plan)
    return (grad_h, None, None, fresh_grad, None, None, None)


faulty_stale_halo.defvjp(_fsh_fwd, _fsh_bwd)
