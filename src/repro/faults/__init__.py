"""repro.faults — seeded fault injection and staleness-as-recovery.

Scheduling (`plan`) is host-side and deterministic in (seed, epoch);
injection (`wire`, `comm`) is traced data so chaos adds zero executables;
`backend.FaultyBackend` binds a plan to any HaloBackend.
"""
from .backend import FaultyBackend
from .comm import faulty_fresh_halo, faulty_quantized_halo, faulty_stale_halo
from .plan import (BWD, FWD, FaultCtl, FaultEvents, FaultPlan, RowGeometry,
                   SiteFaults)
from .wire import checked_exchange, flip_rows, row_checksum

__all__ = [
    "BWD",
    "FWD",
    "FaultCtl",
    "FaultEvents",
    "FaultPlan",
    "FaultyBackend",
    "RowGeometry",
    "SiteFaults",
    "checked_exchange",
    "faulty_fresh_halo",
    "faulty_quantized_halo",
    "faulty_stale_halo",
    "flip_rows",
    "row_checksum",
]
