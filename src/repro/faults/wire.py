"""Checksummed quantized exchange: corruption injection + detection.

The quantized wire payload gets a per-row integrity word: the sender computes
an int32 byte-sum checksum over each row of the (packed or passthrough)
payload *before* any injected corruption, ships it through the same exchange
as an int32 sidecar, and the receiver recomputes it over what actually
arrived. A mismatched row is *never dequantized into the model* — the caller
treats it exactly like a dropped row and falls back to its cached halo
(``faults/comm.py``).

Injected corruption is a single XOR of bit 0 of byte 0 of the row — the
smallest possible wire upset, and one a byte-sum checksum detects with
certainty (the sum changes by exactly ±1). Real multi-bit upsets could in
principle collide with a sum; the injection deliberately stays in the
guaranteed-detectable regime so the tests assert detection, not probability.

Everything here is traced (masks are data); byte views use
``lax.bitcast_convert_type`` so packed uint8, bf16 and f32 payloads all take
the same path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.exchange import PlanArrays, exchange_halo, exchange_quantized_halo
from ..core.quantization import QuantizedTensor


def _byte_view(data: jax.Array) -> jax.Array:
    """(P, rows, w) any dtype -> (P, rows, bytes) uint8 view."""
    if data.dtype == np.uint8:
        return data
    b = jax.lax.bitcast_convert_type(data, jnp.uint8)
    return b.reshape(data.shape[:2] + (-1,))


def row_checksum(data: jax.Array) -> jax.Array:
    """(P, rows, w) payload -> (P, rows) int32 byte-sum checksum."""
    return _byte_view(data).astype(jnp.int32).sum(axis=-1)


def flip_rows(data: jax.Array, mask: jax.Array) -> jax.Array:
    """XOR bit 0 of byte 0 of every row where ``mask`` (P, rows) is set."""
    if data.dtype == np.uint8:
        bump = jnp.zeros_like(data).at[..., 0].set(mask.astype(jnp.uint8))
        return data ^ bump
    bv = jax.lax.bitcast_convert_type(data, jnp.uint8)
    bump = jnp.zeros_like(bv).at[..., 0, 0].set(mask.astype(jnp.uint8))
    return jax.lax.bitcast_convert_type(bv ^ bump, data.dtype)


def checked_exchange(qt: QuantizedTensor, plan: PlanArrays, backend,
                     corrupt_send: jax.Array, drop_recv: jax.Array,
                     reverse: bool = False
                     ) -> tuple[QuantizedTensor, jax.Array]:
    """Exchange ``qt`` with fault injection; -> (received qt, ok mask).

    ``corrupt_send`` (P, rows) flips payload bits on the send side;
    ``drop_recv`` (P, rows) marks rows whose message was lost (the data still
    moves — the stacked/sharded collective is all-or-nothing — but the row is
    condemned). ``ok`` is False exactly where the receiver must fall back to
    its cache: checksum mismatch or drop. Scale/zero sidecars travel
    untouched; corrupting them would also surface as a checksum-clean row
    with wrong values, which is out of this model's scope (documented in
    DESIGN.md §12).
    """
    sent_sum = row_checksum(qt.data)
    qt = QuantizedTensor(data=flip_rows(qt.data, corrupt_send),
                         scale=qt.scale, zero=qt.zero,
                         bits=qt.bits, feat_dim=qt.feat_dim)
    qr = exchange_quantized_halo(qt, plan, backend, reverse=reverse)
    recv_sum = exchange_halo(sent_sum[..., None], plan, backend,
                             reverse=reverse)[..., 0]
    ok = (row_checksum(qr.data) == recv_sum) & ~drop_recv
    return qr, ok
