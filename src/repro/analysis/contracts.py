"""Contract registry: trace the real entry points, apply the jaxpr checks.

Each contract builds a *representative* workload — a 96-node skewed-partition
synthetic graph on 4 partitions (skewed so ring buckets are ragged: a
symmetric graph would make the forward and inverted-backward shift censuses
identical and the ring-inversion check vacuous) — traces an entry point with
``jax.make_jaxpr`` (tracing only; nothing executes except the two
budget/serve contracts, which must run to count executables), and diffs the
lowered structure against its :class:`~.jaxpr_checks.ExchangeExpectation`.

Covered entry points (acceptance matrix):

* train_step_sync for GCN/GraphSAGE x dense/compact, simulated + shard_map;
* train_step_async + eval_step (GCN/compact, shard_map);
* the serve sweep (quantized forward + uint8 affected-mask rides);
* the quantize kernel's payload dtypes across the whole bit lattice (RC206);
* recompile budgets: train executables per lattice decision (RC204) and the
  serve single-sweep-executable guarantee from PR 6 (RC207);
* fault-injection transparency: with ``faults=None`` a FaultyBackend-built
  step traces the *identical* program as the plain backend, and two armed
  epochs with different fault masks share one jaxpr (RC208);
* overlap-schedule parity: the ``schedule="overlap"`` step lowers the *same*
  ppermute-per-bucket census and wire dtypes as blocking — the fence
  (``optimization_barrier``) reorders, it must never duplicate or widen an
  exchange — and overlap decisions stay inside the RC204 budget of two
  executables per lattice decision (RC209);
* observability transparency: enabling the span tracer (``repro.obs``)
  traces jaxpr-identical train/serve programs — spans and counters live at
  the host seams, never in the lowered program (RC210).

shard_map contracts need >= 4 devices; with fewer they are *reported as
skipped*, never silently passed (``python -m repro.analysis`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` itself, so the CLI
always runs them on CPU).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..api import partition
from ..core import quantization as qlib
from ..core.sylvie import SylvieConfig
from ..dist.runtime import Runtime
from ..graph import synthetic
from ..models.gnn import blocks as B
from ..models.gnn.models import GCN, GraphSAGE
from ..policy.base import BIT_LATTICE, EpochDecision
from ..train import gnn_step, optimizer as optlib
from ..train.gnn_step import GNNTrainState, make_gnn_steps
from .jaxpr_checks import (ExchangeExpectation, check_exchange_census,
                           check_no_callbacks, check_no_collectives,
                           check_wire_dtypes, summarize)
from .report import Finding

N_PARTS = 4
ARCHS: dict[str, Callable] = {
    "gcn": lambda d_in, d_out: GCN(d_in, 8, d_out, n_layers=2),
    "sage": lambda d_in, d_out: GraphSAGE(d_in, 8, d_out, n_layers=2),
}


def _mesh_ready() -> bool:
    return len(jax.devices()) >= N_PARTS


def _workload(arch: str, layout: str):
    """(model, pg, state, args) for one traced config — skewed partitions so
    every ring bucket has a distinct row count."""
    g = synthetic.planted_partition(n_nodes=96, d_feat=8, seed=0)
    pg = partition(g, N_PARTS, method="skewed", layout=layout, alignment=4)
    model = ARCHS[arch](8, g.n_classes)
    opt = optlib.sgd(1e-1)
    block = B.build_block(pg)
    state = GNNTrainState.create(model, opt, jax.random.PRNGKey(0),
                                 block.plan, stacked_parts=N_PARTS)
    args = (block, jnp.asarray(pg.x), jnp.asarray(pg.y),
            jnp.asarray(pg.train_mask), jax.random.PRNGKey(1))
    return model, pg, opt, state, args


def _buckets(pg, layout: str) -> Optional[tuple[int, ...]]:
    if layout != "compact":
        return None
    return tuple(int(b) for b in pg.plan.bucket_sizes)


def _train_exp(model, state, pg, layout: str, bits: int,
               *, sync: bool) -> ExchangeExpectation:
    """Declared comm structure of a train step.

    Forward: one exchange per site. Backward (sync): the site-0 exchange
    ships raw input features for GCN/SAGE, which carry no gradient, so its
    backward exchange is dead-code-eliminated — ``n_sites - 1`` ops. Async
    steps exchange the *gradient caches* instead, and every cache (site 0
    included) is a differentiated output, so nothing is eliminated.
    psums: one per weight-grad leaf (Alg. 2 line 16) + 2 for the masked loss
    (sum, count) + 1 for the site telemetry.
    """
    n_sites = len(model.comm_dims())
    n_leaves = len(jax.tree.leaves(state.params))
    return ExchangeExpectation(
        fwd_ops=n_sites,
        bwd_ops=n_sites - 1 if sync else n_sites,
        bits=bits, buckets=_buckets(pg, layout), psums=n_leaves + 3)


# ---------------------------------------------------------------------------
# contracts (each returns (findings, skipped-notes))
# ---------------------------------------------------------------------------
def contract_train_census(arch: str, layout: str
                          ) -> tuple[list[Finding], list[str]]:
    """RC201/202/203/205 on the shard_map sync train step."""
    where = f"contract:train_sync/{arch}/{layout}/shard_map"
    if not _mesh_ready():
        return [], [f"{where} (needs {N_PARTS} devices)"]
    model, pg, opt, state, args = _workload(arch, layout)
    rt = Runtime.sharded(N_PARTS)
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=False)
    ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend)
    ts, _, _ = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
    summary = summarize(jax.make_jaxpr(ts)(state, *args))
    exp = _train_exp(model, state, pg, layout, bits=1, sync=True)
    return (check_exchange_census(summary, exp, where)
            + check_wire_dtypes(summary, exp, where)
            + check_no_callbacks(summary, where)), []


def contract_train_async_census() -> tuple[list[Finding], list[str]]:
    """The async (Sylvie-A) step: cached-halo consumption still lowers to one
    quantized exchange per site per direction, inverted rings in backward."""
    where = "contract:train_async/gcn/compact/shard_map"
    if not _mesh_ready():
        return [], [f"{where} (needs {N_PARTS} devices)"]
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.sharded(N_PARTS)
    cfg = SylvieConfig(mode="async", bits=1, stochastic=False)
    ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend)
    _, ta, _ = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
    summary = summarize(jax.make_jaxpr(ta)(state, *args))
    exp = _train_exp(model, state, pg, "compact", bits=1, sync=False)
    return (check_exchange_census(summary, exp, where)
            + check_wire_dtypes(summary, exp, where)
            + check_no_callbacks(summary, where)), []


def contract_eval_census() -> tuple[list[Finding], list[str]]:
    """eval_step: full-precision forward exchange, exactly 2 psums
    (correct, count) — no telemetry, no weight-grad reduce."""
    where = "contract:eval/gcn/compact/shard_map"
    if not _mesh_ready():
        return [], [f"{where} (needs {N_PARTS} devices)"]
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.sharded(N_PARTS)
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=False)
    ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend)
    _, _, ev = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
    summary = summarize(jax.make_jaxpr(ev)(state.params, *args))
    n_sites = len(model.comm_dims())
    exp = ExchangeExpectation(
        fwd_ops=n_sites, bwd_ops=0, bits=32, buckets=_buckets(pg, "compact"),
        psums=2, wire_dtypes=frozenset({"float32"}))
    return (check_exchange_census(summary, exp, where)
            + check_wire_dtypes(summary, exp, where)
            + check_no_callbacks(summary, where)), []


def contract_simulated_pure(arch: str, layout: str
                            ) -> tuple[list[Finding], list[str]]:
    """The simulated backend compiles the whole stack to one program: zero
    collective primitives, zero callbacks (RC201/RC205)."""
    where = f"contract:train_sync/{arch}/{layout}/simulated"
    model, pg, opt, state, args = _workload(arch, layout)
    rt = Runtime.simulated(N_PARTS)
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=False)
    ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend)
    summary = summarize(jax.make_jaxpr(ts)(state, *args))
    return (check_no_collectives(summary, where)
            + check_no_callbacks(summary, where)), []


def contract_serve_census() -> tuple[list[Finding], list[str]]:
    """The serve sweep: per site one quantized forward exchange + one uint8
    affected-mask ride; no psum, no backward, nothing fp32 on the wire."""
    where = "contract:serve_sweep/gcn/compact/shard_map"
    if not _mesh_ready():
        return [], [f"{where} (needs {N_PARTS} devices)"]
    from ..serve.engine import InferenceEngine, ServeConfig
    from ..serve import delta as deltalib
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.sharded(N_PARTS)
    eng = InferenceEngine(model, pg, model.init(jax.random.PRNGKey(0)),
                          config=ServeConfig(bits=1), runtime=rt)
    masks = deltalib.plan_full(pg, eng.n_sites).device_masks()
    summary = summarize(jax.make_jaxpr(eng._sweep)(
        eng.params, eng.block, eng.x, eng._halos, masks,
        jax.random.PRNGKey(2)))
    exp = ExchangeExpectation(
        fwd_ops=eng.n_sites, bwd_ops=0, bits=1,
        buckets=_buckets(pg, "compact"), mask_ops=eng.n_sites, psums=0)
    return (check_exchange_census(summary, exp, where)
            + check_wire_dtypes(summary, exp, where)
            + check_no_callbacks(summary, where)), []


def contract_quantize_payload() -> tuple[list[Finding], list[str]]:
    """RC206: across the whole bit lattice the quantize kernel's wire payload
    is uint8 (packed to ``packed_width`` bytes) with scale_dtype error
    compensation — passthrough widths keep bf16/f32 and ship no scale."""
    where = "contract:quantize_payload"
    findings = []
    h = jax.ShapeDtypeStruct((N_PARTS, 24, 16), jnp.float32)
    for bits in BIT_LATTICE:
        qt = jax.eval_shape(
            lambda x, b=bits: qlib.quantize(x, b, jax.random.PRNGKey(0),
                                            stochastic=False), h)
        if bits >= 16:
            want = "bfloat16" if bits == 16 else "float32"
            if qt.data.dtype.name != want or qt.scale.size:
                findings.append(Finding(
                    code="RC206", where=where,
                    message=f"bits={bits} passthrough must ship {want} with "
                    f"empty scale, got {qt.data.dtype.name} + scale shape "
                    f"{qt.scale.shape}"))
            continue
        want_w = qlib.packed_width(16, bits)
        if qt.data.dtype.name != "uint8" or qt.data.shape[-1] != want_w:
            findings.append(Finding(
                code="RC206", where=where,
                message=f"bits={bits} payload must be uint8 packed to "
                f"{want_w} bytes/row, got {qt.data.dtype.name} "
                f"shape {qt.data.shape}"))
        if qt.scale.dtype.name != "bfloat16":
            findings.append(Finding(
                code="RC206", where=where,
                message=f"bits={bits} scale must be bfloat16 (wire-cheap "
                f"error compensation), got {qt.scale.dtype.name}"))
    return findings, []


def contract_recompile_budget() -> tuple[list[Finding], list[str]]:
    """RC204: the executable budget. One compiled program per (step flavor,
    lattice decision) — re-invoking a built step must hit the jit cache, so
    K distinct decisions trace exactly K sync + K async executables. This is
    the static generalization of tests/test_policy's TRACE_LOG assertions to
    a *declared* budget."""
    where = "contract:recompile_budget/train"
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.simulated(N_PARTS)
    cfg = SylvieConfig(mode="async", bits=1, stochastic=False)
    n_sites = len(model.comm_dims())
    decisions = [EpochDecision.uniform(n_sites, bits=b, stochastic=False)
                 for b in (1, 2)]
    budget = 2 * len(decisions)   # sync + async per lattice point
    base = len(gnn_step.TRACE_LOG)
    for d in decisions:
        ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend,
                                    decision=d)
        ts, ta, _ = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
        for _ in range(2):        # second call must reuse the executable
            st2, _ = ts(state, *args)
            st2, _ = ta(st2, *args)
    traced = len(gnn_step.TRACE_LOG) - base
    if traced != budget:
        return [Finding(
            code="RC204", where=where,
            message=f"recompile budget exceeded: {len(decisions)} lattice "
            f"decisions x (sync+async) x 2 invocations must trace exactly "
            f"{budget} executables, traced {traced}")], []
    return [], []


def contract_serve_one_executable() -> tuple[list[Finding], list[str]]:
    """RC207: PR 6's claim, verified instead of trusted — a full sweep and a
    delta refresh are served by ONE traced sweep executable (the affected
    masks ride as data), and the jaxprs traced with full vs delta mask values
    are structurally identical."""
    where = "contract:serve_one_executable"
    import numpy as np
    from ..serve import delta as deltalib, engine as englib
    model, pg, opt, state, args = _workload("gcn", "compact")
    eng = englib.InferenceEngine(model, pg, model.init(jax.random.PRNGKey(0)),
                                 config=englib.ServeConfig(bits=1),
                                 runtime=Runtime.simulated(N_PARTS))
    findings = []
    base = len(englib.TRACE_LOG)
    eng.full_sweep()
    eng.refresh(np.array([0]), np.zeros((1, 8), np.float32))
    eng.full_sweep()
    traced = len(englib.TRACE_LOG) - base
    if traced != 1:
        findings.append(Finding(
            code="RC204", where=where,
            message=f"full sweep + delta refresh + full sweep must share one "
            f"traced executable, traced {traced}"))
    full = deltalib.plan_full(pg, eng.n_sites).device_masks()
    part = eng._frontier.plan_refresh(np.array([0]),
                                      eng.n_sites).device_masks()
    key = jax.random.PRNGKey(3)
    trace = jax.make_jaxpr(lambda m: eng._sweep(
        eng.params, eng.block, eng.x, eng._halos, m, key))
    if str(trace(full)) != str(trace(part)):
        findings.append(Finding(
            code="RC207", where=where,
            message="jaxpr traced with the all-rows mask differs from the "
            "delta-frontier mask trace — the masks are influencing program "
            "structure instead of riding as data"))
    return findings, []


def contract_fault_transparency() -> tuple[list[Finding], list[str]]:
    """RC208: fault injection must be invisible to the compiler. Two halves:

    (a) fault-free transparency — a train step built against a
        ``FaultyBackend`` wrapper, invoked with ``faults=None``, traces a
        jaxpr *string-identical* to the plain-backend step (zero extra traced
        executables when no chaos is armed);
    (b) masks-as-data — the armed step traces the same jaxpr for two epochs
        with *different* fault sets (the masks ride in
        ``GNNTrainState.faults``; fault values never shape the program).
    """
    import dataclasses
    import re

    from ..faults import FaultCtl, FaultPlan, FaultyBackend, RowGeometry

    def canon(fn, st):
        # jaxpr pretty-printing embeds repr()s of custom_vjp thunks, which
        # carry object addresses; strip them so only structure is compared.
        return re.sub(r"0x[0-9a-f]+", "0x", str(jax.make_jaxpr(fn)(st, *args)))

    where = "contract:fault_transparency"
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.simulated(N_PARTS)
    plan = FaultPlan(seed=3, drop_rate=0.2, corrupt_rate=0.1)
    faulty = FaultyBackend(rt.backend, plan)
    findings: list[Finding] = []
    for mode in ("sync", "async"):
        cfg = SylvieConfig(mode=mode, bits=1, stochastic=False)
        ts_p, ta_p, _ = make_gnn_steps(model, cfg, opt, backend=rt.backend)
        ts_f, ta_f, _ = make_gnn_steps(model, cfg, opt, backend=faulty)
        step_p = ts_p if mode == "sync" else ta_p
        step_f = ts_f if mode == "sync" else ta_f
        if canon(step_p, state) != canon(step_f, state):
            findings.append(Finding(
                code="RC208", where=f"{where}/{mode}",
                message="FaultyBackend with faults=None traces a different "
                "program than the plain backend — the fault path leaks into "
                "the fault-free trace"))
        # (b) two different armed epochs must share one jaxpr
        geom = RowGeometry.from_plan(args[0].plan)
        n_sites = len(model.comm_dims())
        ctls = [FaultCtl.expand(plan.events(e, n_sites, N_PARTS), geom,
                                n_sites) for e in (1, 2)]
        traces = [canon(step_f, dataclasses.replace(state, faults=c))
                  for c in ctls]
        if traces[0] != traces[1]:
            findings.append(Finding(
                code="RC208", where=f"{where}/{mode}/armed",
                message="two epochs with different fault masks trace "
                "different jaxprs — fault events are shaping program "
                "structure instead of riding as data"))
    return findings, []


def contract_overlap_census() -> tuple[list[Finding], list[str]]:
    """RC209(a): the overlap schedule is *census-identical* to blocking. The
    issue/land split reorders work around the collective; it must not add,
    drop, widen, or re-route a single exchange. So the shard_map sync step
    traced with ``schedule="overlap"`` must pass the exact
    :class:`ExchangeExpectation` the blocking step is held to (same bucket
    multiset, same ring inversion, same wire dtypes, same psum count) — plus
    at least one ``optimization_barrier`` eqn, the fence that pins the land
    after the issue."""
    where = "contract:overlap_census/gcn/compact/shard_map"
    if not _mesh_ready():
        return [], [f"{where} (needs {N_PARTS} devices)"]
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.sharded(N_PARTS)
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=False,
                       schedule="overlap")
    ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend)
    ts, _, _ = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
    summary = summarize(jax.make_jaxpr(ts)(state, *args))
    exp = _train_exp(model, state, pg, "compact", bits=1, sync=True)
    findings = (check_exchange_census(summary, exp, where)
                + check_wire_dtypes(summary, exp, where)
                + check_no_callbacks(summary, where))
    if not summary.count("optimization_barrier"):
        findings.append(Finding(
            code="RC209", where=where,
            message="overlap-schedule step lowers no optimization_barrier — "
            "without the fence the land is free to fold back into the issue "
            "and the schedule silently degenerates to blocking"))
    return findings, []


def contract_overlap_budget() -> tuple[list[Finding], list[str]]:
    """RC209(b): overlap decisions obey the RC204 budget — one executable per
    (step flavor, decision), so a blocking + an overlap decision trace exactly
    2 sync + 2 async executables across repeated invocations (the schedule is
    part of ``EpochDecision.step_key()``; it must not retrace per call)."""
    where = "contract:overlap_budget/train"
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.simulated(N_PARTS)
    cfg = SylvieConfig(mode="async", bits=1, stochastic=False)
    n_sites = len(model.comm_dims())
    decisions = [EpochDecision.uniform(n_sites, bits=1, stochastic=False,
                                       schedule=s)
                 for s in ("blocking", "overlap")]
    budget = 2 * len(decisions)
    base = len(gnn_step.TRACE_LOG)
    for d in decisions:
        ts, ta, ev = make_gnn_steps(model, cfg, opt, backend=rt.backend,
                                    decision=d)
        ts, ta, _ = rt.shard_gnn_steps(ts, ta, ev, state, *args[:1])
        for _ in range(2):        # second call must reuse the executable
            st2, _ = ts(state, *args)
            st2, _ = ta(st2, *args)
    traced = len(gnn_step.TRACE_LOG) - base
    if traced != budget:
        return [Finding(
            code="RC209", where=where,
            message=f"overlap recompile budget exceeded: blocking + overlap "
            f"decisions x (sync+async) x 2 invocations must trace exactly "
            f"{budget} executables, traced {traced}")], []
    return [], []


def contract_obs_transparency() -> tuple[list[Finding], list[str]]:
    """RC210: observability must be compiler-invisible. The span tracer and
    metrics counters live at the host seams (the same trace-time seams as the
    TRACE_LOG appends); enabling tracing must not add, drop, or reorder a
    single eqn. Checked by canon-comparing (hex addresses stripped) the
    jaxprs of the sync + async train steps (``schedule="overlap"``, the one
    path whose traced bodies *contain* obs.event seams) and the serve sweep,
    traced with the tracer disabled vs enabled on a FakeClock."""
    import re

    from .. import obs
    from ..serve import delta as deltalib, engine as englib

    where = "contract:obs_transparency"
    model, pg, opt, state, args = _workload("gcn", "compact")
    rt = Runtime.simulated(N_PARTS)
    cfg = SylvieConfig(mode="sync", bits=1, stochastic=False,
                       schedule="overlap")
    acfg = SylvieConfig(mode="async", bits=1, stochastic=False,
                        schedule="overlap")
    key = jax.random.PRNGKey(2)

    def canon(jaxpr):
        # jaxpr pretty-printing embeds repr()s of custom_vjp thunks with
        # object addresses; strip them so only structure is compared.
        return re.sub(r"0x[0-9a-f]+", "0x", str(jaxpr))

    def snapshot() -> dict[str, str]:
        # fresh step functions + a fresh engine per pass: the steps are
        # jitted, so reusing them would serve the second trace from the jit
        # cache without ever re-running the instrumented python bodies
        ts, _, _ = make_gnn_steps(model, cfg, opt, backend=rt.backend)
        _, ta, _ = make_gnn_steps(model, acfg, opt, backend=rt.backend)
        eng = englib.InferenceEngine(
            model, pg, model.init(jax.random.PRNGKey(0)),
            config=englib.ServeConfig(bits=1), runtime=rt)
        masks = deltalib.plan_full(pg, eng.n_sites).device_masks()
        return {
            "train_sync": canon(jax.make_jaxpr(ts)(state, *args)),
            "train_async": canon(jax.make_jaxpr(ta)(state, *args)),
            "serve_sweep": canon(jax.make_jaxpr(eng._sweep)(
                eng.params, eng.block, eng.x, eng._halos, masks, key)),
        }

    was_on = obs.enabled()
    try:
        obs.disable()
        off = snapshot()
        obs.enable(obs.FakeClock())
        on = snapshot()
        obs.drain()               # discard the trace-time events we provoked
    finally:
        if was_on:
            obs.enable()
        else:
            obs.disable()
    return [Finding(
        code="RC210", where=f"{where}/{k}",
        message="enabling the span tracer changes the traced program — "
        "instrumentation is leaking ops into the jaxpr instead of staying "
        "at the host seams")
        for k in off if off[k] != on[k]], []


# ---------------------------------------------------------------------------
# registry + driver
# ---------------------------------------------------------------------------
CONTRACTS: dict[str, Callable[[], tuple[list[Finding], list[str]]]] = {
    **{f"train_sync/{a}/{lay}/shard_map":
       (lambda a=a, lay=lay: contract_train_census(a, lay))
       for a in ARCHS for lay in ("compact", "dense")},
    **{f"train_sync/{a}/{lay}/simulated":
       (lambda a=a, lay=lay: contract_simulated_pure(a, lay))
       for a in ARCHS for lay in ("compact", "dense")},
    "train_async/gcn/compact/shard_map": contract_train_async_census,
    "eval/gcn/compact/shard_map": contract_eval_census,
    "serve_sweep/gcn/compact/shard_map": contract_serve_census,
    "quantize_payload": contract_quantize_payload,
    "recompile_budget/train": contract_recompile_budget,
    "serve_one_executable": contract_serve_one_executable,
    "fault_transparency": contract_fault_transparency,
    "overlap_census/gcn/compact/shard_map": contract_overlap_census,
    "overlap_budget/train": contract_overlap_budget,
    "obs_transparency": contract_obs_transparency,
}


def run_contracts(only: Optional[list[str]] = None
                  ) -> tuple[list[Finding], list[str]]:
    """Run every registered contract (or the named subset). Returns
    (findings, skipped-notes); a contract that *errors* is itself a finding
    (RC200) — a broken checker must fail CI, not pass it."""
    findings: list[Finding] = []
    skipped: list[str] = []
    for name, fn in CONTRACTS.items():
        if only is not None and name not in only:
            continue
        try:
            got, skip = fn()
        except Exception as e:  # noqa: BLE001 - surfaced as a finding
            findings.append(Finding(
                code="RC200", where=f"contract:{name}",
                message=f"contract raised {type(e).__name__}: {e}"))
            continue
        findings.extend(got)
        skipped.extend(skip)
    return findings, skipped
