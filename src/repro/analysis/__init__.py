"""repro.analysis — static analysis for the repro stack.

Two layers, one gate:

* **AST lint** (``repro.analysis.lint``): repo-specific trace-discipline
  rules (RA1xx) on stdlib ``ast`` — no third-party linter needed to run them.
* **Jaxpr contracts** (``repro.analysis.contracts``): trace the real train /
  eval / serve entry points and assert the lowered communication structure
  (RC2xx) — collective census per ring bucket, wire dtypes, backward ring
  inversion, recompile budgets, host-callback bans.

``python -m repro.analysis`` runs both, applies the checked-in baseline
(``tools/analysis_baseline.txt``), writes ``artifacts/analysis/report.json``
with ``--json``, and exits non-zero on any non-baselined finding. CI runs it
as ``tools/ci.sh --analysis``.
"""
from .lint import run_lint  # noqa: F401
from .report import (Finding, load_baseline,  # noqa: F401
                     split_by_baseline, write_report)

__all__ = ["Finding", "load_baseline", "run_lint", "split_by_baseline",
           "write_report"]
