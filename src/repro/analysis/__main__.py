"""``python -m repro.analysis`` — the exit-code-gated static-analysis gate.

Runs the AST lint and (unless ``--lint-only``) the jaxpr contract suite,
subtracts the checked-in baseline, prints fresh findings, and exits 1 if any
remain. ``--json`` additionally writes ``artifacts/analysis/report.json``.

The contract suite traces shard_map entry points, which need 4 devices; this
entry point owns process startup, so it forces 4 host CPU devices itself
(before jax initializes) instead of making every caller export XLA_FLAGS.
"""
from __future__ import annotations

import argparse
import os
import sys


def _force_host_devices() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr contract checks + trace-discipline lint")
    ap.add_argument("paths", nargs="*", help="files/dirs to lint "
                    "(default: src/repro benchmarks)")
    ap.add_argument("--root", default=".", help="repo root (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="accepted-debt file (default: "
                    "tools/analysis_baseline.txt under --root)")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the jaxpr contract suite (no jax import — "
                    "fast enough for a pre-commit hook)")
    ap.add_argument("--contracts-only", action="store_true",
                    help="skip the AST lint")
    ap.add_argument("--json", action="store_true",
                    help="write artifacts/analysis/report.json")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-finding output (exit code only)")
    args = ap.parse_args(argv)

    from .report import (DEFAULT_BASELINE, DEFAULT_REPORT_DIR, load_baseline,
                         split_by_baseline, stale_baseline_entries,
                         write_report)

    findings, skipped, lanes = [], [], []
    if not args.contracts_only:
        from .lint import DEFAULT_PATHS, run_lint
        findings.extend(run_lint(args.paths or DEFAULT_PATHS,
                                 root=args.root))
        lanes.append("lint")
    if not args.lint_only:
        _force_host_devices()
        from .contracts import run_contracts
        cfind, cskip = run_contracts()
        findings.extend(cfind)
        skipped.extend(cskip)
        lanes.append("contracts")

    baseline_path = args.baseline or os.path.join(args.root, DEFAULT_BASELINE)
    baseline = load_baseline(baseline_path)
    fresh, known = split_by_baseline(findings, baseline)
    stale = stale_baseline_entries(findings, baseline)

    if args.json:
        out = write_report(
            os.path.join(args.root, DEFAULT_REPORT_DIR, "report.json"),
            findings, baseline, skipped, meta={"lanes": lanes})
        if not args.quiet:
            print(f"report: {out}")

    if not args.quiet:
        for f in sorted(fresh, key=lambda f: (f.code, f.where, f.line)):
            print(f.render())
        for note in skipped:
            print(f"skipped: {note}")
        for fp in stale:
            print(f"stale baseline entry (fixed? delete it): {fp}")
        print(f"analysis[{'+'.join(lanes)}]: {len(fresh)} finding(s), "
              f"{len(known)} baselined, {len(skipped)} skipped")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
