"""Structural checks on lowered jaxprs: collective census, wire dtypes, ring
inversion, host-callback bans.

The checks operate on :class:`JaxprSummary` — a recursive walk of a traced
entry point (``jax.make_jaxpr`` output) that records every communication
primitive with its operand shape/dtype and, for ``ppermute``, the cyclic ring
shift its permutation implements. The contract layer (``contracts.py``)
declares what each entry point *should* contain; this module measures and
diffs.

Why shifts + row counts: the compact halo layout ships ring bucket ``k``
(``b_k`` rows) from partition ``p`` to ``(p+k) % P``; the backward
communication must run the *inverted* rings (``shift P-k``). Because bucket
sizes are ragged (skewed partitions), the multiset of ``(shift, rows)`` pairs
is a fingerprint of the whole schedule: a missing bucket, an extra exchange,
or a non-inverted backward pass each perturb it differently. The checks
compare that fingerprint against the expectation computed from the plan's
static metadata — nothing is learned from the jaxpr being checked.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable, Optional

from .report import Finding

# Cross-device communication primitives (jaxpr names).
EXCHANGE_PRIMS = ("ppermute", "all_to_all")
REDUCE_PRIMS = ("psum", "psum_invariant", "pmax", "pmin")
GATHER_PRIMS = ("all_gather", "all_gather_invariant", "pgather")
# Host-callback / side-channel primitives banned inside hot entry points.
CALLBACK_MARKERS = ("callback", "infeed", "outfeed", "host_local_array")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One communication eqn: primitive, operand aval, ring shift (ppermute)."""

    prim: str
    dtype: str            # canonical dtype name, e.g. "uint8", "bfloat16"
    shape: tuple[int, ...]
    shift: Optional[int]  # cyclic ring shift for ppermute; None otherwise

    @property
    def rows(self) -> int:
        """Halo rows moved: axis 1 of the stacked ``(P_local, rows, ...)``
        buffer (falls back to the leading axis for 1-D operands)."""
        return self.shape[1] if len(self.shape) > 1 else (
            self.shape[0] if self.shape else 1)


@dataclasses.dataclass
class JaxprSummary:
    """Everything the contracts need from one traced entry point."""

    prim_counts: collections.Counter
    collectives: list[CollectiveOp]
    callbacks: list[str]

    def count(self, prim: str) -> int:
        return self.prim_counts[prim]

    def ops(self, *prims: str) -> list[CollectiveOp]:
        return [c for c in self.collectives if c.prim in prims]


def cyclic_shift(perm: Iterable[tuple[int, int]]) -> Optional[int]:
    """The constant ``(dst - src) % P`` when ``perm`` is a full cyclic shift
    over P members; ``None`` for anything else (partial/irregular perms)."""
    pairs = sorted(perm)
    p = len(pairs)
    if p == 0 or [src for src, _ in pairs] != list(range(p)):
        return None
    shifts = {(dst - src) % p for src, dst in pairs}
    return shifts.pop() if len(shifts) == 1 else None


def _sub_jaxprs(params: dict):
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for j in vs:
            if hasattr(j, "eqns"):          # Jaxpr
                yield j
            elif hasattr(j, "jaxpr"):       # ClosedJaxpr
                yield j.jaxpr


def summarize(closed_jaxpr) -> JaxprSummary:
    """Recursively walk a (Closed)Jaxpr; collect primitive counts, collective
    ops, and callback sightings. Call primitives (pjit, shard_map, custom_vjp,
    scan, cond, ...) are traversed through their sub-jaxpr params."""
    counts: collections.Counter = collections.Counter()
    collectives: list[CollectiveOp] = []
    callbacks: list[str] = []
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    stack = [jaxpr]
    while stack:
        jx = stack.pop()
        for eq in jx.eqns:
            name = eq.primitive.name
            counts[name] += 1
            if any(m in name for m in CALLBACK_MARKERS):
                callbacks.append(name)
            if name in EXCHANGE_PRIMS + REDUCE_PRIMS + GATHER_PRIMS:
                shift = None
                if name == "ppermute":
                    shift = cyclic_shift(eq.params.get("perm", ()))
                for v in eq.invars:
                    aval = v.aval
                    collectives.append(CollectiveOp(
                        prim=name, dtype=getattr(aval.dtype, "name",
                                                 str(aval.dtype)),
                        shape=tuple(aval.shape), shift=shift))
            stack.extend(_sub_jaxprs(eq.params))
    return JaxprSummary(prim_counts=counts, collectives=collectives,
                        callbacks=callbacks)


# ---------------------------------------------------------------------------
# expectations
# ---------------------------------------------------------------------------
def quant_components(bits: int) -> int:
    """Arrays per quantized exchange: packed payload + scale + zero for real
    quantization; passthrough widths (16/32) ship the payload alone."""
    return 1 if bits >= 16 else 3


@dataclasses.dataclass(frozen=True)
class ExchangeExpectation:
    """Declared communication structure of one traced entry point.

    ``fwd_ops``/``bwd_ops`` count *logical halo exchanges* (one per live
    exchange site per direction); each op moves :func:`quant_components`
    arrays. ``mask_ops`` are the serving path's unquantized affected-mask
    rides (1 array each, forward direction). ``buckets`` is the compact
    layout's static ragged bucket-size tuple, ``None`` for the dense layout.
    ``psums`` is the exact all-reduce count (``None`` = don't check).
    """

    fwd_ops: int
    bwd_ops: int
    bits: int
    buckets: Optional[tuple[int, ...]]
    mask_ops: int = 0
    psums: Optional[int] = None
    wire_dtypes: frozenset = frozenset({"uint8", "bfloat16"})

    @property
    def comps(self) -> int:
        return quant_components(self.bits)


def expected_shift_census(exp: ExchangeExpectation
                          ) -> collections.Counter:
    """Multiset of (shift, rows) a compact-layout entry point must produce.

    Forward ops ship bucket ``k`` (``b_k`` rows) at shift ``k``; backward ops
    run the inverted rings — bucket ``k``'s rows at shift ``P - k``. The
    diagonal bucket (k=0) and empty buckets never hit the wire.
    """
    assert exp.buckets is not None
    p = len(exp.buckets)
    census: collections.Counter = collections.Counter()
    fwd_arrays = exp.fwd_ops * exp.comps + exp.mask_ops
    bwd_arrays = exp.bwd_ops * exp.comps
    for k, b in enumerate(exp.buckets):
        if k == 0 or not b:
            continue
        census[(k, b)] += fwd_arrays
        census[((p - k) % p, b)] += bwd_arrays
    return census


def check_exchange_census(summary: JaxprSummary, exp: ExchangeExpectation,
                          where: str) -> list[Finding]:
    """Collective census + ring-inversion check for one entry point."""
    out = []

    def bad(code, msg):
        out.append(Finding(code=code, where=where, message=msg))

    n_pp = summary.count("ppermute")
    n_a2a = summary.count("all_to_all")
    n_gather = sum(summary.count(p) for p in GATHER_PRIMS)
    if n_gather:
        bad("RC201", f"{n_gather} all_gather-family collective(s) — the halo "
            "exchange must never gather globally (wire cost P x payload)")

    if exp.buckets is not None:
        # compact: one ppermute per non-empty ring bucket per shipped array
        if n_a2a:
            bad("RC201", f"{n_a2a} all_to_all op(s) in a compact-layout entry "
                "point — ring buckets must lower to ppermute only")
        want = expected_shift_census(exp)
        got: collections.Counter = collections.Counter()
        for op in summary.ops("ppermute"):
            if op.shift is None:
                bad("RC203", "ppermute permutation is not a cyclic ring shift")
                continue
            got[(op.shift, op.rows)] += 1
        if got != want:
            missing = {k: v for k, v in (want - got).items()}
            extra = {k: v for k, v in (got - want).items()}
            detail = []
            if missing:
                detail.append(f"missing (shift, rows) ops {missing}")
            if extra:
                detail.append(f"unexpected {extra}")
            # a pure fwd<->bwd swap is specifically a ring-inversion bug
            code = "RC203" if _is_inversion_miss(want, got) else "RC201"
            bad(code, "ppermute census mismatch — expected "
                f"{exp.fwd_ops} fwd + {exp.bwd_ops} bwd ops x {exp.comps} "
                f"arrays (+{exp.mask_ops} mask) over buckets "
                f"{exp.buckets}: " + "; ".join(detail))
    else:
        # dense: one tiled all_to_all per shipped array, no ppermute
        if n_pp:
            bad("RC201", f"{n_pp} ppermute op(s) in a dense-layout entry "
                "point — pairwise blocks must lower to one tiled all_to_all")
        want_a2a = (exp.fwd_ops + exp.bwd_ops) * exp.comps + exp.mask_ops
        if n_a2a != want_a2a:
            bad("RC201", f"all_to_all census mismatch: expected {want_a2a} "
                f"({exp.fwd_ops} fwd + {exp.bwd_ops} bwd ops x {exp.comps} "
                f"arrays + {exp.mask_ops} mask), found {n_a2a}")

    if exp.psums is not None:
        n_psum = sum(summary.count(p) for p in REDUCE_PRIMS)
        if n_psum != exp.psums:
            bad("RC201", f"psum census mismatch: expected exactly "
                f"{exp.psums} (weight-grad leaves + loss + telemetry), "
                f"found {n_psum} — a stray all-reduce silently multiplies "
                "gradient sync cost")
    return out


def _is_inversion_miss(want: collections.Counter,
                       got: collections.Counter) -> bool:
    """True when ``got`` is ``want`` with some shifts un-inverted (k vs P-k
    confusion) — same totals per rows-class, wrong directions."""
    if sum(want.values()) != sum(got.values()):
        return False

    def by_rows(c):
        out = collections.Counter()
        for (_, rows), n in c.items():
            out[rows] += n
        return out

    return by_rows(want) == by_rows(got) and want != got


def check_wire_dtypes(summary: JaxprSummary, exp: ExchangeExpectation,
                      where: str) -> list[Finding]:
    """Every cross-device exchange operand must be a wire-cheap dtype.

    For quantized entry points (bits <= 8) that is uint8 payload + the
    scale_dtype error compensation — **never** fp32: an fp32 operand means
    dequantized data crossed the wire and the one-bit claim is void. The
    reduce family (psum of losses/grads/stats) is exempt — gradient sync is
    full-precision by design (EF21 is its own, separately-audited path).
    """
    out = []
    for op in summary.ops(*EXCHANGE_PRIMS):
        if op.dtype not in exp.wire_dtypes:
            out.append(Finding(
                code="RC202", where=where,
                message=f"{op.prim} ships {op.dtype}{list(op.shape)} but this "
                f"entry point is contracted to {sorted(exp.wire_dtypes)} — "
                "a full-precision operand on a quantized exchange leaks "
                "dequantized data onto the wire"))
    return out


def check_no_callbacks(summary: JaxprSummary, where: str) -> list[Finding]:
    """Hot entry points must not lower host callbacks (pure_callback,
    io_callback, debug prints, infeed/outfeed): each one stalls the device
    pipeline and breaks async dispatch."""
    if not summary.callbacks:
        return []
    return [Finding(
        code="RC205", where=where,
        message=f"host callback primitive(s) {sorted(set(summary.callbacks))} "
        "inside a hot entry point — host round-trips are banned on the "
        "train/serve path")]


def check_no_collectives(summary: JaxprSummary, where: str) -> list[Finding]:
    """Simulated-backend entry points run the whole stack in one program:
    any collective primitive means backend dispatch leaked."""
    found = {p: summary.count(p)
             for p in EXCHANGE_PRIMS + REDUCE_PRIMS + GATHER_PRIMS
             if summary.count(p)}
    if not found:
        return []
    return [Finding(
        code="RC201", where=where,
        message=f"collective primitives {found} in a simulated-backend entry "
        "point — the stacked reference semantics must compile to pure "
        "array ops")]
