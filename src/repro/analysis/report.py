"""Findings, baselines, and the JSON report — shared by both analysis layers.

A :class:`Finding` is one violation: a rule/contract ``code`` (``RA1xx`` =
AST lint, ``RC2xx`` = jaxpr/HLO contract), a location (``path:line`` for lint,
``contract:<entry-point>`` for contracts), and a message.

The **baseline** is a checked-in text file (``tools/analysis_baseline.txt``)
listing findings that are *accepted debt*: one fingerprint per line, ``code ::
location :: message``, with ``#`` comments explaining why each entry is
tolerated. Fingerprints drop line numbers so unrelated edits do not
invalidate the baseline; everything else must match exactly — a baselined
finding whose message drifts resurfaces as a fresh violation. An empty (or
absent) baseline means the repo is expected to be clean.

``python -m repro.analysis --json`` writes the machine-readable report to
``artifacts/analysis/report.json`` (schema: see :func:`write_report`).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, Optional, Sequence

DEFAULT_BASELINE = os.path.join("tools", "analysis_baseline.txt")
DEFAULT_REPORT_DIR = os.path.join("artifacts", "analysis")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule or contract violation."""

    code: str       # "RA105", "RC201", ...
    where: str      # "src/repro/core/sylvie.py" or "contract:train_sync/..."
    message: str
    line: int = 0   # 0 = not line-addressed (contracts)

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used for baseline matching."""
        return f"{self.code} :: {self.where} :: {self.message}"

    def render(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return f"{loc}: {self.code} {self.message}"


def load_baseline(path: Optional[str]) -> set[str]:
    """Read accepted-debt fingerprints. Missing file == empty baseline."""
    if path is None or not os.path.exists(path):
        return set()
    out = set()
    with open(path) as f:
        for raw in f:
            line = raw.strip()
            if line and not line.startswith("#"):
                out.add(line)
    return out


def split_by_baseline(findings: Sequence[Finding], baseline: set[str]
                      ) -> tuple[list[Finding], list[Finding]]:
    """(fresh, baselined) — fresh findings gate the exit code."""
    fresh, known = [], []
    for f in findings:
        (known if f.fingerprint in baseline else fresh).append(f)
    return fresh, known


def stale_baseline_entries(findings: Sequence[Finding],
                           baseline: set[str]) -> list[str]:
    """Baseline lines no current finding matches — debt that was paid off and
    should be deleted from the file (reported, never fatal)."""
    seen = {f.fingerprint for f in findings}
    return sorted(baseline - seen)


def write_report(path: str, findings: Sequence[Finding],
                 baseline: set[str], skipped: Iterable[str] = (),
                 meta: Optional[dict] = None) -> str:
    """Write the JSON report. Schema::

        {"meta": {...}, "counts": {"fresh": N, "baselined": M},
         "skipped": ["contract:... (why)", ...],
         "findings": [{"code", "where", "line", "message", "baselined"}...],
         "stale_baseline": ["fingerprint", ...]}
    """
    fresh, known = split_by_baseline(findings, baseline)
    body = {
        "meta": meta or {},
        "counts": {"fresh": len(fresh), "baselined": len(known)},
        "skipped": sorted(skipped),
        "findings": [
            dataclasses.asdict(f) | {"baselined": f.fingerprint in baseline}
            for f in sorted(findings, key=lambda f: (f.code, f.where, f.line))
        ],
        "stale_baseline": stale_baseline_entries(findings, baseline),
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(body, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
