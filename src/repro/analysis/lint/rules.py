"""Repo-specific AST lint rules (stdlib ``ast`` — no third-party linter).

Each rule is a function ``(module: Module) -> list[Finding]`` registered in
``RULES`` under its code. The rules encode *trace discipline*: invariants that
keep jit-traced code correct and recompile-bounded, which generic linters
cannot know about. Codes:

======  ======================================================================
RA101   Python ``if``/``while`` branching on a traced (jnp/lax) expression
        inside a traced module — control flow must be ``lax.cond``/``where``.
RA102   ``jax.jit`` with ``static_argnums``/``static_argnames`` naming a
        parameter whose default is an unhashable literal (list/dict/set).
RA103   ``custom_vjp`` residual-arity mismatch: the bwd function must return
        one cotangent per *differentiable* primal argument (positional args
        minus ``nondiff_argnums``); the fwd function must return a 2-tuple.
RA104   Import-time JAX device work: module-level calls to ``jnp.*`` /
        ``jax.random.*`` / ``jax.devices`` / ``jax.device_put`` allocate or
        touch devices before any ``main()`` can configure them.
RA105   Nondeterminism in traced modules: ``time`` / ``random`` imports or
        calls — traced code must draw randomness from threaded PRNG keys.
RA106   Host synchronization in traced modules: ``.item()``,
        ``jax.device_get``, ``np.asarray``/``np.array`` force a device sync
        inside what should be a pure traced hot path.
RA107   Unused import (F401-lite fallback for environments without ruff).
        ``__init__.py`` re-exports and ``# noqa``-marked lines are exempt.
RA108   Raw wall-clock reads (``time.time``/``time.perf_counter``/
        ``time.monotonic`` and their ``_ns`` variants) in *instrumented*
        modules — timing there must go through ``repro.obs.clock`` (or an
        injected clock) so FakeClock tests and traced runs see one time
        source. See :data:`INSTRUMENTED_MODULES`.
======  ======================================================================

"Traced modules" (RA101/RA105/RA106) are the files whose function bodies run
under ``jit``/``shard_map``/``custom_vjp`` — see :data:`TRACED_MODULES`. Host
orchestration (trainer loop, serve engine host side, benchmarks) is
deliberately out of scope: ``time.time()`` around a step is fine there —
*except* in the obs-instrumented modules, where RA108 routes it through the
injectable obs clock (``time.sleep`` stays allowed: it waits, it doesn't
measure).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Optional

from ..report import Finding

# Files (repo-relative, '/'-separated; prefixes for directories) whose
# function bodies are traced. Keep in sync with DESIGN.md §11.
TRACED_MODULES = (
    "src/repro/core/",
    "src/repro/kernels/",
    "src/repro/dist/overlap.py",
    "src/repro/faults/comm.py",
    "src/repro/faults/wire.py",
    "src/repro/train/gnn_step.py",
    "src/repro/train/compression.py",
    "src/repro/train/optimizer.py",
)

# Files (repo-relative; prefixes for directories) instrumented through
# repro.obs — their timing must read the injectable obs clock, never the
# wall clock directly (RA108). benchmarks/ are exempt: they *measure* the
# instrumentation, so they need an independent time source.
INSTRUMENTED_MODULES = (
    "src/repro/serve/",
    "src/repro/store/",
    "src/repro/train/trainer.py",
    "src/repro/launch/scenarios.py",
)

# jax attribute calls that are pure metadata — allowed at import time (RA104).
_IMPORT_TIME_OK = {"ShapeDtypeStruct", "tree_util", "custom_vjp", "custom_jvp",
                   "jit", "vmap", "grad", "value_and_grad", "named_scope"}


@dataclasses.dataclass
class Module:
    """One parsed file handed to every rule."""

    relpath: str          # repo-relative, '/'-separated
    tree: ast.Module
    lines: list[str]

    @property
    def is_traced(self) -> bool:
        return _matches(self.relpath, TRACED_MODULES)

    @property
    def is_instrumented(self) -> bool:
        return _matches(self.relpath, INSTRUMENTED_MODULES)

    def noqa(self, lineno: int) -> bool:
        if 1 <= lineno <= len(self.lines):
            return "# noqa" in self.lines[lineno - 1]
        return False


def _matches(relpath: str, prefixes) -> bool:
    return any(relpath == p or (p.endswith("/") and relpath.startswith(p))
               for p in prefixes)


RULES: dict[str, Callable[[Module], list[Finding]]] = {}


def rule(code: str):
    def deco(fn):
        RULES[code] = fn
        return fn
    return deco


def _attr_root(node: ast.AST) -> Optional[str]:
    """Leftmost name of an attribute chain (``jnp.max(...)`` -> ``jnp``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _attr_chain(node: ast.AST) -> str:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _finding(code: str, mod: Module, node: ast.AST, msg: str) -> Finding:
    return Finding(code=code, where=mod.relpath, message=msg,
                   line=getattr(node, "lineno", 0))


# ---------------------------------------------------------------------------
# RA101 — Python branching on traced values
# ---------------------------------------------------------------------------
@rule("RA101")
def traced_branch(mod: Module) -> list[Finding]:
    if not mod.is_traced:
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        for sub in ast.walk(node.test):
            root = _attr_root(sub) if isinstance(sub, (ast.Attribute,
                                                       ast.Call)) else None
            if isinstance(sub, ast.Call):
                root = _attr_root(sub.func)
            if root in ("jnp", "lax") and not mod.noqa(node.lineno):
                kind = "while" if isinstance(node, ast.While) else "if"
                out.append(_finding(
                    "RA101", mod, node,
                    f"python `{kind}` branches on a traced `{root}.*` "
                    "expression; use lax.cond/jnp.where (trace-time branching "
                    "forces recompilation or fails under jit)"))
                break
    return out


# ---------------------------------------------------------------------------
# RA102 — unhashable static args
# ---------------------------------------------------------------------------
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set)


def _jit_static_params(call: ast.Call) -> Optional[tuple[list[int], list[str]]]:
    """(static positions, static names) if ``call`` configures jax.jit with
    static args — handles ``jax.jit(...)`` and ``partial(jax.jit, ...)``."""
    target = call.func
    if _attr_chain(target) in ("partial", "functools.partial") and call.args:
        inner = call.args[0]
        if _attr_chain(inner) not in ("jax.jit", "jit"):
            return None
    elif _attr_chain(target) not in ("jax.jit", "jit"):
        return None
    nums: list[int] = []
    names: list[str] = []
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.append(n.value)
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.append(n.value)
    if not nums and not names:
        return None
    return nums, names


@rule("RA102")
def unhashable_static_args(mod: Module) -> list[Finding]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            static = _jit_static_params(deco)
            if static is None:
                continue
            nums, names = static
            args = node.args.args
            defaults = node.args.defaults
            offset = len(args) - len(defaults)
            for i, a in enumerate(args):
                if i < offset:
                    continue
                default = defaults[i - offset]
                if not isinstance(default, _MUTABLE_LITERALS):
                    continue
                if (i in nums or a.arg in names) and not mod.noqa(node.lineno):
                    out.append(_finding(
                        "RA102", mod, node,
                        f"static arg {a.arg!r} of jitted {node.name!r} "
                        f"defaults to an unhashable "
                        f"{type(default).__name__.lower()} literal — jit "
                        "static args must be hashable"))
    return out


# ---------------------------------------------------------------------------
# RA103 — custom_vjp fwd/bwd residual arity
# ---------------------------------------------------------------------------
def _custom_vjp_info(fn: ast.FunctionDef) -> Optional[tuple[int, set[int]]]:
    """(n positional args, nondiff positions) when ``fn`` is a custom_vjp
    primal — ``@jax.custom_vjp`` or ``@partial(jax.custom_vjp, ...)``."""
    for deco in fn.decorator_list:
        chain = _attr_chain(deco if not isinstance(deco, ast.Call)
                            else deco.func)
        nondiff: set[int] = set()
        if isinstance(deco, ast.Call) and chain in ("partial",
                                                    "functools.partial"):
            if not deco.args or _attr_chain(deco.args[0]) not in (
                    "jax.custom_vjp", "custom_vjp"):
                continue
            for kw in deco.keywords:
                if kw.arg == "nondiff_argnums":
                    for n in ast.walk(kw.value):
                        if isinstance(n, ast.Constant) and \
                                isinstance(n.value, int):
                            nondiff.add(n.value)
        elif chain not in ("jax.custom_vjp", "custom_vjp"):
            continue
        if fn.args.vararg is not None:
            return None  # *args defeat static arity counting
        return len(fn.args.args), nondiff
    return None


@rule("RA103")
def custom_vjp_arity(mod: Module) -> list[Finding]:
    fns = {n.name: n for n in ast.walk(mod.tree)
           if isinstance(n, ast.FunctionDef)}
    primals = {name: info for name, fn in fns.items()
               if (info := _custom_vjp_info(fn)) is not None}
    out = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "defvjp" and len(node.args) >= 2):
            continue
        primal = _attr_root(node.func)
        if primal not in primals:
            continue
        n_args, nondiff = primals[primal]
        want = n_args - len(nondiff)
        fwd, bwd = (a.id if isinstance(a, ast.Name) else None
                    for a in node.args[:2])
        for name, expect, what in ((fwd, 2, "fwd (out, residuals)"),
                                   (bwd, want, "bwd cotangent")):
            fn = fns.get(name)
            if fn is None:
                continue
            for ret in ast.walk(fn):
                if isinstance(ret, ast.Return) and \
                        isinstance(ret.value, ast.Tuple) and \
                        len(ret.value.elts) != expect and \
                        not mod.noqa(ret.lineno):
                    out.append(_finding(
                        "RA103", mod, ret,
                        f"{name} returns a {len(ret.value.elts)}-tuple but "
                        f"custom_vjp {primal!r} needs a {expect}-tuple "
                        f"({what}; {n_args} positional args, "
                        f"{len(nondiff)} nondiff)"))
    return out


# ---------------------------------------------------------------------------
# RA104 — import-time JAX device work
# ---------------------------------------------------------------------------
def _module_level_nodes(tree: ast.Module):
    """Statements executed at import: everything except function bodies."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                stack.append(child)


@rule("RA104")
def import_time_device_work(mod: Module) -> list[Finding]:
    out = []
    for node in _module_level_nodes(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        root = chain.split(".")[0] if chain else None
        bad = (root == "jnp"
               or chain.startswith("jax.numpy.")
               or chain.startswith("jax.random.")
               or chain in ("jax.devices", "jax.device_put",
                            "jax.device_get", "jax.eval_shape"))
        if root == "jax" and chain.split(".")[-1] in _IMPORT_TIME_OK:
            bad = False
        if bad and not mod.noqa(node.lineno):
            out.append(_finding(
                "RA104", mod, node,
                f"module-level `{chain}(...)` runs JAX device work at import "
                "time (allocates/initializes backends before main() can "
                "configure them)"))
    return out


# ---------------------------------------------------------------------------
# RA105 — nondeterminism in traced modules
# ---------------------------------------------------------------------------
@rule("RA105")
def nondeterminism(mod: Module) -> list[Finding]:
    if not mod.is_traced:
        return []
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] in ("time", "random") and \
                        not mod.noqa(node.lineno):
                    out.append(_finding(
                        "RA105", mod, node,
                        f"`import {a.name}` in a traced module — traced code "
                        "must be deterministic (PRNG keys, not "
                        "wall-clock/global RNG)"))
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] in ("time", "random") \
                    and not mod.noqa(node.lineno):
                out.append(_finding(
                    "RA105", mod, node,
                    f"`from {node.module} import ...` in a traced module"))
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain.split(".")[0] in ("time", "random") and \
                    not mod.noqa(node.lineno):
                out.append(_finding(
                    "RA105", mod, node,
                    f"`{chain}(...)` in a traced module — nondeterministic "
                    "under jit (called at trace time, frozen thereafter)"))
    return out


# ---------------------------------------------------------------------------
# RA106 — host synchronization in traced modules
# ---------------------------------------------------------------------------
_HOST_SYNC_CALLS = ("jax.device_get", "np.asarray", "np.array",
                    "numpy.asarray", "numpy.array")


@rule("RA106")
def host_sync(mod: Module) -> list[Finding]:
    if not mod.is_traced:
        return []
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            if not mod.noqa(node.lineno):
                out.append(_finding(
                    "RA106", mod, node,
                    "`.item()` in a traced module forces a host sync "
                    "(blocks the device stream; fails under jit)"))
            continue
        chain = _attr_chain(node.func)
        if chain in _HOST_SYNC_CALLS and not mod.noqa(node.lineno):
            out.append(_finding(
                "RA106", mod, node,
                f"`{chain}(...)` in a traced module pulls values to the "
                "host — hot paths must stay on device"))
    return out


# ---------------------------------------------------------------------------
# RA107 — unused imports (F401-lite; ruff owns this when available)
# ---------------------------------------------------------------------------
@rule("RA107")
def unused_imports(mod: Module) -> list[Finding]:
    if mod.relpath.endswith("__init__.py"):
        return []  # __init__ imports are the package's public re-exports
    imported: dict[str, tuple[int, str]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = (a.asname or a.name).split(".")[0]
                imported[name] = (node.lineno, a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name != "*":
                    imported[a.asname or a.name] = (node.lineno, a.name)
    used = {n.id for n in ast.walk(mod.tree) if isinstance(n, ast.Name)}
    # names exported via __all__ count as used
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    for c in ast.walk(node.value):
                        if isinstance(c, ast.Constant) and \
                                isinstance(c.value, str):
                            used.add(c.value)
    out = []
    for name, (lineno, orig) in sorted(imported.items()):
        if name in used or mod.noqa(lineno):
            continue
        out.append(Finding(
            code="RA107", where=mod.relpath, line=lineno,
            message=f"unused import {orig!r}"))
    return out


# ---------------------------------------------------------------------------
# RA108 — raw wall-clock reads in obs-instrumented modules
# ---------------------------------------------------------------------------
_WALLCLOCK_NAMES = ("time", "perf_counter", "monotonic",
                    "perf_counter_ns", "monotonic_ns")
_WALLCLOCK_CALLS = tuple(f"time.{n}" for n in _WALLCLOCK_NAMES)


@rule("RA108")
def raw_wallclock(mod: Module) -> list[Finding]:
    if not mod.is_instrumented:
        return []
    # `from time import perf_counter [as pc]` makes the read a bare-name
    # call — track the local aliases so the rename doesn't evade the rule
    aliases: set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name in _WALLCLOCK_NAMES:
                    aliases.add(a.asname or a.name)
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if (chain in _WALLCLOCK_CALLS or chain in aliases) and \
                not mod.noqa(node.lineno):
            out.append(_finding(
                "RA108", mod, node,
                f"`{chain}(...)` reads the wall clock directly in an "
                "obs-instrumented module — use repro.obs.clock() (or an "
                "injected clock) so FakeClock tests and traces share one "
                "time source"))
    return out
