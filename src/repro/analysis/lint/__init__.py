"""AST lint driver: parse files, run every registered rule.

``run_lint(paths)`` walks the given files/directories (default: ``src/repro``
+ ``benchmarks``), parses each ``.py`` once, and applies :data:`rules.RULES`.
Pure stdlib — this is the lint layer that works in any environment; ``ruff``
(wired in ``pyproject.toml``/CI) covers the generic style axis when installed.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, Optional, Sequence

from ..report import Finding
from . import rules as rules_mod
from .rules import RULES, TRACED_MODULES, Module  # noqa: F401

DEFAULT_PATHS = ("src/repro", "benchmarks")


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def parse_module(path: str, root: str = ".") -> Module:
    with open(path) as f:
        src = f.read()
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    return Module(relpath=rel, tree=ast.parse(src, filename=path),
                  lines=src.splitlines())


def run_lint(paths: Sequence[str] = DEFAULT_PATHS, root: str = ".",
             only: Optional[Sequence[str]] = None) -> list[Finding]:
    """Lint ``paths`` (files or directories). ``only`` restricts to specific
    rule codes (used by the planted-violation tests)."""
    selected = {c: fn for c, fn in RULES.items()
                if only is None or c in only}
    findings: list[Finding] = []
    for path in _iter_py_files(paths):
        try:
            mod = parse_module(path, root)
        except SyntaxError as e:
            findings.append(Finding(
                code="RA100", where=path.replace(os.sep, "/"),
                line=e.lineno or 0, message=f"syntax error: {e.msg}"))
            continue
        for code in sorted(selected):
            findings.extend(selected[code](mod))
    return findings
