from . import gnn_step, optimizer  # noqa: F401
