"""Pure-JAX optimizers (SGD/momentum, Adam, AdamW) + global-norm clipping.

Paper training uses plain SGD/Adam per model; we default to Adam for the GNN
experiments (matching common GCN/SAGE/GAT setups) and AdamW for LM configs.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return _tmap(lambda g: g * scale, grads), n


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(jnp.zeros_like, params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return _tmap(lambda g: -lr * g, grads), state
        new_m = _tmap(lambda m, g: momentum * m + g, state, grads)
        return _tmap(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.zeros_like, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = _tmap(upd, m, v,
                        params if params is not None else _tmap(jnp.zeros_like, m))
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def apply_updates(params, updates):
    return _tmap(lambda p, u: p + u.astype(p.dtype), params, updates)
