"""Error-feedback quantized weight-gradient all-reduce (beyond-paper).

Sylvie leaves the DP weight-gradient all-reduce in full precision because it
is negligible in the paper's 2-8 GPU setting (Fig. 2). At 256-512 chips the
all-reduce term grows with log(P) latency and byte volume, so we provide an
EF21-style compressed all-reduce that composes with Sylvie's Low-bit Module
(same quantizer) for the ``data`` axis:

    c_t   = Q_b(g_t - m_t + e_t)          per-device compress with memory
    e_t+1 = (g_t - m_t + e_t) - DQ(c_t)   local error feedback
    m_t+1 = m_t + psum(DQ(c_t)) / P       shared gradient estimate

``m`` (the running estimate) is replicated state; each step only the
*innovation* is quantized and reduced, so the estimate converges to the true
mean gradient while the wire carries b-bit payloads (Richtárik et al.,
EF21 [arXiv:2106.05203]; 1-bit Adam [arXiv:2102.02888]).

Off by default. The bit-width is part of the per-epoch communication
decision: any :class:`repro.policy.base.CommPolicy` whose ``EpochDecision``
sets ``ef_bits`` (e.g. ``Uniform(bits=1, ef_bits=2)``) routes the reduced
weight gradient through :func:`ef_allreduce` inside the step
(``train/gnn_step.py``); the EF error/estimate state lives in
``GNNTrainState.ef`` and :func:`ef_wire_bytes` joins the trainer's per-epoch
byte accounting.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from ..core import quantization as qlib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EFState:
    error: dict      # per-leaf local residual
    estimate: dict   # per-leaf shared gradient estimate (replicated)

    @staticmethod
    def zeros_like(params) -> "EFState":
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return EFState(error=z, estimate=jax.tree.map(jnp.zeros_like, z))


def _axis_size(axis_name) -> int:
    from ..dist import compat
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n = 1
    for a in names:
        n *= compat.axis_size(a)
    return n


def ef_allreduce(grads, state: EFState, bits: int = 1, axis_name=None):
    """-> (mean-gradient estimate tree, new EFState).

    Deterministic by construction: both compressors below are contractive
    *deterministic* maps (stochastic rounding breaks EF21 — see the inline
    note), so no PRNG key enters the signature.

    With ``axis_name=None`` (simulated / single-device) the wire is the
    identity and only the quantization noise path is exercised.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = jax.tree_util.tree_flatten(state.error)[0]
    m_leaves = jax.tree_util.tree_flatten(state.estimate)[0]
    new_e, new_m = [], []
    for g, e, m in zip(leaves, e_leaves, m_leaves):
        g = g.astype(jnp.float32)
        innov = g - m + e
        flat = innov.reshape(-1, innov.shape[-1]) if innov.ndim > 1 \
            else innov.reshape(1, -1)
        # Error feedback requires a CONTRACTIVE compressor. The Low-bit
        # Module's unbiased stochastic rounding has per-element variance
        # ~range^2/4 at 1 bit — above ||x||^2 for gaussian-ish vectors — and
        # the feedback loop diverges (measured: NaN within 60 rounds). At
        # 1 bit we therefore use scaled-sign (1-bit Adam's compressor,
        # delta = ||x||_1^2 / (D ||x||_2^2) > 0); >= 2 bits, deterministic
        # round-to-nearest affine is contractive enough. Same wire format:
        # packed bits + one bf16 scale per row.
        if bits == 1:
            scale = jnp.mean(jnp.abs(flat), axis=-1, keepdims=True)
            deq = (jnp.sign(flat) * scale).reshape(innov.shape)
        else:
            qt = qlib.quantize(flat, bits, stochastic=False)
            deq = qlib.dequantize(qt).reshape(innov.shape)
        new_e.append(innov - deq)
        if axis_name is not None:
            deq = jax.lax.psum(deq, axis_name) / _axis_size(axis_name)
        new_m.append(m + deq)
    est = jax.tree_util.tree_unflatten(treedef, new_m)
    return est, EFState(error=jax.tree_util.tree_unflatten(treedef, new_e),
                        estimate=est)


def ef_wire_bytes(params, bits: int) -> tuple[int, int]:
    """(payload, error-compensation) bytes one compressed all-reduce moves."""
    payload = ec = 0
    for p in jax.tree.leaves(params):
        rows = int(p.size // p.shape[-1]) if p.ndim > 1 else 1
        d = int(p.shape[-1]) if p.ndim > 1 else int(p.size)
        pb, eb = qlib.comm_bytes(rows, d, bits)
        payload += pb
        ec += eb
    return payload, ec
