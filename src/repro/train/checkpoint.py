"""Atomic sharded checkpoints with elastic-repartition resume.

Layout (one directory per step):

    <dir>/step_0000100/
        manifest.json     step, tree paths, partition layout, keep-k metadata
        arrays.npz        path-keyed leaves (device_get'd)
    <dir>/step_0000100.tmp...   (written first, atomically renamed)

Fault-tolerance contract:
  * atomic: a crash mid-write never corrupts the latest checkpoint (tmp dir +
    ``os.replace`` rename; readers only ever see complete directories);
  * keep-k: older checkpoints garbage-collected after a successful save;
  * bit-exact resume: PRNG keys, optimizer state, Sylvie-A halo caches and
    the step counter all live in the saved tree (tested);
  * elastic: GNN weights are partition-count-independent (replicated), so a
    checkpoint taken at N partitions restores at N' — ``restore`` detects a
    halo-cache shape mismatch, zeroes the caches, and flags
    ``needs_sync_epoch`` so the trainer runs one synchronous epoch (the
    Bounded Staleness Adaptor's refresh) before resuming pipelined steps.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key or "_root"] = leaf
    return flat


def save(ckpt_dir: str | os.PathLike, step: int, tree, meta: Optional[dict] = None,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    np.savez(tmp / "arrays.npz", **flat)
    manifest = dict(step=int(step), keys=sorted(flat),
                    shapes={k: list(v.shape) for k, v in flat.items()},
                    dtypes={k: str(v.dtype) for k, v in flat.items()},
                    meta=meta or {})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish

    kept = sorted(p for p in ckpt_dir.iterdir()
                  if p.is_dir() and p.name.startswith("step_"))
    for old in kept[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
             if p.is_dir() and p.name.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, example_tree,
            step: Optional[int] = None):
    """-> (tree, manifest_meta, needs_sync_epoch).

    ``example_tree`` supplies structure + target shapes. Leaves whose stored
    shape mismatches (halo caches after an elastic repartition) are replaced
    with zeros of the target shape and flagged.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    stored = np.load(d / "arrays.npz")
    flat_example = _flatten(example_tree)
    needs_sync = False
    out = {}
    for key, ex in flat_example.items():
        ex_shape = tuple(getattr(ex, "shape", ()))
        ex_dtype = getattr(ex, "dtype", np.float32)
        if key not in stored.files:
            out[key] = np.zeros(ex_shape, ex_dtype)
            needs_sync = True
            continue
        arr = stored[key]
        if tuple(arr.shape) != ex_shape:
            out[key] = np.zeros(ex_shape, ex_dtype)   # elastic repartition
            needs_sync = True
        else:
            out[key] = arr.astype(ex_dtype)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    keys = [SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                     for p in path) or "_root" for path, _ in leaves_paths]
    tree = jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
    return tree, manifest["meta"], needs_sync
