"""Atomic sharded checkpoints with elastic-repartition resume.

Layout (one directory per step):

    <dir>/step_0000100/
        manifest.json     step, tree paths, partition layout, keep-k metadata
        arrays.npz        path-keyed leaves (device_get'd)
    <dir>/step_0000100.tmp...   (written first, atomically renamed)

Fault-tolerance contract:
  * atomic: a crash mid-write never corrupts the latest checkpoint (tmp dir +
    ``os.replace`` rename; readers only ever see complete directories);
  * keep-k: older checkpoints garbage-collected after a successful save;
  * bit-exact resume: PRNG keys, optimizer state, Sylvie-A halo caches and
    the step counter all live in the saved tree (tested);
  * elastic: GNN weights are partition-count-independent (replicated), so a
    checkpoint taken at N partitions restores at N' — ``restore`` detects a
    halo-cache shape mismatch, zeroes the caches, and flags
    ``needs_sync_epoch`` so the trainer runs one synchronous epoch (the
    Bounded Staleness Adaptor's refresh) before resuming pipelined steps;
  * versioned: every manifest records ``format_version`` so readers can
    refuse checkpoints newer than they understand (pre-versioning manifests
    read as version 1).

Train -> serve handoff: :func:`restore_for_inference` loads *only* the model
parameters out of a full :class:`~repro.train.gnn_step.GNNTrainState`
checkpoint — optimizer state, EF21 compressor state, Sylvie-A halo caches,
site telemetry and the step counter are training-only leaves the inference
engine (``repro.serve``) neither needs nor trusts (halo caches are rebuilt by
the engine's first full sweep at serving precision). Unlike :func:`restore`,
missing or shape-mismatched *parameter* leaves are an error, never zero-filled
— serving zeroed weights is silent garbage.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"

# Manifest format history:
#   1 — unversioned (PR 1..5): step / keys / shapes / dtypes / meta
#   2 — adds the explicit "format_version" field (contents unchanged; the
#       GNNTrainState itself grew ef/site_stats leaves back in PR 4, which
#       path-keyed flattening absorbs without a format change)
FORMAT_VERSION = 2


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key or "_root"] = leaf
    return flat


def save(ckpt_dir: str | os.PathLike, step: int, tree, meta: Optional[dict] = None,
         keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    np.savez(tmp / "arrays.npz", **flat)
    manifest = dict(format_version=FORMAT_VERSION, step=int(step),
                    keys=sorted(flat),
                    shapes={k: list(v.shape) for k, v in flat.items()},
                    dtypes={k: str(v.dtype) for k, v in flat.items()},
                    meta=meta or {})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish

    kept = sorted(p for p in ckpt_dir.iterdir()
                  if p.is_dir() and p.name.startswith("step_"))
    for old in kept[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if not p.is_dir():
            continue
        if p.name.startswith(".tmp_step_"):
            # orphan from a crash mid-save: never published (the atomic
            # rename didn't happen), so its contents are untrusted — collect
            # it now instead of waiting for the same step to be saved again.
            shutil.rmtree(p, ignore_errors=True)
            continue
        if p.name.startswith("step_"):
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def _open(ckpt_dir: str | os.PathLike, step: Optional[int]):
    """Resolve + open one checkpoint: (dir, manifest, arrays). Refuses
    manifests written by a *newer* format than this reader understands."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    version = int(manifest.get("format_version", 1))
    if version > FORMAT_VERSION:
        raise ValueError(
            f"{d} was written with checkpoint format {version}; this reader "
            f"understands <= {FORMAT_VERSION}")
    return d, manifest, np.load(d / "arrays.npz")


def restore(ckpt_dir: str | os.PathLike, example_tree,
            step: Optional[int] = None):
    """-> (tree, manifest_meta, needs_sync_epoch).

    ``example_tree`` supplies structure + target shapes. Leaves whose stored
    shape mismatches (halo caches after an elastic repartition) are replaced
    with zeros of the target shape and flagged.
    """
    d, manifest, stored = _open(ckpt_dir, step)
    flat_example = _flatten(example_tree)
    needs_sync = False
    out = {}
    for key, ex in flat_example.items():
        ex_shape = tuple(getattr(ex, "shape", ()))
        ex_dtype = getattr(ex, "dtype", np.float32)
        if key not in stored.files:
            out[key] = np.zeros(ex_shape, ex_dtype)
            needs_sync = True
            continue
        arr = stored[key]
        if tuple(arr.shape) != ex_shape:
            out[key] = np.zeros(ex_shape, ex_dtype)   # elastic repartition
            needs_sync = True
        else:
            out[key] = arr.astype(ex_dtype)
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    keys = [SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                     for p in path) or "_root" for path, _ in leaves_paths]
    tree = jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
    return tree, manifest["meta"], needs_sync


def restore_for_inference(ckpt_dir: str | os.PathLike, example_params,
                          step: Optional[int] = None):
    """Load only the model parameters of a :class:`GNNTrainState` checkpoint.

    ``example_params`` supplies the parameter pytree structure + target
    shapes/dtypes (``model.init(key)`` on any key works — only structure is
    read). Training-only leaves (optimizer / EF21 / halo caches / telemetry /
    step counter) are never materialized. Returns ``(params, meta)`` where
    ``meta`` is the manifest's user meta dict augmented with ``step`` and
    ``format_version``.

    Raises ``KeyError`` on a missing parameter leaf and ``ValueError`` on a
    shape mismatch — a serving process must fail loudly rather than serve
    zero-filled weights (contrast :func:`restore`, whose zero-fill is the
    *elastic resume* contract for halo caches).
    """
    _, manifest, stored = _open(ckpt_dir, step)
    flat_example = _flatten(example_params)
    out = {}
    for key, ex in flat_example.items():
        stored_key = f"params{SEP}{key}" if key != "_root" else "params"
        if stored_key not in stored.files:
            raise KeyError(
                f"checkpoint step_{manifest['step']:08d} has no leaf "
                f"{stored_key!r}; is this a GNNTrainState checkpoint for "
                f"this model?")
        arr = stored[stored_key]
        ex_shape = tuple(getattr(ex, "shape", ()))
        if tuple(arr.shape) != ex_shape:
            raise ValueError(
                f"parameter {stored_key!r} has stored shape {arr.shape}, "
                f"model expects {ex_shape}")
        out[key] = arr.astype(getattr(ex, "dtype", np.float32))
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(example_params)
    keys = [SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                     for p in path) or "_root" for path, _ in leaves_paths]
    params = jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
    meta = dict(manifest["meta"])
    meta["step"] = int(manifest["step"])
    meta["format_version"] = int(manifest.get("format_version", 1))
    return params, meta
