"""GNN trainer: epoch loop, Bounded Staleness Adaptor scheduling, eval,
checkpoint/restart, optional EF21 gradient compression, metrics.

One :class:`GNNTrainer` drives either execution mode through a
:class:`repro.dist.runtime.Runtime`:
  * ``Runtime.simulated(...)`` (the default on 1 CPU device) — the stacked
    reference semantics used by tests/benchmarks;
  * ``Runtime.from_mesh(mesh)`` — shard_map, one partition per device (the
    production path).

The *Bounded Staleness Adaptor* (paper §3.3) lives here: with
``cfg.mode == "async"`` and ``eps_s = k``, every k-th epoch runs the
synchronous step, refreshing all halo caches and draining in-flight boundary
gradients; epoch 0 is always synchronous (cache warm-up). ``eps_s=None``
means pure Sylvie-A.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.exchange import exchange_bytes, wire_bytes
from ..core.staleness import use_sync_step
from ..core.sylvie import SylvieConfig
from ..dist.runtime import Runtime
from ..models.gnn import blocks as B
from . import checkpoint as ckpt
from . import optimizer as optlib
from .gnn_step import GNNTrainState, make_gnn_steps


@dataclasses.dataclass
class EpochMetrics:
    epoch: int
    loss: float
    seconds: float
    mode: str
    comm_payload_mb: float
    comm_ec_mb: float
    val_acc: Optional[float] = None


class GNNTrainer:
    def __init__(self, model, pg, cfg: SylvieConfig,
                 opt: Optional[optlib.Optimizer] = None,
                 eps_s: Optional[int] = None,
                 runtime: Optional[Runtime] = None, mesh=None, seed: int = 0,
                 ckpt_dir: Optional[str] = None, keep: int = 3):
        self.model = model
        self.pg = pg
        self.cfg = cfg
        self.eps_s = eps_s
        p = pg.plan.n_parts
        if runtime is not None and mesh is not None:
            raise ValueError("pass runtime or mesh, not both "
                             "(mesh is shorthand for Runtime.from_mesh)")
        if runtime is None:
            runtime = (Runtime.from_mesh(mesh) if mesh is not None
                       else Runtime.simulated(p))
        if runtime.n_parts not in (None, p):
            raise ValueError(
                f"runtime is committed to {runtime.n_parts} partitions but the "
                f"graph was partitioned into {p}")
        self.runtime = runtime
        self.mesh = runtime.mesh
        self.opt = opt or optlib.adam(1e-2)
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.key = jax.random.PRNGKey(seed)

        self.block = B.build_block(pg)
        self.x = jnp.asarray(pg.x)
        self.y = jnp.asarray(pg.y)
        self.train_mask = jnp.asarray(pg.train_mask)
        self.val_mask = jnp.asarray(pg.val_mask)
        self.test_mask = jnp.asarray(pg.test_mask)
        self.state = GNNTrainState.create(self.model, self.opt, self.key,
                                          self.block.plan, stacked_parts=p)
        ts, ta, ev = make_gnn_steps(self.model, cfg, self.opt,
                                    backend=runtime.backend)
        self._ts, self._ta, self._ev = runtime.shard_gnn_steps(
            ts, ta, ev, self.state, self.block)
        self.state, self.block, arrs = runtime.device_put_gnn(
            self.state, self.block,
            (self.x, self.y, self.train_mask, self.val_mask, self.test_mask))
        (self.x, self.y, self.train_mask, self.val_mask,
         self.test_mask) = arrs
        self.epoch = 0
        self.history: list[EpochMetrics] = []
        self._needs_sync = False

    # ------------------------------------------------------------------
    def _bytes_per_epoch(self, bytes_fn) -> tuple[float, float]:
        """x2 for forward + backward exchanges, summed over comm sites."""
        bits = self.cfg.effective_bits
        payload = ec = 0
        for d in self.model.comm_dims():
            pb, eb = bytes_fn(self.block.plan, d, bits, self.cfg.scale_dtype)
            payload += 2 * pb
            ec += 2 * eb
        return payload, ec

    def comm_bytes_per_epoch(self) -> tuple[float, float]:
        """(payload, error-compensation) *true wire* bytes moved per epoch,
        totaled across partitions. Diagonal self-blocks and padding rows are
        excluded (Table 3)."""
        return self._bytes_per_epoch(exchange_bytes)

    def wire_bytes_per_epoch(self) -> tuple[float, float]:
        """Like :meth:`comm_bytes_per_epoch` but counting the rows the plan's
        layout actually ships (incl. bucket-alignment / pairwise padding) —
        the layout-efficiency number the compact plan optimizes."""
        return self._bytes_per_epoch(wire_bytes)

    def _epoch_key(self):
        return jax.random.fold_in(self.key, self.epoch)

    def train_epoch(self) -> EpochMetrics:
        sync = (self.cfg.mode != "async" or self._needs_sync
                or use_sync_step(self.epoch, self.eps_s))
        fn = self._ts if sync else self._ta
        t0 = time.time()
        self.state, loss = fn(self.state, self.block, self.x, self.y,
                              self.train_mask, self._epoch_key())
        loss = float(loss)
        dt = time.time() - t0
        self._needs_sync = False
        pb, eb = self.comm_bytes_per_epoch()
        m = EpochMetrics(self.epoch, loss, dt, "sync" if sync else "async",
                         pb / 1e6, eb / 1e6)
        self.history.append(m)
        self.epoch += 1
        return m

    def evaluate(self, split: str = "val") -> float:
        mask = {"train": self.train_mask, "val": self.val_mask,
                "test": self.test_mask}[split]
        c, n = self._ev(self.state.params, self.block, self.x, self.y, mask,
                        self._epoch_key())
        return float(c) / max(float(n), 1.0)

    def fit(self, epochs: int, eval_every: int = 0) -> list[EpochMetrics]:
        for _ in range(epochs):
            m = self.train_epoch()
            if eval_every and self.epoch % eval_every == 0:
                m.val_acc = self.evaluate("val")
            if self.ckpt_dir and self.epoch % max(1, epochs // 5) == 0:
                self.save()
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        meta = dict(n_parts=self.pg.plan.n_parts, epoch=self.epoch,
                    mode=self.cfg.mode, bits=self.cfg.bits)
        ckpt.save(self.ckpt_dir, self.epoch, self.state, meta, keep=self.keep)

    def resume(self) -> bool:
        """Restore the latest checkpoint if present. Returns True if resumed.
        An elastic repartition (different n_parts) zeroes halo caches and
        forces one synchronous epoch."""
        step = ckpt.latest_step(self.ckpt_dir) if self.ckpt_dir else None
        if step is None:
            return False
        tree, meta, needs_sync = ckpt.restore(self.ckpt_dir, self.state)
        self.state = jax.tree.map(jnp.asarray, tree)
        self.state, self.block, _ = self.runtime.device_put_gnn(
            self.state, self.block, ())
        self.epoch = int(meta.get("epoch", step))
        self._needs_sync = needs_sync or \
            meta.get("n_parts") != self.pg.plan.n_parts
        return True
