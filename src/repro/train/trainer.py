"""GNN trainer: epoch loop, the CommPolicy loop, eval, checkpoint/restart,
EF21 gradient compression, metrics.

One :class:`GNNTrainer` drives either execution mode through a
:class:`repro.dist.runtime.Runtime`:
  * ``Runtime.simulated(...)`` (the default on 1 CPU device) — the stacked
    reference semantics used by tests/benchmarks;
  * ``Runtime.from_mesh(mesh)`` — shard_map, one partition per device (the
    production path).

The **policy loop** lives here. Once per epoch, *outside the trace*:

  1. telemetry is assembled from host-side observations (epoch index, the
     EMA-smoothed per-site range stats the previous step emitted, the val
     trajectory, the resume/elastic ``needs_sync`` flag);
  2. ``policy.decide(telemetry)`` maps it to an
     :class:`~repro.policy.base.EpochDecision` — per-site fwd/bwd bit-widths,
     rounding, boundary sampling, EF bits, and the sync/async choice;
  3. the decision is snapped to the lattice (``decision.snapped()``) and used
     as the key of a compiled-step cache, so jit compiles one executable per
     *distinct* decision — a drifting policy cannot trigger unbounded
     recompilation.

``SylvieConfig(bits=...)`` (no policy) degenerates to the ``Uniform`` policy
and is bit-identical to the historical static path. The paper's Bounded
Staleness Adaptor (§3.3) is ``policy=BoundedStaleness(eps_s)``; the old
``eps_s=`` kwarg survives as a deprecation shim that builds exactly that.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.exchange import exchange_bytes, wire_bytes
from ..core.sylvie import SylvieConfig
from ..dist.runtime import Runtime
from ..faults.backend import FaultyBackend
from ..faults.plan import FaultCtl, FaultPlan, RowGeometry
from ..models.gnn import blocks as B
from ..policy.base import (CommPolicy, EpochDecision, SiteStats, Telemetry,
                           validate_decision)
from ..policy.builtin import BoundedStaleness, Uniform
from . import checkpoint as ckpt
from . import optimizer as optlib
from .compression import ef_wire_bytes
from .gnn_step import GNNTrainState, make_gnn_steps

# EMA smoothing factor for the per-site range stats fed back to policies —
# damps epoch-to-epoch jitter so adaptive bit assignments settle on one
# lattice point instead of oscillating (recompile budget).
STATS_EMA = 0.5


@dataclasses.dataclass
class EpochMetrics:
    epoch: int
    loss: float
    seconds: float
    mode: str
    comm_payload_mb: float
    comm_ec_mb: float
    val_acc: Optional[float] = None
    # exchange schedule actually traced this epoch ("blocking" | "overlap").
    schedule: str = "blocking"
    # per-site (fwd_bits, bwd_bits) actually used this epoch + the policy
    # that chose them (heterogeneous-bits accounting).
    bits_per_site: tuple = ()
    policy: str = ""
    ef_bits: Optional[int] = None
    # chaos accounting (unit = one scheduled drop/corrupt message). Invariant:
    # faults_injected == halos_reused + forced_syncs, exactly — a normal
    # faulty epoch recovers every unit from the stale cache, a recovery epoch
    # suppresses its whole schedule and retries synchronously. ``stall_s`` is
    # the modeled straggler critical-path extension (not wall clock).
    faults_injected: int = 0
    halos_reused: int = 0
    forced_syncs: int = 0
    stall_s: float = 0.0
    # measured whole-epoch wall time on the obs clock (decide + fault arming
    # + step + telemetry absorption), vs ``seconds`` = the step call alone.
    # Deterministic under an injected FakeClock; feeds the modeled-vs-measured
    # join in repro.obs.export.
    wall_s: float = 0.0


class GNNTrainer:
    """Full-graph trainer over a partitioned graph.

    Example::

        pg, _ = datasets.load_partitioned("yelp_like@small", n_parts=4)
        tr = GNNTrainer(GCN(pg.x.shape[-1], 64, pg.n_classes), pg,
                        SylvieConfig(mode="async", bits=1),
                        policy=BoundedStaleness(eps_s=4))
        tr.fit(40); tr.evaluate("test")

    .. deprecated:: ``eps_s=k`` — the pre-policy staleness knob. It now
       builds ``policy=BoundedStaleness(eps_s=k, bits=cfg.effective_bits,
       stochastic=cfg.stochastic, boundary_sample_p=cfg.boundary_sample_p)``
       and warns; pass that policy yourself instead.
    """

    def __init__(self, model, pg, cfg: Optional[SylvieConfig] = None,
                 opt: Optional[optlib.Optimizer] = None,
                 policy: Optional[CommPolicy] = None,
                 eps_s: Optional[int] = None,
                 runtime: Optional[Runtime] = None, mesh=None, seed: int = 0,
                 ckpt_dir: Optional[str] = None, keep: int = 3,
                 fault_plan: Optional[FaultPlan] = None,
                 ckpt_every: Optional[int] = None):
        self.model = model
        self.pg = pg
        self.cfg = cfg = cfg if cfg is not None else SylvieConfig()
        if eps_s is not None:
            warnings.warn(
                "GNNTrainer(eps_s=...) is deprecated; pass "
                "policy=repro.policy.BoundedStaleness(eps_s) instead",
                DeprecationWarning, stacklevel=2)
            if policy is not None:
                raise ValueError("pass policy or eps_s, not both")
            policy = BoundedStaleness(
                eps_s=eps_s, bits=cfg.effective_bits,
                stochastic=cfg.stochastic,
                boundary_sample_p=cfg.boundary_sample_p)
        self.policy: CommPolicy = policy if policy is not None \
            else Uniform.from_config(cfg)
        p = pg.plan.n_parts
        if runtime is not None and mesh is not None:
            raise ValueError("pass runtime or mesh, not both "
                             "(mesh is shorthand for Runtime.from_mesh)")
        if runtime is None:
            runtime = (Runtime.from_mesh(mesh) if mesh is not None
                       else Runtime.simulated(p))
        if runtime.n_parts not in (None, p):
            raise ValueError(
                f"runtime is committed to {runtime.n_parts} partitions but the "
                f"graph was partitioned into {p}")
        # a chaos run is any of: fault_plan=..., or a runtime whose backend is
        # already a FaultyBackend (the plan is then discovered from it).
        if isinstance(runtime.backend, FaultyBackend):
            if fault_plan is not None and fault_plan != runtime.backend.plan:
                raise ValueError("runtime backend already carries a FaultPlan "
                                 "that differs from fault_plan")
            fault_plan = runtime.backend.plan
        elif fault_plan is not None:
            runtime = Runtime(FaultyBackend(runtime.backend, fault_plan))
        self.fault_plan = fault_plan
        self.ckpt_every = ckpt_every
        self.runtime = runtime
        self.mesh = runtime.mesh
        self.opt = opt or optlib.adam(1e-2)
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.key = jax.random.PRNGKey(seed)

        self.block = B.build_block(pg)
        self.x = jnp.asarray(pg.x)
        self.y = jnp.asarray(pg.y)
        self.train_mask = jnp.asarray(pg.train_mask)
        self.val_mask = jnp.asarray(pg.val_mask)
        self.test_mask = jnp.asarray(pg.test_mask)
        self.site_dims = tuple(int(d) for d in model.comm_dims())
        self.n_sites = len(self.site_dims)
        self.state = GNNTrainState.create(self.model, self.opt, self.key,
                                          self.block.plan, stacked_parts=p)
        # compiled train steps per distinct (snapped) decision; eval is
        # decision-independent (always full precision) and built once.
        self._step_cache: dict = {}
        ts0, ta0, ev = make_gnn_steps(self.model, cfg, self.opt,
                                      backend=runtime.backend)
        _, _, self._ev = runtime.shard_gnn_steps(ts0, ta0, ev, self.state,
                                                 self.block)
        self.state, self.block, arrs = runtime.device_put_gnn(
            self.state, self.block,
            (self.x, self.y, self.train_mask, self.val_mask, self.test_mask))
        (self.x, self.y, self.train_mask, self.val_mask,
         self.test_mask) = arrs
        self.epoch = 0
        self.history: list[EpochMetrics] = []
        self._needs_sync = False
        self._site_stats: Optional[tuple[SiteStats, ...]] = None
        self._last_decision: Optional[EpochDecision] = None
        # chaos state: per-site consecutive-faulty-epoch counters (the
        # escalation rule watches their max) and the force-recovery latch set
        # when a site crosses ``fault_plan.escalate_after``.
        self._fault_geom = (RowGeometry.from_plan(self.block.plan)
                            if self.fault_plan is not None else None)
        self._site_staleness = np.zeros(self.n_sites, np.int64)
        self._force_recovery = False

    # ------------------------------------------------------------------
    # the policy loop
    # ------------------------------------------------------------------
    def _telemetry(self) -> Telemetry:
        return Telemetry(
            epoch=self.epoch, n_parts=self.pg.plan.n_parts,
            n_sites=self.n_sites, site_dims=self.site_dims,
            site_stats=self._site_stats,
            val_history=tuple(m.val_acc for m in self.history
                              if m.val_acc is not None),
            needs_sync=self._needs_sync, prev=self._last_decision,
            site_staleness=(tuple(int(x) for x in self._site_staleness)
                            if self.fault_plan is not None else ()))

    def _decide(self) -> EpochDecision:
        """Pure: telemetry -> snapped EpochDecision (callable speculatively,
        e.g. for byte accounting before any epoch ran). Mode invariants are
        enforced here, not trusted to the policy: vanilla pins 32-bit, only
        async mode may skip the synchronous step, epoch 0 always runs it (the
        zero-initialized halo caches must be warmed before any pipelined
        step), and a pending cache refresh (``needs_sync``) always wins."""
        d = self.policy.decide(self._telemetry()).snapped()
        d = validate_decision(d, self.n_sites)
        if self.cfg.mode == "vanilla":
            d = d.with_bits(32)
        sync = (bool(d.sync) or self.cfg.mode != "async" or self._needs_sync
                or self.epoch == 0)
        # the exchange schedule is an execution-mode choice, not a precision
        # one: the config owns it (policies cannot flip it mid-run, so one
        # trainer stays within the per-decision recompile budget).
        return dataclasses.replace(d, sync=sync, schedule=self.cfg.schedule)

    def _steps_for(self, decision: EpochDecision):
        """(train_sync, train_async) compiled for this decision. Cached on
        ``decision.step_key()`` (sync excluded — it picks *which* step runs),
        so distinct executables are bounded by distinct lattice points."""
        key = decision.step_key()
        if key not in self._step_cache:
            ts, ta, ev = make_gnn_steps(self.model, self.cfg, self.opt,
                                        backend=self.runtime.backend,
                                        decision=decision)
            ts, ta, _ = self.runtime.shard_gnn_steps(ts, ta, ev, self.state,
                                                     self.block)
            self._step_cache[key] = (ts, ta)
        return self._step_cache[key]

    def _absorb_site_stats(self):
        """Fold the step's emitted (n_sites, 2) [sum range^2, live rows] into
        the EMA-smoothed SiteStats telemetry."""
        raw = np.asarray(jax.device_get(self.state.site_stats))
        rows = self.block.plan.real_rows
        cur = []
        for i, d in enumerate(self.site_dims):
            mean_sq = float(raw[i, 0]) / max(float(raw[i, 1]), 1.0)
            if self._site_stats is not None:
                prev = self._site_stats[i].mean_range_sq
                mean_sq = STATS_EMA * prev + (1.0 - STATS_EMA) * mean_sq
            cur.append(SiteStats(dim=d, rows=rows, mean_range_sq=mean_sq))
        self._site_stats = tuple(cur)

    # ------------------------------------------------------------------
    # heterogeneous-bits comm accounting
    # ------------------------------------------------------------------
    def _bytes_per_epoch(self, bytes_fn,
                         decision: Optional[EpochDecision] = None):
        """Sum per-site, per-direction bytes under the epoch's actual
        decision (forward and backward exchanges may use different widths)."""
        if decision is None:
            decision = self._last_decision or self._decide()
        payload = ec = 0
        for d, sd in zip(self.site_dims, decision.sites):
            for bits in (sd.fwd_bits, sd.bwd_bits):
                pb, eb = bytes_fn(self.block.plan, d, bits,
                                  self.cfg.scale_dtype)
                payload += pb
                ec += eb
        if decision.ef_bits is not None:
            pb, eb = ef_wire_bytes(self.state.params, decision.ef_bits)
            payload += pb
            ec += eb
        return payload, ec

    def comm_bytes_per_epoch(self, decision: Optional[EpochDecision] = None
                             ) -> tuple[float, float]:
        """(payload, error-compensation) *true wire* bytes moved per epoch,
        totaled across partitions. Diagonal self-blocks and padding rows are
        excluded (Table 3). Defaults to the last epoch's decision (or the
        policy's next decision before any epoch ran)."""
        return self._bytes_per_epoch(exchange_bytes, decision)

    def wire_bytes_per_epoch(self, decision: Optional[EpochDecision] = None
                             ) -> tuple[float, float]:
        """Like :meth:`comm_bytes_per_epoch` but counting the rows the plan's
        layout actually ships (incl. bucket-alignment / pairwise padding) —
        the layout-efficiency number the compact plan optimizes."""
        return self._bytes_per_epoch(wire_bytes, decision)

    def modeled_comm_split(self, flops_per_part: float, peak_flops: float,
                           ici_bw: float,
                           decision: Optional[EpochDecision] = None
                           ) -> tuple[float, float]:
        """DESIGN §8/§14: modeled ``(exposed_s, overlapped_s)`` comm split per
        epoch under this trainer's schedule. ``flops_per_part`` is the model's
        analytic per-partition FLOPs (``launch.cells._gnn_model_flops`` /
        n_parts); each site's overlappable compute window is its uniform
        share of it. Blocking exposes everything; their sum is always the
        ``modeled_tpu_comm_s`` total."""
        from ..dist import overlap as olap
        if decision is None:
            decision = self._last_decision or self._decide()
        comm = olap.site_comm_seconds(self.block.plan, self.site_dims,
                                      decision, ici_bw, self.cfg.scale_dtype)
        per_site = flops_per_part / peak_flops / max(self.n_sites, 1)
        return olap.split_comm_time(comm, (per_site,) * self.n_sites,
                                    decision.schedule)

    def _epoch_key(self):
        return jax.random.fold_in(self.key, self.epoch)

    # ------------------------------------------------------------------
    # chaos: arm the epoch's seeded fault schedule
    # ------------------------------------------------------------------
    def _arm_faults(self, decision: EpochDecision):
        """Draw this epoch's seeded fault set, expand it to wire masks in
        ``state.faults`` (data — armed epochs share one executable), and do
        the staleness-as-recovery bookkeeping.

        Returns ``(decision, injected, reused, forced, stall_s, escalate)``.
        A recovery epoch (the latch set by a previous escalation) suppresses
        the whole schedule — all-false masks, same pytree structure — and
        retries as a full-precision synchronous exchange; its scheduled units
        are accounted as ``forced_syncs``. Otherwise every scheduled unit is
        recovered from the stale cache (``halos_reused``), keeping
        ``faults_injected == halos_reused + forced_syncs`` exact."""
        plan = self.fault_plan
        ev = plan.events(self.epoch, self.n_sites, self.pg.plan.n_parts)
        injected = ev.n_injected
        escalate = False
        if self._force_recovery:
            decision = dataclasses.replace(decision.with_bits(32), sync=True)
            ctl = FaultCtl.clean(self._fault_geom, self.n_sites)
            reused, forced, stall = 0, injected, 0.0
            self._site_staleness[:] = 0
            self._force_recovery = False
        else:
            ctl = FaultCtl.expand(ev, self._fault_geom, self.n_sites)
            reused, forced = injected, 0
            stall = ev.stall_s(plan.delay_s)
            self._site_staleness = np.where(ev.faulty_sites(),
                                            self._site_staleness + 1, 0)
            if int(self._site_staleness.max(initial=0)) >= plan.escalate_after:
                escalate = True  # applied to the *next* epoch, below
        self.state = dataclasses.replace(
            self.state, faults=self.runtime.device_put_stacked(ctl))
        return decision, injected, reused, forced, stall, escalate

    def train_epoch(self) -> EpochMetrics:
        w0 = obs.clock()
        with obs.span("epoch", {"epoch": self.epoch}):
            with obs.span("decide"):
                decision = self._decide()
            injected = reused = forced = 0
            stall = 0.0
            escalate = False
            if self.fault_plan is not None:
                (decision, injected, reused, forced, stall,
                 escalate) = self._arm_faults(decision)
                obs.count("faults.injected", injected)
                obs.count("faults.halos_reused", reused)
                obs.count("faults.forced_syncs", forced)
            ts, ta = self._steps_for(decision)
            fn = ts if decision.sync else ta
            t0 = obs.clock()
            with obs.span("step",
                          {"mode": "sync" if decision.sync else "async"}):
                self.state, loss = fn(self.state, self.block, self.x, self.y,
                                      self.train_mask, self._epoch_key())
                loss = float(loss)
            dt = obs.clock() - t0
            self._needs_sync = False
            if escalate:
                # staleness-as-recovery escalation: some site has been faulted
                # for >= escalate_after consecutive epochs; the next epoch is a
                # forced full-precision synchronous retry (BoundedStaleness
                # also sees the counters via Telemetry.site_staleness).
                self._needs_sync = True
                self._force_recovery = True
            self._last_decision = decision
            self._absorb_site_stats()
            pb, eb = self.comm_bytes_per_epoch(decision)
            m = EpochMetrics(self.epoch, loss, dt,
                             "sync" if decision.sync else "async",
                             pb / 1e6, eb / 1e6,
                             schedule=decision.schedule,
                             bits_per_site=decision.bits_per_site(),
                             policy=self.policy.name,
                             ef_bits=decision.ef_bits,
                             faults_injected=injected, halos_reused=reused,
                             forced_syncs=forced, stall_s=stall)
        m.wall_s = obs.clock() - w0
        self.history.append(m)
        self.epoch += 1
        return m

    def evaluate(self, split: str = "val") -> float:
        mask = {"train": self.train_mask, "val": self.val_mask,
                "test": self.test_mask}[split]
        c, n = self._ev(self.state.params, self.block, self.x, self.y, mask,
                        self._epoch_key())
        return float(c) / max(float(n), 1.0)

    def fit(self, epochs: int, eval_every: int = 0) -> list[EpochMetrics]:
        # auto-checkpoint cadence: explicit ``ckpt_every`` epochs (preemption-
        # safe runs want every epoch) or 5 checkpoints over the run.
        every = self.ckpt_every if self.ckpt_every else max(1, epochs // 5)
        for _ in range(epochs):
            m = self.train_epoch()
            if eval_every and self.epoch % eval_every == 0:
                m.val_acc = self.evaluate("val")
            if self.ckpt_dir and self.epoch % every == 0:
                self.save()
        return self.history

    # ------------------------------------------------------------------
    def save(self):
        meta = dict(n_parts=self.pg.plan.n_parts, epoch=self.epoch,
                    mode=self.cfg.mode, policy=self.policy.name)
        ckpt.save(self.ckpt_dir, self.epoch, self.state, meta, keep=self.keep)

    def resume(self) -> bool:
        """Restore the latest checkpoint if present. Returns True if resumed.
        An elastic repartition (different n_parts) zeroes halo caches and
        forces one synchronous epoch (``Telemetry.needs_sync`` — every
        built-in policy honors it, and ``_decide`` enforces it regardless)."""
        step = ckpt.latest_step(self.ckpt_dir) if self.ckpt_dir else None
        if step is None:
            return False
        tree, meta, needs_sync = ckpt.restore(self.ckpt_dir, self.state)
        self.state = jax.tree.map(jnp.asarray, tree)
        self.state, self.block, _ = self.runtime.device_put_gnn(
            self.state, self.block, ())
        self.epoch = int(meta.get("epoch", step))
        self._needs_sync = needs_sync or \
            meta.get("n_parts") != self.pg.plan.n_parts
        if self.fault_plan is not None:
            # staleness counters are host state, not checkpointed — start the
            # resumed run conservatively clean (the first post-resume epoch is
            # synchronous anyway via needs_sync/epoch-0 rules only if flagged).
            self._site_staleness[:] = 0
            self._force_recovery = False
        return True
