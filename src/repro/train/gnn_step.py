"""Step functions for distributed full-graph GNN training (the paper's Trainer).

Three step flavors over one :class:`GNNTrainState`:

* ``train_step_sync``  — vanilla (bits=32) or Sylvie-S. Fresh quantized exchange
  both passes; also refreshes the Sylvie-A feature caches (a
  ``BoundedStaleness`` policy schedules exactly this step every ``eps_s``
  epochs) and *drains* the grad caches (a synchronous epoch leaves no
  in-flight boundary gradients).
* ``train_step_async`` — Sylvie-A: consumes cached halo features/gradients,
  emits fresh caches for the next step.
* ``eval_step``        — full-precision synchronous exchange (accuracy metric).

What each halo-exchange site does — per-direction bit-widths, rounding mode,
boundary sampling — comes from an :class:`~repro.policy.base.EpochDecision`
(``decision.sites[i]`` at the i-th exchange). The decision is **static**: each
distinct decision traces its own executable, and the trainer caches compiled
steps per lattice-snapped decision so adaptive policies stay within a small
recompile budget. Omitting the decision falls back to the one global
``SylvieConfig`` choice (the Uniform degenerate case).

The decision (or config) also picks the exchange *schedule*: ``"blocking"``
consumes each halo where it is produced; ``"overlap"`` routes the same sites
through the issue/land double buffering of ``dist/overlap.py`` (bit-exact
under sync, the DESIGN §14 staleness contract under async). The schedule is
part of ``EpochDecision.step_key()``, so each schedule traces its own
executables within the same per-decision budget.

The steps also *emit telemetry for the policy loop*: ``state.site_stats`` is a
``(n_sites, 2)`` array of ``[sum of squared boundary-row ranges, live row
count]`` per exchange site, psum'd across partitions — the raw material for
AdaQP-style variance-budgeted bit assignment.

Weight gradients are all-reduced across partitions (Alg. 2 line 16): explicit
``lax.psum`` under shard_map; implicit via the stacked-axis contraction in the
simulated mode. When ``decision.ef_bits`` is set the reduced gradient then
passes through the EF21 compressor (``train/compression.py``) whose error /
estimate state lives in ``state.ef``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.staleness import HaloState
from ..core.sylvie import SCHEDULES, SylvieComm, SylvieConfig
from ..dist.backend import as_backend
from ..models import nn
from ..obs import TraceLog
from ..policy.base import EpochDecision, validate_decision
from . import optimizer as optlib
from .compression import EFState, ef_allreduce

# Trace instrumentation: step bodies append ("sync" | "async") here at trace
# time (the python body only runs when jit traces). tests/test_policy.py uses
# it to assert the recompile budget of adaptive policies; the TraceLog shim
# additionally counts ``retrace.train`` in the obs metrics registry.
TRACE_LOG = TraceLog("train")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GNNTrainState:
    params: dict
    opt_state: dict
    halo: HaloState
    step: jax.Array
    # EF21 compressed-all-reduce state (zeros / inert unless the epoch
    # decision sets ef_bits) and the per-site comm telemetry emitted by the
    # last step — (n_sites, 2): [sum of squared row ranges, live rows].
    ef: EFState
    site_stats: jax.Array
    # Per-epoch fault control block (repro.faults.plan.FaultCtl) — boolean
    # wire masks riding as *data*, set by the trainer each chaos epoch.
    # None = fault-free pytree structure, tracing the exact legacy program.
    faults: Optional[object] = None

    @staticmethod
    def create(model, opt, key, plan, stacked_parts=None):
        params = model.init(key)
        n_sites = len(model.comm_dims())
        return GNNTrainState(
            params=params, opt_state=opt.init(params),
            halo=HaloState.zeros(plan, model.comm_dims(),
                                 stacked_parts=stacked_parts),
            step=jnp.zeros((), jnp.int32),
            ef=EFState.zeros_like(params),
            site_stats=jnp.zeros((n_sites, 2), jnp.float32))


def _masked_loss(logits, y, mask, backend):
    s, c = nn.cross_entropy(logits, y, mask.astype(jnp.float32))
    return backend.psum(s) / jnp.maximum(backend.psum(c), 1.0)


def make_gnn_steps(model, cfg: SylvieConfig, opt: optlib.Optimizer,
                   backend=None, clip_norm: Optional[float] = None,
                   decision: Optional[EpochDecision] = None):
    """Builds (train_step_sync, train_step_async, eval_step). All three are pure
    and jit/shard_map-compatible; the caller decides which to invoke per epoch
    (a :class:`~repro.policy.base.CommPolicy` — ``GNNTrainer`` owns that loop).

    ``decision`` fixes the per-site communication schedule the steps are
    traced with; ``None`` builds the Uniform shim from ``cfg`` (bit-identical
    to the historical ``cfg.bits`` path). ``backend`` fixes the communicator
    (a :class:`repro.dist.backend.HaloBackend`; simulated stack by default).
    Steps built with a :class:`ShardMapBackend` must be wrapped via
    ``dist.api.shard_gnn_steps`` (or ``Runtime``) so their collectives find
    the mesh axes."""
    backend = as_backend(backend)
    n_sites = len(model.comm_dims())
    if decision is None:
        decision = EpochDecision.from_config(cfg, n_sites)
    decision = validate_decision(decision, n_sites)
    for sched in (cfg.schedule, decision.schedule):
        if sched not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {sched!r}; known: {SCHEDULES}")
    sync_cfg = cfg if cfg.mode != "async" else cfg.replace(mode="sync")
    async_cfg = cfg.replace(mode="async")

    def _stats(comm):
        return backend.psum(jnp.stack(comm.site_stats))

    def _finish(state, params_grads, loss, new_halo, stats):
        # Alg. 2 line 16: weight gradients are all-reduced across partitions —
        # an explicit backend.psum under shard_map, the identity in the
        # simulated stack (whose contraction is already global).
        params_grads = jax.tree.map(backend.psum, params_grads)
        if decision.ef_bits is not None:
            # EF21 compression of the reduced gradient (deterministic, so the
            # error/estimate state stays replicated across partitions); wire
            # savings are accounted by compression.ef_wire_bytes.
            params_grads, new_ef = ef_allreduce(params_grads, state.ef,
                                                bits=decision.ef_bits)
        else:
            new_ef = state.ef
        if clip_norm is not None:
            params_grads, _ = optlib.clip_by_global_norm(params_grads, clip_norm)
        updates, new_opt = opt.update(params_grads, state.opt_state, state.params)
        new_params = optlib.apply_updates(state.params, updates)
        return GNNTrainState(new_params, new_opt, new_halo, state.step + 1,
                             new_ef, stats, state.faults), loss

    def train_step_sync(state: GNNTrainState, block, x, y, mask, key):
        TRACE_LOG.append("sync")

        def loss_fn(params):
            armed = state.faults is not None
            comm = SylvieComm(sync_cfg, block.plan, key, backend=backend,
                              decision=decision, collect_stats=True,
                              feat_caches=(state.halo.feats if armed else None),
                              fault_sites=(state.faults.sites if armed
                                           else None))
            logits = model.apply(params, block, x, comm)
            loss = _masked_loss(logits, y, mask, backend)
            caches = tuple(jax.lax.stop_gradient(c) for c in comm.new_feat_caches)
            return loss, (caches, _stats(comm))

        ((loss, (caches, stats)),
         grads) = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_halo = HaloState(feats=caches,
                             grads=tuple(jnp.zeros_like(f) for f in caches))
        return _finish(state, grads, loss, new_halo, stats)

    def train_step_async(state: GNNTrainState, block, x, y, mask, key):
        TRACE_LOG.append("async")

        def loss_fn(params, gslots):
            comm = SylvieComm(async_cfg, block.plan, key, backend=backend,
                              decision=decision, collect_stats=True,
                              feat_caches=state.halo.feats,
                              grad_ins=state.halo.grads, gslots=gslots,
                              fault_sites=(state.faults.sites
                                           if state.faults is not None
                                           else None))
            logits = model.apply(params, block, x, comm)
            loss = _masked_loss(logits, y, mask, backend)
            caches = tuple(jax.lax.stop_gradient(c) for c in comm.new_feat_caches)
            return loss, (caches, _stats(comm))

        grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)
        ((loss, (caches, stats)),
         (pgrads, ggrads)) = grad_fn(state.params, state.halo.gslots())
        new_halo = HaloState(feats=caches, grads=ggrads)
        return _finish(state, pgrads, loss, new_halo, stats)

    def eval_step(params, block, x, y, mask, key):
        comm = SylvieComm(sync_cfg.replace(mode="vanilla", stochastic=False),
                          block.plan, key, backend=backend)
        logits = model.apply(params, block, x, comm)
        correct, count = nn.accuracy_counts(logits, y, mask.astype(jnp.float32))
        return backend.psum(correct), backend.psum(count)

    return train_step_sync, train_step_async, eval_step
