"""Step functions for distributed full-graph GNN training (the paper's Trainer).

Three step flavors over one :class:`GNNTrainState`:

* ``train_step_sync``  — vanilla (bits=32) or Sylvie-S. Fresh quantized exchange
  both passes; also refreshes the Sylvie-A feature caches (the Bounded Staleness
  Adaptor runs exactly this step every ``eps_s`` epochs) and *drains* the grad
  caches (a synchronous epoch leaves no in-flight boundary gradients).
* ``train_step_async`` — Sylvie-A: consumes cached halo features/gradients,
  emits fresh caches for the next step.
* ``eval_step``        — full-precision synchronous exchange (accuracy metric).

Weight gradients are all-reduced across partitions (Alg. 2 line 16): explicit
``lax.psum`` under shard_map; implicit via the stacked-axis contraction in the
simulated mode.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.staleness import HaloState
from ..core.sylvie import SylvieComm, SylvieConfig
from ..dist.backend import as_backend
from ..models import nn
from . import optimizer as optlib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GNNTrainState:
    params: dict
    opt_state: dict
    halo: HaloState
    step: jax.Array

    @staticmethod
    def create(model, opt, key, plan, stacked_parts=None):
        params = model.init(key)
        return GNNTrainState(
            params=params, opt_state=opt.init(params),
            halo=HaloState.zeros(plan, model.comm_dims(),
                                 stacked_parts=stacked_parts),
            step=jnp.zeros((), jnp.int32))


def _masked_loss(logits, y, mask, backend):
    s, c = nn.cross_entropy(logits, y, mask.astype(jnp.float32))
    return backend.psum(s) / jnp.maximum(backend.psum(c), 1.0)


def make_gnn_steps(model, cfg: SylvieConfig, opt: optlib.Optimizer,
                   backend=None, clip_norm: Optional[float] = None):
    """Builds (train_step_sync, train_step_async, eval_step). All three are pure
    and jit/shard_map-compatible; the caller decides which to invoke per epoch
    (Bounded Staleness Adaptor — core/staleness.use_sync_step).

    ``backend`` fixes the communicator (a :class:`repro.dist.backend.HaloBackend`;
    simulated stack by default). Steps built with a :class:`ShardMapBackend`
    must be wrapped via ``dist.api.shard_gnn_steps`` (or ``Runtime``) so their
    collectives find the mesh axes."""
    backend = as_backend(backend)
    sync_cfg = cfg if cfg.mode != "async" else cfg.replace(mode="sync")
    async_cfg = cfg.replace(mode="async")

    def _finish(state, params_grads, loss, new_halo):
        # Alg. 2 line 16: weight gradients are all-reduced across partitions —
        # an explicit backend.psum under shard_map, the identity in the
        # simulated stack (whose contraction is already global).
        params_grads = jax.tree.map(backend.psum, params_grads)
        if clip_norm is not None:
            params_grads, _ = optlib.clip_by_global_norm(params_grads, clip_norm)
        updates, new_opt = opt.update(params_grads, state.opt_state, state.params)
        new_params = optlib.apply_updates(state.params, updates)
        return GNNTrainState(new_params, new_opt, new_halo, state.step + 1), loss

    def train_step_sync(state: GNNTrainState, block, x, y, mask, key):
        def loss_fn(params):
            comm = SylvieComm(sync_cfg, block.plan, key, backend=backend)
            logits = model.apply(params, block, x, comm)
            loss = _masked_loss(logits, y, mask, backend)
            caches = tuple(jax.lax.stop_gradient(c) for c in comm.new_feat_caches)
            return loss, caches

        (loss, caches), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_halo = HaloState(feats=caches,
                             grads=tuple(jnp.zeros_like(f) for f in caches))
        return _finish(state, grads, loss, new_halo)

    def train_step_async(state: GNNTrainState, block, x, y, mask, key):
        def loss_fn(params, gslots):
            comm = SylvieComm(async_cfg, block.plan, key, backend=backend,
                              feat_caches=state.halo.feats,
                              grad_ins=state.halo.grads, gslots=gslots)
            logits = model.apply(params, block, x, comm)
            loss = _masked_loss(logits, y, mask, backend)
            caches = tuple(jax.lax.stop_gradient(c) for c in comm.new_feat_caches)
            return loss, caches

        grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)
        (loss, caches), (pgrads, ggrads) = grad_fn(state.params, state.halo.gslots())
        new_halo = HaloState(feats=caches, grads=ggrads)
        return _finish(state, pgrads, loss, new_halo)

    def eval_step(params, block, x, y, mask, key):
        comm = SylvieComm(sync_cfg.replace(mode="vanilla", stochastic=False),
                          block.plan, key, backend=backend)
        logits = model.apply(params, block, x, comm)
        correct, count = nn.accuracy_counts(logits, y, mask.astype(jnp.float32))
        return backend.psum(correct), backend.psum(count)

    return train_step_sync, train_step_async, eval_step
