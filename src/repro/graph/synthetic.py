"""Synthetic graph generators (numpy, seeded, offline — no dataset downloads).

The paper evaluates on Reddit / Yelp / Ogbn-products / Amazon. Offline we generate
structurally comparable graphs:

* ``planted_partition`` — community graph with class-correlated features; GCN-family
  models reach high accuracy on it, so convergence experiments (Fig. 1/8, Tables 2/4)
  are meaningful.
* ``powerlaw`` — preferential-attachment-style degree distribution for comm-volume /
  partition-quality realism (Reddit/products-like).
* ``powerlaw_community`` — the two combined: heavy-tailed degrees *and*
  class-correlated structure/features, so Reddit/products/Amazon-shaped
  workloads are simultaneously comm-realistic and accuracy-meaningful. This is
  what the :mod:`repro.datasets` registry builds its social/co-purchase
  workloads from.
* ``grid_mesh`` — 2D simulation mesh (MeshGraphNet's regime).
* ``molecules`` — batched random-geometric molecular graphs with 3D positions
  (SchNet / NequIP regime).

All return :class:`repro.graph.formats.Graph` with both edge directions stored.
"""
from __future__ import annotations

import numpy as np

from .formats import Graph


def _split_masks(rng, n, frac=(0.6, 0.2, 0.2)):
    perm = rng.permutation(n)
    a = int(frac[0] * n); b = int((frac[0] + frac[1]) * n)
    tr = np.zeros(n, bool); va = np.zeros(n, bool); te = np.zeros(n, bool)
    tr[perm[:a]] = True; va[perm[a:b]] = True; te[perm[b:]] = True
    return tr, va, te


def _undirect(src, dst):
    return (np.concatenate([src, dst]), np.concatenate([dst, src]))


def planted_partition(n_nodes=2708, n_classes=7, d_feat=64, avg_degree=8,
                      p_in=0.9, noise=1.0, seed=0) -> Graph:
    """Stochastic block model with Gaussian class-mean features.

    ``p_in`` = probability an edge is intra-community (homophily). Labels are
    recoverable from features + structure, so 2-layer GCN reaches ~90%+.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    n_edges = n_nodes * avg_degree // 2
    src = rng.integers(0, n_nodes, n_edges)
    intra = rng.random(n_edges) < p_in
    # intra edges: pick dst from same community; inter: uniform
    dst = rng.integers(0, n_nodes, n_edges)
    by_class = [np.where(y == c)[0] for c in range(n_classes)]
    same = np.array([by_class[y[s]][rng.integers(0, len(by_class[y[s]]))]
                     for s in src[intra]], dtype=np.int64) if intra.any() else np.array([], np.int64)
    dst = dst.copy()
    dst[intra] = same
    keep = src != dst
    src, dst = src[keep], dst[keep]
    src, dst = _undirect(src, dst)
    means = rng.normal(0, 1, (n_classes, d_feat))
    x = (means[y] + noise * rng.normal(0, 1, (n_nodes, d_feat))).astype(np.float32)
    tr, va, te = _split_masks(rng, n_nodes)
    ei = np.stack([src, dst]).astype(np.int32)
    return Graph(n_nodes, ei, x, y, tr, va, te, n_classes=n_classes)


def powerlaw(n_nodes=10000, avg_degree=16, d_feat=128, n_classes=16, seed=0) -> Graph:
    """Preferential-attachment-ish power-law graph (vectorized approximation):
    each node attaches ``avg_degree/2`` edges to targets sampled with probability
    proportional to (index+1)^-0.8-ranked popularity — heavy-tailed in-degree."""
    rng = np.random.default_rng(seed)
    m = max(1, avg_degree // 2)
    # popularity ~ Zipf over a random permutation of nodes
    pop = (1.0 / (np.arange(1, n_nodes + 1) ** 0.8))
    pop = pop[rng.permutation(n_nodes)]
    pop /= pop.sum()
    src = np.repeat(np.arange(n_nodes), m)
    dst = rng.choice(n_nodes, size=src.size, p=pop)
    keep = src != dst
    src, dst = _undirect(src[keep], dst[keep])
    x = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    y = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    tr, va, te = _split_masks(rng, n_nodes)
    return Graph(n_nodes, np.stack([src, dst]).astype(np.int32), x, y, tr, va, te,
                 n_classes=n_classes)


def powerlaw_community(n_nodes=4000, n_classes=16, d_feat=96, avg_degree=16,
                       p_in=0.8, gamma=0.8, noise=1.0, seed=0) -> Graph:
    """Heavy-tailed degrees + planted communities in one graph.

    Each node attaches ``avg_degree/2`` edges; with probability ``p_in`` the
    target is drawn popularity-weighted *within the node's own class*
    (homophily — labels are recoverable, so convergence curves mean
    something), otherwise popularity-weighted over all nodes (hubs — the
    skewed per-pair halo counts the compact layout is built for). Popularity
    is Zipf-like with exponent ``gamma`` over a random node permutation.
    Features are Gaussian class means + ``noise``, as in
    :func:`planted_partition`.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    pop = 1.0 / (np.arange(1, n_nodes + 1) ** gamma)
    pop = pop[rng.permutation(n_nodes)]
    m = max(1, avg_degree // 2)
    src = np.repeat(np.arange(n_nodes), m)
    intra = rng.random(src.size) < p_in
    dst = rng.choice(n_nodes, size=src.size, p=pop / pop.sum())
    for c in range(n_classes):
        nodes_c = np.where(y == c)[0]
        sel = intra & (y[src] == c)
        if nodes_c.size and sel.any():
            pc = pop[nodes_c] / pop[nodes_c].sum()
            dst[sel] = nodes_c[rng.choice(nodes_c.size, size=int(sel.sum()),
                                          p=pc)]
    keep = src != dst
    src, dst = _undirect(src[keep], dst[keep])
    means = rng.normal(0, 1, (n_classes, d_feat))
    x = (means[y] + noise * rng.normal(0, 1, (n_nodes, d_feat))).astype(
        np.float32)
    tr, va, te = _split_masks(rng, n_nodes)
    return Graph(n_nodes, np.stack([src, dst]).astype(np.int32), x, y,
                 tr, va, te, n_classes=n_classes)


def grid_mesh(nx=32, ny=32, d_feat=16, seed=0) -> Graph:
    """2D grid mesh with diagonal struts + world positions (MeshGraphNet regime)."""
    rng = np.random.default_rng(seed)
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    pairs = []
    pairs.append((idx[:-1, :].ravel(), idx[1:, :].ravel()))
    pairs.append((idx[:, :-1].ravel(), idx[:, 1:].ravel()))
    pairs.append((idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()))
    src = np.concatenate([p[0] for p in pairs])
    dst = np.concatenate([p[1] for p in pairs])
    src, dst = _undirect(src, dst)
    xs, ys = np.meshgrid(np.linspace(0, 1, nx), np.linspace(0, 1, ny), indexing="ij")
    pos = np.stack([xs.ravel(), ys.ravel(), np.zeros(n)], axis=1).astype(np.float32)
    x = rng.normal(0, 1, (n, d_feat)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    tr, va, te = _split_masks(rng, n)
    return Graph(n, np.stack([src, dst]).astype(np.int32), x, y, tr, va, te,
                 pos=pos, n_classes=4)


def molecules(n_nodes=30, d_feat=16, cutoff=2.0, box=4.0, seed=0) -> Graph:
    """One random-geometric 'molecule': 3D positions in a box, radius graph."""
    rng = np.random.default_rng(seed)
    pos = (rng.random((n_nodes, 3)) * box).astype(np.float32)
    diff = pos[:, None, :] - pos[None, :, :]
    dist = np.sqrt((diff ** 2).sum(-1))
    adj = (dist < cutoff) & ~np.eye(n_nodes, dtype=bool)
    src, dst = np.where(adj)
    x = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    y = rng.integers(0, 4, n_nodes).astype(np.int32)
    tr, va, te = _split_masks(rng, n_nodes)
    return Graph(n_nodes, np.stack([src, dst]).astype(np.int32), x, y, tr, va, te,
                 pos=pos, n_classes=4)


# The generator dispatch table — the single source the CLI checks raw
# generator names against (launch/train.py).
GENERATORS = {"planted": planted_partition, "powerlaw": powerlaw,
              "powerlaw_community": powerlaw_community,
              "grid": grid_mesh, "molecule": molecules}


def by_name(name: str, **kw) -> Graph:
    """Generator lookup by short name. For *named workloads* (calibrated
    sizes, scale tiers) use :func:`repro.datasets.load` instead."""
    return GENERATORS[name](**kw)
