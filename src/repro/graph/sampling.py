"""Fanout neighbor sampler for the ``minibatch_lg`` shape (sampled-training).

GraphSAINT-style: sample a k-hop neighborhood subgraph around ``batch_nodes`` seed
nodes with per-hop fanouts (e.g. 15-10), then train on the induced subgraph as a
small full graph — which the distributed runtime partitions exactly like any other
full graph (so Sylvie's quantized halo exchange applies unchanged).

Sampling is host-side numpy over CSR (uniform with replacement per DGL's default),
static-padded to jit-stable shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import Graph


@dataclasses.dataclass(frozen=True)
class SamplerShapes:
    """Static padded sizes for a (batch_nodes, fanouts) sampler config."""
    batch_nodes: int
    fanouts: tuple[int, ...]

    @property
    def max_nodes(self) -> int:
        n, tot = self.batch_nodes, self.batch_nodes
        for f in self.fanouts:
            n *= f
            tot += n
        return tot

    @property
    def max_edges(self) -> int:
        n, tot = self.batch_nodes, 0
        for f in self.fanouts:
            tot += n * f
            n *= f
        return tot


class NeighborSampler:
    def __init__(self, g: Graph, fanouts=(15, 10), seed: int = 0):
        self.g = g
        self.fanouts = tuple(fanouts)
        self.indptr, self.indices = g.to_csr()
        self.rng = np.random.default_rng(seed)
        self.train_ids = (np.where(g.train_mask)[0] if g.train_mask is not None
                          else np.arange(g.n_nodes))

    def _sample_hop(self, frontier: np.ndarray, fanout: int):
        """Uniform-with-replacement fanout sample of each frontier node's neighbors."""
        deg = (self.indptr[frontier + 1] - self.indptr[frontier]).astype(np.int64)
        has = deg > 0
        f = frontier[has]
        d = deg[has]
        offs = self.rng.integers(0, d[:, None], size=(f.size, fanout))
        nbrs = self.indices[self.indptr[f][:, None] + offs]
        src = nbrs.ravel()
        dst = np.repeat(f, fanout)
        return src, dst

    def sample(self, seeds: np.ndarray | None = None, batch_nodes: int = 1024):
        """Returns a Graph over the sampled subgraph (relabeled, deduped edges)
        with ``train_mask`` marking the seed nodes (loss is seeds-only)."""
        if seeds is None:
            seeds = self.rng.choice(self.train_ids, size=batch_nodes,
                                    replace=self.train_ids.size < batch_nodes)
        srcs, dsts = [], []
        frontier = np.unique(seeds)
        for f in self.fanouts:
            s, d = self._sample_hop(frontier, f)
            srcs.append(s)
            dsts.append(d)
            frontier = np.unique(s)
        src = np.concatenate(srcs)
        dst = np.concatenate(dsts)
        # dedupe (messages src->dst; seeds are dsts of hop-1)
        combo = src.astype(np.int64) * self.g.n_nodes + dst
        combo = np.unique(combo)
        src = (combo // self.g.n_nodes).astype(np.int64)
        dst = (combo % self.g.n_nodes).astype(np.int64)
        nodes = np.unique(np.concatenate([seeds, src, dst]))
        relabel = np.full(self.g.n_nodes, -1, dtype=np.int64)
        relabel[nodes] = np.arange(nodes.size)
        ei = np.stack([relabel[src], relabel[dst]]).astype(np.int32)
        tr = np.zeros(nodes.size, dtype=bool)
        tr[relabel[seeds]] = True
        return Graph(
            n_nodes=int(nodes.size), edge_index=ei,
            x=self.g.x[nodes],
            y=None if self.g.y is None else self.g.y[nodes],
            train_mask=tr, val_mask=tr.copy(), test_mask=tr.copy(),
            pos=None if self.g.pos is None else self.g.pos[nodes],
            n_classes=self.g.n_classes)
