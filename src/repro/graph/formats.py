"""Graph containers: COO edge lists, padded structures, GCN normalization.

JAX has no CSR/CSC — message passing is implemented as gather over an edge index
followed by ``jax.ops.segment_sum`` / ``segment_max`` scatter onto nodes (see
``models/gnn``). Everything here is static-shaped (padded + masked) so it jits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Host-side (numpy) graph. ``edge_index[0]=src, edge_index[1]=dst``; messages
    flow src -> dst. Directed storage; undirected graphs store both directions."""

    n_nodes: int
    edge_index: np.ndarray                 # (2, E) int32
    x: np.ndarray                          # (N, d) float32 node features
    y: Optional[np.ndarray] = None         # (N,) int32 labels
    train_mask: Optional[np.ndarray] = None
    val_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None
    pos: Optional[np.ndarray] = None       # (N, 3) positions (molecular/mesh models)
    edge_attr: Optional[np.ndarray] = None # (E, d_e)
    n_classes: int = 0

    @property
    def n_edges(self) -> int:
        return int(self.edge_index.shape[1])

    def degrees(self, kind: str = "in") -> np.ndarray:
        idx = self.edge_index[1] if kind == "in" else self.edge_index[0]
        return np.bincount(idx, minlength=self.n_nodes).astype(np.int64)

    def to_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, indices) over *outgoing* edges of each node (src -> its dsts)."""
        order = np.argsort(self.edge_index[0], kind="stable")
        src = self.edge_index[0][order]
        dst = self.edge_index[1][order]
        counts = np.bincount(src, minlength=self.n_nodes)
        indptr = np.zeros(self.n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return indptr, dst.astype(np.int32)


def add_self_loops(edge_index: np.ndarray, n_nodes: int) -> np.ndarray:
    loop = np.arange(n_nodes, dtype=edge_index.dtype)
    return np.concatenate([edge_index, np.stack([loop, loop])], axis=1)


def gcn_edge_weights(edge_index: np.ndarray, n_nodes: int) -> np.ndarray:
    """Symmetric-normalized weights  w_uv = 1/sqrt((d_u+1)(d_v+1))  for A+I rows.

    Matches the paper's  D^{-1/2}(A+I)D^{-1/2}  (Alg. 1 line 15). Self loops must
    already be present in ``edge_index``.
    """
    deg = np.bincount(edge_index[1], minlength=n_nodes).astype(np.float64)
    inv_sqrt = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    return (inv_sqrt[edge_index[0]] * inv_sqrt[edge_index[1]]).astype(np.float32)


def gcn_normalize(g: Graph, *, self_loops: bool = True,
                  gcn_weights: bool = True):
    """The canonical GCN pre-partition normalization: append self-loops
    (zero-valued attribute rows for graphs carrying ``edge_attr`` — matching
    the zero-length geometric edge) and attach symmetric-normalized weights.

    Returns ``(graph, edge_weight)``. This is the one definition shared by
    ``repro.api.partition``, ``repro.datasets.load_partitioned``, the launch
    CLI, and the benchmark harness, so a plan cached through any of them is
    the partition every other path would build.
    """
    ei, ea = g.edge_index, g.edge_attr
    if self_loops:
        n_before = ei.shape[1]
        ei = add_self_loops(ei, g.n_nodes)
        if ea is not None:
            pad = np.zeros((ei.shape[1] - n_before, ea.shape[1]), ea.dtype)
            ea = np.concatenate([ea, pad], axis=0)
    ew = gcn_edge_weights(ei, g.n_nodes) if gcn_weights else None
    return dataclasses.replace(g, edge_index=ei, edge_attr=ea), ew


def mean_edge_weights(edge_index: np.ndarray, n_nodes: int) -> np.ndarray:
    """1/deg_in(dst) weights — mean aggregation as edge weights (GraphSAGE-mean)."""
    deg = np.bincount(edge_index[1], minlength=n_nodes).astype(np.float64)
    w = 1.0 / np.maximum(deg, 1.0)
    return w[edge_index[1]].astype(np.float32)


def pad_edges(edge_index: np.ndarray, e_pad: int, fill_node: int = 0,
              extra: Optional[np.ndarray] = None):
    """Pad a (2, E) edge list to (2, e_pad) + mask. Padded edges point at
    ``fill_node`` with mask 0 so segment ops ignore them."""
    e = edge_index.shape[1]
    assert e <= e_pad, (e, e_pad)
    mask = np.zeros(e_pad, dtype=bool)
    mask[:e] = True
    out = np.full((2, e_pad), fill_node, dtype=np.int32)
    out[:, :e] = edge_index
    if extra is not None:
        ex = np.zeros((e_pad,) + extra.shape[1:], dtype=extra.dtype)
        ex[:e] = extra
        return out, mask, ex
    return out, mask


def pad_to(arr: np.ndarray, n: int, axis: int = 0) -> np.ndarray:
    pad = n - arr.shape[axis]
    assert pad >= 0
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return np.pad(arr, widths)
