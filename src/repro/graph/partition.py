"""Host-side graph partitioner + static halo-exchange plan (Sylvie's Graph Engine).

Splits a global graph into ``P`` equal (padded) partitions, builds the HALO node
sets (paper §2.2 / Alg. 1 lines 3-7), and emits a **static** exchange plan in one
of two layouts:

* ``dense`` — the classic pairwise-blocked buffer: ``send_idx[p, q, s]`` is the
  local index (in partition ``p``) of the ``s``-th node that ``p`` must send to
  ``q``; every (p, q) block is padded to ``h_pad`` (the max over all pairs) so a
  single ``all_to_all`` moves everything. Wire bytes scale with the *worst* pair
  — badly skewed on power-law graphs — and the all-masked diagonal self-blocks
  ride along for free.
* ``compact`` (default) — ragged ring buckets: the send buffer of partition
  ``p`` is the concatenation over ring offsets ``k = 1..P-1`` of the rows ``p``
  sends to partition ``(p+k) % P``. Bucket ``k`` is sized to the *ring max*
  ``max_p count[p -> (p+k)%P]`` rounded up to ``alignment`` rows (SPMD needs one
  static shape per bucket, not per pair), the diagonal (``k = 0``) is dropped
  from the wire entirely, and ``send_idx`` doubles as the compaction
  permutation: ``gather_boundary`` produces a packed buffer with no dead
  pairwise blocks. The exchange is one ``ppermute`` (or stacked roll) per
  bucket; it is *not* an involution — the backward communication runs the
  reversed rings (see ``core/exchange.py``).

Either way the partition-local edge list's ``src`` indices address the
concatenated ``[local_features ; halo_buffer]`` table: a halo node received
from ``q`` at slot ``s`` lives at extended index ``n_local + q*h_pad + s``
(dense) or ``n_local + bucket_start[(p-q) % P] + s`` (compact).

All arrays carry a leading partition axis ``P`` and are sharded one-partition-
per-device by the runtime. The plan is independent of the *model*; it is
computed once per (graph, P) and reused every layer/epoch (as in the paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .formats import Graph


@dataclasses.dataclass
class HaloPlan:
    n_parts: int
    n_local: int
    h_pad: int                    # max per-(p,q) pairwise count (dense slot count)
    send_idx: np.ndarray          # dense: (P, P, h_pad) int32; compact: (P, R)
    send_mask: np.ndarray         # same shape as send_idx, bool
    recv_mask: np.ndarray         # (P, halo_rows) bool
    layout: str = "dense"         # "dense" | "compact"
    bucket_sizes: Optional[np.ndarray] = None   # (P,) aligned ring-bucket rows
    pair_counts: Optional[np.ndarray] = None    # (P_recv, P_send) true halo counts
    alignment: int = 1

    @property
    def halo_rows(self) -> int:
        """Rows of the (send or recv) halo buffer of one partition."""
        if self.layout == "compact":
            return int(self.bucket_sizes.sum())
        return self.n_parts * self.h_pad

    def wire_rows(self) -> int:
        """Rows this layout actually ships per exchange, totaled across all
        partitions. Diagonal self-blocks never hit the wire (a real all_to_all
        keeps the self-chunk local; the compact layout has no diagonal at all)."""
        if self.layout == "compact":
            return self.n_parts * self.halo_rows
        return self.n_parts * (self.n_parts - 1) * self.h_pad

    def real_rows(self) -> int:
        """True (unpadded, off-diagonal) halo rows per exchange, all partitions."""
        return int(self.send_mask.sum())

    def real_send_counts(self) -> np.ndarray:
        """(P,) true halo rows sent by each partition."""
        return self.send_mask.reshape(self.n_parts, -1).sum(axis=1)

    def pad_efficiency(self) -> float:
        """Fraction of buffered rows that are real (1.0 = no padding waste)."""
        total = self.send_mask.size
        return float(self.send_mask.sum()) / max(total, 1)


@dataclasses.dataclass
class PartitionedGraph:
    plan: HaloPlan
    part_of: np.ndarray           # (N,) partition of each global node
    global_ids: np.ndarray        # (P, n_local) global id of each local slot (pad=-1)
    node_mask: np.ndarray         # (P, n_local)
    x: np.ndarray                 # (P, n_local, d)
    y: Optional[np.ndarray]       # (P, n_local)
    train_mask: Optional[np.ndarray]
    val_mask: Optional[np.ndarray]
    test_mask: Optional[np.ndarray]
    edges: np.ndarray             # (P, e_pad, 2) int32  [src_ext, dst_local]
    edge_mask: np.ndarray         # (P, e_pad)
    edge_weight: Optional[np.ndarray]  # (P, e_pad)
    pos: Optional[np.ndarray] = None    # (P, n_local, 3)
    edge_attr: Optional[np.ndarray] = None  # (P, e_pad, d_e)
    n_classes: int = 0

    @property
    def n_parts(self) -> int:
        return self.plan.n_parts

    def unpartition(self, h_parts: np.ndarray) -> np.ndarray:
        """Reassemble a (P, n_local, ...) per-partition array into global node order."""
        n = int(self.part_of.shape[0])
        out = np.zeros((n,) + h_parts.shape[2:], dtype=np.asarray(h_parts).dtype)
        ids = self.global_ids[self.node_mask]
        out[ids] = np.asarray(h_parts)[self.node_mask]
        return out


def global_to_slot(pg: PartitionedGraph) -> tuple[np.ndarray, np.ndarray]:
    """``(part_of, slot_of)`` int64 maps: global node id -> (partition, local
    slot). The O(lookup) request-path index shared by the inference engine,
    its store readers, and the sharded embedding store (a store shard is
    addressed by exactly these ``(part, slot)`` coordinates)."""
    n = int(pg.part_of.shape[0])
    slot_of = np.full(n, -1, dtype=np.int64)
    pi, li = np.nonzero(pg.node_mask)
    slot_of[pg.global_ids[pi, li]] = li
    return pg.part_of.astype(np.int64), slot_of


def assign_parts(g: Graph, n_parts: int, method: str = "block", seed: int = 0) -> np.ndarray:
    """Partition assignment. ``block`` = contiguous id ranges (our synthetic
    generators have id locality, so this approximates a METIS-quality cut);
    ``random`` = hash partition (worst case, used to stress comm volume);
    ``skewed`` = contiguous blocks of geometrically decaying size (stress case
    for per-pair halo imbalance — what the compact layout is built for)."""
    n = g.n_nodes
    if method == "block":
        return (np.arange(n) * n_parts // n).astype(np.int32)
    if method == "random":
        rng = np.random.default_rng(seed)
        return rng.integers(0, n_parts, n).astype(np.int32)
    if method == "skewed":
        w = 0.5 ** np.arange(n_parts)
        bounds = np.ceil(np.cumsum(w / w.sum()) * n).astype(np.int64)
        bounds[-1] = n
        return np.searchsorted(bounds, np.arange(n), side="right").astype(np.int32)
    raise ValueError(method)


def _align_up(x: np.ndarray, a: int) -> np.ndarray:
    return -(-x // a) * a


def partition_graph(g: Graph, n_parts: int, method: str = "block",
                    edge_weight: Optional[np.ndarray] = None,
                    seed: int = 0, layout: str = "compact",
                    alignment: int = 8) -> PartitionedGraph:
    if layout not in ("dense", "compact"):
        raise ValueError(f"unknown halo layout {layout!r}")
    n = g.n_nodes
    src, dst = g.edge_index[0].astype(np.int64), g.edge_index[1].astype(np.int64)
    part_of = assign_parts(g, n_parts, method, seed)

    # --- local node numbering (padded to equal n_local) ------------------------
    counts = np.bincount(part_of, minlength=n_parts)
    n_local = int(counts.max())
    order = np.argsort(part_of, kind="stable")
    starts = np.zeros(n_parts + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    local_index = np.empty(n, dtype=np.int64)
    for p in range(n_parts):
        local_index[order[starts[p]:starts[p + 1]]] = np.arange(counts[p])
    global_ids = np.full((n_parts, n_local), -1, dtype=np.int64)
    node_mask = np.zeros((n_parts, n_local), dtype=bool)
    for p in range(n_parts):
        ids = order[starts[p]:starts[p + 1]]
        global_ids[p, :counts[p]] = ids
        node_mask[p, :counts[p]] = True

    # --- halo sets: unique (dst_part p, src_part q, node u) with q != p --------
    p_dst = part_of[dst].astype(np.int64)
    p_src = part_of[src].astype(np.int64)
    is_halo = p_src != p_dst
    pairkey = p_dst[is_halo] * n_parts + p_src[is_halo]
    combo = pairkey * n + src[is_halo]
    uniq, inv = np.unique(combo, return_inverse=True)
    u_pair = uniq // n
    u_node = uniq % n
    # slot of each unique halo node within its (p,q) group
    group_start_of = np.searchsorted(u_pair, np.arange(n_parts * n_parts))
    slot = np.arange(uniq.size) - group_start_of[u_pair]
    group_sizes = np.bincount(u_pair, minlength=n_parts * n_parts)
    pair_counts = group_sizes.reshape(n_parts, n_parts)  # [recv p, send q]
    h_pad = max(1, int(group_sizes.max()) if uniq.size else 1)
    q_of = u_pair % n_parts          # owner / sender
    p_of = u_pair // n_parts         # receiver

    bucket_sizes = None
    if layout == "dense":
        send_idx = np.zeros((n_parts, n_parts, h_pad), dtype=np.int64)
        send_mask = np.zeros((n_parts, n_parts, h_pad), dtype=bool)
        send_idx[q_of, p_of, slot] = local_index[u_node]
        send_mask[q_of, p_of, slot] = True
        recv_mask = np.transpose(send_mask, (1, 0, 2)).reshape(
            n_parts, n_parts * h_pad)
        # halo node from q at slot s -> extended index n_local + q*h_pad + s
        halo_ext = n_local + p_src[is_halo] * h_pad + slot[inv]
    else:
        # ring bucket k holds what each p sends to (p+k)%P; sized to the ring
        # max and lane-aligned so every partition shares one static shape.
        ring = np.arange(n_parts)
        ring_counts = np.zeros(n_parts, dtype=np.int64)
        for k in range(1, n_parts):
            ring_counts[k] = pair_counts[(ring + k) % n_parts, ring].max()
        bucket_sizes = np.where(ring_counts > 0,
                                _align_up(ring_counts, max(1, alignment)), 0)
        bucket_sizes[0] = 0          # diagonal self-block: never on the wire
        bstart = np.zeros(n_parts + 1, dtype=np.int64)
        np.cumsum(bucket_sizes, out=bstart[1:])
        rows = int(bucket_sizes.sum())
        k_of = (p_of - q_of) % n_parts
        send_idx = np.zeros((n_parts, rows), dtype=np.int64)
        send_mask = np.zeros((n_parts, rows), dtype=bool)
        pos = bstart[k_of] + slot
        send_idx[q_of, pos] = local_index[u_node]
        send_mask[q_of, pos] = True
        # recv[p][bucket k] = send[(p-k)%P][bucket k]  (the ring exchange)
        recv_mask = np.zeros_like(send_mask)
        for k in range(1, n_parts):
            if bucket_sizes[k] == 0:
                continue
            sl = slice(bstart[k], bstart[k] + bucket_sizes[k])
            recv_mask[:, sl] = np.roll(send_mask[:, sl], k, axis=0)
        # halo node from q at slot s -> n_local + bucket_start[(p-q)%P] + s
        halo_ext = n_local + bstart[(p_dst[is_halo] - p_src[is_halo]) % n_parts] \
            + slot[inv]

    # --- per-partition edge lists (ext src indexing) ---------------------------
    src_ext = np.where(is_halo, 0, local_index[src])
    src_ext[is_halo] = halo_ext
    dst_loc = local_index[dst]

    e_counts = np.bincount(p_dst, minlength=n_parts)
    e_pad = max(1, int(e_counts.max()))
    edges = np.zeros((n_parts, e_pad, 2), dtype=np.int64)
    edge_mask = np.zeros((n_parts, e_pad), dtype=bool)
    ew = None if edge_weight is None else np.zeros((n_parts, e_pad), dtype=np.float32)
    ea = None if g.edge_attr is None else np.zeros(
        (n_parts, e_pad) + g.edge_attr.shape[1:], dtype=g.edge_attr.dtype)
    eorder = np.argsort(p_dst, kind="stable")
    estarts = np.zeros(n_parts + 1, dtype=np.int64)
    np.cumsum(e_counts, out=estarts[1:])
    for p in range(n_parts):
        sel = eorder[estarts[p]:estarts[p + 1]]
        k = sel.size
        edges[p, :k, 0] = src_ext[sel]
        edges[p, :k, 1] = dst_loc[sel]
        edge_mask[p, :k] = True
        if ew is not None:
            ew[p, :k] = edge_weight[sel]
        if ea is not None:
            ea[p, :k] = g.edge_attr[sel]

    def scatter_nodes(arr, fill=0.0):
        if arr is None:
            return None
        out = np.full((n_parts, n_local) + arr.shape[1:], fill, dtype=arr.dtype)
        out[node_mask] = arr[global_ids[node_mask]]
        return out

    plan = HaloPlan(n_parts, n_local, h_pad,
                    send_idx.astype(np.int32), send_mask, recv_mask,
                    layout=layout, bucket_sizes=bucket_sizes,
                    pair_counts=pair_counts,
                    alignment=alignment if layout == "compact" else 1)
    return PartitionedGraph(
        plan=plan, part_of=part_of, global_ids=global_ids, node_mask=node_mask,
        x=scatter_nodes(g.x),
        y=scatter_nodes(g.y) if g.y is not None else None,
        train_mask=scatter_nodes(g.train_mask),
        val_mask=scatter_nodes(g.val_mask),
        test_mask=scatter_nodes(g.test_mask),
        edges=edges.astype(np.int32), edge_mask=edge_mask, edge_weight=ew,
        pos=scatter_nodes(g.pos), edge_attr=ea, n_classes=g.n_classes)


# ---------------------------------------------------------------------------
# Halo-structure introspection: which *global* node each halo-buffer row
# carries, and the k-hop frontier of a seed set. Host-side (numpy), built
# entirely from the partition plan — the serving-time delta refresh
# (repro.serve.delta) plans its per-layer affected sets with these.
# ---------------------------------------------------------------------------
def halo_source_globals(pg: PartitionedGraph) -> np.ndarray:
    """(P, halo_rows) global node id carried by each halo-buffer row of each
    partition (-1 for padding rows). Inverts the exchange: row ``r`` of
    partition ``p``'s *receive* buffer holds the node partition ``q`` gathered
    at the matching slot of its *send* buffer (``q = (p-k) % P`` for compact
    ring bucket ``k``; the block sender for dense)."""
    plan = pg.plan
    n_parts = plan.n_parts
    out = np.full((n_parts, plan.halo_rows), -1, dtype=np.int64)
    if plan.layout == "compact":
        bstart = np.zeros(n_parts + 1, dtype=np.int64)
        np.cumsum(plan.bucket_sizes, out=bstart[1:])
        for p in range(n_parts):
            for k in range(1, n_parts):
                if plan.bucket_sizes[k] == 0:
                    continue
                q = (p - k) % n_parts
                sl = slice(bstart[k], bstart[k + 1])
                idx, m = plan.send_idx[q, sl], plan.send_mask[q, sl]
                row = out[p, sl]
                row[m] = pg.global_ids[q, idx[m]]
    else:
        for p in range(n_parts):
            for q in range(n_parts):
                sl = slice(q * plan.h_pad, (q + 1) * plan.h_pad)
                idx, m = plan.send_idx[q, p], plan.send_mask[q, p]
                row = out[p, sl]
                row[m] = pg.global_ids[q, idx[m]]
    return out


def global_edges(pg: PartitionedGraph) -> tuple[np.ndarray, np.ndarray]:
    """(src_global, dst_global) of every real (unmasked) edge, reconstructed
    from the per-partition extended-index edge lists. Local extended indices
    resolve through ``global_ids``; halo indices through
    :func:`halo_source_globals`."""
    plan = pg.plan
    halo_src = halo_source_globals(pg)
    srcs, dsts = [], []
    for p in range(plan.n_parts):
        m = pg.edge_mask[p]
        se = pg.edges[p, m, 0].astype(np.int64)
        dl = pg.edges[p, m, 1].astype(np.int64)
        local = se < plan.n_local
        sg = np.where(local,
                      pg.global_ids[p, np.where(local, se, 0)],
                      halo_src[p, np.where(local, 0, se - plan.n_local)])
        srcs.append(sg)
        dsts.append(pg.global_ids[p, dl])
    src_g = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst_g = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    assert (src_g >= 0).all() and (dst_g >= 0).all(), \
        "edge list references a padding halo row"
    return src_g, dst_g


def khop_frontier(pg: PartitionedGraph, seed_nodes, k: int,
                  edges: Optional[tuple[np.ndarray, np.ndarray]] = None
                  ) -> np.ndarray:
    """(k+1, N) bool: ``out[h]`` marks the global nodes reachable from
    ``seed_nodes`` within ``h`` *directed* hops (message direction src -> dst;
    ``out[0]`` is the seed set itself, each row a superset of the previous).

    This is the incremental-refresh frontier: when the features of
    ``seed_nodes`` change, the layer-``h`` input embeddings of exactly the
    nodes in ``out[h]`` can change (each GNN layer pulls one hop), so a
    serving-time delta refresh only needs to re-ship layer ``h``'s boundary
    rows inside ``out[h]`` (see ``repro.serve.delta``).

    ``edges`` optionally supplies a precomputed :func:`global_edges` pair —
    callers planning many refreshes over one immutable partition (the
    inference engine) amortize the O(E) reconstruction that way."""
    n = int(pg.part_of.shape[0])
    seeds = np.asarray(seed_nodes, dtype=np.int64).reshape(-1)
    if seeds.size and (seeds.min() < 0 or seeds.max() >= n):
        raise ValueError(f"seed node ids must be in [0, {n})")
    out = np.zeros((k + 1, n), dtype=bool)
    out[0, seeds] = True
    if k == 0:
        return out
    src_g, dst_g = global_edges(pg) if edges is None else edges
    for h in range(k):
        nxt = out[h].copy()
        nxt[dst_g[out[h][src_g]]] = True
        out[h + 1] = nxt
    return out


# ---------------------------------------------------------------------------
# Analytic plan *shapes* for the full-config dry-run (no 62M-edge graph is
# materialized; .lower() only needs ShapeDtypeStructs). Used by
# launch/dryrun.py; the sharding contract is DESIGN.md §5.
# The dry-run sizes the dense layout (the conservative upper bound).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PartitionShapeSpec:
    n_parts: int
    n_local: int
    e_pad: int
    h_pad: int

    @property
    def halo_rows(self) -> int:
        return self.n_parts * self.h_pad


def analytic_partition_spec(n_nodes: int, n_edges: int, n_parts: int,
                            halo_frac: float = 0.5, pair_imbalance: float = 4.0,
                            edge_imbalance: float = 1.15) -> PartitionShapeSpec:
    """Size the static buffers for a hypothetical good (METIS-quality) partition.

    ``halo_frac``: halo nodes per partition as a fraction of local nodes (0.3-1.0
    for locality-aware cuts of power-law graphs at this parallelism).
    ``pair_imbalance``: max/mean ratio of per-pair halo counts (padding factor).
    """
    n_local = math.ceil(n_nodes / n_parts)
    e_pad = max(1, math.ceil(n_edges / n_parts * edge_imbalance))
    halo_total = halo_frac * n_local
    h_pad = max(1, math.ceil(halo_total * pair_imbalance / max(1, n_parts - 1)))
    return PartitionShapeSpec(n_parts, n_local, e_pad, h_pad)
