from . import formats, partition, sampling, synthetic  # noqa: F401
