"""CommPolicy vocabulary: decisions, telemetry, and the policy protocol.

Sylvie's original design fixes one static compression decision for the whole
run (``SylvieConfig.bits`` plus a lone ``eps_s`` staleness knob). The paper's
own Bounded Staleness Adaptor (§3.3) and the staged follow-ups — AdaQP's
variance-budgeted per-message bit-widths (Wan et al., arXiv:2306.01381) and
variable communication rates over training (Cerviño et al., arXiv:2406.17611)
— all show the *right* decision varies by exchange site and by epoch. This
module makes that decision a first-class object:

* :class:`SiteDecision` — what one halo-exchange site does this epoch
  (forward/backward bit-widths, stochastic vs deterministic rounding,
  BNS-style boundary sampling).
* :class:`EpochDecision` — one :class:`SiteDecision` per exchange site plus
  the epoch-level choices (synchronous vs pipelined step, EF21 weight-gradient
  compression bits). **Hashable and fully static**: the trainer threads it
  into the step as trace-level config, so jit caches one executable per
  distinct decision.
* :class:`Telemetry` / :class:`SiteStats` — what a policy may observe, all
  host-side floats gathered *outside the trace* (epoch index, per-site
  quantization range/variance statistics emitted by the previous step, the
  validation trajectory, partition count).
* :class:`CommPolicy` — the protocol: once per epoch, ``decide(telemetry) ->
  EpochDecision``. Policies are pure host-side objects; nothing they return
  ever becomes a traced value.

Trace-staticness rule: every field of an :class:`EpochDecision` selects *code*
(bit-widths pick pack/unpack shapes, ``sync`` picks the step function), never
data. To keep the number of compiled executables small the trainer snaps
decisions to the lattice below (:meth:`EpochDecision.snapped`) before using
them as cache keys.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

# The decision lattice: bit-widths a snapped decision may use (the widths the
# Low-bit Module packs / passes through) and the grid boundary-sampling rates
# are rounded to. Policies may compute anything; the trainer quantizes to this
# lattice so a drifting policy cannot trigger unbounded recompilation.
BIT_LATTICE = (1, 2, 4, 8, 16, 32)
SAMPLE_P_STEP = 0.05


def snap_bits(bits: int | float) -> int:
    """Round a requested bit-width *up* to the nearest lattice width::

        snap_bits(3)    # -> 4
        snap_bits(100)  # -> 32 (clamped to the widest lattice point)
    """
    for b in BIT_LATTICE:
        if bits <= b:
            return b
    return BIT_LATTICE[-1]


def snap_sample_p(p: float) -> float:
    """Round a boundary-sampling rate to the lattice grid, clamped to
    [0, 0.95] (p=1 would drop every halo row)::

        snap_sample_p(0.33)  # -> 0.35
        snap_sample_p(1.0)   # -> 0.95
    """
    q = round(float(p) / SAMPLE_P_STEP) * SAMPLE_P_STEP
    return min(max(q, 0.0), 0.95)


@dataclasses.dataclass(frozen=True)
class SiteDecision:
    """Per-exchange-site communication decision for one epoch.

    ``fwd_bits`` quantizes the forward halo features, ``bwd_bits`` the
    backward boundary-gradient communication (Alg. 2 lines 10-12) — the two
    directions are independent code paths through the custom_vjps in
    ``core/sylvie.py``. ``boundary_sample_p`` is the BNS-GCN keep-out rate
    (0 disables).

    Example — 1-bit features forward, 8-bit gradients backward::

        SiteDecision(fwd_bits=1, bwd_bits=8, stochastic=True)
    """

    fwd_bits: int = 1
    bwd_bits: int = 1
    stochastic: bool = True
    boundary_sample_p: float = 0.0

    @staticmethod
    def from_config(cfg) -> "SiteDecision":
        """The Uniform degenerate case: one global ``SylvieConfig`` decision.
        This is the only sanctioned place runtime code reads ``cfg.bits``."""
        b = int(cfg.effective_bits)
        return SiteDecision(fwd_bits=b, bwd_bits=b, stochastic=cfg.stochastic,
                            boundary_sample_p=cfg.boundary_sample_p)

    def snapped(self) -> "SiteDecision":
        return SiteDecision(fwd_bits=snap_bits(self.fwd_bits),
                            bwd_bits=snap_bits(self.bwd_bits),
                            stochastic=bool(self.stochastic),
                            boundary_sample_p=snap_sample_p(
                                self.boundary_sample_p))


@dataclasses.dataclass(frozen=True)
class EpochDecision:
    """One epoch's full communication schedule. Hashable; used as a jit/step
    cache key, so every field must stay static python data.

    * ``sites[i]`` drives the i-th ``comm.halo(h)`` call (``model.comm_dims()``
      order).
    * ``sync`` — run the synchronous step (Sylvie-S semantics, refreshes all
      staleness caches) instead of the pipelined Sylvie-A step. Only honored
      when the trainer's mode is ``"async"``; sync-mode trainers always run
      the synchronous step.
    * ``ef_bits`` — EF21-compressed weight-gradient all-reduce bit-width
      (``None`` = full-precision psum, the paper's setting).
    * ``schedule`` — ``"blocking"`` (each halo consumed as it is produced) or
      ``"overlap"`` (issue/land double buffering, ``dist/overlap.py``). Part
      of :meth:`step_key`: the two schedules trace different programs.

    Example — Sylvie-S at 1 bit on a 2-site model::

        EpochDecision.uniform(n_sites=2, bits=1, sync=True)
    """

    sites: tuple[SiteDecision, ...]
    sync: bool = False
    ef_bits: Optional[int] = None
    schedule: str = "blocking"

    @staticmethod
    def uniform(n_sites: int, bits: int = 1, *, sync: bool = False,
                stochastic: bool = True, boundary_sample_p: float = 0.0,
                ef_bits: Optional[int] = None,
                schedule: str = "blocking") -> "EpochDecision":
        site = SiteDecision(fwd_bits=bits, bwd_bits=bits, stochastic=stochastic,
                            boundary_sample_p=boundary_sample_p)
        return EpochDecision(sites=(site,) * n_sites, sync=sync,
                             ef_bits=ef_bits, schedule=schedule)

    @staticmethod
    def from_config(cfg, n_sites: int, *, sync: bool = False) -> "EpochDecision":
        """The ``SylvieConfig(bits=...)`` shim: every site gets the config's
        one global decision (see :meth:`SiteDecision.from_config`)."""
        return EpochDecision(sites=(SiteDecision.from_config(cfg),) * n_sites,
                             sync=sync, schedule=cfg.schedule)

    def snapped(self) -> "EpochDecision":
        return EpochDecision(
            sites=tuple(s.snapped() for s in self.sites), sync=bool(self.sync),
            ef_bits=None if self.ef_bits is None else snap_bits(self.ef_bits),
            schedule=str(self.schedule))

    def with_bits(self, bits: int) -> "EpochDecision":
        """Every site forced to ``bits`` both directions (the trainer uses
        this to pin vanilla mode at 32)."""
        return EpochDecision(
            sites=tuple(dataclasses.replace(s, fwd_bits=bits, bwd_bits=bits)
                        for s in self.sites),
            sync=self.sync, ef_bits=self.ef_bits, schedule=self.schedule)

    def step_key(self):
        """Cache key for compiled step functions. ``sync`` is excluded — it
        selects *which* step runs, not how either is traced — so an adaptor
        toggling sync/async costs no extra compilation. ``schedule`` is
        included: blocking and overlap trace different programs."""
        return (self.sites, self.ef_bits, self.schedule)

    def bits_per_site(self) -> tuple[tuple[int, int], ...]:
        """((fwd_bits, bwd_bits), ...) — the EpochMetrics record."""
        return tuple((s.fwd_bits, s.bwd_bits) for s in self.sites)


@dataclasses.dataclass(frozen=True)
class SiteStats:
    """Observed per-site quantization statistics from the previous epoch.

    ``mean_range_sq`` is the mean over live boundary rows of the squared
    per-row range ``(max - min)^2`` — the quantity Theorem 1's variance bound
    is built from. ``rows`` is the live boundary-row count totaled across
    partitions; ``dim`` the feature width at this site.
    """

    dim: int
    rows: int
    mean_range_sq: float

    def variance(self, bits: int) -> float:
        """Theorem-1 quantization variance summed over this site's rows:
        ``rows * dim * E[range^2] / (6 * (2^bits - 1)^2)``. Passthrough
        widths (16/32) contribute zero."""
        if bits >= 16:
            return 0.0
        big = 2.0 ** bits - 1.0
        return self.rows * self.dim * self.mean_range_sq / (6.0 * big * big)


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Everything a policy may observe. Host-side, gathered once per epoch,
    outside any trace.

    ``site_stats`` is ``None`` until the first training epoch has run (the
    step emits the stats; see ``train/gnn_step.py``). ``prev`` is the previous
    epoch's (snapped) decision — policies can use it for hysteresis.
    ``needs_sync`` flags a trainer-level cache-coherence requirement (resume
    after an elastic repartition): policies must return ``sync=True`` when it
    is set, and the trainer enforces it regardless.
    """

    epoch: int
    n_parts: int
    n_sites: int
    site_dims: tuple[int, ...]
    site_stats: Optional[tuple[SiteStats, ...]] = None
    val_history: tuple[float, ...] = ()
    needs_sync: bool = False
    prev: Optional[EpochDecision] = None
    # Consecutive-faulty-epoch count per site under a chaos run (see
    # ``repro.faults``): a dropped/corrupted exchange degrades to the cached
    # halo, making that site's effective staleness grow — ``BoundedStaleness``
    # treats a counter at/over its ``eps_s`` exactly like a due refresh.
    # Empty when no fault plan is armed.
    site_staleness: tuple[int, ...] = ()


@runtime_checkable
class CommPolicy(Protocol):
    """Per-epoch communication schedules as a pluggable strategy.

    ``decide`` runs on the host once per epoch, before the step is chosen and
    compiled; it must be a pure function of the telemetry (the trainer may
    call it speculatively, e.g. for byte accounting). The returned decision is
    snapped to the lattice and used as the step-compilation cache key, so a
    well-behaved policy emits few distinct decisions over a run.

    A policy is any object with ``decide`` + ``name`` — e.g. one that widens
    bits whenever validation accuracy stalls::

        @dataclasses.dataclass(frozen=True)
        class WidenOnPlateau:
            name: str = "widen_on_plateau"
            def decide(self, tel):
                stalled = (len(tel.val_history) >= 2
                           and tel.val_history[-1] <= tel.val_history[-2])
                return EpochDecision.uniform(tel.n_sites,
                                             bits=4 if stalled else 1,
                                             sync=tel.needs_sync)

        GNNTrainer(model, pg, cfg, policy=WidenOnPlateau())
    """

    def decide(self, tel: Telemetry) -> EpochDecision: ...

    @property
    def name(self) -> str: ...


def validate_decision(decision: EpochDecision, n_sites: int) -> EpochDecision:
    """Shape-check a policy's output against the model's exchange sites."""
    if len(decision.sites) != n_sites:
        raise ValueError(
            f"EpochDecision has {len(decision.sites)} site decisions but the "
            f"model has {n_sites} halo-exchange sites (comm_dims order)")
    return decision
