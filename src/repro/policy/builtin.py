"""Built-in communication policies.

* :class:`Uniform` — the paper's static setting: one bit-width everywhere,
  every epoch (``SylvieConfig(bits=...)`` degenerates to this).
* :class:`Warmup` — full-precision exchanges for the first ``epochs`` epochs,
  then drop to ``bits`` (variable-communication-rate training à la Cerviño et
  al., arXiv:2406.17611, in its simplest two-phase form).
* :class:`BoundedStaleness` — the paper's Bounded Staleness Adaptor (§3.3):
  one synchronous cache-refresh epoch every ``eps_s`` epochs, pipelined
  otherwise. Subsumes the old trainer-level ``eps_s`` knob.
* :class:`AdaQPVariance` — AdaQP-style (Wan et al., arXiv:2306.01381)
  per-site bit-width assignment: spend a fixed byte budget (uniform
  ``budget_bits`` equivalent) where the observed quantization variance is
  highest, using the Theorem-1 variance model over the per-site range stats
  the step emits.
* :class:`Chain` — compose policies: conservative merge of their decisions
  (any sync wins, widest bits win).

All built-ins honor ``Telemetry.needs_sync`` (the trainer's cache-coherence
flag after resume/elastic repartition) and treat epoch 0 as a synchronous
warmup — exactly ``core.staleness.use_sync_step``'s contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.staleness import use_sync_step
from .base import (EpochDecision, SiteDecision, SiteStats, Telemetry,
                   snap_bits)


def _uniform_sites(tel: Telemetry, bits: int, stochastic: bool,
                   boundary_sample_p: float) -> tuple[SiteDecision, ...]:
    site = SiteDecision(fwd_bits=bits, bwd_bits=bits, stochastic=stochastic,
                        boundary_sample_p=boundary_sample_p)
    return (site,) * tel.n_sites


@dataclasses.dataclass(frozen=True)
class Uniform:
    """One static decision for every site and epoch — the paper default.
    ``sync=None`` lets the mode decide (epoch 0 warmup only, pure Sylvie-A
    afterwards); ``sync=True`` forces every epoch synchronous.

    Example::

        repro.train(model, pg, mode="sync", policy=Uniform(bits=1))
        Uniform(bits=32)                  # the fp32 vanilla baseline
    """

    bits: int = 1
    stochastic: bool = True
    boundary_sample_p: float = 0.0
    ef_bits: Optional[int] = None
    sync: Optional[bool] = None

    @staticmethod
    def from_config(cfg) -> "Uniform":
        """The ``SylvieConfig`` shim — the one sanctioned reader of
        ``cfg.bits`` (via ``effective_bits``) outside core."""
        return Uniform(bits=int(cfg.effective_bits), stochastic=cfg.stochastic,
                       boundary_sample_p=cfg.boundary_sample_p)

    @property
    def name(self) -> str:
        return "uniform"

    def decide(self, tel: Telemetry) -> EpochDecision:
        sync = (use_sync_step(tel.epoch, None) if self.sync is None
                else self.sync) or tel.needs_sync
        return EpochDecision(
            sites=_uniform_sites(tel, self.bits, self.stochastic,
                                 self.boundary_sample_p),
            sync=sync, ef_bits=self.ef_bits)


@dataclasses.dataclass(frozen=True)
class Warmup:
    """Full-precision exchanges for ``epochs`` epochs, then ``bits``.

    Example — ease early-training quantization noise, then go one-bit::

        repro.train(model, pg, policy=Warmup(epochs=5, bits=1), epochs=40)
    """

    epochs: int = 5
    bits: int = 1
    warmup_bits: int = 32
    stochastic: bool = True
    ef_bits: Optional[int] = None

    @property
    def name(self) -> str:
        return "warmup"

    def decide(self, tel: Telemetry) -> EpochDecision:
        bits = self.warmup_bits if tel.epoch < self.epochs else self.bits
        return EpochDecision(
            sites=_uniform_sites(tel, bits, self.stochastic, 0.0),
            sync=use_sync_step(tel.epoch, None) or tel.needs_sync,
            ef_bits=self.ef_bits)


@dataclasses.dataclass(frozen=True)
class BoundedStaleness:
    """The paper's Bounded Staleness Adaptor (§3.3) as a policy: one
    synchronous cache-refresh epoch every ``eps_s`` epochs (``None`` = pure
    Sylvie-A, ``1`` = always synchronous); epoch 0 and any
    ``Telemetry.needs_sync`` epoch (resume, elastic repartition) are forced
    synchronous.

    Example — Sylvie-A with a cache refresh every 4 epochs (the setting the
    deprecated ``GNNTrainer(eps_s=4)`` shim maps onto)::

        repro.train(model, pg, mode="async",
                    policy=BoundedStaleness(eps_s=4, bits=1))
    """

    eps_s: Optional[int] = None
    bits: int = 1
    stochastic: bool = True
    boundary_sample_p: float = 0.0
    ef_bits: Optional[int] = None

    @property
    def name(self) -> str:
        return f"bounded_staleness({self.eps_s})"

    def decide(self, tel: Telemetry) -> EpochDecision:
        # Fault-induced staleness counts against the same eps_s bound as the
        # scheduled staleness: a site that has been degrading to its cached
        # halo for eps_s consecutive epochs is due for a refresh now.
        stale = (bool(tel.site_staleness) and self.eps_s is not None
                 and max(tel.site_staleness) >= self.eps_s)
        return EpochDecision(
            sites=_uniform_sites(tel, self.bits, self.stochastic,
                                 self.boundary_sample_p),
            sync=use_sync_step(tel.epoch, self.eps_s) or tel.needs_sync
            or stale,
            ef_bits=self.ef_bits)


@dataclasses.dataclass(frozen=True)
class AdaQPVariance:
    """Variance-budgeted per-site bit-width assignment (AdaQP-style).

    Budget: the bytes one epoch would ship at uniform ``budget_bits``
    (both directions, every site). Assignment: every site starts at
    ``levels[0]``; upgrades (site -> next level, both directions) are applied
    greedily by Theorem-1 variance reduction per extra payload byte until the
    budget is exhausted. Sites whose boundary rows swing over a wider range —
    higher observed ``E[(max-min)^2]`` — therefore end up with more bits.

    Until stats exist (epoch 0, or a fresh resume) the decision is uniform at
    ``budget_bits``. The trainer smooths the stats with an EMA, so the
    assignment converges and stays on one lattice point — the recompile
    budget in practice is sync-warmup + one or two adaptive decisions.

    Example — spend a uniform-4-bit byte envelope where variance is worst::

        repro.train(model, pg, policy=AdaQPVariance(budget_bits=4))
    """

    budget_bits: int = 4
    levels: tuple[int, ...] = (1, 2, 4, 8)
    stochastic: bool = True
    ef_bits: Optional[int] = None

    @property
    def name(self) -> str:
        return f"adaqp_variance({self.budget_bits})"

    def _payload(self, st: SiteStats, bits: int) -> float:
        from ..core.quantization import comm_bytes
        pb, eb = comm_bytes(st.rows, st.dim, bits)
        return 2.0 * (pb + eb)          # fwd + bwd exchanges

    def decide(self, tel: Telemetry) -> EpochDecision:
        sync = use_sync_step(tel.epoch, None) or tel.needs_sync
        stats = tel.site_stats
        if not stats or len(stats) != tel.n_sites:
            return EpochDecision(
                sites=_uniform_sites(tel, self.budget_bits, self.stochastic,
                                     0.0),
                sync=sync, ef_bits=self.ef_bits)

        levels = tuple(sorted(snap_bits(b) for b in self.levels))
        budget = sum(self._payload(st, self.budget_bits) for st in stats)
        level_ix = [0] * tel.n_sites
        spent = sum(self._payload(st, levels[0]) for st in stats)
        while True:
            best, best_score = None, 0.0
            for i, st in enumerate(stats):
                j = level_ix[i]
                if j + 1 >= len(levels):
                    continue
                dvar = st.variance(levels[j]) - st.variance(levels[j + 1])
                dbytes = self._payload(st, levels[j + 1]) \
                    - self._payload(st, levels[j])
                if spent + dbytes > budget or dbytes <= 0:
                    continue
                score = dvar / dbytes
                if score > best_score:
                    best, best_score = i, score
            if best is None:
                break
            spent += self._payload(stats[best], levels[level_ix[best] + 1]) \
                - self._payload(stats[best], levels[level_ix[best]])
            level_ix[best] += 1
        sites = tuple(
            SiteDecision(fwd_bits=levels[j], bwd_bits=levels[j],
                         stochastic=self.stochastic)
            for j in level_ix)
        return EpochDecision(sites=sites, sync=sync, ef_bits=self.ef_bits)


class Chain:
    """Compose policies by conservative merge: any member asking for a
    synchronous epoch gets one; each site takes the *widest* bits any member
    assigned (per direction); stochastic rounding only if every member keeps
    it; the largest boundary-sampling rate and EF bit-width win.

    ``Chain(Warmup(5), BoundedStaleness(4))`` therefore trains full-precision
    for 5 epochs and refreshes caches every 4 epochs throughout.
    """

    def __init__(self, *policies):
        if not policies:
            raise ValueError("Chain needs at least one policy")
        self.policies = tuple(policies)

    @property
    def name(self) -> str:
        return "chain(" + ",".join(p.name for p in self.policies) + ")"

    def decide(self, tel: Telemetry) -> EpochDecision:
        decisions = [p.decide(tel) for p in self.policies]
        sites = []
        for per_site in zip(*(d.sites for d in decisions)):
            sites.append(SiteDecision(
                fwd_bits=max(s.fwd_bits for s in per_site),
                bwd_bits=max(s.bwd_bits for s in per_site),
                stochastic=all(s.stochastic for s in per_site),
                boundary_sample_p=max(s.boundary_sample_p for s in per_site)))
        # conservative EF merge: None means the full-precision (32-bit)
        # all-reduce — the widest option — so any member keeping it wins.
        efs = [d.ef_bits for d in decisions]
        ef = max(efs) if all(e is not None for e in efs) else None
        return EpochDecision(sites=tuple(sites),
                             sync=any(d.sync for d in decisions),
                             ef_bits=ef)
