"""repro.policy — per-site, per-epoch communication schedules.

A :class:`CommPolicy` maps host-side telemetry (epoch, per-site quantization
stats, validation trajectory) to an :class:`EpochDecision` — a hashable,
trace-static schedule of per-site bit-widths plus the sync/async choice — once
per epoch, outside the trace. See ``policy/base.py`` for the contract and
DESIGN.md §"Communication policies" for the architecture.
"""
from .base import (BIT_LATTICE, CommPolicy, EpochDecision, SiteDecision,
                   SiteStats, Telemetry, snap_bits, snap_sample_p,
                   validate_decision)
from .builtin import (AdaQPVariance, BoundedStaleness, Chain, Uniform,
                      Warmup)

__all__ = [
    "BIT_LATTICE", "CommPolicy", "EpochDecision", "SiteDecision", "SiteStats",
    "Telemetry", "snap_bits", "snap_sample_p", "validate_decision",
    "AdaQPVariance", "BoundedStaleness", "Chain", "Uniform", "Warmup",
]
