"""On-disk partition-plan cache.

``partition_graph`` is pure host-side numpy and rebuilds the same
:class:`~repro.graph.partition.PartitionedGraph` for the same inputs every
run; at ``paper``-tier sizes that is seconds of per-process startup the
scenario runner and benchmark harness pay over and over. This module caches
the *whole* partitioned graph (plan + scattered node/edge arrays) under
``artifacts/plans/``.

Cache key (the **invalidation rule**, see DESIGN.md §9): a sha256 over

* a format-version tag (bump :data:`CACHE_VERSION` whenever the serialized
  layout or ``partition_graph``'s semantics change),
* the full graph content — ``edge_index``, features, labels, masks,
  positions, edge attributes, edge weights (dtype + shape + bytes each), and
* every partition parameter — ``n_parts``, ``method``, ``seed``, ``layout``,
  ``alignment``.

Any change to any of these yields a different key, i.e. a cache miss; entries
are never mutated in place, and the directory can be deleted at any time
(``rm -rf artifacts/plans`` just means the next run repartitions).

    from repro.datasets import plans
    pg, hit = plans.cached_partition(g, n_parts=8)      # miss: partitions+saves
    pg, hit = plans.cached_partition(g, n_parts=8)      # hit: loads the .npz
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional

import numpy as np

from ..graph.formats import Graph
from ..graph.partition import HaloPlan, PartitionedGraph, partition_graph

# Bump on any change to the serialization below or to partition_graph's
# output for identical inputs — old entries then simply stop being referenced.
CACHE_VERSION = 1


def default_cache_dir() -> Path:
    """``$REPRO_PLAN_CACHE`` if set, else ``<repo>/artifacts/plans``."""
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "artifacts" / "plans"


def _hash_array(h, label: str, arr: Optional[np.ndarray]) -> None:
    h.update(label.encode())
    if arr is None:
        h.update(b"<none>")
        return
    a = np.ascontiguousarray(arr)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())


def plan_key(g: Graph, n_parts: int, *, method: str = "block", seed: int = 0,
             layout: str = "compact", alignment: int = 8,
             edge_weight: Optional[np.ndarray] = None) -> str:
    """Content hash of (graph, partition parameters) — the cache key."""
    h = hashlib.sha256()
    h.update(f"v{CACHE_VERSION};n={g.n_nodes};cls={g.n_classes};"
             f"parts={n_parts};method={method};seed={seed};"
             f"layout={layout};align={alignment}".encode())
    for label, arr in (("ei", g.edge_index), ("x", g.x), ("y", g.y),
                       ("tr", g.train_mask), ("va", g.val_mask),
                       ("te", g.test_mask), ("pos", g.pos),
                       ("ea", g.edge_attr), ("ew", edge_weight)):
        _hash_array(h, label, arr)
    return h.hexdigest()[:32]


# -- (de)serialization -------------------------------------------------------

_PLAN_INTS = ("n_parts", "n_local", "h_pad", "alignment")
_PLAN_ARRS = ("send_idx", "send_mask", "recv_mask", "bucket_sizes",
              "pair_counts")
_PG_ARRS = ("part_of", "global_ids", "node_mask", "x", "y", "train_mask",
            "val_mask", "test_mask", "edges", "edge_mask", "edge_weight",
            "pos", "edge_attr")


def save_partitioned(path: Path, pg: PartitionedGraph) -> None:
    """Serialize a PartitionedGraph (plan included) to one ``.npz``."""
    arrays: dict = {}
    meta = {"version": CACHE_VERSION, "layout": pg.plan.layout,
            "n_classes": pg.n_classes,
            **{k: int(getattr(pg.plan, k)) for k in _PLAN_INTS}}
    for k in _PLAN_ARRS:
        v = getattr(pg.plan, k)
        if v is not None:
            arrays[f"plan__{k}"] = v
    for k in _PG_ARRS:
        v = getattr(pg, k)
        if v is not None:
            arrays[f"pg__{k}"] = v
    arrays["meta"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    # write-then-rename with a per-writer temp file: concurrent same-key
    # writers each publish a complete entry; readers never see partial bytes
    fd, tmp = tempfile.mkstemp(suffix=".tmp.npz", dir=path.parent)
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load_partitioned_file(path: Path) -> PartitionedGraph:
    """Inverse of :func:`save_partitioned`."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode())
        plan_kw = {k: meta[k] for k in _PLAN_INTS}
        for k in _PLAN_ARRS:
            plan_kw[k] = z[f"plan__{k}"] if f"plan__{k}" in z else None
        plan = HaloPlan(layout=meta["layout"], **plan_kw)
        pg_kw = {k: (z[f"pg__{k}"] if f"pg__{k}" in z else None)
                 for k in _PG_ARRS}
    return PartitionedGraph(plan=plan, n_classes=meta["n_classes"], **pg_kw)


# -- the cached entry point --------------------------------------------------

def cached_partition(g: Graph, n_parts: int, *, method: str = "block",
                     edge_weight: Optional[np.ndarray] = None, seed: int = 0,
                     layout: str = "compact", alignment: int = 8,
                     cache_dir: Optional[Path] = None,
                     refresh: bool = False
                     ) -> tuple[PartitionedGraph, bool]:
    """``partition_graph`` behind the on-disk cache.

    Returns ``(pg, hit)`` — ``hit`` is True when the entry was loaded from
    disk. ``refresh=True`` forces a repartition (and rewrites the entry). A
    corrupt/unreadable entry is treated as a miss and overwritten.
    """
    cache_dir = Path(cache_dir) if cache_dir is not None else \
        default_cache_dir()
    key = plan_key(g, n_parts, method=method, seed=seed, layout=layout,
                   alignment=alignment, edge_weight=edge_weight)
    path = cache_dir / f"{key}.npz"
    if not refresh and path.exists():
        try:
            return load_partitioned_file(path), True
        except Exception:
            pass                        # fall through: repartition + rewrite
    pg = partition_graph(g, n_parts, method=method, edge_weight=edge_weight,
                         seed=seed, layout=layout, alignment=alignment)
    save_partitioned(path, pg)
    return pg, False
