"""repro.datasets — named workloads + the partition-plan cache.

The registry (:mod:`repro.datasets.registry`) maps a workload name and scale
tier to a seeded synthetic :class:`~repro.graph.formats.Graph` calibrated to
one of the paper's evaluation graphs; the plan cache
(:mod:`repro.datasets.plans`) memoizes ``partition_graph`` on disk under
``artifacts/plans/``. :func:`load_partitioned` composes the two — it is what
the scenario runner and the benchmark harness call::

    from repro import datasets
    print(datasets.names())                     # ('amazon_like', ..., 'yelp_like')
    g = datasets.load("products_like@small")    # host Graph, deterministic
    pg, hit = datasets.load_partitioned("products_like@small", n_parts=8)
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

from ..graph import formats
from . import plans, registry
from .plans import cached_partition, plan_key  # noqa: F401
from .registry import (DEFAULT_TIER, TIERS, TargetStats,  # noqa: F401
                       WorkloadSpec, get, load, names, parse, register)

__all__ = [
    "TIERS", "DEFAULT_TIER", "TargetStats", "WorkloadSpec", "register",
    "names", "get", "parse", "load", "load_partitioned", "cached_partition",
    "plan_key", "plans", "registry",
]


def load_partitioned(ref: str, n_parts: int, *, seed: int = 0,
                     method: str = "block", layout: str = "compact",
                     alignment: int = 8, self_loops: bool = True,
                     gcn_weights: bool = True,
                     cache_dir: Optional[Path] = None, refresh: bool = False):
    """Registry load + GCN normalization + cached partition, in one call.

    Returns ``(pg, hit)`` like :func:`repro.datasets.plans.cached_partition`.
    Normalization matches :func:`repro.api.partition` (self-loops appended,
    symmetric-normalized edge weights attached), so a cache entry written
    here is exactly the partition a manual ``repro.api.partition`` of the
    same graph would build::

        pg, hit = load_partitioned("yelp_like@small", n_parts=8)
        assert not hit                    # first run partitions and saves
        pg, hit = load_partitioned("yelp_like@small", n_parts=8)
        assert hit                        # second run loads artifacts/plans/
    """
    g = load(ref, seed=seed)
    g, ew = formats.gcn_normalize(g, self_loops=self_loops,
                                  gcn_weights=gcn_weights)
    return cached_partition(g, n_parts, method=method, edge_weight=ew,
                            seed=seed, layout=layout, alignment=alignment,
                            cache_dir=cache_dir, refresh=refresh)
