"""Named-workload registry: the paper's evaluation graphs as scale-tiered,
seeded synthetic stand-ins.

Sylvie validates on four real graphs (Reddit, Yelp, ogbn-products, Amazon).
This container is offline, so each becomes a *named workload*: a
:class:`WorkloadSpec` records the real graph's statistics
(:class:`TargetStats`) and maps a **scale tier** to calibrated generator
kwargs for one of the :mod:`repro.graph.synthetic` generators:

* ``smoke`` — a few hundred nodes; CI and unit tests.
* ``small`` — a few thousand nodes; benchmarks and examples (the fig/table
  scripts run at this tier).
* ``paper`` — tens of thousands of nodes with the target graph's real feature
  width and class count; the largest size a CPU run stays pleasant at.

Every load is a pure function of ``(name, tier, seed)``::

    from repro import datasets
    g = datasets.load("reddit_like", tier="small", seed=0)
    g2, hit = datasets.load_partitioned("reddit_like@small", n_parts=4)

``"name@tier"`` references (:func:`parse`) are what the scenario runner and
the benchmark harness use on the command line.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from ..graph import synthetic
from ..graph.formats import Graph

TIERS = ("smoke", "small", "paper")
DEFAULT_TIER = "smoke"


@dataclasses.dataclass(frozen=True)
class TargetStats:
    """Published statistics of the real graph a workload is calibrated to.

    Reference only — the generated stand-ins scale these down (see the
    per-tier kwargs); ``paper`` tier keeps the real ``d_feat``/``n_classes``.
    """

    n_nodes: int
    n_edges: int
    avg_degree: float
    d_feat: int
    n_classes: int


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One named workload: a generator plus per-tier calibrated kwargs.

    Example::

        spec = get("yelp_like")
        g = spec.load(tier="smoke", seed=3)     # deterministic in (tier, seed)
        assert g.n_classes == spec.tiers["smoke"]["n_classes"]
    """

    name: str
    generator: str                      # key into synthetic.by_name
    tiers: Mapping[str, dict]           # tier -> generator kwargs
    description: str = ""
    target: Optional[TargetStats] = None
    # temporal workloads: tier -> MutationStream kwargs (rate in events/s,
    # feat_frac, skew) calibrating the seeded node-feature/edge mutation
    # feed; empty for static graphs. Consumed by
    # ``repro.store.stream.MutationStream.from_workload``.
    stream: Mapping[str, dict] = dataclasses.field(default_factory=dict)

    def load(self, tier: str = DEFAULT_TIER, seed: int = 0) -> Graph:
        """Generate the graph at ``tier``. Same ``(tier, seed)`` -> identical
        arrays (the generators are pure functions of their kwargs + seed)."""
        if tier not in self.tiers:
            raise KeyError(
                f"workload {self.name!r} has no tier {tier!r}; "
                f"known: {sorted(self.tiers)}")
        return synthetic.by_name(self.generator, seed=seed,
                                 **self.tiers[tier])


REGISTRY: dict[str, WorkloadSpec] = {}


def register(spec: WorkloadSpec) -> WorkloadSpec:
    """Add a workload to the registry (idempotent per name)."""
    REGISTRY[spec.name] = spec
    return spec


def names() -> tuple[str, ...]:
    """Registered workload names, sorted."""
    return tuple(sorted(REGISTRY))


def get(name: str) -> WorkloadSpec:
    """Resolve a workload name; raises with the known names on a miss."""
    if name not in REGISTRY:
        raise KeyError(f"unknown workload {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def parse(ref: str) -> tuple[str, str]:
    """Split a ``"name@tier"`` reference (tier defaults to ``smoke``)::

        parse("reddit_like@paper")  # -> ("reddit_like", "paper")
        parse("mesh_like")          # -> ("mesh_like", "smoke")
    """
    name, _, tier = ref.partition("@")
    tier = tier or DEFAULT_TIER
    if tier not in TIERS:
        raise KeyError(f"unknown tier {tier!r} in {ref!r}; known: {TIERS}")
    return name, tier


def load(ref: str, tier: Optional[str] = None, seed: int = 0) -> Graph:
    """Load a workload by name or ``"name@tier"`` reference::

        load("yelp_like", tier="small")    # explicit tier
        load("yelp_like@small")            # reference form (CLI / scenarios)
    """
    name, ref_tier = parse(ref)
    return get(name).load(tier or ref_tier, seed=seed)


# ---------------------------------------------------------------------------
# The built-in workloads. Social/co-purchase graphs use powerlaw_community
# (heavy-tailed degrees + recoverable labels); Yelp's milder degree profile
# uses the plain planted partition; mesh/molecule keep their generators.
# `small` tiers are sized to the pre-registry benchmark graphs so the
# fig/table scripts' runtimes (and, for yelp_like, their exact graphs) are
# unchanged.
# ---------------------------------------------------------------------------

register(WorkloadSpec(
    name="reddit_like", generator="powerlaw_community",
    description="Reddit stand-in: dense hubs, strong communities "
                "(post-to-post graph).",
    target=TargetStats(n_nodes=232_965, n_edges=114_615_892,
                       avg_degree=492.0, d_feat=602, n_classes=41),
    tiers={
        "smoke": dict(n_nodes=600, avg_degree=16, d_feat=32, n_classes=8,
                      p_in=0.85, gamma=0.8),
        "small": dict(n_nodes=2500, avg_degree=32, d_feat=64, n_classes=16,
                      p_in=0.85, gamma=0.8),
        "paper": dict(n_nodes=25_000, avg_degree=64, d_feat=602,
                      n_classes=41, p_in=0.85, gamma=0.8),
    }))

register(WorkloadSpec(
    name="yelp_like", generator="planted",
    description="Yelp stand-in: moderate degree, homophilous business "
                "graph.",
    target=TargetStats(n_nodes=716_847, n_edges=13_954_819, avg_degree=19.5,
                       d_feat=300, n_classes=100),
    tiers={
        "smoke": dict(n_nodes=500, avg_degree=8, d_feat=32, n_classes=6,
                      p_in=0.9),
        # == the pre-registry benchmark reference graph ("planted-sm").
        "small": dict(n_nodes=1200, avg_degree=10, d_feat=64, n_classes=7,
                      p_in=0.9),
        "paper": dict(n_nodes=20_000, avg_degree=20, d_feat=300,
                      n_classes=50, p_in=0.9),
    }))

register(WorkloadSpec(
    name="products_like", generator="powerlaw_community",
    description="ogbn-products stand-in: co-purchase graph, heavy tail, "
                "many classes.",
    target=TargetStats(n_nodes=2_449_029, n_edges=123_718_280,
                       avg_degree=50.5, d_feat=100, n_classes=47),
    tiers={
        "smoke": dict(n_nodes=500, avg_degree=12, d_feat=32, n_classes=8,
                      p_in=0.8, gamma=0.8),
        "small": dict(n_nodes=4000, avg_degree=16, d_feat=96, n_classes=16,
                      p_in=0.8, gamma=0.8),
        "paper": dict(n_nodes=40_000, avg_degree=48, d_feat=100,
                      n_classes=47, p_in=0.8, gamma=0.8),
    }))

register(WorkloadSpec(
    name="amazon_like", generator="powerlaw_community",
    description="Amazon stand-in: the heaviest degree tail of the four "
                "(stresses per-pair halo imbalance).",
    target=TargetStats(n_nodes=1_569_960, n_edges=264_339_468,
                       avg_degree=168.0, d_feat=200, n_classes=107),
    tiers={
        "smoke": dict(n_nodes=600, avg_degree=20, d_feat=32, n_classes=8,
                      p_in=0.75, gamma=1.0),
        "small": dict(n_nodes=3000, avg_degree=40, d_feat=64, n_classes=32,
                      p_in=0.75, gamma=1.0),
        "paper": dict(n_nodes=30_000, avg_degree=96, d_feat=200,
                      n_classes=107, p_in=0.75, gamma=1.0),
    }))

register(WorkloadSpec(
    name="gdelt_like", generator="powerlaw_community",
    description="GDELT stand-in: temporal event knowledge graph whose "
                "node features and edges mutate continuously — the "
                "calibration source for repro.store streaming feeds "
                "(stream tiers: smoke/small; the store gate runs at small).",
    target=TargetStats(n_nodes=16_682, n_edges=191_290_882,
                       avg_degree=11_467.0, d_feat=413, n_classes=81),
    tiers={
        "smoke": dict(n_nodes=600, avg_degree=12, d_feat=32, n_classes=8,
                      p_in=0.8, gamma=0.9),
        # 10x yelp_like@small — the scale the store gate runs at.
        "small": dict(n_nodes=12_000, avg_degree=16, d_feat=64,
                      n_classes=16, p_in=0.8, gamma=0.9),
        "paper": dict(n_nodes=16_682, avg_degree=64, d_feat=413,
                      n_classes=81, p_in=0.8, gamma=0.9),
    },
    # Real GDELT averages ~1 event per node per 15 min with bursty,
    # hub-concentrated updates; scaled to bench wall-clock these tiers
    # offer tens of mutations per second, ~70% feature refreshes vs ~30%
    # edge events, with a heavy Zipf skew toward hub entities.
    stream={
        "smoke": dict(rate=40.0, feat_frac=0.7, skew=1.1),
        "small": dict(rate=80.0, feat_frac=0.7, skew=1.1),
    }))

register(WorkloadSpec(
    name="mesh_like", generator="grid",
    description="2D simulation mesh (MeshGraphNet regime).",
    tiers={
        "smoke": dict(nx=12, ny=12, d_feat=16),
        "small": dict(nx=32, ny=32, d_feat=16),
        "paper": dict(nx=96, ny=96, d_feat=16),
    }))

register(WorkloadSpec(
    name="molecule_like", generator="molecule",
    description="Random-geometric molecular graph with 3D positions "
                "(SchNet/NequIP regime).",
    tiers={
        "smoke": dict(n_nodes=30, d_feat=16, cutoff=2.0, box=4.0),
        "small": dict(n_nodes=120, d_feat=16, cutoff=1.6, box=5.0),
        "paper": dict(n_nodes=400, d_feat=16, cutoff=1.4, box=8.0),
    }))
